//! End-to-end validation driver: the full three-layer stack on a real small
//! workload (DESIGN.md §End-to-end validation; recorded in EXPERIMENTS.md).
//!
//! Workload: a dataset of synthetic 3-D volumes (the paper's Fig 6 setting,
//! scaled to CI time) run through multi-stage pipelines on BOTH backends:
//!
//!   native — rust broadcast kernels;
//!   pjrt   — the AOT-compiled L1 Pallas kernels (artifacts/*.hlo.txt) via
//!            the PJRT CPU client, proving L1 -> L2 -> L3 compose.
//!
//! Reports the paper's headline metrics: wall-clock scaling with worker
//! count (Fig 6 shape) and native-vs-PJRT backend equivalence (Fig 8's
//! backend-swap property): identical numerics, same API.
//!
//! Run: `make artifacts && cargo run --release --example e2e_pipeline`
//!
//! Flags: `--halo-mode recompute|exchange` selects the fused-executor halo
//! strategy for the pipeline stage (exchange also over-partitions to 4
//! chunks per worker — the oversubscribed configuration CI smokes), and
//! `--workers N` sets the fleet size.

use std::time::Instant;

use meltframe::coordinator::pipeline::{run_job, run_pipeline, ExecOptions};
use meltframe::coordinator::Job;
use meltframe::prelude::*;

fn main() -> Result<()> {
    let mut halo_mode = HaloMode::Recompute;
    let mut workers = 4usize;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let mut value = |flag: &str| {
            args.next()
                .ok_or_else(|| Error::Config(format!("{flag} expects a value")))
        };
        match a.as_str() {
            "--halo-mode" => halo_mode = HaloMode::parse(&value("--halo-mode")?)?,
            "--workers" => {
                workers = value("--workers")?
                    .parse()
                    .map_err(|_| Error::Config("--workers expects a number".into()))?;
            }
            other => {
                return Err(Error::Config(format!(
                    "unknown argument '{other}' (e2e_pipeline takes --halo-mode and --workers)"
                )))
            }
        }
    }

    let artifact_dir = std::path::PathBuf::from("artifacts");
    let have_artifacts = artifact_dir.join("manifest.json").exists()
        && meltframe::runtime::client::PjrtContext::available();
    if !have_artifacts {
        eprintln!(
            "warning: artifacts/ or PJRT bindings missing — run `make artifacts`; PJRT half skipped"
        );
    }

    // ---- the dataset: 6 synthetic volumes ---------------------------------
    let dims = [40usize, 40, 40];
    let dataset: Vec<Tensor<f32>> = (0..6)
        .map(|i| Tensor::<f32>::synthetic_volume(&dims, 100 + i))
        .collect();
    println!(
        "dataset: {} volumes of {:?} ({} voxels each)\n",
        dataset.len(),
        dims,
        dims.iter().product::<usize>()
    );

    // ---- stage 1: parallel-unit scaling (Fig 6 shape) ----------------------
    // the image exposes one core, so scaling uses the simulated-unit mode:
    // serial timed chunks replayed through the work-stealing scheduler
    // (DESIGN.md §Substitutions); outputs are also cross-checked against the
    // real thread fleet.
    use meltframe::coordinator::plan::ChunkPolicy;
    use meltframe::coordinator::simulate::{list_schedule, run_job_timed_chunks};
    let job = Job::gaussian(&[3, 3, 3], 1.0);
    println!("## parallel-unit scaling (gaussian 3^3, native kernels)\n");
    println!("| units | mean compute/volume | speedup |");
    println!("|---|---|---|");
    let policy = ChunkPolicy::Fixed { chunk_rows: 4096 };
    let mut per_volume: Vec<Vec<std::time::Duration>> = Vec::new();
    for vol in &dataset {
        let (sim_out, durations) = run_job_timed_chunks(vol, &job, policy)?;
        // §2.4 end-to-end: the threaded fleet computes the identical tensor
        let (thr_out, _) = run_job(vol, &job, &ExecOptions::native(3))?;
        assert_eq!(sim_out.data(), thr_out.data());
        per_volume.push(durations);
    }
    let mut base = 0.0f64;
    for units in [1usize, 2, 3, 4] {
        let mean: f64 = per_volume
            .iter()
            .map(|d| list_schedule(d, units).unwrap().makespan.as_secs_f64())
            .sum::<f64>()
            / per_volume.len() as f64;
        if units == 1 {
            base = mean;
        }
        println!("| {units} | {:.2} ms | {:.2}x |", mean * 1e3, base / mean);
    }

    // ---- stage 2: the full pipeline (denoise -> curvature -> quantile) ----
    // run BOTH executors over the dataset: the legacy fold→re-melt baseline
    // and the fused lazy Plan (one melt/fold, chunk-resident streaming) —
    // identical outputs, the fused path skips every intermediate tensor.
    println!(
        "\n## multi-stage pipeline (bilateral_adaptive 3^3 -> curvature 3^3 -> q90 3^3, \
         halo {halo_mode}, {workers} workers)\n"
    );
    let stages = vec![
        Job::bilateral_adaptive(&[3, 3, 3], 1.5, 2.0),
        Job::curvature(&[3, 3, 3]),
        Job::quantile(&[3, 3, 3], 0.9),
    ];
    let opts = ExecOptions::native(workers);
    let mut fused_opts = ExecOptions::native(workers).with_halo_mode(halo_mode);
    if halo_mode == HaloMode::Exchange {
        // oversubscribe deliberately: chunks > workers exercises the
        // dependency-aware stage scheduler end to end
        fused_opts.chunk_policy = Some(ChunkPolicy::EvenPerWorker { parts_per_worker: 4 });
    }
    let t = Instant::now();
    let mut legacy_outs = Vec::new();
    for vol in &dataset {
        let (k, _) = run_pipeline(vol, &stages, &opts)?;
        legacy_outs.push(k);
    }
    let legacy_elapsed = t.elapsed();
    let t = Instant::now();
    let mut responses = Vec::new();
    let mut eager_lead = std::time::Duration::ZERO;
    for (vol, legacy) in dataset.iter().zip(&legacy_outs) {
        let (k, pm) = Plan::over(vol)
            .bilateral_adaptive(&[3, 3, 3], 1.5, 2.0)
            .curvature(&[3, 3, 3])
            .quantile(&[3, 3, 3], 0.9)
            .run(&fused_opts)?;
        assert_eq!(pm.melts(), 1, "three fusable stages must share one melt");
        assert_eq!(k.data(), legacy.data(), "fused must equal legacy bit-for-bit");
        if halo_mode == HaloMode::Exchange {
            assert_eq!(pm.halo_recomputed(), 0, "exchange must recompute zero halo rows");
            assert!(pm.halo_published() > 0, "oversubscribed chunks must trade rows");
            eager_lead += pm.halo_eager_lead();
        }
        // headline analytic: cuboid vertices light up
        responses.push(k.map(|v| v.abs()).max());
    }
    if halo_mode == HaloMode::Exchange {
        // the boundary-first split (and therefore a nonzero lead) only
        // exists for chunks wider than both boundary segments combined; at
        // very high worker counts every chunk is narrower than 2×halo and
        // publishes whole — correct, just nothing to lead with
        let halo = meltframe::melt::melt::flat_halo(&dims, &Operator::new(&[3, 3, 3])?);
        let chunk_rows = dims.iter().product::<usize>() / (4 * workers);
        if chunk_rows > 2 * halo {
            assert!(eager_lead > std::time::Duration::ZERO, "eager publish must lead");
        }
        println!("exchange: 0 halo rows recomputed, eager-publish lead {eager_lead:.2?}");
    }
    println!(
        "processed {} volumes | legacy fold→re-melt {legacy_elapsed:.2?} | fused Plan {:.2?}",
        dataset.len(),
        t.elapsed(),
    );
    println!(
        "max |K|-q90 per volume: {:?}",
        responses.iter().map(|v| format!("{v:.3}")).collect::<Vec<_>>()
    );
    assert!(responses.iter().all(|&r| r > 0.0));

    // ---- stage 3: backend swap — native vs AOT Pallas via PJRT ------------
    if have_artifacts {
        println!("\n## backend equivalence + throughput (Fig 8 backend swap)\n");
        println!("| job | backend | compute | max |native - pjrt| |");
        println!("|---|---|---|---|");
        let vol = &dataset[0];
        for job in [
            Job::gaussian(&[3, 3, 3], 1.0),
            Job::bilateral_const(&[3, 3, 3], 1.5, 30.0),
            Job::bilateral_adaptive(&[3, 3, 3], 1.5, 2.0),
            Job::curvature(&[3, 3, 3]),
        ] {
            let (native, mn) = run_job(vol, &job, &ExecOptions::native(2))?;
            let (pjrt, mp) = run_job(vol, &job, &ExecOptions::pjrt(2, &artifact_dir))?;
            let max_diff = native
                .data()
                .iter()
                .zip(pjrt.data())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!(
                "| {:?} | native | {:.2?} | |",
                job.kind.artifact_kind(),
                mn.compute
            );
            println!(
                "| {:?} | pjrt | {:.2?} | {max_diff:.2e} |",
                job.kind.artifact_kind(),
                mp.compute
            );
            let scale = native.data().iter().fold(0.0f32, |a, &b| a.max(b.abs()));
            assert!(
                max_diff <= 1e-3 * scale.max(1.0),
                "backends disagree for {job:?}: {max_diff}"
            );
        }
        println!("\nbackends agree to float tolerance — the L1 Pallas artifacts and the");
        println!("native kernels implement the same melt-row contract.");
    }

    println!("\ne2e_pipeline OK");
    Ok(())
}
