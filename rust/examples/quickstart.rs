//! Quickstart: the whole Fig 1/Fig 2 story on one page.
//!
//! 1. build a tensor, 2. melt it under an operator on a quasi-grid,
//! 3. broadcast a kernel over the rows, 4. fold back, 5. do the same thing
//! through the parallel coordinator and check the outputs agree, 6. compose
//! a multi-stage lazy `Plan` and watch the planner fuse it into one
//! melt/fold pass.
//!
//! Run: `cargo run --release --example quickstart`

use meltframe::coordinator::pipeline::{run_job, ExecOptions};
use meltframe::coordinator::Job;
use meltframe::prelude::*;

fn main() -> Result<()> {
    // ---- 1. a high-dimensional input: a synthetic 3-D volume -------------
    let vol = Tensor::<f32>::synthetic_volume(&[24, 24, 24], 42);
    println!("input tensor: shape {:?}, {} elements", vol.shape(), vol.len());

    // ---- 2. melt: rank-3 tensor -> rank-2 melt matrix (Fig 1) ------------
    let op = Operator::cubic(3, 3)?; // the 3x3x3 neighbourhood operator m
    let m = melt(&vol, &op, GridMode::Same, BoundaryMode::Reflect)?;
    println!(
        "melt matrix:  {} rows x {} cols (grid shape {:?})",
        m.rows(),
        m.cols(),
        m.grid_shape()
    );

    // every row is the raveled neighbourhood of one grid point; the centre
    // column is the tensor itself
    assert_eq!(m.row(0)[m.center()], vol.data()[0]);

    // ---- 3. broadcast: array programming over rows (Fig 2) ---------------
    let kernel = gaussian_kernel(op.window(), 1.0);
    let rows = apply_kernel_broadcast(&m, &kernel);

    // ---- 4. fold: per-row results -> grid tensor -------------------------
    let smoothed = fold(&rows, m.grid_shape())?;
    println!(
        "smoothed:     shape {:?}, variance {:.1} (input {:.1})",
        smoothed.shape(),
        smoothed.variance(),
        vol.variance()
    );
    assert!(smoothed.variance() < vol.variance());

    // ---- 5. the same computation through the parallel coordinator --------
    let job = Job::gaussian(&[3, 3, 3], 1.0);
    for workers in [1, 2, 4] {
        let (out, metrics) = run_job(&vol, &job, &ExecOptions::native(workers))?;
        assert_eq!(out.data(), smoothed.data(), "worker count must not change results");
        println!("{workers} worker(s): {}", metrics.summary());
    }

    // ---- 6. the lazy Plan: record stages, fuse, stream --------------------
    // gaussian → curvature → per-row median (a stats reduction) become ONE
    // melt and ONE fold; chunks stream worker-resident through all three.
    let plan = Plan::over(&vol)
        .gaussian(&[3, 3, 3], 1.0)
        .curvature(&[3, 3, 3])
        .median(&[3, 3, 3]);
    let compiled = plan.compile(Backend::Native)?;
    println!("plan: {}", compiled.describe());
    let (fused, pm) = compiled.execute(&ExecOptions::native(4))?;
    assert_eq!(pm.melts(), 1);
    assert_eq!(pm.folds(), 1);
    assert_eq!(pm.stages(), 3);
    println!("fused plan: {}", pm.summary());

    // bit-for-bit equal to the legacy stage-by-stage path
    let jobs = [
        Job::gaussian(&[3, 3, 3], 1.0),
        Job::curvature(&[3, 3, 3]),
        Job::median(&[3, 3, 3]),
    ];
    let (legacy, _) = run_pipeline(&vol, &jobs, &ExecOptions::native(4))?;
    assert_eq!(fused.data(), legacy.data(), "fused must equal legacy bit-for-bit");

    // ---- 7. halo exchange: trade boundary rows instead of recomputing -----
    // the default fused executor recomputes each chunk's halo rows; in
    // exchange mode neighbouring chunks publish/fetch them through the
    // halo board — same bits, zero duplicated kernel work
    let exchange_opts = ExecOptions::native(4).with_halo_mode(HaloMode::Exchange);
    let (exchanged, xm) = compiled.execute(&exchange_opts)?;
    assert_eq!(exchanged.data(), fused.data(), "halo modes must agree bit-for-bit");
    assert_eq!(xm.halo_recomputed(), 0, "exchange recomputes no halo rows");
    println!(
        "halo exchange: {} rows published, {} received, {} recomputed",
        xm.halo_published(),
        xm.halo_received(),
        xm.halo_recomputed()
    );

    // ---- bonus: partitions are §2.4-valid by construction -----------------
    let partition = RowPartition::even(m.rows(), 4)?;
    partition.validate()?;
    println!(
        "partition of {} rows into {} parts validates the paper's three conditions",
        m.rows(),
        partition.num_parts()
    );

    println!("\nquickstart OK");
    Ok(())
}
