//! Fig 3 reproduction: the generic bilateral filter's three regimes.
//!
//! Panels (matching the paper):
//!   (a) noisy synthetic "natural image" (replaces the pixnio photograph)
//!   (b) locally adaptive σ_r            — strongest denoise, edges kept
//!   (c) constant σ_r ≈ ‖Σ_d‖ scale      — classic bilateral look
//!   (d) constant σ_r ≫ ‖Σ_d‖            — degenerates to a plain gaussian
//!
//! Writes PGM panels + a montage to `target/fig3/` and prints a PSNR/edge
//! table demonstrating the regime ordering the paper shows visually.
//!
//! Run: `cargo run --release --example bilateral_denoise`

use meltframe::coordinator::pipeline::{run_job, ExecOptions};
use meltframe::coordinator::Job;
use meltframe::prelude::*;
use meltframe::tensor::image::{montage, save_pgm};

fn edge_energy(t: &Tensor<f32>) -> f64 {
    // mean |horizontal gradient| — a cheap edge-preservation proxy
    let (h, w) = (t.shape()[0], t.shape()[1]);
    let mut acc = 0.0f64;
    for y in 0..h {
        for x in 1..w {
            acc += (t.at(&[y, x]) - t.at(&[y, x - 1])).abs() as f64;
        }
    }
    acc / (h * (w - 1)) as f64
}

fn main() -> Result<()> {
    let dims = [192usize, 192usize];
    // clean reference and its noisy observation (deterministic seeds)
    let clean = {
        // same structure, no noise: regenerate with noise seed suppressed by
        // averaging many realizations is overkill — build directly instead.
        let mut img = Tensor::<f32>::synthetic_image(&dims, 7);
        // approximate the clean image by a tight median-like smooth of many
        // noisy draws: 8 independent seeds averaged cancels the N(0,12) noise
        for seed in 8..15 {
            let other = Tensor::<f32>::synthetic_image(&dims, seed);
            img = img.add(&other)?;
        }
        img.scale(1.0 / 8.0)
    };
    let noisy = Tensor::<f32>::synthetic_image(&dims, 1);
    println!("synthetic image {dims:?}; noisy PSNR vs clean: {:.2} dB", noisy.psnr(&clean, 255.0)?);

    let window = [5usize, 5usize];
    let sigma_d = 1.5f32;
    let opts = ExecOptions::native(4);

    // the four regimes as single-stage lazy plans through the coordinator
    // (b) adaptive σ_r
    let (adaptive, mb) = Plan::over(&noisy)
        .bilateral_adaptive(&window, sigma_d, 2.0)
        .run(&opts)?;
    // (c) appropriate constant σ_r — on the scale of the local noise
    let (appropriate, mc) = Plan::over(&noisy)
        .bilateral_const(&window, sigma_d, 30.0)
        .run(&opts)?;
    // (d) excessive constant σ_r — range term vanishes, gaussian behaviour
    let (excessive, md) = Plan::over(&noisy)
        .bilateral_const(&window, sigma_d, 1e5)
        .run(&opts)?;
    // reference gaussian for the (d) comparison; the legacy run_job shim
    // computes the identical tensor through the same executor
    let (gaussian, _) = run_job(&noisy, &Job::gaussian(&window, sigma_d), &opts)?;

    println!("timings: adaptive {} | const {} | excessive {}", mb.summary(), mc.summary(), md.summary());

    let table = [
        ("(a) noisy", &noisy),
        ("(b) adaptive sigma_r", &adaptive),
        ("(c) const sigma_r ~ noise", &appropriate),
        ("(d) const sigma_r >> |Sigma_d|", &excessive),
    ];
    println!("\n| panel | PSNR vs clean (dB) | edge energy |");
    println!("|---|---|---|");
    for (label, img) in &table {
        println!(
            "| {label} | {:.2} | {:.2} |",
            img.psnr(&clean, 255.0)?,
            edge_energy(img)
        );
    }

    // the paper's regime claims, as assertions:
    // every filter improves on the noisy input...
    for (label, img) in &table[1..] {
        assert!(
            img.psnr(&clean, 255.0)? > noisy.psnr(&clean, 255.0)?,
            "{label} should denoise"
        );
    }
    // ...(d) behaves like the plain gaussian...
    let d_vs_gauss = excessive.mse(&gaussian)?;
    println!("\nMSE[(d), gaussian] = {d_vs_gauss:.4} (regime d == gaussian degeneration)");
    assert!(d_vs_gauss < 1.0, "excessive sigma_r must degenerate to gaussian");
    // ...and the edge-aware variants keep more edges than (d)
    assert!(edge_energy(&appropriate) > edge_energy(&excessive));

    let outdir = std::path::Path::new("target/fig3");
    std::fs::create_dir_all(outdir)?;
    for (name, img) in [
        ("a_noisy", &noisy),
        ("b_adaptive", &adaptive),
        ("c_const_ok", &appropriate),
        ("d_const_excessive", &excessive),
    ] {
        save_pgm(img, outdir.join(format!("{name}.pgm")))?;
    }
    let strip = montage(&[&noisy, &adaptive, &appropriate, &excessive], 4)?;
    save_pgm(&strip, outdir.join("fig3_montage.pgm"))?;
    println!("\nwrote panels to {}", outdir.display());
    println!("bilateral_denoise OK");
    Ok(())
}
