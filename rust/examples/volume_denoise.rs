//! Volume denoising walkthrough: the 3-D workload end to end.
//!
//! A synthetic `(D, H, W)` volume (bright cuboid in a noisy field) is
//! corrupted with salt-and-pepper impulses and pushed through a fused
//! 3-D pipeline:
//!
//!   median 3³            — removes the impulses (sample-determined stage)
//!   separable gaussian 3³ — three axis-factored passes [3,1,1]·[1,3,1]·
//!                           [1,1,3] that together equal the dense 3³
//!                           gaussian at Σw instead of Πw multiplies
//!
//! All four stages are `Same`-grid / `Reflect`, so the planner fuses them
//! into ONE melt/fold group; chunks are cut with the depth-slab policy
//! (`ChunkPolicy::Aligned { unit: H * W }`), so every chunk is a run of
//! whole z-slabs and its halo is a stack of complete `(z, y)` lines —
//! the 3-D geometry the halo board and stage scheduler carry.
//!
//! The fused result is asserted bit-for-bit against the legacy per-stage
//! baseline, and denoising quality is reported as mean absolute error
//! against the noise-free phantom.
//!
//! Run: `cargo run --release --example volume_denoise`
//! Flags: `--dims D,H,W` (default 40,40,40), `--workers N` (default 4),
//! `--halo-mode recompute|exchange`, `--out file.npy`.

use meltframe::coordinator::pipeline::{run_pipeline, ExecOptions};
use meltframe::coordinator::{ChunkPolicy, Job};
use meltframe::prelude::*;
use meltframe::testing::{assert_allclose, SplitMix64};

fn main() -> Result<()> {
    let mut dims = vec![40usize, 40, 40];
    let mut workers = 4usize;
    let mut halo_mode = HaloMode::Recompute;
    let mut out_path: Option<std::path::PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        let mut value = |flag: &str| {
            args.next()
                .ok_or_else(|| Error::Config(format!("{flag} expects a value")))
        };
        match a.as_str() {
            "--dims" => {
                dims = value("--dims")?
                    .split(',')
                    .map(|s| {
                        s.trim()
                            .parse::<usize>()
                            .map_err(|_| Error::Config(format!("bad extent '{s}' in --dims")))
                    })
                    .collect::<Result<_>>()?;
                if dims.len() != 3 {
                    return Err(Error::Config("--dims expects D,H,W (three extents)".into()));
                }
            }
            "--workers" => {
                workers = value("--workers")?
                    .parse()
                    .map_err(|_| Error::Config("--workers expects a number".into()))?;
            }
            "--halo-mode" => halo_mode = HaloMode::parse(&value("--halo-mode")?)?,
            "--out" => out_path = Some(std::path::PathBuf::from(value("--out")?)),
            other => {
                return Err(Error::Config(format!(
                    "unknown argument '{other}' (volume_denoise takes --dims, --workers, \
                     --halo-mode, --out)"
                )))
            }
        }
    }

    // ---- the workload ------------------------------------------------------
    // phantom: the noise-free cuboid the synthetic volume draws over
    let phantom = {
        let mut t = Tensor::zeros(&dims)?;
        let shape = t.shape_obj().clone();
        for (flat, idx) in shape.iter_indices().enumerate() {
            let inside = idx
                .iter()
                .zip(&dims)
                .all(|(&i, &d)| i >= d / 4 && i < d - d / 4);
            t.data_mut()[flat] = if inside { 200.0 } else { 40.0 };
        }
        t
    };
    let mut noisy = Tensor::synthetic_volume(&dims, 7);
    let n = noisy.len();
    let mut rng = SplitMix64::new(11);
    for _ in 0..n / 50 {
        let i = rng.below(n);
        noisy.data_mut()[i] = if rng.below(2) == 0 { 0.0 } else { 255.0 };
    }
    let mae = |t: &Tensor<f32>| -> f64 {
        t.data()
            .iter()
            .zip(phantom.data())
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / n as f64
    };
    println!(
        "volume {:?} | {} voxels | ~{} impulses injected | {workers} worker(s) | halo {halo_mode}",
        dims,
        n,
        n / 50
    );

    // ---- legacy baseline: the same stages, fold→re-melt between each ------
    let jobs = vec![
        Job::median(&[3, 3, 3]),
        Job::gaussian(&[3, 1, 1], 1.0),
        Job::gaussian(&[1, 3, 1], 1.0),
        Job::gaussian(&[1, 1, 3], 1.0),
    ];
    let (legacy, _) = run_pipeline(&noisy, &jobs, &ExecOptions::native(1))?;

    // ---- the fused volume plan: depth-slab chunks, 4 per worker ------------
    let mut opts = ExecOptions::native(workers).with_halo_mode(halo_mode);
    opts.chunk_policy = Some(ChunkPolicy::Aligned {
        unit: dims[1] * dims[2],
        parts_per_worker: 4,
    });
    let plan = Plan::over_volume(&noisy)
        .median(&[3, 3, 3])
        .gaussian_separable(&[3, 3, 3], 1.0);
    let compiled = plan.compile(Backend::Native)?;
    println!("plan: {}", compiled.describe());
    let (out, pm) = compiled.execute(&opts)?;
    assert_allclose(out.data(), legacy.data(), 0.0, 0.0);
    assert_eq!(pm.melts(), 1, "median + 3 axis passes must share one melt");
    assert_eq!(pm.folds(), 1);
    assert_eq!(pm.stages(), 4);
    if halo_mode == HaloMode::Exchange {
        assert_eq!(pm.halo_recomputed(), 0, "exchange must recompute zero halo rows");
        // a depth-1 volume has a single slab chunk: nothing to trade, and
        // correctly so — only multi-chunk geometries must show traffic
        if dims[0] > 1 {
            assert!(pm.halo_published() > 0, "slab chunks must trade boundary lines");
        }
        println!(
            "exchange: pub {} recv {} redo {} | eager lead {:.2?} | {} stall(s)",
            pm.halo_published(),
            pm.halo_received(),
            pm.halo_recomputed(),
            pm.halo_eager_lead(),
            pm.sched_stalls()
        );
    }
    for (i, g) in pm.groups.iter().enumerate() {
        println!("group {}: {}", i + 1, g.summary());
    }

    // ---- quality -----------------------------------------------------------
    let (before, after) = (mae(&noisy), mae(&out));
    println!("MAE vs phantom: noisy {before:.2} -> denoised {after:.2}");
    assert!(
        after < before,
        "denoising must move the volume toward the phantom ({after:.2} vs {before:.2})"
    );

    if let Some(path) = out_path {
        meltframe::tensor::npy::save(&out, &path)?;
        println!("wrote {}", path.display());
    }
    println!("\nvolume_denoise OK");
    Ok(())
}
