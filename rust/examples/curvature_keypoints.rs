//! Figs 4 & 5 reproduction: Gaussian curvature as a dimension-aware
//! key-point detector.
//!
//! Fig 4: a 2-D geometric segmentation mask — curvature magnitude peaks at
//! polygon corners, stays low along straight edges.
//!
//! Fig 5: a 3-D cube volume — the native 3-D operator enhances the cube's
//! *vertices*; forcing a planar (2-D) operator slice-by-slice instead
//! enhances z-directed *edges*: the paper's "dimension-induced improper
//! operation" made measurable.
//!
//! Run: `cargo run --release --example curvature_keypoints`

use meltframe::coordinator::pipeline::{run_job, ExecOptions};
use meltframe::coordinator::Job;
use meltframe::prelude::*;
use meltframe::tensor::image::save_pgm;

/// Mean |K| over a small box around a voxel.
fn local_response(k: &Tensor<f32>, center: &[usize], radius: usize) -> f64 {
    let dims = k.shape().to_vec();
    let mut acc = 0.0f64;
    let mut n = 0usize;
    let lo: Vec<usize> = center.iter().map(|&c| c.saturating_sub(radius)).collect();
    let hi: Vec<usize> = center
        .iter()
        .zip(&dims)
        .map(|(&c, &d)| (c + radius + 1).min(d))
        .collect();
    let mut idx = lo.clone();
    loop {
        acc += k.at(&idx).abs() as f64;
        n += 1;
        let mut a = idx.len();
        loop {
            if a == 0 {
                return acc / n as f64;
            }
            a -= 1;
            idx[a] += 1;
            if idx[a] < hi[a] {
                break;
            }
            idx[a] = lo[a];
        }
    }
}

fn fig4(opts: &ExecOptions) -> Result<()> {
    println!("== Fig 4: 2-D segmentation -> corner enhancement ==");
    let dims = [128usize, 128usize];
    let mask = Tensor::<f32>::segmentation_mask(&dims);
    // light smoothing first (the paper's masks are anti-aliased renders),
    // fused with the curvature stage: one melt, one fold, chunk-resident
    let (k, pm) = Plan::over(&mask)
        .gaussian(&[3, 3], 0.8)
        .curvature(&[3, 3])
        .run(opts)?;
    assert_eq!(pm.melts(), 1, "smooth + curvature must fuse into one melt");
    println!("fused plan: {}", pm.summary());

    // rectangle corners of segmentation_mask: y in {h/5, 3h/5}, x in {w/6, w/2}
    let corners = [
        [dims[0] / 5, dims[1] / 6],
        [dims[0] / 5, dims[1] / 2 - 1],
        [3 * dims[0] / 5 - 1, dims[1] / 6],
    ];
    let edge_mid = [dims[0] / 5, dims[1] / 3]; // straight top edge midpoint
    let corner_resp: f64 = corners
        .iter()
        .map(|c| local_response(&k, c, 2))
        .fold(0.0, f64::max);
    let edge_resp = local_response(&k, &edge_mid, 2);
    println!("corner response {corner_resp:.4} vs straight-edge response {edge_resp:.4}");
    assert!(
        corner_resp > 5.0 * edge_resp.max(1e-9),
        "corners must dominate straight edges"
    );

    let outdir = std::path::Path::new("target/fig4");
    std::fs::create_dir_all(outdir)?;
    save_pgm(&mask, outdir.join("a_mask.pgm"))?;
    save_pgm(&k.map(|v| v.abs()), outdir.join("b_curvature.pgm"))?;
    println!("wrote {}\n", outdir.display());
    Ok(())
}

fn fig5(opts: &ExecOptions) -> Result<()> {
    println!("== Fig 5: 3-D cube — native 3-D vs forced planar operator ==");
    let dims = [48usize, 48, 48];
    // noise-free cube render (the paper's monocolor render)
    let mut cube = Tensor::<f32>::zeros(&dims)?;
    let (lo, hi) = (12usize, 36usize);
    for z in lo..hi {
        for y in lo..hi {
            for x in lo..hi {
                cube.set(&[z, y, x], 1.0)?;
            }
        }
    }
    let smooth = [Job::gaussian(&[3, 3, 3], 0.8)];
    let (cube_s, _) = run_job(&cube, &smooth[0], opts)?;

    // (b) native 3-D curvature
    let (k3, m3) = run_job(&cube_s, &Job::curvature(&[3, 3, 3]), opts)?;
    println!("native 3-D: {}", m3.summary());

    // (c) forced 2-D operator stacked along z (the improper operation)
    let mut k2_stack = Tensor::<f32>::zeros(&dims)?;
    let opts1 = ExecOptions::native(1);
    for z in 0..dims[0] {
        let plane = cube_s.slice_plane(0, z)?;
        let (kz, _) = run_job(&plane, &Job::curvature(&[3, 3]), &opts1)?;
        k2_stack.set_plane(0, z, &kz)?;
    }

    // measure: vertex vs z-edge-midpoint responses
    let vertex = [lo, lo, lo];
    let z_edge_mid = [(lo + hi) / 2, lo, lo]; // runs along z at an x/y corner
    let v3 = local_response(&k3, &vertex, 2);
    let e3 = local_response(&k3, &z_edge_mid, 2);
    let v2 = local_response(&k2_stack, &vertex, 2);
    let e2 = local_response(&k2_stack, &z_edge_mid, 2);
    println!("| operator | vertex |K| | z-edge |K| | vertex/edge |");
    println!("|---|---|---|---|");
    println!("| native 3-D | {v3:.5} | {e3:.5} | {:.2} |", v3 / e3.max(1e-12));
    println!("| planar 2-D stacked | {v2:.5} | {e2:.5} | {:.2} |", v2 / e2.max(1e-12));

    // the paper's claim: native 3-D is vertex-selective; the planar stack
    // responds along z-edges as strongly as at vertices (it cannot tell).
    assert!(v3 / e3.max(1e-12) > 3.0, "3-D operator must prefer vertices");
    assert!(
        v2 / e2.max(1e-12) < 2.0,
        "stacked 2-D operator must conflate vertices with z-edges"
    );

    let outdir = std::path::Path::new("target/fig5");
    std::fs::create_dir_all(outdir)?;
    save_pgm(&cube_s.slice_plane(0, lo)?, outdir.join("a_cube_slice.pgm"))?;
    save_pgm(&k3.map(|v| v.abs()).slice_plane(0, lo)?, outdir.join("b_native3d_slice.pgm"))?;
    save_pgm(&k2_stack.map(|v| v.abs()).slice_plane(0, lo)?, outdir.join("c_planar2d_slice.pgm"))?;
    println!("wrote {}\n", outdir.display());
    Ok(())
}

fn main() -> Result<()> {
    let opts = ExecOptions::native(4);
    fig4(&opts)?;
    fig5(&opts)?;
    println!("curvature_keypoints OK");
    Ok(())
}
