//! Long-lived worker pool: OS threads spawned once, reused by every job.
//!
//! The one-shot executor pays a `thread::scope` spawn per run — fine for
//! batch, measurable overhead under serving traffic. [`WorkerPool`] keeps
//! `threads` workers parked on a condvar and hands each run a borrowed
//! fleet through [`WorkerPool::run_scoped`], which has the same blocking
//! contract as `thread::scope`: control cannot leave it — by return *or*
//! by unwind (a panicking leader closure) — until every task it enqueued
//! has finished, so tasks may safely borrow from the caller's stack (see
//! the safety argument on `run_scoped`).
//!
//! Panic containment: every task body runs under `catch_unwind`, so a
//! poisoned job (PR 4 fault-injection kernels) reports `Err("worker {w}
//! panicked")` through its own result slot and the pool thread survives to
//! serve the next job — the property `tests/integration_serve.rs` pins.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::error::{Error, Result};
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::thread::JoinHandle;
use crate::sync::{thread, Arc, Condvar, Mutex, NamedCondvar, NamedMutex};

type Task = Box<dyn FnOnce() + Send>;

struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// A fixed-size pool of reusable worker threads.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (floored at 1). They idle until tasks
    /// arrive and live until the pool is dropped.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new_named("serve.pool.queue", VecDeque::new()),
            available: Condvar::new_named("serve.pool.available"),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("meltframe-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, handles }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    fn enqueue(&self, task: Task) {
        let mut q = self
            .shared
            .queue
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        q.push_back(task);
        drop(q);
        self.shared.available.notify_one();
    }

    /// Run `tasks` instances of `work` (passed their index `0..tasks`) on
    /// the pool plus `leader` on the calling thread, then block until every
    /// task has finished. Returns one `Result` per task, in index order; a
    /// panicking task yields `Err("worker {w} panicked")` and leaves its
    /// pool thread healthy.
    ///
    /// Mirrors the `thread::scope` fleet in `coordinator::exec`: `work`
    /// may borrow anything on the caller's stack.
    pub fn run_scoped<T, F, L>(&self, tasks: usize, work: F, leader: L) -> Vec<Result<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
        L: FnOnce(),
    {
        struct Latch<T> {
            slots: Mutex<(Vec<Option<Result<T>>>, usize)>,
            done: Condvar,
        }
        impl<T> Latch<T> {
            fn wait_for(&self, count: usize) -> crate::sync::MutexGuard<'_, (Vec<Option<Result<T>>>, usize)> {
                let mut guard = self.slots.lock().unwrap_or_else(|p| p.into_inner());
                while guard.1 < count {
                    guard = self.done.wait(guard).unwrap_or_else(|p| p.into_inner());
                }
                guard
            }
        }
        // Blocks in `drop` until every enqueued task has completed, so
        // leaving this frame by ANY path — return or unwind (a panicking
        // `leader`) — waits for the pool threads first. This is the same
        // join-in-drop-guard discipline `thread::scope` uses.
        struct WaitGuard<'a, T> {
            latch: &'a Latch<T>,
            enqueued: usize,
        }
        impl<T> Drop for WaitGuard<'_, T> {
            fn drop(&mut self) {
                drop(self.latch.wait_for(self.enqueued));
            }
        }
        let latch = Latch::<T> {
            slots: Mutex::new_named("serve.pool.latch", ((0..tasks).map(|_| None).collect(), 0)),
            done: Condvar::new_named("serve.pool.latch.done"),
        };
        let latch = &latch;
        let work = &work;
        let mut wait = WaitGuard { latch, enqueued: 0 };
        for w in 0..tasks {
            let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(|| work(w)))
                    .unwrap_or_else(|_| Err(Error::Coordinator(format!("worker {w} panicked"))));
                let mut guard = latch.slots.lock().unwrap_or_else(|p| p.into_inner());
                guard.0[w] = Some(result);
                guard.1 += 1;
                if guard.1 == tasks {
                    latch.done.notify_all();
                }
            });
            // SAFETY: the closure borrows `latch` and `work` from this
            // stack frame, and control cannot leave this frame — by return
            // OR by unwind — until the completion latch has counted every
            // enqueued task: `wait` (whose `enqueued` is bumped below,
            // after the hand-off) blocks in its destructor, which runs
            // even when `leader()` panics, exactly the guarantee
            // `thread::scope` provides via its join-in-drop guard. So the
            // 'static lifetime the queue requires is never exercised past
            // the borrows' real extent: no task outlives this call.
            let task: Task = unsafe { std::mem::transmute(task) };
            self.enqueue(task);
            wait.enqueued += 1;
        }
        leader();
        // normal path: same wait the unwind path gets from the guard
        drop(wait);
        let mut guard = latch.wait_for(tasks);
        std::mem::take(&mut guard.0)
            .into_iter()
            .map(|slot| slot.expect("latch counted a task whose slot is empty"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.available.wait(q).unwrap_or_else(|p| p.into_inner());
            }
        };
        // tasks arrive pre-wrapped in catch_unwind by run_scoped; the
        // extra guard here keeps a raw `submit`-style task from ever
        // killing the thread either
        let _ = catch_unwind(AssertUnwindSafe(task));
        // a task that leaked a facade guard past its own body would wedge
        // every later job contending on it; under lockdep this names the
        // leaked class and its acquisition site (no-op otherwise)
        crate::sync::checkpoint("WorkerPool task boundary");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_floors_at_one_thread() {
        assert_eq!(WorkerPool::new(0).size(), 1);
        assert_eq!(WorkerPool::new(3).size(), 3);
    }

    #[test]
    fn run_scoped_sees_stack_borrows_and_orders_results() {
        let pool = WorkerPool::new(4);
        let base = 100usize; // stack-local, borrowed by every task
        let results = pool.run_scoped(8, |w| Ok(base + w), || {});
        let got: Vec<usize> = results.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, (100..108).collect::<Vec<_>>());
    }

    #[test]
    fn run_scoped_runs_leader_on_calling_thread() {
        let pool = WorkerPool::new(2);
        let caller = std::thread::current().id();
        let mut leader_thread = None;
        pool.run_scoped(
            2,
            |_| Ok(()),
            || leader_thread = Some(std::thread::current().id()),
        );
        assert_eq!(leader_thread, Some(caller));
    }

    #[test]
    fn panicking_task_reports_error_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let results = pool.run_scoped(
            3,
            |w| {
                if w == 1 {
                    panic!("injected pool panic");
                }
                Ok(w)
            },
            || {},
        );
        assert_eq!(results[0].as_ref().unwrap(), &0);
        assert!(results[1]
            .as_ref()
            .unwrap_err()
            .to_string()
            .contains("worker 1 panicked"));
        assert_eq!(results[2].as_ref().unwrap(), &2);
        // the same threads still serve the next job
        let again = pool.run_scoped(2, |w| Ok(w * 10), || {});
        assert!(again.into_iter().all(|r| r.is_ok()));
    }

    #[test]
    fn panicking_leader_still_waits_for_tasks_and_pool_survives() {
        let pool = WorkerPool::new(2);
        // stack-local, borrowed by every task: if run_scoped unwound past
        // the latch wait this would be a use-after-free under the tasks
        let finished = AtomicUsize::new(0);
        let unwound = catch_unwind(AssertUnwindSafe(|| {
            pool.run_scoped(
                4,
                |_| {
                    std::thread::sleep(std::time::Duration::from_millis(30));
                    finished.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                || panic!("injected leader panic"),
            )
        }));
        assert!(unwound.is_err());
        // the drop guard held the frame open until every task completed
        assert_eq!(finished.load(Ordering::SeqCst), 4);
        // and the pool threads are still healthy for the next job
        let again = pool.run_scoped(2, |w| Ok(w * 7), || {});
        let got: Vec<usize> = again.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, vec![0, 7]);
    }

    #[test]
    fn sequential_jobs_reuse_the_same_threads() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        for _ in 0..5 {
            let results = pool.run_scoped(
                2,
                |_| {
                    hits.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                || {},
            );
            assert_eq!(results.len(), 2);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn more_tasks_than_threads_complete() {
        let pool = WorkerPool::new(1);
        let results = pool.run_scoped(6, Ok, || {});
        let got: Vec<usize> = results.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
    }
}
