//! Long-lived worker pool: OS threads spawned once, reused by every job.
//!
//! The one-shot executor pays a `thread::scope` spawn per run — fine for
//! batch, measurable overhead under serving traffic. [`WorkerPool`] keeps
//! `threads` workers parked on a condvar and hands each run a borrowed
//! fleet through [`WorkerPool::run_scoped`], which has the same blocking
//! contract as `thread::scope`: it does not return until every task it
//! enqueued has finished, so tasks may safely borrow from the caller's
//! stack (see the safety argument on `run_scoped`).
//!
//! Panic containment: every task body runs under `catch_unwind`, so a
//! poisoned job (PR 4 fault-injection kernels) reports `Err("worker {w}
//! panicked")` through its own result slot and the pool thread survives to
//! serve the next job — the property `tests/integration_serve.rs` pins.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

type Task = Box<dyn FnOnce() + Send>;

struct PoolShared {
    queue: Mutex<VecDeque<Task>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// A fixed-size pool of reusable worker threads.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (floored at 1). They idle until tasks
    /// arrive and live until the pool is dropped.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("meltframe-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { shared, handles }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.handles.len()
    }

    fn enqueue(&self, task: Task) {
        let mut q = self
            .shared
            .queue
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        q.push_back(task);
        drop(q);
        self.shared.available.notify_one();
    }

    /// Run `tasks` instances of `work` (passed their index `0..tasks`) on
    /// the pool plus `leader` on the calling thread, then block until every
    /// task has finished. Returns one `Result` per task, in index order; a
    /// panicking task yields `Err("worker {w} panicked")` and leaves its
    /// pool thread healthy.
    ///
    /// Mirrors the `thread::scope` fleet in `coordinator::exec`: `work`
    /// may borrow anything on the caller's stack.
    pub fn run_scoped<T, F, L>(&self, tasks: usize, work: F, leader: L) -> Vec<Result<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
        L: FnOnce(),
    {
        struct Latch<T> {
            slots: Mutex<(Vec<Option<Result<T>>>, usize)>,
            done: Condvar,
        }
        let latch = Latch::<T> {
            slots: Mutex::new(((0..tasks).map(|_| None).collect(), 0)),
            done: Condvar::new(),
        };
        let latch = &latch;
        let work = &work;
        for w in 0..tasks {
            let task: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let result = catch_unwind(AssertUnwindSafe(|| work(w)))
                    .unwrap_or_else(|_| Err(Error::Coordinator(format!("worker {w} panicked"))));
                let mut guard = latch.slots.lock().unwrap_or_else(|p| p.into_inner());
                guard.0[w] = Some(result);
                guard.1 += 1;
                if guard.1 == tasks {
                    latch.done.notify_all();
                }
            });
            // SAFETY: the closure borrows `latch` and `work` from this
            // stack frame, but this function does not return until the
            // completion latch below has counted every task — exactly the
            // guarantee `thread::scope` provides — so the 'static lifetime
            // the queue requires is never actually exercised past the
            // borrows' real extent. No task outlives this call.
            let task: Task = unsafe { std::mem::transmute(task) };
            self.enqueue(task);
        }
        leader();
        let mut guard = latch.slots.lock().unwrap_or_else(|p| p.into_inner());
        while guard.1 < tasks {
            guard = latch
                .done
                .wait(guard)
                .unwrap_or_else(|p| p.into_inner());
        }
        std::mem::take(&mut guard.0)
            .into_iter()
            .map(|slot| slot.expect("latch counted a task whose slot is empty"))
            .collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.available.wait(q).unwrap_or_else(|p| p.into_inner());
            }
        };
        // tasks arrive pre-wrapped in catch_unwind by run_scoped; the
        // extra guard here keeps a raw `submit`-style task from ever
        // killing the thread either
        let _ = catch_unwind(AssertUnwindSafe(task));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_floors_at_one_thread() {
        assert_eq!(WorkerPool::new(0).size(), 1);
        assert_eq!(WorkerPool::new(3).size(), 3);
    }

    #[test]
    fn run_scoped_sees_stack_borrows_and_orders_results() {
        let pool = WorkerPool::new(4);
        let base = 100usize; // stack-local, borrowed by every task
        let results = pool.run_scoped(8, |w| Ok(base + w), || {});
        let got: Vec<usize> = results.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, (100..108).collect::<Vec<_>>());
    }

    #[test]
    fn run_scoped_runs_leader_on_calling_thread() {
        let pool = WorkerPool::new(2);
        let caller = std::thread::current().id();
        let mut leader_thread = None;
        pool.run_scoped(
            2,
            |_| Ok(()),
            || leader_thread = Some(std::thread::current().id()),
        );
        assert_eq!(leader_thread, Some(caller));
    }

    #[test]
    fn panicking_task_reports_error_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let results = pool.run_scoped(
            3,
            |w| {
                if w == 1 {
                    panic!("injected pool panic");
                }
                Ok(w)
            },
            || {},
        );
        assert_eq!(results[0].as_ref().unwrap(), &0);
        assert!(results[1]
            .as_ref()
            .unwrap_err()
            .to_string()
            .contains("worker 1 panicked"));
        assert_eq!(results[2].as_ref().unwrap(), &2);
        // the same threads still serve the next job
        let again = pool.run_scoped(2, |w| Ok(w * 10), || {});
        assert!(again.into_iter().all(|r| r.is_ok()));
    }

    #[test]
    fn sequential_jobs_reuse_the_same_threads() {
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        for _ in 0..5 {
            let results = pool.run_scoped(
                2,
                |_| {
                    hits.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                || {},
            );
            assert_eq!(results.len(), 2);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn more_tasks_than_threads_complete() {
        let pool = WorkerPool::new(1);
        let results = pool.run_scoped(6, Ok, || {});
        let got: Vec<usize> = results.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
    }
}
