//! Bounded FIFO job queue with admission control.
//!
//! The daemon accepts requests on connection threads and executes them on
//! a single dispatcher (jobs on one pool are serialized anyway — see
//! [`Executor`](crate::serve::Executor)). [`JobQueue`] is the hand-off:
//! bounded depth, reject-with-error when full (the client gets an
//! immediate admission error instead of unbounded buffering), FIFO pop on
//! the dispatcher side, and a close signal that drains cleanly — already
//! admitted jobs still run, new pushes are refused.

use std::collections::VecDeque;

use crate::sync::{Condvar, Mutex, NamedCondvar, NamedMutex};

use crate::error::{Error, Result};

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
    accepted: u64,
    rejected: u64,
}

/// Admission statistics for the daemon's `stats` endpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    pub depth: usize,
    pub queued: usize,
    pub accepted: u64,
    pub rejected: u64,
}

/// A bounded multi-producer single-consumer FIFO queue.
pub struct JobQueue<T> {
    inner: Mutex<QueueInner<T>>,
    ready: Condvar,
    depth: usize,
}

impl<T> JobQueue<T> {
    /// Queue admitting at most `depth` pending jobs (floored at 1).
    pub fn new(depth: usize) -> Self {
        Self {
            inner: Mutex::new_named("serve.queue.jobs", QueueInner {
                items: VecDeque::new(),
                closed: false,
                accepted: 0,
                rejected: 0,
            }),
            ready: Condvar::new_named("serve.queue.ready"),
            depth: depth.max(1),
        }
    }

    /// Maximum pending depth.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Admit `item`, or reject immediately: `Err` when the queue already
    /// holds `depth` pending jobs (admission control) or has been closed.
    pub fn push(&self, item: T) -> Result<()> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if inner.closed {
            // counts as a refusal just like the full-queue path, so
            // QueueStats::rejected covers shutdown-window rejections too
            inner.rejected += 1;
            return Err(Error::Coordinator("job queue closed (daemon shutting down)".into()));
        }
        if inner.items.len() >= self.depth {
            inner.rejected += 1;
            return Err(Error::Coordinator(format!(
                "job queue full (depth {}) — resubmit later",
                self.depth
            )));
        }
        inner.items.push_back(item);
        inner.accepted += 1;
        drop(inner);
        self.ready.notify_one();
        Ok(())
    }

    /// Block for the next job in FIFO order. `None` once the queue is
    /// closed *and* drained — already admitted jobs are still delivered.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(item) = inner.items.pop_front() {
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.ready.wait(inner).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Refuse new admissions; pending jobs still drain through `pop`.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        inner.closed = true;
        drop(inner);
        self.ready.notify_all();
    }

    /// Currently pending jobs.
    pub fn len(&self) -> usize {
        self.inner
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .items
            .len()
    }

    /// Whether nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point-in-time admission statistics.
    pub fn stats(&self) -> QueueStats {
        let inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        QueueStats {
            depth: self.depth,
            queued: inner.items.len(),
            accepted: inner.accepted,
            rejected: inner.rejected,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_depth_floor() {
        let q = JobQueue::new(0);
        assert_eq!(q.depth(), 1);
        let q = JobQueue::new(3);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let q = JobQueue::new(2);
        q.push("a").unwrap();
        q.push("b").unwrap();
        let err = q.push("c").unwrap_err();
        assert!(err.to_string().contains("full (depth 2)"), "{err}");
        // a pop frees a slot; admission resumes
        assert_eq!(q.pop(), Some("a"));
        q.push("c").unwrap();
        let s = q.stats();
        assert_eq!((s.accepted, s.rejected, s.queued), (3, 1, 2));
    }

    #[test]
    fn close_drains_pending_then_ends() {
        let q = JobQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.push(3).unwrap_err().to_string().contains("closed"));
        // the closed refusal counts in `rejected` like the full-queue path
        assert_eq!(q.stats().rejected, 1);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_blocks_until_push_or_close() {
        let q = Arc::new(JobQueue::new(2));
        let qc = Arc::clone(&q);
        let consumer = std::thread::spawn(move || {
            let first = qc.pop();
            let second = qc.pop();
            (first, second)
        });
        q.push(42).unwrap();
        q.close();
        let (first, second) = consumer.join().unwrap();
        assert_eq!(first, Some(42));
        assert_eq!(second, None);
    }
}
