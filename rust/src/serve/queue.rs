//! Bounded job queue with admission control and per-client fairness.
//!
//! The daemon accepts requests on connection threads and executes them on
//! one or more dispatcher threads (see
//! [`Executor`](crate::serve::Executor)). [`JobQueue`] is the hand-off:
//! bounded depth, reject-with-error when full (the client gets an
//! immediate admission error instead of unbounded buffering), and a close
//! signal that drains cleanly — already admitted jobs still run, new
//! pushes are refused.
//!
//! Internally the queue keeps one FIFO *lane per client* and serves lanes
//! round-robin: [`JobQueue::pop`] takes the front lane's oldest item and
//! rotates that lane to the back, so a chatty client's backlog cannot
//! starve the others — each pending client advances once per round.
//! [`JobQueue::push`] is the single-lane legacy shape (client 0), which
//! degenerates to plain FIFO. [`JobQueue::pop_matching`] is the batch
//! collector's side door: it removes every pending item matching a
//! predicate (up to a cap), optionally lingering inside a bounded window
//! for more mates, and leaves non-matching items untouched in their
//! lanes.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::sync::{Condvar, Mutex, NamedCondvar, NamedMutex};

use crate::error::{Error, Result};

struct QueueInner<T> {
    /// One FIFO lane per client id, in round-robin service order. Lanes
    /// are created on first push and dropped when emptied — an invariant
    /// the pop paths maintain is that no lane is ever empty.
    lanes: VecDeque<(u64, VecDeque<T>)>,
    /// Total items across all lanes (the admission-control figure).
    queued: usize,
    closed: bool,
    accepted: u64,
    rejected: u64,
}

/// Admission statistics for the daemon's `stats` endpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct QueueStats {
    pub depth: usize,
    pub queued: usize,
    pub accepted: u64,
    pub rejected: u64,
}

/// A bounded multi-producer multi-consumer queue with per-client
/// round-robin fairness.
pub struct JobQueue<T> {
    inner: Mutex<QueueInner<T>>,
    ready: Condvar,
    depth: usize,
}

impl<T> JobQueue<T> {
    /// Queue admitting at most `depth` pending jobs (floored at 1).
    pub fn new(depth: usize) -> Self {
        Self {
            inner: Mutex::new_named("serve.queue.jobs", QueueInner {
                lanes: VecDeque::new(),
                queued: 0,
                closed: false,
                accepted: 0,
                rejected: 0,
            }),
            ready: Condvar::new_named("serve.queue.ready"),
            depth: depth.max(1),
        }
    }

    /// Maximum pending depth.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Admit `item` on client 0's lane — the legacy single-lane shape,
    /// plain FIFO when nobody uses [`JobQueue::push_from`].
    pub fn push(&self, item: T) -> Result<()> {
        self.push_from(0, item)
    }

    /// Admit `item` on `client`'s lane, or reject immediately: `Err` when
    /// the queue already holds `depth` pending jobs across all lanes
    /// (admission control) or has been closed.
    pub fn push_from(&self, client: u64, item: T) -> Result<()> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if inner.closed {
            // counts as a refusal just like the full-queue path, so
            // QueueStats::rejected covers shutdown-window rejections too
            inner.rejected += 1;
            return Err(Error::Coordinator("job queue closed (daemon shutting down)".into()));
        }
        if inner.queued >= self.depth {
            inner.rejected += 1;
            return Err(Error::Coordinator(format!(
                "job queue full (depth {}) — resubmit later",
                self.depth
            )));
        }
        match inner.lanes.iter_mut().find(|(c, _)| *c == client) {
            Some((_, lane)) => lane.push_back(item),
            None => inner.lanes.push_back((client, VecDeque::from([item]))),
        }
        inner.queued += 1;
        inner.accepted += 1;
        drop(inner);
        // the waiter set is heterogeneous — plain `pop` dispatchers and
        // `pop_matching` batch collectors with predicates — so a
        // notify_one could wake a collector the new item doesn't match
        // and strand it; wake everyone and let the predicates sort it out
        self.ready.notify_all();
        Ok(())
    }

    /// Block for the next job, round-robin across client lanes (FIFO
    /// within each lane). `None` once the queue is closed *and* drained —
    /// already admitted jobs are still delivered.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some((client, mut lane)) = inner.lanes.pop_front() {
                if let Some(item) = lane.pop_front() {
                    inner.queued -= 1;
                    if !lane.is_empty() {
                        // the serviced client goes to the back of the round
                        inner.lanes.push_back((client, lane));
                    }
                    return Some(item);
                }
                // an empty lane violates the construction invariant; drop
                // it and retry rather than panic a dispatcher
                continue;
            }
            if inner.closed {
                return None;
            }
            inner = self.ready.wait(inner).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Remove up to `max` pending items satisfying `matches`, from any
    /// position in any lane (lane order, oldest first within a lane). If
    /// fewer than `max` match immediately and `window` is nonzero, linger
    /// up to `window` for more mates, returning early once `max` are in
    /// hand or the queue closes. A zero `window` makes this a single
    /// non-blocking sweep. Never blocks on an *empty* result beyond the
    /// window; non-matching items are left untouched.
    pub fn pop_matching<F>(&self, matches: F, max: usize, window: Duration) -> Vec<T>
    where
        F: Fn(&T) -> bool,
    {
        let mut out = Vec::new();
        if max == 0 {
            return out;
        }
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        Self::drain_matching(&mut inner, &matches, max, &mut out);
        if out.len() >= max || window.is_zero() {
            return out;
        }
        let deadline = Instant::now() + window;
        while out.len() < max && !inner.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, res) = self
                .ready
                .wait_timeout(inner, deadline - now)
                .unwrap_or_else(|p| p.into_inner());
            inner = guard;
            Self::drain_matching(&mut inner, &matches, max, &mut out);
            if res.timed_out() {
                // a timed-out wakeup is final (after the sweep above):
                // looping on the clock here could spin unboundedly under
                // the model checker, whose timeout deliveries do not
                // advance real time
                break;
            }
        }
        out
    }

    /// One locked sweep of every lane, moving items matching `matches`
    /// into `out` (up to `max` total) and dropping lanes it empties.
    fn drain_matching<F>(inner: &mut QueueInner<T>, matches: &F, max: usize, out: &mut Vec<T>)
    where
        F: Fn(&T) -> bool,
    {
        let mut li = 0;
        while li < inner.lanes.len() && out.len() < max {
            let lane = &mut inner.lanes[li].1;
            let mut i = 0;
            while i < lane.len() && out.len() < max {
                if matches(&lane[i]) {
                    match lane.remove(i) {
                        Some(item) => {
                            out.push(item);
                            inner.queued -= 1;
                        }
                        // unreachable (i < lane.len()), but stepping past
                        // beats panicking the collector
                        None => i += 1,
                    }
                } else {
                    i += 1;
                }
            }
            if lane.is_empty() {
                inner.lanes.remove(li);
            } else {
                li += 1;
            }
        }
    }

    /// Refuse new admissions; pending jobs still drain through `pop`.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        inner.closed = true;
        drop(inner);
        self.ready.notify_all();
    }

    /// Currently pending jobs across all lanes.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).queued
    }

    /// Whether nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Point-in-time admission statistics.
    pub fn stats(&self) -> QueueStats {
        let inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        QueueStats {
            depth: self.depth,
            queued: inner.queued,
            accepted: inner.accepted,
            rejected: inner.rejected,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_and_depth_floor() {
        let q = JobQueue::new(0);
        assert_eq!(q.depth(), 1);
        let q = JobQueue::new(3);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let q = JobQueue::new(2);
        q.push("a").unwrap();
        q.push("b").unwrap();
        let err = q.push("c").unwrap_err();
        assert!(err.to_string().contains("full (depth 2)"), "{err}");
        // a pop frees a slot; admission resumes
        assert_eq!(q.pop(), Some("a"));
        q.push("c").unwrap();
        let s = q.stats();
        assert_eq!((s.accepted, s.rejected, s.queued), (3, 1, 2));
    }

    #[test]
    fn close_drains_pending_then_ends() {
        let q = JobQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.push(3).unwrap_err().to_string().contains("closed"));
        // the closed refusal counts in `rejected` like the full-queue path
        assert_eq!(q.stats().rejected, 1);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_blocks_until_push_or_close() {
        let q = Arc::new(JobQueue::new(2));
        let qc = Arc::clone(&q);
        let consumer = std::thread::spawn(move || {
            let first = qc.pop();
            let second = qc.pop();
            (first, second)
        });
        q.push(42).unwrap();
        q.close();
        let (first, second) = consumer.join().unwrap();
        assert_eq!(first, Some(42));
        assert_eq!(second, None);
    }

    #[test]
    fn round_robin_interleaves_clients() {
        // client 1 floods; clients 2 and 3 each get served on the first
        // round anyway, then 1's backlog drains
        let q = JobQueue::new(8);
        q.push_from(1, "a1").unwrap();
        q.push_from(1, "a2").unwrap();
        q.push_from(1, "a3").unwrap();
        q.push_from(2, "b1").unwrap();
        q.push_from(3, "c1").unwrap();
        let order: Vec<_> = (0..5).map(|_| q.pop().unwrap()).collect();
        assert_eq!(order, ["a1", "b1", "c1", "a2", "a3"]);
        assert!(q.is_empty());
    }

    #[test]
    fn pop_matching_sweeps_without_blocking_on_zero_window() {
        let q = JobQueue::new(8);
        for i in 1..=5 {
            q.push_from(i % 2, i).unwrap();
        }
        // odd items match, capped at 2, no lingering
        let got = q.pop_matching(|i| i % 2 == 1, 2, Duration::ZERO);
        assert_eq!(got, [1, 3]);
        // the rest are untouched and still pop in round-robin order
        assert_eq!(q.len(), 3);
        let rest: Vec<_> = (0..3).map(|_| q.pop().unwrap()).collect();
        assert_eq!(rest, [5, 2, 4]);
    }

    #[test]
    fn pop_matching_returns_all_matches_under_cap() {
        let q = JobQueue::new(8);
        q.push(10).unwrap();
        q.push(11).unwrap();
        let got = q.pop_matching(|_| true, 8, Duration::ZERO);
        assert_eq!(got, [10, 11]);
        assert!(q.is_empty());
        // an empty queue yields an empty sweep, not a block
        assert!(q.pop_matching(|_| true, 8, Duration::ZERO).is_empty());
        // max == 0 is a no-op even with items pending
        q.push(1).unwrap();
        assert!(q.pop_matching(|_| true, 0, Duration::from_secs(5)).is_empty());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn pop_matching_wakes_for_late_mates_and_fills_the_cap() {
        let q = Arc::new(JobQueue::new(8));
        let qc = Arc::clone(&q);
        let collector = std::thread::spawn(move || {
            // generous window: returns the moment the cap is reached
            qc.pop_matching(|i| *i < 100, 2, Duration::from_secs(30))
        });
        std::thread::sleep(Duration::from_millis(20));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let got = collector.join().unwrap();
        assert_eq!(got, [1, 2]);
    }

    #[test]
    fn pop_matching_stops_lingering_on_close() {
        let q = JobQueue::new(8);
        q.push(7).unwrap();
        q.close();
        // closed queue: collect what is there, never wait out the window
        let t0 = Instant::now();
        let got = q.pop_matching(|_| true, 5, Duration::from_secs(30));
        assert_eq!(got, [7]);
        assert!(t0.elapsed() < Duration::from_secs(5));
    }
}
