//! Serving subsystem: a persistent daemon over the lazy `Plan` executor.
//!
//! One-shot `meltframe run` pays three fixed costs on every invocation:
//! process start, worker-thread spawn, and planner output — the
//! `RowGather` tables that §2.4's data independence makes a pure function
//! of `(shape, op-chain, grid, boundary)`, never of the data. This module
//! keeps all three warm across requests:
//!
//! - [`pool::WorkerPool`] — a long-lived fleet decoupled from any single
//!   run; jobs borrow the threads through the same scoped-closure shape
//!   the one-shot executor uses, so execution is bit-for-bit identical.
//! - [`cache::PlanCache`] — an LRU of planner output keyed by
//!   `(shape, op-chain, grid, boundary, halo_mode, tile_rows)` with
//!   hit/miss/evict counters surfaced through `RunMetrics`.
//! - [`executor::Executor`] — the reusable handle owning both, with
//!   one-job-at-a-time dispatch (a shared barrier fleet cannot interleave
//!   jobs) and fault isolation: a poisoned job fails alone.
//! - [`queue::JobQueue`] — bounded admission control with per-client
//!   round-robin fairness lanes and a predicate sweep
//!   ([`queue::JobQueue::pop_matching`]) used by the batch collector.
//! - [`protocol`] / [`daemon`] — the line-delimited JSON request protocol
//!   and the Unix-domain-socket front end (`meltframe serve` /
//!   `meltframe submit`).
//!
//! On top of those, the daemon folds **cross-request batches**: admitted
//! jobs that share a batch key (shape, op-chain, parameters, grid,
//! boundary, halo mode, tile height) are stacked along a leading batch
//! axis and executed as ONE fused run — one plan lookup, one melt, one
//! fold for the whole group — then split and answered individually,
//! each member bit-for-bit identical to its own standalone run
//! ([`protocol::execute_batch`]). `--executors N` shards the worker
//! budget into N independent executor/dispatcher pairs so unrelated
//! batches run concurrently.

pub mod cache;
pub mod daemon;
pub mod executor;
pub mod pool;
pub mod protocol;
pub mod queue;

pub use cache::{CacheStats, PlanCache};
pub use daemon::{
    serve, ResponseSlot, ServeOptions, DEFAULT_BATCH_WINDOW_MS, DEFAULT_EXECUTORS,
    DEFAULT_MAX_BATCH, DEFAULT_QUEUE_DEPTH,
};
pub use executor::{Executor, DEFAULT_CACHE_CAPACITY};
pub use pool::WorkerPool;
pub use protocol::{execute_batch, execute_request, parse_request, JobRequest, Request};
pub use queue::{JobQueue, QueueStats};
