//! Serving subsystem: a persistent daemon over the lazy `Plan` executor.
//!
//! One-shot `meltframe run` pays three fixed costs on every invocation:
//! process start, worker-thread spawn, and planner output — the
//! `RowGather` tables that §2.4's data independence makes a pure function
//! of `(shape, op-chain, grid, boundary)`, never of the data. This module
//! keeps all three warm across requests:
//!
//! - [`pool::WorkerPool`] — a long-lived fleet decoupled from any single
//!   run; jobs borrow the threads through the same scoped-closure shape
//!   the one-shot executor uses, so execution is bit-for-bit identical.
//! - [`cache::PlanCache`] — an LRU of planner output keyed by
//!   `(shape, op-chain, grid, boundary, halo_mode, tile_rows)` with
//!   hit/miss/evict counters surfaced through `RunMetrics`.
//! - [`executor::Executor`] — the reusable handle owning both, with
//!   one-job-at-a-time dispatch (a shared barrier fleet cannot interleave
//!   jobs) and fault isolation: a poisoned job fails alone.
//! - [`queue::JobQueue`] — bounded FIFO admission control for the daemon.
//! - [`protocol`] / [`daemon`] — the line-delimited JSON request protocol
//!   and the Unix-domain-socket front end (`meltframe serve` /
//!   `meltframe submit`).

pub mod cache;
pub mod daemon;
pub mod executor;
pub mod pool;
pub mod protocol;
pub mod queue;

pub use cache::{CacheStats, PlanCache};
pub use daemon::{serve, ResponseSlot, ServeOptions, DEFAULT_QUEUE_DEPTH};
pub use executor::{Executor, DEFAULT_CACHE_CAPACITY};
pub use pool::WorkerPool;
pub use protocol::{execute_request, parse_request, JobRequest, Request};
pub use queue::{JobQueue, QueueStats};
