//! The serving daemon: Unix-domain socket front end over a sharded set
//! of [`Executor`]s with cross-request batching.
//!
//! Lifecycle: `serve` binds the socket (probing it first — a path served
//! by a live daemon is an error, only a stale file from a crashed daemon
//! is unlinked), spawns `--executors` persistent [`Executor`] shards
//! (each owning its slice of the worker budget plus its own plan cache)
//! and one dispatcher thread per shard, then accepts connections. Each
//! connection gets a reader thread speaking the line protocol
//! ([`protocol`]) with a bounded per-line read (an oversized request
//! answers with an error instead of growing the buffer without bound):
//! job requests are admitted into a bounded [`JobQueue`] (admission
//! control — a full queue rejects immediately with an error line instead
//! of buffering unboundedly) on a per-client fairness lane, and the
//! connection thread blocks on the job's response slot, so each
//! connection sees strict request→response order while separate
//! connections proceed concurrently.
//!
//! Dispatch is a **batch collector** per shard: after popping a job, the
//! dispatcher sweeps the queue for up to `--max-batch − 1` mates sharing
//! the job's [batch key](crate::serve::protocol::JobRequest::batch_key),
//! lingering at most `--batch-window-ms` for stragglers, and executes
//! the whole group as ONE stacked fold — one plan lookup, one melt and
//! one fold for the entire batch — then answers every member's slot
//! individually. Faulted requests carry no batch key and always run
//! alone; a batch that errors or panics falls back to singletons so one
//! bad member cannot poison its batchmates (see
//! [`execute_batch`](crate::serve::protocol::execute_batch)). With
//! multiple shards, independent batches run concurrently.
//!
//! `{"op": "shutdown"}` stops admissions, drains already-admitted jobs,
//! acknowledges, and unblocks the accept loop; `serve` returns once
//! every dispatcher has drained.
//!
//! [`protocol`]: crate::serve::protocol

use std::io::{BufRead, BufReader, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::time::Duration;

use crate::coordinator::pipeline::ExecOptions;
use crate::error::{Error, Result};
use crate::serve::executor::{Executor, DEFAULT_CACHE_CAPACITY};
use crate::serve::protocol::{
    client_lane, error_response, execute_batch, execute_request, parse_request, JobRequest,
    Request,
};
use crate::serve::queue::JobQueue;
use crate::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::sync::{thread, Arc, Condvar, Mutex, NamedCondvar, NamedMutex};

/// Default pending-job admission depth.
pub const DEFAULT_QUEUE_DEPTH: usize = 16;

/// Default batch-collection window in milliseconds (0 disables batching).
pub const DEFAULT_BATCH_WINDOW_MS: u64 = 2;

/// Default cap on jobs folded into one batch.
pub const DEFAULT_MAX_BATCH: usize = 8;

/// Default executor shard count.
pub const DEFAULT_EXECUTORS: usize = 1;

/// Longest request line the daemon will read before answering with an
/// error and dropping the connection (a newline-less byte stream must
/// not grow the line buffer without bound).
pub const MAX_REQUEST_BYTES: u64 = 16 * 1024 * 1024;

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Unix-domain socket path to bind.
    pub socket: PathBuf,
    /// Default execution options; `exec.workers` is the TOTAL worker
    /// budget, split across the executor shards.
    pub exec: ExecOptions,
    /// Pending-job admission depth (floored at 1).
    pub queue_depth: usize,
    /// Plan-cache capacity in entries, per shard (floored at 1).
    pub cache_capacity: usize,
    /// Batch-collection window in milliseconds; 0 turns batching off.
    pub batch_window_ms: u64,
    /// Max jobs folded into one batch (values < 2 turn batching off).
    pub max_batch: usize,
    /// Executor shards (floored at 1, capped at `exec.workers` so every
    /// shard owns at least one worker thread).
    pub executors: usize,
}

impl ServeOptions {
    /// Defaults around `exec` at `socket`.
    pub fn new(socket: impl Into<PathBuf>, exec: ExecOptions) -> Self {
        Self {
            socket: socket.into(),
            exec,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            batch_window_ms: DEFAULT_BATCH_WINDOW_MS,
            max_batch: DEFAULT_MAX_BATCH,
            executors: DEFAULT_EXECUTORS,
        }
    }
}

/// One-shot rendezvous for a job's response line.
///
/// Public so `tests/model_concurrency.rs` can drive the dispatcher ↔
/// connection hand-off protocol under the model scheduler.
pub struct ResponseSlot {
    line: Mutex<Option<String>>,
    ready: Condvar,
}

impl Default for ResponseSlot {
    fn default() -> Self {
        Self::new()
    }
}

impl ResponseSlot {
    pub fn new() -> Self {
        Self {
            line: Mutex::new_named("serve.response.line", None),
            ready: Condvar::new_named("serve.response.ready"),
        }
    }

    pub fn fill(&self, line: String) {
        let mut slot = self.line.lock().unwrap_or_else(|p| p.into_inner());
        *slot = Some(line);
        drop(slot);
        self.ready.notify_all();
    }

    pub fn wait(&self) -> String {
        let mut slot = self.line.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(line) = slot.take() {
                return line;
            }
            slot = self.ready.wait(slot).unwrap_or_else(|p| p.into_inner());
        }
    }
}

struct QueuedJob {
    req: JobRequest,
    slot: Arc<ResponseSlot>,
    /// Precomputed co-batching key (`None` ⇒ never co-batch).
    batch_key: Option<String>,
}

/// One executor shard plus its dispatch counters (all atomics — no new
/// lock classes).
struct ExecutorShard {
    exec: Executor,
    /// Jobs this shard executed (batched or not).
    jobs: AtomicUsize,
    /// Batches of size ≥ 2 this shard folded.
    batches: AtomicUsize,
    /// Jobs answered through those batches.
    batched_jobs: AtomicUsize,
}

/// Everything the connection and dispatcher threads share.
struct DaemonState {
    shards: Vec<ExecutorShard>,
    queue: JobQueue<QueuedJob>,
    shutdown: AtomicBool,
    socket: PathBuf,
    /// Batch-collection window (zero ⇒ batching off).
    window: Duration,
    max_batch: usize,
    /// Fairness-lane ids for untagged connections.
    next_lane: AtomicUsize,
}

impl DaemonState {
    fn batching(&self) -> bool {
        !self.window.is_zero() && self.max_batch >= 2
    }
}

/// Split `total` workers across `shards` executor shards: every shard
/// gets at least one, earlier shards absorb the remainder.
fn shard_workers(total: usize, shards: usize) -> Vec<usize> {
    let total = total.max(1);
    let shards = shards.max(1).min(total);
    let per = total / shards;
    let rem = total % shards;
    (0..shards).map(|i| per + usize::from(i < rem)).collect()
}

/// Run the daemon until a `shutdown` request. Blocks the calling thread.
pub fn serve(opts: ServeOptions) -> Result<()> {
    // A stale socket file from a crashed daemon would fail the bind, but
    // unlinking unconditionally would silently steal the path from a LIVE
    // daemon (which keeps running, unreachable). Probe first: only clear
    // the file if nothing answers a connect.
    if opts.socket.exists() {
        if UnixStream::connect(&opts.socket).is_ok() {
            return Err(Error::Coordinator(format!(
                "socket {} is already served by a live daemon (shut it down first, \
                 or pick another --socket)",
                opts.socket.display()
            )));
        }
        let _ = std::fs::remove_file(&opts.socket);
    }
    let listener = UnixListener::bind(&opts.socket)?;

    let shards: Vec<ExecutorShard> = shard_workers(opts.exec.workers, opts.executors)
        .into_iter()
        .map(|workers| {
            let mut exec_opts = opts.exec.clone();
            exec_opts.workers = workers;
            ExecutorShard {
                exec: Executor::persistent(exec_opts, opts.cache_capacity),
                jobs: AtomicUsize::new(0),
                batches: AtomicUsize::new(0),
                batched_jobs: AtomicUsize::new(0),
            }
        })
        .collect();
    let state = Arc::new(DaemonState {
        shards,
        queue: JobQueue::new(opts.queue_depth),
        shutdown: AtomicBool::new(false),
        socket: opts.socket.clone(),
        window: Duration::from_millis(opts.batch_window_ms),
        max_batch: opts.max_batch,
        next_lane: AtomicUsize::new(1),
    });

    let dispatchers: Vec<_> = (0..state.shards.len())
        .map(|i| {
            let state = Arc::clone(&state);
            thread::Builder::new()
                .name(format!("meltframe-exec-{i}"))
                .spawn(move || dispatch_loop(&state, i))
                .expect("spawn dispatcher thread")
        })
        .collect();

    println!(
        "meltframe serve: listening on {} ({} workers × {} executor(s), queue depth {}, \
         cache {} plans, batch window {} ms, max batch {})",
        opts.socket.display(),
        opts.exec.workers,
        state.shards.len(),
        state.queue.depth(),
        opts.cache_capacity,
        opts.batch_window_ms,
        opts.max_batch
    );

    for stream in listener.incoming() {
        if state.shutdown.load(Ordering::SeqCst) {
            // A real client racing the shutdown gets an answer instead of
            // a silently dropped connection (the wake-up self-connect from
            // the shutdown handler just ignores the line).
            if let Ok(mut s) = stream {
                let _ = writeln!(s, "{}", error_response("", "daemon shutting down"));
            }
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let state = Arc::clone(&state);
        // detached: a connection lingering past shutdown only ever sees
        // "queue closed" rejections and its own stream
        let _ = thread::Builder::new()
            .name("meltframe-conn".into())
            .spawn(move || handle_connection(stream, &state));
    }

    state.queue.close();
    for d in dispatchers {
        let _ = d.join();
    }
    let _ = std::fs::remove_file(&opts.socket);
    Ok(())
}

/// One shard's dispatcher: pop a job, sweep the queue for batchmates
/// (same batch key, bounded count, bounded wait), execute the group as
/// one stacked fold — or the lone job as a singleton — and answer every
/// member's response slot.
fn dispatch_loop(state: &DaemonState, shard_idx: usize) {
    let shard = &state.shards[shard_idx];
    while let Some(job) = state.queue.pop() {
        let mut batch = vec![job];
        if state.batching() {
            if let Some(key) = batch[0].batch_key.clone() {
                batch.extend(state.queue.pop_matching(
                    |j| j.batch_key.as_deref() == Some(key.as_str()),
                    state.max_batch - 1,
                    state.window,
                ));
            }
        }
        shard.jobs.fetch_add(batch.len(), Ordering::SeqCst);
        if batch.len() >= 2 {
            shard.batches.fetch_add(1, Ordering::SeqCst);
            shard.batched_jobs.fetch_add(batch.len(), Ordering::SeqCst);
        }
        // Worker-side panics are already caught by the pool, but a panic
        // on the leader/planning side of a run (plan building, partition
        // validation, aggregation) would otherwise kill the dispatcher
        // and strand every admitted job in slot.wait() forever. Contain
        // it: the jobs answer with error lines, the dispatcher lives on
        // to drain the queue. (execute_batch has its own internal
        // singleton fallback for batched failures.)
        let mut responses = catch_unwind(AssertUnwindSafe(|| {
            if batch.len() == 1 {
                vec![execute_request(&batch[0].req, &shard.exec)]
            } else {
                let reqs: Vec<&JobRequest> = batch.iter().map(|j| &j.req).collect();
                execute_batch(&reqs, &shard.exec)
            }
        }))
        .unwrap_or_else(|_| {
            batch
                .iter()
                .map(|j| {
                    error_response(
                        &j.req.id,
                        "internal error: job panicked during planning/dispatch",
                    )
                })
                .collect()
        });
        // every admitted job MUST be answered or its connection blocks
        // forever; pad defensively if a response path ever short-counts
        while responses.len() < batch.len() {
            responses.push(error_response(
                &batch[responses.len()].req.id,
                "internal error: missing batch response",
            ));
        }
        for (j, response) in batch.iter().zip(responses) {
            j.slot.fill(response);
        }
    }
}

fn handle_connection(stream: UnixStream, state: &DaemonState) {
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    // untagged requests on this connection share one fairness lane
    let conn_lane = state.next_lane.fetch_add(1, Ordering::SeqCst) as u64;
    let mut line = String::new();
    loop {
        line.clear();
        // bounded read: at most MAX_REQUEST_BYTES + 1 bytes land in the
        // line buffer however long the sender's line really is
        let n = match (&mut reader).take(MAX_REQUEST_BYTES + 1).read_line(&mut line) {
            Ok(0) => break, // EOF
            Ok(n) => n,
            Err(_) => break,
        };
        if n as u64 > MAX_REQUEST_BYTES && !line.ends_with('\n') {
            // the line is longer than the cap and we cannot resync to its
            // end without buffering it: answer, then drop the connection
            let _ = writeln!(
                writer,
                "{}",
                error_response(
                    "",
                    &format!("request line exceeds {MAX_REQUEST_BYTES} bytes")
                )
            );
            break;
        }
        if line.trim().is_empty() {
            continue;
        }
        let response = match parse_request(&line) {
            Err(e) => error_response("", &e.to_string()),
            Ok(Request::Ping) => "{\"ok\": true, \"pong\": true}".to_string(),
            Ok(Request::Stats) => stats_response(state),
            Ok(Request::Shutdown) => {
                state.shutdown.store(true, Ordering::SeqCst);
                state.queue.close();
                let _ = writeln!(writer, "{{\"ok\": true, \"shutdown\": true}}");
                // Unblock the accept loop so `serve` can observe the flag.
                // The connect must actually land — otherwise the accept
                // loop stays blocked despite the flag — so retry briefly;
                // if every attempt fails the next real connection (which
                // gets a "shutting down" line) completes the hand-off.
                for _ in 0..5 {
                    if UnixStream::connect(&state.socket).is_ok() {
                        break;
                    }
                    thread::sleep(Duration::from_millis(10));
                }
                return;
            }
            Ok(Request::Run(req)) => {
                let id = req.id.clone();
                let slot = Arc::new(ResponseSlot::new());
                // tagged requests share a lane across connections; the
                // batch key is computed once, against shard 0's options
                // (halo mode and tile height are identical across shards)
                let lane = req.client.as_deref().map(client_lane).unwrap_or(conn_lane);
                let batch_key = req.batch_key(state.shards[0].exec.options());
                match state.queue.push_from(lane, QueuedJob {
                    req: *req,
                    slot: Arc::clone(&slot),
                    batch_key,
                }) {
                    // admission control: rejected jobs answer immediately
                    Err(e) => error_response(&id, &e.to_string()),
                    Ok(()) => slot.wait(),
                }
            }
        };
        if writeln!(writer, "{response}").is_err() {
            break;
        }
    }
}

fn stats_response(state: &DaemonState) -> String {
    // cache stats are summed across the shards' independent plan caches
    let (mut hits, mut misses, mut evictions, mut entries, mut resident) = (0, 0, 0, 0, 0);
    let mut executors = String::new();
    let (mut batches, mut batched_jobs) = (0, 0);
    for (i, s) in state.shards.iter().enumerate() {
        let c = s.exec.cache_stats();
        hits += c.hits;
        misses += c.misses;
        evictions += c.evictions;
        entries += c.entries;
        resident += c.resident_bytes;
        let (j, b, bj) = (
            s.jobs.load(Ordering::SeqCst),
            s.batches.load(Ordering::SeqCst),
            s.batched_jobs.load(Ordering::SeqCst),
        );
        batches += b;
        batched_jobs += bj;
        if i > 0 {
            executors.push_str(", ");
        }
        executors.push_str(&format!(
            "{{\"workers\": {}, \"jobs\": {}, \"batches\": {}, \"batched_jobs\": {}}}",
            s.exec.options().workers,
            j,
            b,
            bj
        ));
    }
    let q = state.queue.stats();
    format!(
        "{{\"ok\": true, \"cache\": {{\"hits\": {hits}, \"misses\": {misses}, \
         \"evictions\": {evictions}, \"entries\": {entries}, \"resident_bytes\": {resident}}}, \
         \"queue\": {{\"depth\": {}, \"queued\": {}, \"accepted\": {}, \"rejected\": {}}}, \
         \"batching\": {{\"window_ms\": {}, \"max_batch\": {}, \"batches\": {batches}, \
         \"batched_jobs\": {batched_jobs}}}, \
         \"executors\": [{executors}]}}",
        q.depth,
        q.queued,
        q.accepted,
        q.rejected,
        state.window.as_millis(),
        state.max_batch
    )
}
