//! The serving daemon: Unix-domain socket front end over one
//! [`Executor`].
//!
//! Lifecycle: `serve` binds the socket (probing it first — a path served
//! by a live daemon is an error, only a stale file from a crashed daemon
//! is unlinked),
//! spawns one persistent [`Executor`] (pool + plan cache) and one
//! dispatcher thread, then accepts connections. Each connection gets a
//! reader thread speaking the line protocol ([`protocol`]): job requests
//! are admitted into a bounded [`JobQueue`] (admission control — a full
//! queue rejects immediately with an error line instead of buffering
//! unboundedly) and executed in FIFO order by the dispatcher; the
//! connection thread blocks on the job's response slot, so each
//! connection sees strict request→response order while separate
//! connections proceed concurrently. `{"op": "shutdown"}` stops
//! admissions, drains already-admitted jobs, acknowledges, and unblocks
//! the accept loop; `serve` returns once the dispatcher has drained.
//!
//! [`protocol`]: crate::serve::protocol

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::time::Duration;

use crate::coordinator::pipeline::ExecOptions;
use crate::error::{Error, Result};
use crate::serve::executor::{Executor, DEFAULT_CACHE_CAPACITY};
use crate::serve::protocol::{error_response, execute_request, parse_request, JobRequest, Request};
use crate::serve::queue::JobQueue;
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{thread, Arc, Condvar, Mutex, NamedCondvar, NamedMutex};

/// Default pending-job admission depth.
pub const DEFAULT_QUEUE_DEPTH: usize = 16;

/// Daemon configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Unix-domain socket path to bind.
    pub socket: PathBuf,
    /// Default execution options; `exec.workers` sizes the pool.
    pub exec: ExecOptions,
    /// Pending-job admission depth (floored at 1).
    pub queue_depth: usize,
    /// Plan-cache capacity in entries (floored at 1).
    pub cache_capacity: usize,
}

impl ServeOptions {
    /// Defaults around `exec` at `socket`.
    pub fn new(socket: impl Into<PathBuf>, exec: ExecOptions) -> Self {
        Self {
            socket: socket.into(),
            exec,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            cache_capacity: DEFAULT_CACHE_CAPACITY,
        }
    }
}

/// One-shot rendezvous for a job's response line.
///
/// Public so `tests/model_concurrency.rs` can drive the dispatcher ↔
/// connection hand-off protocol under the model scheduler.
pub struct ResponseSlot {
    line: Mutex<Option<String>>,
    ready: Condvar,
}

impl Default for ResponseSlot {
    fn default() -> Self {
        Self::new()
    }
}

impl ResponseSlot {
    pub fn new() -> Self {
        Self {
            line: Mutex::new_named("serve.response.line", None),
            ready: Condvar::new_named("serve.response.ready"),
        }
    }

    pub fn fill(&self, line: String) {
        let mut slot = self.line.lock().unwrap_or_else(|p| p.into_inner());
        *slot = Some(line);
        drop(slot);
        self.ready.notify_all();
    }

    pub fn wait(&self) -> String {
        let mut slot = self.line.lock().unwrap_or_else(|p| p.into_inner());
        loop {
            if let Some(line) = slot.take() {
                return line;
            }
            slot = self.ready.wait(slot).unwrap_or_else(|p| p.into_inner());
        }
    }
}

struct QueuedJob {
    req: JobRequest,
    slot: Arc<ResponseSlot>,
}

/// Run the daemon until a `shutdown` request. Blocks the calling thread.
pub fn serve(opts: ServeOptions) -> Result<()> {
    // A stale socket file from a crashed daemon would fail the bind, but
    // unlinking unconditionally would silently steal the path from a LIVE
    // daemon (which keeps running, unreachable). Probe first: only clear
    // the file if nothing answers a connect.
    if opts.socket.exists() {
        if UnixStream::connect(&opts.socket).is_ok() {
            return Err(Error::Coordinator(format!(
                "socket {} is already served by a live daemon (shut it down first, \
                 or pick another --socket)",
                opts.socket.display()
            )));
        }
        let _ = std::fs::remove_file(&opts.socket);
    }
    let listener = UnixListener::bind(&opts.socket)?;

    let exec = Arc::new(Executor::persistent(opts.exec.clone(), opts.cache_capacity));
    let queue: Arc<JobQueue<QueuedJob>> = Arc::new(JobQueue::new(opts.queue_depth));
    let shutdown = Arc::new(AtomicBool::new(false));

    let dispatcher = {
        let exec = Arc::clone(&exec);
        let queue = Arc::clone(&queue);
        thread::Builder::new()
            .name("meltframe-dispatch".into())
            .spawn(move || {
                while let Some(job) = queue.pop() {
                    // Worker-side panics are already caught by the pool,
                    // but a panic on the leader/planning side of a run
                    // (plan building, partition validation, aggregation)
                    // would otherwise kill the dispatcher and strand every
                    // admitted job in slot.wait() forever. Contain it: the
                    // job answers with an error line, the dispatcher lives
                    // on to drain the queue.
                    let response =
                        catch_unwind(AssertUnwindSafe(|| execute_request(&job.req, &exec)))
                            .unwrap_or_else(|_| {
                                error_response(
                                    &job.req.id,
                                    "internal error: job panicked during planning/dispatch",
                                )
                            });
                    job.slot.fill(response);
                }
            })
            .expect("spawn dispatcher thread")
    };

    println!(
        "meltframe serve: listening on {} ({} workers, queue depth {}, cache {} plans)",
        opts.socket.display(),
        exec.options().workers,
        queue.depth(),
        opts.cache_capacity
    );

    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            // A real client racing the shutdown gets an answer instead of
            // a silently dropped connection (the wake-up self-connect from
            // the shutdown handler just ignores the line).
            if let Ok(mut s) = stream {
                let _ = writeln!(s, "{}", error_response("", "daemon shutting down"));
            }
            break;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let exec = Arc::clone(&exec);
        let queue = Arc::clone(&queue);
        let shutdown = Arc::clone(&shutdown);
        let socket = opts.socket.clone();
        // detached: a connection lingering past shutdown only ever sees
        // "queue closed" rejections and its own stream
        let _ = thread::Builder::new()
            .name("meltframe-conn".into())
            .spawn(move || handle_connection(stream, &exec, &queue, &shutdown, &socket));
    }

    queue.close();
    let _ = dispatcher.join();
    let _ = std::fs::remove_file(&opts.socket);
    Ok(())
}

fn handle_connection(
    stream: UnixStream,
    exec: &Executor,
    queue: &JobQueue<QueuedJob>,
    shutdown: &AtomicBool,
    socket: &Path,
) {
    let reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let response = match parse_request(&line) {
            Err(e) => error_response("", &e.to_string()),
            Ok(Request::Ping) => "{\"ok\": true, \"pong\": true}".to_string(),
            Ok(Request::Stats) => stats_response(exec, queue),
            Ok(Request::Shutdown) => {
                shutdown.store(true, Ordering::SeqCst);
                queue.close();
                let _ = writeln!(writer, "{{\"ok\": true, \"shutdown\": true}}");
                // Unblock the accept loop so `serve` can observe the flag.
                // The connect must actually land — otherwise the accept
                // loop stays blocked despite the flag — so retry briefly;
                // if every attempt fails the next real connection (which
                // gets a "shutting down" line) completes the hand-off.
                for _ in 0..5 {
                    if UnixStream::connect(socket).is_ok() {
                        break;
                    }
                    thread::sleep(Duration::from_millis(10));
                }
                return;
            }
            Ok(Request::Run(req)) => {
                let id = req.id.clone();
                let slot = Arc::new(ResponseSlot::new());
                match queue.push(QueuedJob {
                    req: *req,
                    slot: Arc::clone(&slot),
                }) {
                    // admission control: rejected jobs answer immediately
                    Err(e) => error_response(&id, &e.to_string()),
                    Ok(()) => slot.wait(),
                }
            }
        };
        if writeln!(writer, "{response}").is_err() {
            break;
        }
    }
}

fn stats_response(exec: &Executor, queue: &JobQueue<QueuedJob>) -> String {
    let c = exec.cache_stats();
    let q = queue.stats();
    format!(
        "{{\"ok\": true, \"cache\": {{\"hits\": {}, \"misses\": {}, \"evictions\": {}, \
         \"entries\": {}, \"resident_bytes\": {}}}, \
         \"queue\": {{\"depth\": {}, \"queued\": {}, \"accepted\": {}, \"rejected\": {}}}}}",
        c.hits, c.misses, c.evictions, c.entries, c.resident_bytes,
        q.depth, q.queued, q.accepted, q.rejected
    )
}
