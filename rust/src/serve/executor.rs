//! The reusable [`Executor`] handle: worker lifetime and plan cache
//! decoupled from any single `run()`.
//!
//! A one-shot `Plan::run` spawns a scoped fleet, builds every `RowGather`
//! table, executes, and tears it all down. An `Executor` owns those
//! resources instead: a [`WorkerPool`](crate::serve::pool::WorkerPool)
//! spawned once (persistent mode) and a [`PlanCache`] that survives across
//! jobs, so repeat traffic pays neither thread spawn nor plan
//! construction. Results are bit-for-bit identical to one-shot runs —
//! cached plans are pure functions of their key (§2.4 data independence) —
//! and a job that panics or errors fails alone: the pool threads catch the
//! unwind and the cache holds only data-independent tables, so both stay
//! healthy for the next job (pinned by `tests/integration_serve.rs`).
//!
//! Jobs on one executor are serialized by an internal run lock: the
//! executor's fleet runs one barrier-coordinated job (or batched fold —
//! see [`Executor::run_batch`]) at a time, because two interleaved jobs
//! on one fixed pool would deadlock each other's barriers. The serving
//! [`daemon`](crate::serve::daemon) gets concurrency *across* executors:
//! each of its shards owns one, with its own dispatcher and cache.

use crate::sync::{Mutex, NamedMutex};

use crate::coordinator::exec::{execute_batch_with, Fleet};
use crate::coordinator::metrics::PlanMetrics;
use crate::coordinator::pipeline::ExecOptions;
use crate::coordinator::plan::{Plan, Stage};
use crate::error::{Error, Result};
use crate::serve::cache::{CacheStats, PlanCache};
use crate::serve::pool::WorkerPool;
use crate::tensor::dense::Tensor;

/// Default plan-cache capacity (entries) for executors that don't choose.
pub const DEFAULT_CACHE_CAPACITY: usize = 32;

/// A reusable execution handle owning worker lifetime and plan cache.
pub struct Executor {
    opts: ExecOptions,
    /// `Some` in persistent mode; `None` falls back to a scoped fleet per
    /// run (threads are not reused, but the plan cache still is).
    pool: Option<WorkerPool>,
    cache: PlanCache,
    run_lock: Mutex<()>,
}

impl Executor {
    /// An executor without a persistent pool: each run spawns a scoped
    /// fleet (exactly like `Plan::run`), but plans are still cached —
    /// useful for batch drivers that repeat a spec, and as the
    /// bit-for-bit reference for the served path.
    pub fn one_shot(opts: ExecOptions) -> Self {
        Self {
            opts,
            pool: None,
            cache: PlanCache::new(DEFAULT_CACHE_CAPACITY),
            // gate class: held by the run leader across the whole
            // barrier-coordinated job (including condvar/barrier waits) —
            // see the global lock order in crate::sync
            run_lock: Mutex::new_gate("serve.exec.run", ()),
        }
    }

    /// A serving executor: spawns `opts.workers` pool threads now and
    /// reuses them for every job, with a plan cache of `cache_capacity`
    /// entries (floored at 1).
    pub fn persistent(opts: ExecOptions, cache_capacity: usize) -> Self {
        let pool = WorkerPool::new(opts.workers.max(1));
        Self {
            opts,
            pool: Some(pool),
            cache: PlanCache::new(cache_capacity),
            // gate class: held by the run leader across the whole
            // barrier-coordinated job (including condvar/barrier waits) —
            // see the global lock order in crate::sync
            run_lock: Mutex::new_gate("serve.exec.run", ()),
        }
    }

    /// The executor's default run options.
    pub fn options(&self) -> &ExecOptions {
        &self.opts
    }

    /// Whether this executor owns a persistent pool.
    pub fn is_persistent(&self) -> bool {
        self.pool.is_some()
    }

    /// Plan-cache statistics snapshot.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Run `plan` with the executor's default options.
    pub fn run(&self, plan: Plan<'_>) -> Result<(Tensor<f32>, PlanMetrics)> {
        self.run_with(plan, &self.opts)
    }

    /// Run `plan` with per-job options. `opts.workers` must equal the
    /// pool size in persistent mode (a barrier across more tasks than
    /// pool threads cannot be satisfied); everything else — halo mode,
    /// tile height, backend — may vary per job and participates in the
    /// plan-cache key where the contract says so.
    pub fn run_with(
        &self,
        plan: Plan<'_>,
        opts: &ExecOptions,
    ) -> Result<(Tensor<f32>, PlanMetrics)> {
        self.check_workers(opts)?;
        // one barrier-coordinated job at a time on the shared fleet; a
        // poisoned predecessor must not poison this lock either
        let _running = self.run_lock.lock().unwrap_or_else(|p| p.into_inner());
        plan.compile(opts.backend)?.execute_on(opts, self.fleet(), Some(&self.cache))
    }

    /// Run one batched fold over `inputs` (all the same shape) through
    /// `stages`, with the executor's default options.
    pub fn run_batch(
        &self,
        inputs: &[Tensor<f32>],
        stages: &[Stage],
    ) -> Result<(Vec<Tensor<f32>>, PlanMetrics)> {
        self.run_batch_with(inputs, stages, &self.opts)
    }

    /// [`Executor::run_batch`] with per-batch options (same worker-count
    /// contract as [`Executor::run_with`]). The inputs are stacked along
    /// a leading batch axis and the whole batch executes as one plan —
    /// one plan-cache lookup, one melt and one fold per fused group,
    /// `batched_jobs` set on every group's metrics — then the stacked
    /// output is split back into one tensor per input, each bit-for-bit
    /// identical to its own standalone run.
    pub fn run_batch_with(
        &self,
        inputs: &[Tensor<f32>],
        stages: &[Stage],
        opts: &ExecOptions,
    ) -> Result<(Vec<Tensor<f32>>, PlanMetrics)> {
        self.check_workers(opts)?;
        let _running = self.run_lock.lock().unwrap_or_else(|p| p.into_inner());
        execute_batch_with(inputs, stages, opts, self.fleet(), Some(&self.cache))
    }

    fn check_workers(&self, opts: &ExecOptions) -> Result<()> {
        if let Some(pool) = &self.pool {
            if opts.workers != pool.size() {
                return Err(Error::Coordinator(format!(
                    "serving executor owns a {}-thread pool; jobs must use workers = {} (got {})",
                    pool.size(),
                    pool.size(),
                    opts.workers
                )));
            }
        }
        Ok(())
    }

    fn fleet(&self) -> Fleet<'_> {
        match &self.pool {
            Some(pool) => Fleet::Pool(pool),
            None => Fleet::Scoped,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Job;
    use crate::testing::assert_allclose;

    fn pipeline(x: &Tensor<f32>) -> Plan<'_> {
        Plan::over(x)
            .gaussian(&[3, 3], 1.0)
            .curvature(&[3, 3])
            .median(&[3, 3])
    }

    #[test]
    fn persistent_matches_one_shot_bit_for_bit() {
        let x = Tensor::random(&[20, 21], 0.0, 255.0, 17).unwrap();
        let opts = ExecOptions::native(3);
        let (reference, _) = pipeline(&x).run(&opts).unwrap();
        let exec = Executor::persistent(opts, 8);
        let (served, _) = exec.run(pipeline(&x)).unwrap();
        assert_allclose(served.data(), reference.data(), 0.0, 0.0);
    }

    #[test]
    fn repeat_jobs_hit_the_cache_and_build_nothing() {
        let x = Tensor::random(&[16, 17], 0.0, 255.0, 23).unwrap();
        let exec = Executor::persistent(ExecOptions::native(2), 8);
        let (_, first) = exec.run(pipeline(&x)).unwrap();
        assert_eq!(first.plan_cache_misses(), 1);
        assert!(first.gathers_built() >= 3, "one gather per stage");
        let (_, second) = exec.run(pipeline(&x)).unwrap();
        assert_eq!(second.plan_cache_hits(), 1);
        assert_eq!(second.plan_cache_misses(), 0);
        assert_eq!(second.gathers_built(), 0, "repeat traffic melts nothing");
        let stats = exec.cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1));
        assert!(stats.resident_bytes > 0);
    }

    #[test]
    fn unfused_jobs_also_cache_per_group() {
        // legacy per-stage driver: each stage is its own group/key
        let x = Tensor::random(&[12, 12], 0.0, 255.0, 29).unwrap();
        let exec = Executor::one_shot(ExecOptions::native(2));
        let jobs = [Job::gaussian(&[3, 3], 1.0), Job::median(&[3, 3])];
        for pass in 0..2 {
            let mut metrics = Vec::new();
            let mut cur = x.clone();
            for j in &jobs {
                let stage = j.to_stage().unwrap();
                let plan = Plan::over(&cur).stage(stage);
                let (out, pm) = exec.run(plan).unwrap();
                metrics.push(pm);
                cur = out;
            }
            let built: usize = metrics.iter().map(|m| m.gathers_built()).sum();
            if pass == 0 {
                assert_eq!(built, 2);
            } else {
                assert_eq!(built, 0);
            }
        }
    }

    #[test]
    fn batched_runs_match_singletons_and_cache_like_any_plan() {
        let stages: Vec<Stage> = [
            Job::gaussian(&[3, 3], 1.0),
            Job::curvature(&[3, 3]),
            Job::median(&[3, 3]),
        ]
        .iter()
        .map(|j| j.to_stage().unwrap())
        .collect();
        let inputs: Vec<Tensor<f32>> = (0..3)
            .map(|s| Tensor::random(&[18, 19], 0.0, 255.0, 40 + s).unwrap())
            .collect();
        let exec = Executor::persistent(ExecOptions::native(2), 8);
        let (outs, pm) = exec.run_batch(&inputs, &stages).unwrap();
        // one plan lookup (a miss on the cold cache), one fused fold for
        // the whole batch
        assert_eq!(pm.melts(), 1);
        assert_eq!(pm.folds(), 1);
        assert_eq!(pm.batched_jobs(), 3);
        assert_eq!(pm.plan_cache_misses(), 1);
        for (out, x) in outs.iter().zip(&inputs) {
            let (reference, _) = pipeline(x).run(&ExecOptions::native(1)).unwrap();
            assert_allclose(out.data(), reference.data(), 0.0, 0.0);
        }
        // a second batch of the same shape and size reuses the plan
        let (_, again) = exec.run_batch(&inputs, &stages).unwrap();
        assert_eq!(again.plan_cache_hits(), 1);
        assert_eq!(again.gathers_built(), 0);
    }

    #[test]
    fn worker_count_mismatch_is_rejected() {
        let x = Tensor::random(&[10, 10], 0.0, 1.0, 31).unwrap();
        let exec = Executor::persistent(ExecOptions::native(2), 4);
        let mut opts = exec.options().clone();
        opts.workers = 3;
        let err = exec.run_with(pipeline(&x), &opts).unwrap_err();
        assert!(err.to_string().contains("workers"), "{err}");
    }

    #[test]
    fn failed_job_leaves_pool_and_cache_healthy() {
        let x = Tensor::random(&[14, 15], 0.0, 255.0, 37).unwrap();
        let exec = Executor::persistent(ExecOptions::native(2), 8);
        // a plan whose builder defers an error: run fails, nothing breaks
        let bad = Plan::over(&x).gaussian(&[0, 0], 1.0);
        assert!(exec.run(bad).is_err());
        let (out, pm) = exec.run(pipeline(&x)).unwrap();
        let (reference, _) = pipeline(&x).run(&ExecOptions::native(1)).unwrap();
        assert_allclose(out.data(), reference.data(), 0.0, 0.0);
        assert_eq!(pm.plan_cache_misses(), 1);
    }
}
