//! Line-delimited JSON request protocol for the serving daemon.
//!
//! Each request is one line of JSON; each response is one line of JSON.
//! A job request names an input spec and an ordered job list (the same
//! catalogue `meltframe run` configs use), plus optional per-job
//! overrides for the knobs that participate in the plan-cache key
//! (`halo_mode`, `tile_rows`). Control requests select on `"op"`:
//!
//! ```json
//! {"id": "j1", "input": {"kind": "image", "dims": [64, 64], "seed": 7},
//!  "jobs": [{"kind": "gaussian", "window": [3, 3], "sigma": 1.0}]}
//! {"op": "ping"}
//! {"op": "stats"}
//! {"op": "shutdown"}
//! ```
//!
//! Responses carry `"ok"` plus either a result digest (fowler–noll–vo
//! over the output bits, so bit-for-bit equality with one-shot runs is
//! checkable from outside the process), the output shape, and a metrics
//! object in the `BENCH_*.json` schema — or an `"error"` string. A
//! request may also carry a `"fault"` spec that splices a detonating
//! kernel into the pipeline (the fault-injection layer's pattern), used
//! by the smoke tests to prove a poisoned job fails alone.

use crate::sync::atomic::{AtomicUsize, Ordering};

use crate::bench_harness::JsonReport;
use crate::config::json::JsonValue;
use crate::config::spec::InputSpec;
use crate::coordinator::halo::HaloMode;
use crate::coordinator::job::Job;
use crate::coordinator::kernel::RowKernel;
use crate::coordinator::plan::{Plan, Stage};
use crate::error::{Error, Result};
use crate::serve::executor::Executor;
use crate::testing::value_digest;

/// One parsed request line.
#[derive(Debug)]
pub enum Request {
    /// Execute a job pipeline and stream back digest + metrics.
    Run(Box<JobRequest>),
    /// Liveness probe.
    Ping,
    /// Cache + queue statistics snapshot.
    Stats,
    /// Drain pending jobs, then stop the daemon.
    Shutdown,
}

/// How an injected fault detonates (mirrors the fault-injection tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// The kernel returns `Err` mid-stage.
    Error,
    /// The kernel panics mid-stage.
    Panic,
}

/// A detonating-kernel spec spliced after the requested jobs.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    pub mode: FaultMode,
    /// Kernel calls before detonation (0 = first call).
    pub after: usize,
}

/// A fully parsed job request.
#[derive(Debug)]
pub struct JobRequest {
    pub id: String,
    pub input: InputSpec,
    pub jobs: Vec<Job>,
    /// Override the daemon's halo mode for this job (cache-key relevant).
    pub halo_mode: Option<HaloMode>,
    /// Override the native tile height for this job (cache-key relevant).
    pub tile_rows: Option<usize>,
    pub fault: Option<FaultSpec>,
    /// Client tag for the queue's per-client fairness lanes: requests
    /// sharing a tag share one round-robin lane. Absent ⇒ the daemon
    /// assigns a per-connection lane.
    pub client: Option<String>,
}

impl JobRequest {
    /// Input shape, when it is knowable without touching the filesystem
    /// (`None` for `npy` inputs — those never co-batch).
    fn input_dims(&self) -> Option<Vec<usize>> {
        match &self.input {
            InputSpec::SyntheticVolume { dims, .. } => Some(dims.clone()),
            InputSpec::SyntheticImage { dims, .. } => Some(dims.to_vec()),
            InputSpec::SegmentationMask { dims } => Some(dims.to_vec()),
            InputSpec::Npy { .. } => None,
        }
    }

    /// The co-batching key: requests may share one stacked fold only when
    /// these match. Deliberately **stricter** than the plan-cache key —
    /// the cache keys on kernel *names* (a gaussian σ=1 and σ=2 share a
    /// `RowGather` plan), but co-batched requests share one kernel
    /// instance, so the full job serialization (kind, params, window,
    /// grid, boundary) participates here, alongside the input shape and
    /// the resolved halo-mode/tile-height overrides. `None` means "never
    /// co-batch": faulted requests (their detonating kernel must fail
    /// alone) and file-backed inputs.
    pub fn batch_key(&self, opts: &crate::coordinator::pipeline::ExecOptions) -> Option<String> {
        if self.fault.is_some() {
            return None;
        }
        let dims = self.input_dims()?;
        let halo = self.halo_mode.unwrap_or(opts.halo_mode);
        let tile = self.tile_rows.unwrap_or(opts.tile_rows).max(1);
        Some(format!(
            "dims{:?}|jobs{:?}|halo={:?}|tile={}",
            dims, self.jobs, halo, tile
        ))
    }
}

/// FNV-1a over a client tag: the fairness-lane id for tagged requests.
pub(crate) fn client_lane(tag: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in tag.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

fn opt<'a>(v: &'a JsonValue, key: &str) -> Option<&'a JsonValue> {
    v.as_object().ok().and_then(|m| m.get(key))
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = JsonValue::parse(line)?;
    if let Some(op) = opt(&v, "op") {
        return match op.as_str()? {
            "ping" => Ok(Request::Ping),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            "run" => Ok(Request::Run(Box::new(parse_job_request(&v)?))),
            other => Err(Error::Format(format!(
                "unknown op '{other}' (run|ping|stats|shutdown)"
            ))),
        };
    }
    // no "op" ⇒ a bare job request
    Ok(Request::Run(Box::new(parse_job_request(&v)?)))
}

fn parse_job_request(v: &JsonValue) -> Result<JobRequest> {
    let id = v.field("id")?.as_str()?.to_string();
    let input = parse_input(v.field("input")?)?;
    let jobs = v
        .field("jobs")?
        .as_array()?
        .iter()
        .map(parse_job)
        .collect::<Result<Vec<_>>>()?;
    if jobs.is_empty() {
        return Err(Error::Format("request has an empty job list".into()));
    }
    let halo_mode = opt(v, "halo_mode")
        .map(|h| HaloMode::parse(h.as_str()?))
        .transpose()?;
    let tile_rows = match opt(v, "tile_rows").map(|t| t.as_usize()).transpose()? {
        Some(0) => return Err(Error::Format("tile_rows must be >= 1".into())),
        other => other,
    };
    let fault = opt(v, "fault").map(parse_fault).transpose()?;
    let client = opt(v, "client")
        .map(|c| c.as_str().map(str::to_string))
        .transpose()?;
    Ok(JobRequest {
        id,
        input,
        jobs,
        halo_mode,
        tile_rows,
        fault,
        client,
    })
}

fn parse_input(v: &JsonValue) -> Result<InputSpec> {
    let kind = v.field("kind")?.as_str()?;
    let seed = opt(v, "seed").map(|s| s.as_usize()).transpose()?.unwrap_or(42) as u64;
    match kind {
        "volume" => Ok(InputSpec::SyntheticVolume {
            dims: v.field("dims")?.as_usize_vec()?,
            seed,
        }),
        "image" => {
            let dims = v.field("dims")?.as_usize_vec()?;
            if dims.len() != 2 {
                return Err(Error::Format(format!("image dims must be 2-D: {dims:?}")));
            }
            Ok(InputSpec::SyntheticImage {
                dims: [dims[0], dims[1]],
                seed,
            })
        }
        "mask" => {
            let dims = v.field("dims")?.as_usize_vec()?;
            if dims.len() != 2 {
                return Err(Error::Format(format!("mask dims must be 2-D: {dims:?}")));
            }
            Ok(InputSpec::SegmentationMask {
                dims: [dims[0], dims[1]],
            })
        }
        "npy" => Ok(InputSpec::Npy {
            path: v.field("path")?.as_str()?.into(),
        }),
        other => Err(Error::Format(format!(
            "unknown input kind '{other}' (volume|image|mask|npy)"
        ))),
    }
}

fn parse_job(v: &JsonValue) -> Result<Job> {
    let kind = v.field("kind")?.as_str()?;
    let window = v.field("window")?.as_usize_vec()?;
    let getf = |key: &str| -> Result<f32> { Ok(v.field(key)?.as_f64()? as f32) };
    let job = match kind {
        "gaussian" => Job::gaussian(&window, getf("sigma")?),
        "bilateral_const" => Job::bilateral_const(&window, getf("sigma_d")?, getf("sigma_r")?),
        "bilateral_adaptive" => Job::bilateral_adaptive(&window, getf("sigma_d")?, getf("floor")?),
        "curvature" => Job::curvature(&window),
        "median" => Job::median(&window),
        "quantile" => Job::quantile(&window, v.field("q")?.as_f64()?),
        "minimum" => Job::rank_min(&window),
        "maximum" => Job::rank_max(&window),
        "local_mean" => Job::local_mean(&window),
        "local_std" => Job::local_std(&window),
        other => {
            return Err(Error::Format(format!(
                "unknown job kind '{other}' (gaussian|bilateral_const|bilateral_adaptive|\
                 curvature|median|quantile|minimum|maximum|local_mean|local_std)"
            )))
        }
    };
    job.operator()?; // validate at parse time, like the config path
    Ok(job)
}

fn parse_fault(v: &JsonValue) -> Result<FaultSpec> {
    let mode = match v.field("mode")?.as_str()? {
        "error" => FaultMode::Error,
        "panic" => FaultMode::Panic,
        other => {
            return Err(Error::Format(format!(
                "unknown fault mode '{other}' (error|panic)"
            )))
        }
    };
    Ok(FaultSpec {
        mode,
        after: opt(v, "after").map(|a| a.as_usize()).transpose()?.unwrap_or(0),
    })
}

/// A kernel that behaves as identity (window all-ones) until its call
/// counter reaches the threshold, then detonates — the fault-injection
/// layer's pattern, reachable over the wire for smoke tests.
#[derive(Debug)]
struct FaultyKernel {
    spec: FaultSpec,
    calls: AtomicUsize,
}

impl RowKernel for FaultyKernel {
    fn name(&self) -> &str {
        "injected-fault"
    }

    fn execute(&self, block: &[f32], rows: usize, cols: usize, out: &mut [f32]) -> Result<()> {
        if self.calls.fetch_add(1, Ordering::SeqCst) >= self.spec.after {
            match self.spec.mode {
                FaultMode::Panic => panic!("injected fault: kernel panicked mid-stage"),
                FaultMode::Error => {
                    return Err(Error::Coordinator("injected failure: kernel error".into()))
                }
            }
        }
        for r in 0..rows {
            out[r] = block[r * cols + cols / 2];
        }
        Ok(())
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The failure response line for request `id`.
pub fn error_response(id: &str, error: &str) -> String {
    format!(
        "{{\"id\": \"{}\", \"ok\": false, \"error\": \"{}\"}}",
        json_escape(id),
        json_escape(error)
    )
}

/// Execute a parsed job request on `exec` and render the response line.
/// Never panics and never errors — every failure becomes an `"ok": false`
/// line scoped to this request, leaving the executor healthy.
pub fn execute_request(req: &JobRequest, exec: &Executor) -> String {
    match run_request(req, exec) {
        Ok(line) => line,
        Err(e) => error_response(&req.id, &e.to_string()),
    }
}

fn run_request(req: &JobRequest, exec: &Executor) -> Result<String> {
    let x = req.input.load()?;
    let mut plan = Plan::over(&x);
    for job in &req.jobs {
        plan = plan.stage(job.to_stage()?);
    }
    if let Some(fault) = req.fault {
        let rank = x.shape().len();
        let kernel = FaultyKernel {
            spec: fault,
            calls: AtomicUsize::new(0),
        };
        plan = plan.stage(Stage::new(std::sync::Arc::new(kernel), &vec![1; rank])?);
    }

    let mut opts = exec.options().clone();
    if let Some(mode) = req.halo_mode {
        opts.halo_mode = mode;
    }
    if let Some(tile) = req.tile_rows {
        opts.tile_rows = tile;
    }
    let (out, pm) = exec.run_with(plan, &opts)?;
    Ok(render_ok(req, &out, &pm))
}

/// Render the success line for `req`: digest, shape, and the metrics
/// object shared between singleton and batched execution (a batched
/// response reports the whole batch's plan counters, so `batched_jobs`
/// says how many requests amortized them).
fn render_ok(
    req: &JobRequest,
    out: &crate::tensor::dense::Tensor<f32>,
    pm: &crate::coordinator::metrics::PlanMetrics,
) -> String {
    let mut report = JsonReport::new(format!("serve:{}", req.id));
    report.metric("stages", pm.stages() as f64);
    report.metric("melts", pm.melts() as f64);
    report.metric("folds", pm.folds() as f64);
    report.metric("total_secs", pm.total().as_secs_f64());
    report.metric("gather_rows", pm.gather_rows() as f64);
    report.metric("plan_cache_hits", pm.plan_cache_hits() as f64);
    report.metric("plan_cache_misses", pm.plan_cache_misses() as f64);
    report.metric("plan_cache_evictions", pm.plan_cache_evictions() as f64);
    report.metric("gathers_built", pm.gathers_built() as f64);
    report.metric("batched_jobs", pm.batched_jobs() as f64);

    let shape = out
        .shape()
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    format!(
        "{{\"id\": \"{}\", \"ok\": true, \"digest\": \"{:016x}\", \"shape\": [{}], \
         \"metrics\": {}}}",
        json_escape(&req.id),
        value_digest(out.data()),
        shape,
        report.render_line()
    )
}

/// Execute a batch of co-batchable requests as ONE stacked fold and
/// render one response line per member, in order. Falls back to
/// per-member [`execute_request`] singletons — each of which fails or
/// succeeds alone — whenever the batch cannot or should not run stacked:
/// fewer than 2 members, any member without a batch key or with a key
/// mismatch (collector bug), or a batched run that errors or panics.
/// Like `execute_request`, never panics and never errors.
pub fn execute_batch(reqs: &[&JobRequest], exec: &Executor) -> Vec<String> {
    let singletons = |reqs: &[&JobRequest]| -> Vec<String> {
        reqs.iter().map(|r| execute_request(r, exec)).collect()
    };
    if reqs.len() < 2 {
        return singletons(reqs);
    }
    let key0 = reqs[0].batch_key(exec.options());
    if key0.is_none() || reqs.iter().any(|r| r.batch_key(exec.options()) != key0) {
        return singletons(reqs);
    }
    let batched = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_batch(reqs, exec)));
    match batched {
        Ok(Ok(lines)) => lines,
        // a faulting batch fails over to singletons: every member re-runs
        // alone, so only the actually-broken one answers with an error
        // and the pool and cache stay healthy
        _ => singletons(reqs),
    }
}

fn run_batch(reqs: &[&JobRequest], exec: &Executor) -> Result<Vec<String>> {
    let inputs = reqs
        .iter()
        .map(|r| r.input.load())
        .collect::<Result<Vec<_>>>()?;
    let stages = reqs[0]
        .jobs
        .iter()
        .map(|j| j.to_stage())
        .collect::<Result<Vec<_>>>()?;
    let mut opts = exec.options().clone();
    if let Some(mode) = reqs[0].halo_mode {
        opts.halo_mode = mode;
    }
    if let Some(tile) = reqs[0].tile_rows {
        opts.tile_rows = tile;
    }
    let (outs, pm) = exec.run_batch_with(&inputs, &stages, &opts)?;
    Ok(reqs
        .iter()
        .zip(&outs)
        .map(|(r, out)| render_ok(r, out, &pm))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::ExecOptions;

    const JOB: &str = r#"{"id": "j1",
        "input": {"kind": "image", "dims": [20, 21], "seed": 7},
        "jobs": [{"kind": "gaussian", "window": [3, 3], "sigma": 1.0},
                 {"kind": "median", "window": [3, 3]}]}"#;

    #[test]
    fn parses_job_request() {
        let req = match parse_request(JOB).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        assert_eq!(req.id, "j1");
        assert_eq!(req.jobs.len(), 2);
        assert!(matches!(req.input, InputSpec::SyntheticImage { .. }));
        assert!(req.halo_mode.is_none() && req.tile_rows.is_none() && req.fault.is_none());
    }

    #[test]
    fn parses_ops_and_overrides() {
        assert!(matches!(parse_request(r#"{"op": "ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(parse_request(r#"{"op": "stats"}"#).unwrap(), Request::Stats));
        assert!(matches!(
            parse_request(r#"{"op": "shutdown"}"#).unwrap(),
            Request::Shutdown
        ));
        let line = JOB.replace(
            "\"id\": \"j1\",",
            "\"id\": \"j1\", \"halo_mode\": \"exchange\", \"tile_rows\": 64, \
             \"fault\": {\"mode\": \"panic\", \"after\": 2},",
        );
        let req = match parse_request(&line).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        assert_eq!(req.halo_mode, Some(HaloMode::Exchange));
        assert_eq!(req.tile_rows, Some(64));
        let fault = req.fault.unwrap();
        assert_eq!((fault.mode, fault.after), (FaultMode::Panic, 2));
    }

    #[test]
    fn rejects_bad_requests() {
        // tile_rows = 0 would spin the tile loop — refuse at parse time
        let zero_tile = JOB.replace("\"id\": \"j1\",", "\"id\": \"j1\", \"tile_rows\": 0,");
        assert!(parse_request(&zero_tile)
            .unwrap_err()
            .to_string()
            .contains("tile_rows"));
        assert!(parse_request(r#"{"op": "dance"}"#).is_err());
        let empty_jobs = r#"{"id": "x", "input": {"kind": "image", "dims": [8, 8]}, "jobs": []}"#;
        assert!(parse_request(empty_jobs).is_err());
        assert!(parse_request("not json").is_err());
        // invalid kernel params are caught at parse time, like configs
        let bad_sigma = JOB.replace("\"sigma\": 1.0", "\"sigma\": -1.0");
        assert!(parse_request(&bad_sigma).is_err());
    }

    #[test]
    fn execute_matches_one_shot_digest() {
        let req = match parse_request(JOB).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        let exec = Executor::one_shot(ExecOptions::native(2));
        let line = execute_request(&req, &exec);
        let v = JsonValue::parse(&line).unwrap();
        assert_eq!(v.field("ok").unwrap(), &JsonValue::Bool(true));

        // reference: the same pipeline straight through Plan::run
        let x = req.input.load().unwrap();
        let (reference, _) = crate::coordinator::plan::Plan::over(&x)
            .gaussian(&[3, 3], 1.0)
            .median(&[3, 3])
            .run(&ExecOptions::native(2))
            .unwrap();
        let expected = format!("{:016x}", value_digest(reference.data()));
        assert_eq!(v.field("digest").unwrap().as_str().unwrap(), expected);
        assert_eq!(v.field("shape").unwrap().as_usize_vec().unwrap(), vec![20, 21]);
        let counters = v.field("metrics").unwrap().field("metrics").unwrap();
        assert!(counters.field("stages").unwrap().as_f64().unwrap() >= 2.0);
    }

    fn parse_run(line: &str) -> JobRequest {
        match parse_request(line).unwrap() {
            Request::Run(r) => *r,
            other => panic!("expected run, got {other:?}"),
        }
    }

    #[test]
    fn client_tag_parses_and_hashes_stably() {
        let req = parse_run(JOB);
        assert!(req.client.is_none());
        let line = JOB.replace("\"id\": \"j1\",", "\"id\": \"j1\", \"client\": \"tenant-a\",");
        let req = parse_run(&line);
        assert_eq!(req.client.as_deref(), Some("tenant-a"));
        assert_eq!(client_lane("tenant-a"), client_lane("tenant-a"));
        assert_ne!(client_lane("tenant-a"), client_lane("tenant-b"));
    }

    #[test]
    fn batch_keys_gate_co_batching() {
        let opts = ExecOptions::native(2);
        let a = parse_run(JOB);
        // a different id and a different seed still co-batch: only the
        // shape and the op chain matter, not the data
        let b = parse_run(
            &JOB.replace("\"id\": \"j1\"", "\"id\": \"j2\"")
                .replace("\"seed\": 7", "\"seed\": 8"),
        );
        assert_eq!(a.batch_key(&opts), b.batch_key(&opts));
        assert!(a.batch_key(&opts).is_some());
        // same plan-cache key (kernel *name*), different σ — the batch
        // key is stricter and keeps them apart
        let hot = parse_run(&JOB.replace("\"sigma\": 1.0", "\"sigma\": 2.0"));
        assert_ne!(a.batch_key(&opts), hot.batch_key(&opts));
        // shape differences separate batches
        let big = parse_run(&JOB.replace("[20, 21]", "[22, 21]"));
        assert_ne!(a.batch_key(&opts), big.batch_key(&opts));
        // faulted requests never co-batch (the detonator must fail alone)
        let boom = parse_run(&JOB.replace(
            "\"id\": \"j1\",",
            "\"id\": \"boom\", \"fault\": {\"mode\": \"error\", \"after\": 0},",
        ));
        assert!(boom.batch_key(&opts).is_none());
        // a halo-mode override resolves against the daemon default: the
        // overridden request only matches executors already in that mode
        let ex = parse_run(&JOB.replace(
            "\"id\": \"j1\",",
            "\"id\": \"j1\", \"halo_mode\": \"exchange\",",
        ));
        assert_ne!(a.batch_key(&opts), ex.batch_key(&opts));
        let mut exopts = opts.clone();
        exopts.halo_mode = HaloMode::Exchange;
        assert_eq!(a.batch_key(&exopts), ex.batch_key(&exopts));
    }

    #[test]
    fn batched_responses_match_singletons_digest_for_digest() {
        let exec = Executor::persistent(ExecOptions::native(2), 8);
        let reqs: Vec<JobRequest> = (0..3)
            .map(|i| {
                parse_run(
                    &JOB.replace("\"id\": \"j1\"", &format!("\"id\": \"b{i}\""))
                        .replace("\"seed\": 7", &format!("\"seed\": {}", 7 + i)),
                )
            })
            .collect();
        let refs: Vec<&JobRequest> = reqs.iter().collect();
        let lines = execute_batch(&refs, &exec);
        assert_eq!(lines.len(), 3);
        let solo = Executor::one_shot(ExecOptions::native(2));
        for (line, req) in lines.iter().zip(&reqs) {
            let v = JsonValue::parse(line).unwrap();
            assert_eq!(v.field("ok").unwrap(), &JsonValue::Bool(true));
            assert_eq!(v.field("id").unwrap().as_str().unwrap(), req.id);
            // bit-for-bit what this request's own singleton run digests
            let sv = JsonValue::parse(&execute_request(req, &solo)).unwrap();
            assert_eq!(
                v.field("digest").unwrap().as_str().unwrap(),
                sv.field("digest").unwrap().as_str().unwrap()
            );
            // the whole batch ran as one fold with one plan lookup
            let counters = v.field("metrics").unwrap().field("metrics").unwrap();
            assert_eq!(counters.field("batched_jobs").unwrap().as_f64().unwrap(), 3.0);
            assert_eq!(counters.field("folds").unwrap().as_f64().unwrap(), 1.0);
            assert_eq!(
                counters.field("plan_cache_hits").unwrap().as_f64().unwrap()
                    + counters.field("plan_cache_misses").unwrap().as_f64().unwrap(),
                1.0
            );
        }
    }

    #[test]
    fn mixed_batch_falls_back_to_singletons_and_fault_fails_alone() {
        // hand execute_batch a list a correct collector would never form
        // (a faulty member has no batch key): it must fall back to
        // singletons, poisoning only the faulty response
        let exec = Executor::persistent(ExecOptions::native(2), 8);
        let good = parse_run(&JOB.replace("\"id\": \"j1\"", "\"id\": \"g1\""));
        let boom = parse_run(&JOB.replace(
            "\"id\": \"j1\",",
            "\"id\": \"boom\", \"fault\": {\"mode\": \"panic\", \"after\": 0},",
        ));
        let good2 = parse_run(&JOB.replace("\"id\": \"j1\"", "\"id\": \"g2\""));
        let lines = execute_batch(&[&good, &boom, &good2], &exec);
        let oks: Vec<bool> = lines
            .iter()
            .map(|l| {
                JsonValue::parse(l).unwrap().field("ok").unwrap() == &JsonValue::Bool(true)
            })
            .collect();
        assert_eq!(oks, [true, false, true]);
        // singleton fallbacks report no batching
        let v = JsonValue::parse(&lines[0]).unwrap();
        let counters = v.field("metrics").unwrap().field("metrics").unwrap();
        assert_eq!(counters.field("batched_jobs").unwrap().as_f64().unwrap(), 0.0);
        // and the pool survives for the next job
        let after = execute_request(&good, &exec);
        let v = JsonValue::parse(&after).unwrap();
        assert_eq!(v.field("ok").unwrap(), &JsonValue::Bool(true));
    }

    #[test]
    fn faulted_request_fails_alone() {
        let line = JOB.replace(
            "\"id\": \"j1\",",
            "\"id\": \"boom\", \"fault\": {\"mode\": \"error\", \"after\": 0},",
        );
        let req = match parse_request(&line).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        let exec = Executor::persistent(ExecOptions::native(2), 8);
        let bad = execute_request(&req, &exec);
        let v = JsonValue::parse(&bad).unwrap();
        assert_eq!(v.field("ok").unwrap(), &JsonValue::Bool(false));
        assert!(v.field("error").unwrap().as_str().unwrap().contains("injected"));

        // the pool survives: a healthy request on the same executor succeeds
        let good = match parse_request(JOB).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        let ok = execute_request(&good, &exec);
        let v = JsonValue::parse(&ok).unwrap();
        assert_eq!(v.field("ok").unwrap(), &JsonValue::Bool(true));
    }
}
