//! Line-delimited JSON request protocol for the serving daemon.
//!
//! Each request is one line of JSON; each response is one line of JSON.
//! A job request names an input spec and an ordered job list (the same
//! catalogue `meltframe run` configs use), plus optional per-job
//! overrides for the knobs that participate in the plan-cache key
//! (`halo_mode`, `tile_rows`). Control requests select on `"op"`:
//!
//! ```json
//! {"id": "j1", "input": {"kind": "image", "dims": [64, 64], "seed": 7},
//!  "jobs": [{"kind": "gaussian", "window": [3, 3], "sigma": 1.0}]}
//! {"op": "ping"}
//! {"op": "stats"}
//! {"op": "shutdown"}
//! ```
//!
//! Responses carry `"ok"` plus either a result digest (fowler–noll–vo
//! over the output bits, so bit-for-bit equality with one-shot runs is
//! checkable from outside the process), the output shape, and a metrics
//! object in the `BENCH_*.json` schema — or an `"error"` string. A
//! request may also carry a `"fault"` spec that splices a detonating
//! kernel into the pipeline (the fault-injection layer's pattern), used
//! by the smoke tests to prove a poisoned job fails alone.

use crate::sync::atomic::{AtomicUsize, Ordering};

use crate::bench_harness::JsonReport;
use crate::config::json::JsonValue;
use crate::config::spec::InputSpec;
use crate::coordinator::halo::HaloMode;
use crate::coordinator::job::Job;
use crate::coordinator::kernel::RowKernel;
use crate::coordinator::plan::{Plan, Stage};
use crate::error::{Error, Result};
use crate::serve::executor::Executor;
use crate::testing::value_digest;

/// One parsed request line.
#[derive(Debug)]
pub enum Request {
    /// Execute a job pipeline and stream back digest + metrics.
    Run(Box<JobRequest>),
    /// Liveness probe.
    Ping,
    /// Cache + queue statistics snapshot.
    Stats,
    /// Drain pending jobs, then stop the daemon.
    Shutdown,
}

/// How an injected fault detonates (mirrors the fault-injection tests).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// The kernel returns `Err` mid-stage.
    Error,
    /// The kernel panics mid-stage.
    Panic,
}

/// A detonating-kernel spec spliced after the requested jobs.
#[derive(Clone, Copy, Debug)]
pub struct FaultSpec {
    pub mode: FaultMode,
    /// Kernel calls before detonation (0 = first call).
    pub after: usize,
}

/// A fully parsed job request.
#[derive(Debug)]
pub struct JobRequest {
    pub id: String,
    pub input: InputSpec,
    pub jobs: Vec<Job>,
    /// Override the daemon's halo mode for this job (cache-key relevant).
    pub halo_mode: Option<HaloMode>,
    /// Override the native tile height for this job (cache-key relevant).
    pub tile_rows: Option<usize>,
    pub fault: Option<FaultSpec>,
}

fn opt<'a>(v: &'a JsonValue, key: &str) -> Option<&'a JsonValue> {
    v.as_object().ok().and_then(|m| m.get(key))
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = JsonValue::parse(line)?;
    if let Some(op) = opt(&v, "op") {
        return match op.as_str()? {
            "ping" => Ok(Request::Ping),
            "stats" => Ok(Request::Stats),
            "shutdown" => Ok(Request::Shutdown),
            "run" => Ok(Request::Run(Box::new(parse_job_request(&v)?))),
            other => Err(Error::Format(format!(
                "unknown op '{other}' (run|ping|stats|shutdown)"
            ))),
        };
    }
    // no "op" ⇒ a bare job request
    Ok(Request::Run(Box::new(parse_job_request(&v)?)))
}

fn parse_job_request(v: &JsonValue) -> Result<JobRequest> {
    let id = v.field("id")?.as_str()?.to_string();
    let input = parse_input(v.field("input")?)?;
    let jobs = v
        .field("jobs")?
        .as_array()?
        .iter()
        .map(parse_job)
        .collect::<Result<Vec<_>>>()?;
    if jobs.is_empty() {
        return Err(Error::Format("request has an empty job list".into()));
    }
    let halo_mode = opt(v, "halo_mode")
        .map(|h| HaloMode::parse(h.as_str()?))
        .transpose()?;
    let tile_rows = match opt(v, "tile_rows").map(|t| t.as_usize()).transpose()? {
        Some(0) => return Err(Error::Format("tile_rows must be >= 1".into())),
        other => other,
    };
    let fault = opt(v, "fault").map(parse_fault).transpose()?;
    Ok(JobRequest {
        id,
        input,
        jobs,
        halo_mode,
        tile_rows,
        fault,
    })
}

fn parse_input(v: &JsonValue) -> Result<InputSpec> {
    let kind = v.field("kind")?.as_str()?;
    let seed = opt(v, "seed").map(|s| s.as_usize()).transpose()?.unwrap_or(42) as u64;
    match kind {
        "volume" => Ok(InputSpec::SyntheticVolume {
            dims: v.field("dims")?.as_usize_vec()?,
            seed,
        }),
        "image" => {
            let dims = v.field("dims")?.as_usize_vec()?;
            if dims.len() != 2 {
                return Err(Error::Format(format!("image dims must be 2-D: {dims:?}")));
            }
            Ok(InputSpec::SyntheticImage {
                dims: [dims[0], dims[1]],
                seed,
            })
        }
        "mask" => {
            let dims = v.field("dims")?.as_usize_vec()?;
            if dims.len() != 2 {
                return Err(Error::Format(format!("mask dims must be 2-D: {dims:?}")));
            }
            Ok(InputSpec::SegmentationMask {
                dims: [dims[0], dims[1]],
            })
        }
        "npy" => Ok(InputSpec::Npy {
            path: v.field("path")?.as_str()?.into(),
        }),
        other => Err(Error::Format(format!(
            "unknown input kind '{other}' (volume|image|mask|npy)"
        ))),
    }
}

fn parse_job(v: &JsonValue) -> Result<Job> {
    let kind = v.field("kind")?.as_str()?;
    let window = v.field("window")?.as_usize_vec()?;
    let getf = |key: &str| -> Result<f32> { Ok(v.field(key)?.as_f64()? as f32) };
    let job = match kind {
        "gaussian" => Job::gaussian(&window, getf("sigma")?),
        "bilateral_const" => Job::bilateral_const(&window, getf("sigma_d")?, getf("sigma_r")?),
        "bilateral_adaptive" => Job::bilateral_adaptive(&window, getf("sigma_d")?, getf("floor")?),
        "curvature" => Job::curvature(&window),
        "median" => Job::median(&window),
        "quantile" => Job::quantile(&window, v.field("q")?.as_f64()?),
        "minimum" => Job::rank_min(&window),
        "maximum" => Job::rank_max(&window),
        "local_mean" => Job::local_mean(&window),
        "local_std" => Job::local_std(&window),
        other => {
            return Err(Error::Format(format!(
                "unknown job kind '{other}' (gaussian|bilateral_const|bilateral_adaptive|\
                 curvature|median|quantile|minimum|maximum|local_mean|local_std)"
            )))
        }
    };
    job.operator()?; // validate at parse time, like the config path
    Ok(job)
}

fn parse_fault(v: &JsonValue) -> Result<FaultSpec> {
    let mode = match v.field("mode")?.as_str()? {
        "error" => FaultMode::Error,
        "panic" => FaultMode::Panic,
        other => {
            return Err(Error::Format(format!(
                "unknown fault mode '{other}' (error|panic)"
            )))
        }
    };
    Ok(FaultSpec {
        mode,
        after: opt(v, "after").map(|a| a.as_usize()).transpose()?.unwrap_or(0),
    })
}

/// A kernel that behaves as identity (window all-ones) until its call
/// counter reaches the threshold, then detonates — the fault-injection
/// layer's pattern, reachable over the wire for smoke tests.
#[derive(Debug)]
struct FaultyKernel {
    spec: FaultSpec,
    calls: AtomicUsize,
}

impl RowKernel for FaultyKernel {
    fn name(&self) -> &str {
        "injected-fault"
    }

    fn execute(&self, block: &[f32], rows: usize, cols: usize, out: &mut [f32]) -> Result<()> {
        if self.calls.fetch_add(1, Ordering::SeqCst) >= self.spec.after {
            match self.spec.mode {
                FaultMode::Panic => panic!("injected fault: kernel panicked mid-stage"),
                FaultMode::Error => {
                    return Err(Error::Coordinator("injected failure: kernel error".into()))
                }
            }
        }
        for r in 0..rows {
            out[r] = block[r * cols + cols / 2];
        }
        Ok(())
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The failure response line for request `id`.
pub fn error_response(id: &str, error: &str) -> String {
    format!(
        "{{\"id\": \"{}\", \"ok\": false, \"error\": \"{}\"}}",
        json_escape(id),
        json_escape(error)
    )
}

/// Execute a parsed job request on `exec` and render the response line.
/// Never panics and never errors — every failure becomes an `"ok": false`
/// line scoped to this request, leaving the executor healthy.
pub fn execute_request(req: &JobRequest, exec: &Executor) -> String {
    match run_request(req, exec) {
        Ok(line) => line,
        Err(e) => error_response(&req.id, &e.to_string()),
    }
}

fn run_request(req: &JobRequest, exec: &Executor) -> Result<String> {
    let x = req.input.load()?;
    let mut plan = Plan::over(&x);
    for job in &req.jobs {
        plan = plan.stage(job.to_stage()?);
    }
    if let Some(fault) = req.fault {
        let rank = x.shape().len();
        let kernel = FaultyKernel {
            spec: fault,
            calls: AtomicUsize::new(0),
        };
        plan = plan.stage(Stage::new(std::sync::Arc::new(kernel), &vec![1; rank])?);
    }

    let mut opts = exec.options().clone();
    if let Some(mode) = req.halo_mode {
        opts.halo_mode = mode;
    }
    if let Some(tile) = req.tile_rows {
        opts.tile_rows = tile;
    }
    let (out, pm) = exec.run_with(plan, &opts)?;

    let mut report = JsonReport::new(format!("serve:{}", req.id));
    report.metric("stages", pm.stages() as f64);
    report.metric("melts", pm.melts() as f64);
    report.metric("folds", pm.folds() as f64);
    report.metric("total_secs", pm.total().as_secs_f64());
    report.metric("gather_rows", pm.gather_rows() as f64);
    report.metric("plan_cache_hits", pm.plan_cache_hits() as f64);
    report.metric("plan_cache_misses", pm.plan_cache_misses() as f64);
    report.metric("plan_cache_evictions", pm.plan_cache_evictions() as f64);
    report.metric("gathers_built", pm.gathers_built() as f64);

    let shape = out
        .shape()
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    Ok(format!(
        "{{\"id\": \"{}\", \"ok\": true, \"digest\": \"{:016x}\", \"shape\": [{}], \
         \"metrics\": {}}}",
        json_escape(&req.id),
        value_digest(out.data()),
        shape,
        report.render_line()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::ExecOptions;

    const JOB: &str = r#"{"id": "j1",
        "input": {"kind": "image", "dims": [20, 21], "seed": 7},
        "jobs": [{"kind": "gaussian", "window": [3, 3], "sigma": 1.0},
                 {"kind": "median", "window": [3, 3]}]}"#;

    #[test]
    fn parses_job_request() {
        let req = match parse_request(JOB).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        assert_eq!(req.id, "j1");
        assert_eq!(req.jobs.len(), 2);
        assert!(matches!(req.input, InputSpec::SyntheticImage { .. }));
        assert!(req.halo_mode.is_none() && req.tile_rows.is_none() && req.fault.is_none());
    }

    #[test]
    fn parses_ops_and_overrides() {
        assert!(matches!(parse_request(r#"{"op": "ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(parse_request(r#"{"op": "stats"}"#).unwrap(), Request::Stats));
        assert!(matches!(
            parse_request(r#"{"op": "shutdown"}"#).unwrap(),
            Request::Shutdown
        ));
        let line = JOB.replace(
            "\"id\": \"j1\",",
            "\"id\": \"j1\", \"halo_mode\": \"exchange\", \"tile_rows\": 64, \
             \"fault\": {\"mode\": \"panic\", \"after\": 2},",
        );
        let req = match parse_request(&line).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        assert_eq!(req.halo_mode, Some(HaloMode::Exchange));
        assert_eq!(req.tile_rows, Some(64));
        let fault = req.fault.unwrap();
        assert_eq!((fault.mode, fault.after), (FaultMode::Panic, 2));
    }

    #[test]
    fn rejects_bad_requests() {
        // tile_rows = 0 would spin the tile loop — refuse at parse time
        let zero_tile = JOB.replace("\"id\": \"j1\",", "\"id\": \"j1\", \"tile_rows\": 0,");
        assert!(parse_request(&zero_tile)
            .unwrap_err()
            .to_string()
            .contains("tile_rows"));
        assert!(parse_request(r#"{"op": "dance"}"#).is_err());
        let empty_jobs = r#"{"id": "x", "input": {"kind": "image", "dims": [8, 8]}, "jobs": []}"#;
        assert!(parse_request(empty_jobs).is_err());
        assert!(parse_request("not json").is_err());
        // invalid kernel params are caught at parse time, like configs
        let bad_sigma = JOB.replace("\"sigma\": 1.0", "\"sigma\": -1.0");
        assert!(parse_request(&bad_sigma).is_err());
    }

    #[test]
    fn execute_matches_one_shot_digest() {
        let req = match parse_request(JOB).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        let exec = Executor::one_shot(ExecOptions::native(2));
        let line = execute_request(&req, &exec);
        let v = JsonValue::parse(&line).unwrap();
        assert_eq!(v.field("ok").unwrap(), &JsonValue::Bool(true));

        // reference: the same pipeline straight through Plan::run
        let x = req.input.load().unwrap();
        let (reference, _) = crate::coordinator::plan::Plan::over(&x)
            .gaussian(&[3, 3], 1.0)
            .median(&[3, 3])
            .run(&ExecOptions::native(2))
            .unwrap();
        let expected = format!("{:016x}", value_digest(reference.data()));
        assert_eq!(v.field("digest").unwrap().as_str().unwrap(), expected);
        assert_eq!(v.field("shape").unwrap().as_usize_vec().unwrap(), vec![20, 21]);
        let counters = v.field("metrics").unwrap().field("metrics").unwrap();
        assert!(counters.field("stages").unwrap().as_f64().unwrap() >= 2.0);
    }

    #[test]
    fn faulted_request_fails_alone() {
        let line = JOB.replace(
            "\"id\": \"j1\",",
            "\"id\": \"boom\", \"fault\": {\"mode\": \"error\", \"after\": 0},",
        );
        let req = match parse_request(&line).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        let exec = Executor::persistent(ExecOptions::native(2), 8);
        let bad = execute_request(&req, &exec);
        let v = JsonValue::parse(&bad).unwrap();
        assert_eq!(v.field("ok").unwrap(), &JsonValue::Bool(false));
        assert!(v.field("error").unwrap().as_str().unwrap().contains("injected"));

        // the pool survives: a healthy request on the same executor succeeds
        let good = match parse_request(JOB).unwrap() {
            Request::Run(r) => r,
            other => panic!("expected run, got {other:?}"),
        };
        let ok = execute_request(&good, &exec);
        let v = JsonValue::parse(&ok).unwrap();
        assert_eq!(v.field("ok").unwrap(), &JsonValue::Bool(true));
    }
}
