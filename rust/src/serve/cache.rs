//! LRU cache of planner output: resolved grids and `RowGather` tables.
//!
//! The paper's space-completeness decomposition makes this sound: a melt
//! plan is a pure function of `(shape, op-chain, grid, boundary)` — never
//! of the data — so a cached [`CachedGroupPlan`] replayed against new
//! tensors of the same key is bit-for-bit identical to building from
//! scratch (§2.4; pinned by `tests/integration_serve.rs`).
//!
//! ## Key contract
//!
//! [`PlanCache::key_for`] canonicalizes, per fusion group: the input
//! shape, each stage's kernel *name*, window, grid mode, and boundary
//! mode, plus the run's `halo_mode` and `tile_rows`. Kernel *parameters*
//! (a gaussian's sigma, a quantile's q) are deliberately excluded — the
//! gather tables are value-independent and the kernel object itself is
//! supplied fresh by each request — while the kernel name is included as
//! a conservative op-chain identity. `halo_mode`/`tile_rows` do not
//! change the tables either, but they are part of the serving contract's
//! key (a client changing them gets a fresh entry, keeping observed
//! metrics per-configuration honest). Worker count is *not* in the key: a
//! plan is valid for any fleet size. Changing any keyed field therefore
//! busts the cache; resubmitting an identical spec hits it.

use crate::sync::{Mutex, NamedMutex};

use crate::coordinator::pipeline::ExecOptions;
use crate::coordinator::plan::Stage;
use crate::error::Result;
use crate::melt::melt::RowGather;

/// The reusable, data-independent product of planning one fusion group:
/// everything `coordinator::exec` derives from the stage specs before the
/// first worker touches a value.
#[derive(Debug)]
pub struct CachedGroupPlan {
    /// One precomputed gather per stage (stage 0 reads the input tensor,
    /// stages `k ≥ 1` re-melt Same-grid value slabs).
    pub(crate) gathers: Vec<RowGather>,
    /// The group's output grid shape.
    pub(crate) grid_shape: Vec<usize>,
    /// Total melt rows.
    pub(crate) rows: usize,
    /// Per-stage melt columns (window ravel lengths).
    pub(crate) colsv: Vec<usize>,
    /// Per-stage flat halos (exchange mode).
    pub(crate) halos: Vec<usize>,
    /// Downstream halo budgets (recompute mode).
    pub(crate) budget: Vec<usize>,
}

impl CachedGroupPlan {
    /// Stages covered by this plan.
    pub fn stages(&self) -> usize {
        self.gathers.len()
    }

    /// Cache-resident bytes of the precomputed gather tables — the cost
    /// of keeping this plan warm (see the footprint model in `lib.rs`).
    pub fn bytes(&self) -> usize {
        self.gathers.iter().map(|g| g.table_bytes()).sum()
    }
}

#[derive(Default)]
struct CacheInner {
    /// `(key, plan)` in LRU order — least recently used first, most
    /// recently used last.
    entries: Vec<(String, std::sync::Arc<CachedGroupPlan>)>,
    hits: usize,
    misses: usize,
    evictions: usize,
}

/// What one lookup did to the cache — folded into the run's
/// [`RunMetrics`](crate::coordinator::RunMetrics) cache counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheDelta {
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
    /// `RowGather` tables built from scratch by this lookup.
    pub built: usize,
}

/// Point-in-time cache statistics for the daemon's `stats` endpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub evictions: usize,
    pub entries: usize,
    /// Total gather-table bytes resident across all entries.
    pub resident_bytes: usize,
}

/// A bounded, thread-safe LRU cache of [`CachedGroupPlan`]s.
pub struct PlanCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
}

impl PlanCache {
    /// Cache holding at most `capacity` plans (floored at 1).
    pub fn new(capacity: usize) -> Self {
        Self {
            inner: Mutex::new_named("serve.cache.plans", CacheInner::default()),
            capacity: capacity.max(1),
        }
    }

    /// Maximum entry count.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Canonical cache key for one fusion group — see the module-level key
    /// contract.
    pub fn key_for(shape: &[usize], stages: &[Stage], opts: &ExecOptions) -> String {
        use std::fmt::Write;
        let mut key = format!("shape{shape:?}");
        for s in stages {
            let _ = write!(
                key,
                "|{}:{:?}:{:?}:{:?}",
                s.kernel().name(),
                s.window(),
                s.grid(),
                s.boundary()
            );
        }
        let _ = write!(key, "|halo={}|tile={}", opts.halo_mode, opts.tile_rows.max(1));
        key
    }

    /// Look up `key`; on a miss, run `build` (outside the cache lock — a
    /// slow build never blocks other requests' hits) and insert the
    /// result, evicting the least recently used entry when over capacity.
    /// Returns the plan plus what the lookup did.
    pub fn get_or_build(
        &self,
        key: &str,
        build: impl FnOnce() -> Result<CachedGroupPlan>,
    ) -> Result<(std::sync::Arc<CachedGroupPlan>, CacheDelta)> {
        {
            let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(pos) = inner.entries.iter().position(|(k, _)| k == key) {
                // touch: move to the MRU end
                let entry = inner.entries.remove(pos);
                let plan = std::sync::Arc::clone(&entry.1);
                inner.entries.push(entry);
                inner.hits += 1;
                return Ok((
                    plan,
                    CacheDelta {
                        hits: 1,
                        ..Default::default()
                    },
                ));
            }
        }
        let plan = std::sync::Arc::new(build()?);
        let built = plan.stages();
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        // a racing request may have inserted the same key while we built;
        // keeping both copies would double-count residency, so last write
        // wins and the earlier entry is dropped without an eviction tick
        inner.entries.retain(|(k, _)| k != key);
        inner.entries.push((key.to_string(), std::sync::Arc::clone(&plan)));
        inner.misses += 1;
        let mut evictions = 0usize;
        while inner.entries.len() > self.capacity {
            inner.entries.remove(0);
            evictions += 1;
        }
        inner.evictions += evictions;
        Ok((
            plan,
            CacheDelta {
                misses: 1,
                evictions,
                built,
                ..Default::default()
            },
        ))
    }

    /// Current entry count.
    pub fn len(&self) -> usize {
        self.inner
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .entries
            .len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Keys in LRU order (least recently used first) — the eviction order.
    pub fn lru_keys(&self) -> Vec<String> {
        self.inner
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .entries
            .iter()
            .map(|(k, _)| k.clone())
            .collect()
    }

    /// Point-in-time statistics.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            entries: inner.entries.len(),
            resident_bytes: inner.entries.iter().map(|(_, p)| p.bytes()).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    fn tiny_plan() -> CachedGroupPlan {
        CachedGroupPlan {
            gathers: Vec::new(),
            grid_shape: vec![1],
            rows: 1,
            colsv: vec![1],
            halos: vec![0],
            budget: vec![0],
        }
    }

    #[test]
    fn hit_miss_and_counters() {
        let cache = PlanCache::new(4);
        let (_, d) = cache.get_or_build("a", || Ok(tiny_plan())).unwrap();
        assert_eq!((d.hits, d.misses, d.built), (0, 1, 1));
        let (_, d) = cache.get_or_build("a", || panic!("hit must not rebuild")).unwrap();
        assert_eq!((d.hits, d.misses, d.built), (1, 0, 0));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (1, 1, 0, 1));
    }

    #[test]
    fn build_errors_do_not_poison_or_insert() {
        let cache = PlanCache::new(2);
        let err = cache
            .get_or_build("bad", || Err(crate::error::Error::Coordinator("boom".into())))
            .unwrap_err();
        assert!(err.to_string().contains("boom"));
        assert!(cache.is_empty());
        // the cache still works after the failed build
        cache.get_or_build("good", || Ok(tiny_plan())).unwrap();
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn capacity_evicts_least_recently_used() {
        let cache = PlanCache::new(2);
        cache.get_or_build("a", || Ok(tiny_plan())).unwrap();
        cache.get_or_build("b", || Ok(tiny_plan())).unwrap();
        // touch "a" so "b" becomes LRU
        cache.get_or_build("a", || panic!("hit")).unwrap();
        let (_, d) = cache.get_or_build("c", || Ok(tiny_plan())).unwrap();
        assert_eq!(d.evictions, 1);
        assert_eq!(cache.lru_keys(), vec!["a".to_string(), "c".to_string()]);
        // "b" was evicted: looking it up again misses
        let (_, d) = cache.get_or_build("b", || Ok(tiny_plan())).unwrap();
        assert_eq!(d.misses, 1);
    }

    #[test]
    fn zero_capacity_floors_at_one() {
        let cache = PlanCache::new(0);
        assert_eq!(cache.capacity(), 1);
        cache.get_or_build("a", || Ok(tiny_plan())).unwrap();
        let (_, d) = cache.get_or_build("b", || Ok(tiny_plan())).unwrap();
        assert_eq!(d.evictions, 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn lru_eviction_order_property() {
        // model check: drive a random access sequence against a reference
        // list-based LRU; the cache's eviction order (lru_keys) and every
        // hit/miss must match the model at each step
        check_property("LRU eviction order", 40, |rng: &mut SplitMix64| {
            let capacity = 1 + rng.below(5);
            let universe = 2 + rng.below(8);
            let cache = PlanCache::new(capacity);
            let mut model: Vec<String> = Vec::new(); // LRU first
            for _ in 0..60 {
                let key = format!("k{}", rng.below(universe));
                let expect_hit = model.contains(&key);
                let (_, d) = cache.get_or_build(&key, || Ok(tiny_plan())).unwrap();
                if expect_hit {
                    assert_eq!((d.hits, d.misses), (1, 0), "key {key}");
                    model.retain(|k| k != &key);
                    model.push(key);
                } else {
                    assert_eq!((d.hits, d.misses), (0, 1), "key {key}");
                    model.push(key);
                    let mut evicted = 0;
                    while model.len() > capacity {
                        model.remove(0);
                        evicted += 1;
                    }
                    assert_eq!(d.evictions, evicted, "eviction count diverged");
                }
                assert_eq!(cache.lru_keys(), model, "LRU order diverged");
            }
        });
    }
}
