//! Measurement harness used by every `cargo bench` target (criterion is not
//! in the vendored crate set — DESIGN.md §Substitutions).
//!
//! Methodology mirrors the paper's Fig 6 protocol: fixed repetition count
//! (default 20, like the paper), explicit warmup, robust statistics
//! (median/IQR alongside mean/sd), and per-repetition samples kept so
//! benches can print beeswarm-style raw columns. Results render as a
//! markdown table and machine-readable CSV lines prefixed `CSV,` — and,
//! via [`JsonReport`], as a hand-rolled JSON document (serde is not
//! vendored either) so CI can archive the perf trajectory as an artifact.

use std::time::{Duration, Instant};

/// Samples of one measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub label: String,
    pub samples: Vec<Duration>,
}

impl Measurement {
    /// Time `f` for `reps` repetitions after `warmup` unrecorded runs.
    /// `f` returns a value that is black-boxed to keep the optimizer honest.
    pub fn run<T>(label: impl Into<String>, warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Self {
        for _ in 0..warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed());
        }
        Self {
            label: label.into(),
            samples,
        }
    }

    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn std_dev(&self) -> Duration {
        if self.samples.len() < 2 {
            return Duration::ZERO;
        }
        let mean = self.mean().as_secs_f64();
        let var = self
            .samples
            .iter()
            .map(|s| {
                let d = s.as_secs_f64() - mean;
                d * d
            })
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        Duration::from_secs_f64(var.sqrt())
    }

    fn sorted_secs(&self) -> Vec<f64> {
        let mut v: Vec<f64> = self.samples.iter().map(|s| s.as_secs_f64()).collect();
        v.sort_by(f64::total_cmp);
        v
    }

    pub fn median(&self) -> Duration {
        let v = self.sorted_secs();
        let n = v.len();
        let m = if n % 2 == 1 {
            v[n / 2]
        } else {
            (v[n / 2 - 1] + v[n / 2]) / 2.0
        };
        Duration::from_secs_f64(m)
    }

    /// (q1, q3) interquartile bounds.
    pub fn iqr(&self) -> (Duration, Duration) {
        let v = self.sorted_secs();
        let q = |p: f64| {
            let pos = p * (v.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            let w = pos - lo as f64;
            Duration::from_secs_f64(v[lo] * (1.0 - w) + v[hi] * w)
        };
        (q(0.25), q(0.75))
    }

    pub fn min(&self) -> Duration {
        self.samples.iter().min().copied().unwrap_or_default()
    }
}

/// Defeat constant folding without the unstable `std::hint::black_box`
/// semantics question — a volatile read through a pointer.
pub fn black_box<T>(x: T) -> T {
    // SAFETY: `&x` is a valid, aligned pointer to a live `T` for the
    // whole read; the original is forgotten (not dropped) after being
    // copied out, so no double-drop and no use-after-move.
    unsafe {
        let y = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        y
    }
}

/// A titled group of measurements with table/CSV rendering.
pub struct Report {
    title: String,
    rows: Vec<Measurement>,
}

impl Report {
    pub fn new(title: impl Into<String>) -> Self {
        Self {
            title: title.into(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, m: Measurement) {
        self.rows.push(m);
    }

    pub fn rows(&self) -> &[Measurement] {
        &self.rows
    }

    /// Render the markdown table + CSV lines to stdout. `baseline` (a label)
    /// adds a relative-speedup column.
    pub fn print(&self, baseline: Option<&str>) {
        println!("\n## {}\n", self.title);
        let base = baseline
            .and_then(|b| self.rows.iter().find(|m| m.label == b))
            .map(|m| m.median().as_secs_f64());
        println!("| case | mean | sd | median | q1 | q3 | min | speedup |");
        println!("|---|---|---|---|---|---|---|---|");
        for m in &self.rows {
            let (q1, q3) = m.iqr();
            let speedup = base
                .map(|b| format!("{:.2}x", b / m.median().as_secs_f64()))
                .unwrap_or_else(|| "-".into());
            println!(
                "| {} | {:.3?} | {:.3?} | {:.3?} | {:.3?} | {:.3?} | {:.3?} | {} |",
                m.label,
                m.mean(),
                m.std_dev(),
                m.median(),
                q1,
                q3,
                m.min(),
                speedup
            );
        }
        for m in &self.rows {
            let samples: Vec<String> = m
                .samples
                .iter()
                .map(|s| format!("{:.6}", s.as_secs_f64()))
                .collect();
            println!("CSV,{},{},{}", self.title, m.label, samples.join(","));
        }
    }
}

/// Machine-readable bench output: per-series timing (ns/op median, mean,
/// min, repetition count) plus free-form numeric metric totals (halo and
/// gather counters, footprint bytes, …), serialized as a small JSON
/// document by hand — the vendored crate set has no serde. Benches build
/// one per run and [`JsonReport::write`] it next to the crate (CI uploads
/// the file as a workflow artifact, e.g. `BENCH_fusion.json`).
pub struct JsonReport {
    name: String,
    series: Vec<(String, Measurement)>,
    metrics: Vec<(String, f64)>,
}

/// Escape a string for a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render an f64 as JSON: finite values print plainly, non-finite ones
/// (which JSON cannot represent) become null.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".into()
    }
}

impl JsonReport {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            series: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Record one timed series under `label` (labels should be unique;
    /// later duplicates simply appear twice in the array).
    pub fn series(&mut self, label: impl Into<String>, m: &Measurement) {
        self.series.push((label.into(), m.clone()));
    }

    /// Record one named metric total (counters, bytes, ratios).
    pub fn metric(&mut self, key: impl Into<String>, value: f64) {
        self.metrics.push((key.into(), value));
    }

    /// The JSON document text.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"name\": \"{}\",\n", json_escape(&self.name)));
        s.push_str("  \"series\": [\n");
        for (i, (label, m)) in self.series.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"label\": \"{}\", \"reps\": {}, \"ns_per_op_median\": {}, \
                 \"ns_per_op_mean\": {}, \"ns_per_op_min\": {}}}{}\n",
                json_escape(label),
                m.samples.len(),
                json_num(m.median().as_nanos() as f64),
                json_num(m.mean().as_nanos() as f64),
                json_num(m.min().as_nanos() as f64),
                if i + 1 < self.series.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"metrics\": {\n");
        for (i, (key, value)) in self.metrics.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {}{}\n",
                json_escape(key),
                json_num(*value),
                if i + 1 < self.metrics.len() { "," } else { "" }
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    /// Write the document to `path`.
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }

    /// The same document as [`JsonReport::render`] on a single line, no
    /// interior newlines — the shape the serve daemon's line-delimited
    /// protocol embeds in its responses.
    pub fn render_line(&self) -> String {
        let mut s = String::new();
        s.push('{');
        s.push_str(&format!("\"name\": \"{}\", ", json_escape(&self.name)));
        s.push_str("\"series\": [");
        for (i, (label, m)) in self.series.iter().enumerate() {
            s.push_str(&format!(
                "{{\"label\": \"{}\", \"reps\": {}, \"ns_per_op_median\": {}, \
                 \"ns_per_op_mean\": {}, \"ns_per_op_min\": {}}}{}",
                json_escape(label),
                m.samples.len(),
                json_num(m.median().as_nanos() as f64),
                json_num(m.mean().as_nanos() as f64),
                json_num(m.min().as_nanos() as f64),
                if i + 1 < self.series.len() { ", " } else { "" }
            ));
        }
        s.push_str("], \"metrics\": {");
        for (i, (key, value)) in self.metrics.iter().enumerate() {
            s.push_str(&format!(
                "\"{}\": {}{}",
                json_escape(key),
                json_num(*value),
                if i + 1 < self.metrics.len() { ", " } else { "" }
            ));
        }
        s.push_str("}}");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_line_is_single_line_and_parses() {
        let mut j = JsonReport::new("serve \"smoke\"");
        j.series("run", &Measurement::run("run", 1, 1, || 1 + 1));
        j.metric("stages", 3.0);
        j.metric("plan_cache_hits", 1.0);
        let line = j.render_line();
        assert!(!line.contains('\n'), "must embed in a line protocol");
        let v = crate::config::json::JsonValue::parse(&line).unwrap();
        let obj = v.as_object().unwrap();
        assert!(obj.contains_key("name"));
        assert!(obj.contains_key("series"));
        assert_eq!(
            v.field("metrics").unwrap().field("stages").unwrap().as_f64().unwrap(),
            3.0
        );
    }

    #[test]
    fn measurement_collects_samples() {
        let m = Measurement::run("noop", 2, 5, || 1 + 1);
        assert_eq!(m.samples.len(), 5);
        assert!(m.mean() >= m.min());
        assert!(m.median() >= m.min());
    }

    #[test]
    fn stats_on_known_samples() {
        let m = Measurement {
            label: "x".into(),
            samples: vec![
                Duration::from_millis(10),
                Duration::from_millis(20),
                Duration::from_millis(30),
                Duration::from_millis(40),
            ],
        };
        assert_eq!(m.mean(), Duration::from_millis(25));
        assert_eq!(m.median(), Duration::from_millis(25));
        assert_eq!(m.min(), Duration::from_millis(10));
        let (q1, q3) = m.iqr();
        assert!(q1 < m.median() && m.median() < q3);
    }

    #[test]
    fn std_dev_zero_for_single_sample() {
        let m = Measurement {
            label: "x".into(),
            samples: vec![Duration::from_millis(5)],
        };
        assert_eq!(m.std_dev(), Duration::ZERO);
    }

    #[test]
    fn ordering_reflects_cost() {
        // data-dependent workloads so the optimizer cannot fold them
        let small = black_box(vec![1.0f64; 100]);
        let large = black_box(vec![1.0f64; 4_000_000]);
        let fast = Measurement::run("fast", 1, 5, || small.iter().sum::<f64>());
        let slow = Measurement::run("slow", 1, 5, || large.iter().sum::<f64>());
        assert!(slow.median() > fast.median());
    }

    #[test]
    fn black_box_returns_value() {
        assert_eq!(black_box(42), 42);
        let v = black_box(vec![1, 2, 3]);
        assert_eq!(v.len(), 3);
    }

    #[test]
    fn json_report_renders_valid_structure() {
        let mut j = JsonReport::new("bench \"x\"");
        j.series(
            "legacy\n",
            &Measurement {
                label: "legacy".into(),
                samples: vec![Duration::from_millis(10), Duration::from_millis(20)],
            },
        );
        j.series(
            "tiled",
            &Measurement {
                label: "tiled".into(),
                samples: vec![Duration::from_millis(5)],
            },
        );
        j.metric("gather_rows", 1234.0);
        j.metric("speedup", f64::INFINITY); // non-finite -> null
        let doc = j.render();
        // escaping
        assert!(doc.contains("\"bench \\\"x\\\"\""), "{doc}");
        assert!(doc.contains("legacy\\n"), "{doc}");
        // medians in ns
        assert!(doc.contains("\"ns_per_op_median\": 15000000"), "{doc}");
        assert!(doc.contains("\"reps\": 2"), "{doc}");
        assert!(doc.contains("\"gather_rows\": 1234"), "{doc}");
        assert!(doc.contains("\"speedup\": null"), "{doc}");
        // exactly one comma between the two series, none after the last
        assert_eq!(doc.matches("},\n").count(), 1, "{doc}");
        // crude balance check of the hand-rolled document
        assert_eq!(doc.matches('{').count(), doc.matches('}').count(), "{doc}");
        assert_eq!(doc.matches('[').count(), doc.matches(']').count(), "{doc}");
    }

    #[test]
    fn json_report_round_trips_through_a_file() {
        let mut j = JsonReport::new("file test");
        j.metric("answer", 42.0);
        let path = std::env::temp_dir().join(format!(
            "meltframe_bench_json_{}_{}.json",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        j.write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, j.render());
        let _ = std::fs::remove_file(&path);
    }
}
