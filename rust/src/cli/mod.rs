//! Command-line front end (hand-rolled — clap is not vendored).
//!
//! Subcommands:
//!   run <config.toml> [--out out.npy]      run a configured pipeline
//!   inspect [--artifacts DIR]              list artifacts + PJRT platform
//!   demo [--workers N] [--backend B]       built-in Fig 6 style demo run
//!   serve --socket PATH                    persistent serving daemon
//!   submit --socket PATH --json LINE       client for a running daemon
//!
//! `parse_args` is pure (testable); `main.rs` wires it to the process.

use std::path::PathBuf;

use crate::coordinator::halo::HaloMode;
use crate::error::{Error, Result};

/// Parsed command line.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Run {
        config: PathBuf,
        out: Option<PathBuf>,
        /// Force the stage-by-stage fold→re-melt baseline instead of the
        /// fused lazy `Plan` executor.
        legacy: bool,
        /// Override the config's fused halo strategy
        /// (`--halo-mode recompute|exchange`).
        halo_mode: Option<HaloMode>,
        /// Override the config's exchange-wait watchdog deadline, in
        /// seconds (`--halo-wait-secs N`).
        halo_wait_secs: Option<u64>,
        /// Override the config's native gather→kernel tile height
        /// (`--tile-rows N`).
        tile_rows: Option<usize>,
        /// Pin the lane-parallel row kernels off (`--no-simd`) — results
        /// are bit-for-bit identical either way; this is a perf/debug knob.
        no_simd: bool,
    },
    Inspect {
        artifacts: PathBuf,
    },
    /// Start the serving daemon on a Unix-domain socket.
    Serve {
        socket: PathBuf,
        /// Pool threads (`--workers N`, default 4).
        workers: usize,
        /// Pending-job admission depth (`--queue-depth N`, default 16).
        queue_depth: usize,
        /// Plan-cache capacity in entries (`--cache-capacity N`, default 32).
        cache_capacity: usize,
        /// Daemon-default fused halo strategy (`--halo-mode`).
        halo_mode: Option<HaloMode>,
        /// Exchange-wait watchdog deadline override (`--halo-wait-secs N`).
        halo_wait_secs: Option<u64>,
        /// Native gather→kernel tile height override (`--tile-rows N`).
        tile_rows: Option<usize>,
        /// Cross-request batch-collection window in milliseconds
        /// (`--batch-window-ms N`, default 2; 0 disables batching).
        batch_window_ms: u64,
        /// Max jobs folded into one batch (`--max-batch N`, default 8).
        max_batch: usize,
        /// Executor shards splitting the worker budget
        /// (`--executors N`, default 1).
        executors: usize,
        /// Pin the lane-parallel row kernels off for every served job
        /// (`--no-simd`).
        no_simd: bool,
    },
    /// Submit one protocol line to a daemon (or run it in-process).
    Submit {
        /// Daemon socket (`--socket PATH`); required unless `--oneshot`.
        socket: Option<PathBuf>,
        /// Request line inline (`--json LINE`).
        json: Option<String>,
        /// Request line from a file (`--request-file PATH`).
        request_file: Option<PathBuf>,
        /// Execute in-process on a fresh one-shot executor instead of a
        /// daemon — the bit-for-bit reference for the served path.
        oneshot: bool,
        /// Workers for `--oneshot` (default 4).
        workers: usize,
        /// Send `{"op": "shutdown"}` (`--shutdown`).
        shutdown: bool,
    },
    Demo {
        workers: usize,
        backend: String,
        artifacts: PathBuf,
        /// Workload shape: `(H, W)` image or `(D, H, W)` volume
        /// (`--dims 256,256` / `--dims 48,48,48`).
        dims: Vec<usize>,
    },
    Help,
}

pub const USAGE: &str = "\
meltframe — melt-matrix array programming with parallel acceleration

USAGE:
    meltframe run <config.toml> [--out <file.npy>] [--legacy]
                  [--halo-mode recompute|exchange] [--halo-wait-secs <n>]
                  [--tile-rows <n>] [--no-simd]
    meltframe inspect [--artifacts <dir>]
    meltframe demo [--workers <n>] [--backend native|pjrt] [--artifacts <dir>]
                   [--dims <d,h,w>|<h,w>]
    meltframe serve --socket <path> [--workers <n>] [--queue-depth <n>]
                    [--cache-capacity <n>] [--halo-mode recompute|exchange]
                    [--halo-wait-secs <n>] [--tile-rows <n>] [--no-simd]
                    [--batch-window-ms <n>] [--max-batch <n>] [--executors <n>]
    meltframe submit (--socket <path> | --oneshot [--workers <n>])
                     (--json <line> | --request-file <path> | --shutdown)
    meltframe help

`run` executes the configured stages through the fused lazy Plan (one melt,
one fold per fusable group); `--legacy` forces the stage-by-stage baseline.
`--halo-mode` overrides the config's fused halo strategy: `recompute`
(duplicate boundary rows locally) or `exchange` (trade them between
neighbouring chunks through the halo board, scheduled dependency-aware).
`--halo-wait-secs` overrides the exchange watchdog deadline (default 600).
`--tile-rows` overrides the native gather→kernel tile height (default 256;
purely a cache-footprint knob — results are bit-for-bit identical).
`--no-simd` pins the lane-parallel row kernels off (equivalent to
`simd = \"scalar\"` in the config or MELTFRAME_SIMD=scalar); outputs are
bit-for-bit identical with it on or off.
`demo --dims` picks the synthetic workload shape: three comma-separated
extents run the (D, H, W) volume pipeline, two run the (H, W) image one
(default 48,48,48).
`serve` starts a persistent daemon: a long-lived worker pool and an LRU
plan cache behind a line-delimited JSON protocol on a Unix-domain socket,
with bounded-queue admission control. Admitted jobs whose shape, op-chain,
grid, boundary, halo mode, and tile height all match are folded into one
batched run (one plan lookup, one fused fold for the whole group, answers
split per request): `--batch-window-ms` bounds how long the collector
lingers for batchmates (0 turns batching off), `--max-batch` caps the
group size, and `--executors` shards the worker budget into independent
executors so unrelated batches run concurrently. `submit` is the matching
client:
`--json`/`--request-file` send one job request line and print the response
line (digest + metrics); `--shutdown` drains and stops the daemon;
`--oneshot` executes the same request in-process instead — the bit-for-bit
reference for the served path.
";

/// Parse argv (without the program name).
pub fn parse_args(args: &[String]) -> Result<Command> {
    let mut it = args.iter();
    let cmd = match it.next().map(String::as_str) {
        None | Some("help") | Some("--help") | Some("-h") => return Ok(Command::Help),
        Some(c) => c,
    };
    match cmd {
        "run" => {
            let mut config = None;
            let mut out = None;
            let mut legacy = false;
            let mut halo_mode = None;
            let mut halo_wait_secs = None;
            let mut tile_rows = None;
            let mut no_simd = false;
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--out" => {
                        out = Some(PathBuf::from(expect_value(&mut it, "--out")?));
                    }
                    "--legacy" => legacy = true,
                    "--halo-mode" => {
                        halo_mode = Some(HaloMode::parse(expect_value(&mut it, "--halo-mode")?)?);
                    }
                    "--halo-wait-secs" => {
                        let v = expect_value(&mut it, "--halo-wait-secs")?;
                        let secs: u64 = v.parse().map_err(|_| {
                            Error::Config("--halo-wait-secs expects a number of seconds".into())
                        })?;
                        if secs == 0 {
                            return Err(Error::Config("--halo-wait-secs must be >= 1".into()));
                        }
                        halo_wait_secs = Some(secs);
                    }
                    "--tile-rows" => {
                        let v = expect_value(&mut it, "--tile-rows")?;
                        let n: usize = v.parse().map_err(|_| {
                            Error::Config("--tile-rows expects a number of rows".into())
                        })?;
                        if n == 0 {
                            return Err(Error::Config("--tile-rows must be >= 1".into()));
                        }
                        tile_rows = Some(n);
                    }
                    "--no-simd" => no_simd = true,
                    flag if flag.starts_with("--") => {
                        return Err(Error::Config(format!("unknown flag '{flag}' for run")))
                    }
                    positional => {
                        if config.replace(PathBuf::from(positional)).is_some() {
                            return Err(Error::Config("run takes one config file".into()));
                        }
                    }
                }
            }
            Ok(Command::Run {
                config: config.ok_or_else(|| Error::Config("run requires a config file".into()))?,
                out,
                legacy,
                halo_mode,
                halo_wait_secs,
                tile_rows,
                no_simd,
            })
        }
        "inspect" => {
            let mut artifacts = PathBuf::from("artifacts");
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--artifacts" => {
                        artifacts = PathBuf::from(expect_value(&mut it, "--artifacts")?)
                    }
                    other => return Err(Error::Config(format!("unknown argument '{other}'"))),
                }
            }
            Ok(Command::Inspect { artifacts })
        }
        "demo" => {
            let mut workers = 4usize;
            let mut backend = "native".to_string();
            let mut artifacts = PathBuf::from("artifacts");
            let mut dims = vec![48usize, 48, 48];
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--workers" => {
                        workers = expect_value(&mut it, "--workers")?
                            .parse()
                            .map_err(|_| Error::Config("--workers expects a number".into()))?;
                    }
                    "--backend" => backend = expect_value(&mut it, "--backend")?.to_string(),
                    "--artifacts" => {
                        artifacts = PathBuf::from(expect_value(&mut it, "--artifacts")?)
                    }
                    "--dims" => {
                        dims = expect_value(&mut it, "--dims")?
                            .split(',')
                            .map(|s| {
                                s.trim().parse::<usize>().map_err(|_| {
                                    Error::Config(format!("bad extent '{s}' in --dims"))
                                })
                            })
                            .collect::<Result<_>>()?;
                        if dims.len() != 2 && dims.len() != 3 {
                            return Err(Error::Config(
                                "--dims expects H,W (image) or D,H,W (volume)".into(),
                            ));
                        }
                        if dims.contains(&0) {
                            return Err(Error::Config("--dims extents must be >= 1".into()));
                        }
                    }
                    other => return Err(Error::Config(format!("unknown argument '{other}'"))),
                }
            }
            if backend != "native" && backend != "pjrt" {
                return Err(Error::Config(format!("unknown backend '{backend}'")));
            }
            Ok(Command::Demo {
                workers,
                backend,
                artifacts,
                dims,
            })
        }
        "serve" => {
            let mut socket = None;
            let mut workers = 4usize;
            let mut queue_depth = 16usize;
            let mut cache_capacity = 32usize;
            let mut halo_mode = None;
            let mut halo_wait_secs = None;
            let mut tile_rows = None;
            let mut batch_window_ms = 2u64;
            let mut max_batch = 8usize;
            let mut executors = 1usize;
            let mut no_simd = false;
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--socket" => {
                        socket = Some(PathBuf::from(expect_value(&mut it, "--socket")?));
                    }
                    "--workers" => workers = positive_usize(&mut it, "--workers")?,
                    "--queue-depth" => queue_depth = positive_usize(&mut it, "--queue-depth")?,
                    // NOT positive_usize: 0 is meaningful (batching off)
                    "--batch-window-ms" => {
                        let v = expect_value(&mut it, "--batch-window-ms")?;
                        batch_window_ms = v.parse().map_err(|_| {
                            Error::Config(
                                "--batch-window-ms expects a number of milliseconds".into(),
                            )
                        })?;
                    }
                    "--max-batch" => max_batch = positive_usize(&mut it, "--max-batch")?,
                    "--executors" => executors = positive_usize(&mut it, "--executors")?,
                    "--cache-capacity" => {
                        cache_capacity = positive_usize(&mut it, "--cache-capacity")?
                    }
                    "--halo-mode" => {
                        halo_mode = Some(HaloMode::parse(expect_value(&mut it, "--halo-mode")?)?);
                    }
                    "--halo-wait-secs" => {
                        let v = expect_value(&mut it, "--halo-wait-secs")?;
                        let secs: u64 = v.parse().map_err(|_| {
                            Error::Config("--halo-wait-secs expects a number of seconds".into())
                        })?;
                        if secs == 0 {
                            return Err(Error::Config("--halo-wait-secs must be >= 1".into()));
                        }
                        halo_wait_secs = Some(secs);
                    }
                    "--tile-rows" => tile_rows = Some(positive_usize(&mut it, "--tile-rows")?),
                    "--no-simd" => no_simd = true,
                    other => {
                        return Err(Error::Config(format!("unknown argument '{other}' for serve")))
                    }
                }
            }
            Ok(Command::Serve {
                socket: socket
                    .ok_or_else(|| Error::Config("serve requires --socket <path>".into()))?,
                workers,
                queue_depth,
                cache_capacity,
                halo_mode,
                halo_wait_secs,
                tile_rows,
                batch_window_ms,
                max_batch,
                executors,
                no_simd,
            })
        }
        "submit" => {
            let mut socket = None;
            let mut json = None;
            let mut request_file = None;
            let mut oneshot = false;
            let mut workers = 4usize;
            let mut shutdown = false;
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--socket" => {
                        socket = Some(PathBuf::from(expect_value(&mut it, "--socket")?));
                    }
                    "--json" => json = Some(expect_value(&mut it, "--json")?.to_string()),
                    "--request-file" => {
                        request_file =
                            Some(PathBuf::from(expect_value(&mut it, "--request-file")?));
                    }
                    "--oneshot" => oneshot = true,
                    "--workers" => workers = positive_usize(&mut it, "--workers")?,
                    "--shutdown" => shutdown = true,
                    other => {
                        return Err(Error::Config(format!(
                            "unknown argument '{other}' for submit"
                        )))
                    }
                }
            }
            let payloads = usize::from(json.is_some())
                + usize::from(request_file.is_some())
                + usize::from(shutdown);
            if payloads != 1 {
                return Err(Error::Config(
                    "submit takes exactly one of --json, --request-file, --shutdown".into(),
                ));
            }
            if oneshot && shutdown {
                return Err(Error::Config("--oneshot has no daemon to --shutdown".into()));
            }
            if oneshot == socket.is_some() {
                return Err(Error::Config(
                    "submit needs --socket <path>, or --oneshot to run in-process".into(),
                ));
            }
            Ok(Command::Submit {
                socket,
                json,
                request_file,
                oneshot,
                workers,
                shutdown,
            })
        }
        other => Err(Error::Config(format!(
            "unknown command '{other}'\n{USAGE}"
        ))),
    }
}

/// A flag value that must parse as an integer >= 1 (0 would spin loops,
/// dead pools, or uncacheable caches — refuse at the CLI boundary).
fn positive_usize(it: &mut std::slice::Iter<'_, String>, flag: &str) -> Result<usize> {
    let v = expect_value(it, flag)?;
    let n: usize = v
        .parse()
        .map_err(|_| Error::Config(format!("{flag} expects a number")))?;
    if n == 0 {
        return Err(Error::Config(format!("{flag} must be >= 1")));
    }
    Ok(n)
}

fn expect_value<'a>(
    it: &mut std::slice::Iter<'a, String>,
    flag: &str,
) -> Result<&'a String> {
    it.next()
        .ok_or_else(|| Error::Config(format!("{flag} expects a value")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_run() {
        let c = parse_args(&argv("run pipeline.toml --out result.npy")).unwrap();
        assert_eq!(
            c,
            Command::Run {
                config: PathBuf::from("pipeline.toml"),
                out: Some(PathBuf::from("result.npy")),
                legacy: false,
                halo_mode: None,
                halo_wait_secs: None,
                tile_rows: None,
                no_simd: false,
            }
        );
        let c = parse_args(&argv("run pipeline.toml --legacy")).unwrap();
        assert_eq!(
            c,
            Command::Run {
                config: PathBuf::from("pipeline.toml"),
                out: None,
                legacy: true,
                halo_mode: None,
                halo_wait_secs: None,
                tile_rows: None,
                no_simd: false,
            }
        );
        // mixed-case mode spellings normalize, and the watchdog, tile, and
        // simd overrides parse alongside
        let c = parse_args(&argv(
            "run pipeline.toml --halo-mode Exchange --halo-wait-secs 45 --tile-rows 128 --no-simd",
        ))
        .unwrap();
        assert_eq!(
            c,
            Command::Run {
                config: PathBuf::from("pipeline.toml"),
                out: None,
                legacy: false,
                halo_mode: Some(HaloMode::Exchange),
                halo_wait_secs: Some(45),
                tile_rows: Some(128),
                no_simd: true,
            }
        );
    }

    #[test]
    fn parses_inspect_and_demo() {
        assert_eq!(
            parse_args(&argv("inspect --artifacts build/artifacts")).unwrap(),
            Command::Inspect {
                artifacts: PathBuf::from("build/artifacts")
            }
        );
        assert_eq!(
            parse_args(&argv("demo --workers 2 --backend pjrt")).unwrap(),
            Command::Demo {
                workers: 2,
                backend: "pjrt".into(),
                artifacts: PathBuf::from("artifacts"),
                dims: vec![48, 48, 48],
            }
        );
    }

    #[test]
    fn demo_dims_accept_images_and_volumes() {
        // a 2-extent --dims runs the image demo, 3 extents the volume demo
        assert_eq!(
            parse_args(&argv("demo --dims 128,96")).unwrap(),
            Command::Demo {
                workers: 4,
                backend: "native".into(),
                artifacts: PathBuf::from("artifacts"),
                dims: vec![128, 96],
            }
        );
        assert_eq!(
            parse_args(&argv("demo --dims 32,48,64 --workers 3")).unwrap(),
            Command::Demo {
                workers: 3,
                backend: "native".into(),
                artifacts: PathBuf::from("artifacts"),
                dims: vec![32, 48, 64],
            }
        );
        // padded spellings parse; bad ranks/extents do not
        assert!(parse_args(&argv("demo --dims 16, 16, 16")).is_err()); // shell-split
        assert_eq!(
            parse_args(&["demo".into(), "--dims".into(), "16, 16, 16".into()]).unwrap(),
            Command::Demo {
                workers: 4,
                backend: "native".into(),
                artifacts: PathBuf::from("artifacts"),
                dims: vec![16, 16, 16],
            }
        );
        assert!(parse_args(&argv("demo --dims 16")).is_err());
        assert!(parse_args(&argv("demo --dims 1,2,3,4")).is_err());
        assert!(parse_args(&argv("demo --dims 16,0,16")).is_err());
        assert!(parse_args(&argv("demo --dims abc,16")).is_err());
        assert!(parse_args(&argv("demo --dims")).is_err());
    }

    #[test]
    fn parses_serve() {
        assert_eq!(
            parse_args(&argv("serve --socket /tmp/mf.sock")).unwrap(),
            Command::Serve {
                socket: PathBuf::from("/tmp/mf.sock"),
                workers: 4,
                queue_depth: 16,
                cache_capacity: 32,
                halo_mode: None,
                halo_wait_secs: None,
                tile_rows: None,
                batch_window_ms: 2,
                max_batch: 8,
                executors: 1,
                no_simd: false,
            }
        );
        assert_eq!(
            parse_args(&argv(
                "serve --socket mf.sock --workers 3 --queue-depth 8 --cache-capacity 5 \
                 --halo-mode exchange --halo-wait-secs 30 --tile-rows 64 \
                 --batch-window-ms 0 --max-batch 4 --executors 2 --no-simd"
            ))
            .unwrap(),
            Command::Serve {
                socket: PathBuf::from("mf.sock"),
                workers: 3,
                queue_depth: 8,
                cache_capacity: 5,
                halo_mode: Some(HaloMode::Exchange),
                halo_wait_secs: Some(30),
                tile_rows: Some(64),
                batch_window_ms: 0,
                max_batch: 4,
                executors: 2,
                no_simd: true,
            }
        );
        // 0 is "batching off" for the window, but nonsense for the others
        assert!(parse_args(&argv("serve --socket mf.sock --max-batch 0")).is_err());
        assert!(parse_args(&argv("serve --socket mf.sock --executors 0")).is_err());
        assert!(parse_args(&argv("serve --socket mf.sock --batch-window-ms abc")).is_err());
    }

    #[test]
    fn parses_submit() {
        let args: Vec<String> = ["submit", "--socket", "mf.sock", "--json", "{\"id\": \"j1\"}"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(
            parse_args(&args).unwrap(),
            Command::Submit {
                socket: Some(PathBuf::from("mf.sock")),
                json: Some("{\"id\": \"j1\"}".into()),
                request_file: None,
                oneshot: false,
                workers: 4,
                shutdown: false,
            }
        );
        assert_eq!(
            parse_args(&argv("submit --oneshot --workers 2 --request-file req.json")).unwrap(),
            Command::Submit {
                socket: None,
                json: None,
                request_file: Some(PathBuf::from("req.json")),
                oneshot: true,
                workers: 2,
                shutdown: false,
            }
        );
        assert_eq!(
            parse_args(&argv("submit --socket mf.sock --shutdown")).unwrap(),
            Command::Submit {
                socket: Some(PathBuf::from("mf.sock")),
                json: None,
                request_file: None,
                oneshot: false,
                workers: 4,
                shutdown: true,
            }
        );
    }

    #[test]
    fn serve_and_submit_reject_malformed() {
        // zero values would spin loops / dead pools — refused like
        // `run --tile-rows 0`
        assert!(parse_args(&argv("serve --socket s --workers 0")).is_err());
        assert!(parse_args(&argv("serve --socket s --queue-depth 0")).is_err());
        assert!(parse_args(&argv("serve --socket s --cache-capacity 0")).is_err());
        assert!(parse_args(&argv("serve --socket s --tile-rows 0")).is_err());
        assert!(parse_args(&argv("serve --socket s --halo-wait-secs 0")).is_err());
        assert!(parse_args(&argv("serve")).is_err()); // socket required
        assert!(parse_args(&argv("serve --socket s --bogus")).is_err());
        assert!(parse_args(&argv("submit --socket s")).is_err()); // no payload
        assert!(parse_args(&argv("submit --socket s --shutdown --json x")).is_err());
        assert!(parse_args(&argv("submit --json x")).is_err()); // no socket, no oneshot
        assert!(parse_args(&argv("submit --oneshot --socket s --json x")).is_err());
        assert!(parse_args(&argv("submit --oneshot --shutdown")).is_err());
        assert!(parse_args(&argv("submit --oneshot --json x --workers 0")).is_err());
    }

    #[test]
    fn help_variants() {
        for v in ["", "help", "--help", "-h"] {
            assert_eq!(parse_args(&argv(v)).unwrap(), Command::Help);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_args(&argv("run")).is_err());
        assert!(parse_args(&argv("run a.toml b.toml")).is_err());
        assert!(parse_args(&argv("run a.toml --bogus")).is_err());
        assert!(parse_args(&argv("demo --workers abc")).is_err());
        assert!(parse_args(&argv("demo --backend cuda")).is_err());
        assert!(parse_args(&argv("frobnicate")).is_err());
        assert!(parse_args(&argv("run a.toml --out")).is_err());
        assert!(parse_args(&argv("run a.toml --halo-mode")).is_err());
        assert!(parse_args(&argv("run a.toml --halo-mode psychic")).is_err());
        assert!(parse_args(&argv("run a.toml --halo-wait-secs")).is_err());
        assert!(parse_args(&argv("run a.toml --halo-wait-secs soon")).is_err());
        assert!(parse_args(&argv("run a.toml --halo-wait-secs 0")).is_err());
        assert!(parse_args(&argv("run a.toml --tile-rows")).is_err());
        assert!(parse_args(&argv("run a.toml --tile-rows many")).is_err());
        assert!(parse_args(&argv("run a.toml --tile-rows 0")).is_err());
    }
}
