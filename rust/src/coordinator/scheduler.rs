//! Work-stealing chunk queue + result board shared by the worker fleet.
//!
//! The queue is a lock-free cursor over the partition's ranges: workers
//! `pop()` until drained, which self-balances when chunk costs vary (the
//! bilateral's data-dependent exp() count, PJRT padding overhead on the
//! tail chunk, OS noise). Results land on a mutex-guarded board indexed by
//! chunk id — one short critical section per completed chunk.
//!
//! The fused executor's halo-exchange board
//! ([`crate::coordinator::halo::HaloBoard`]) is built over
//! [`WorkQueue::ranges`] so its cell geometry provably matches the chunk
//! ids this queue dispenses: `pop()` hands out `(id, range)` pairs in index
//! order, and exchange-mode workers publish/fetch against those same ids.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::melt::partition::RowPartition;

/// Lock-free dispenser of partition chunks.
pub struct WorkQueue {
    ranges: Vec<Range<usize>>,
    next: AtomicUsize,
}

impl WorkQueue {
    pub fn new(partition: &RowPartition) -> Self {
        Self {
            ranges: partition.ranges().to_vec(),
            next: AtomicUsize::new(0),
        }
    }

    /// Claim the next chunk: `(chunk id, row range)`.
    pub fn pop(&self) -> Option<(usize, Range<usize>)> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        self.ranges.get(i).map(|r| (i, r.clone()))
    }

    pub fn num_chunks(&self) -> usize {
        self.ranges.len()
    }

    /// The chunk ranges, indexed by the ids `pop()` dispenses — the
    /// geometry the fused executor's halo board is built over.
    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }
}

/// Per-chunk result board.
pub struct ResultBoard {
    slots: Mutex<Vec<Option<Vec<f32>>>>,
}

impl ResultBoard {
    pub fn new(num_chunks: usize) -> Self {
        Self {
            slots: Mutex::new(vec![None; num_chunks]),
        }
    }

    /// Record chunk `id`'s output rows.
    pub fn put(&self, id: usize, values: Vec<f32>) -> Result<()> {
        let mut slots = self.slots.lock().map_err(|_| {
            Error::Coordinator("result board poisoned by a worker panic".into())
        })?;
        if id >= slots.len() {
            return Err(Error::Coordinator(format!(
                "chunk id {id} out of range 0..{}",
                slots.len()
            )));
        }
        if slots[id].is_some() {
            return Err(Error::Coordinator(format!("chunk {id} completed twice")));
        }
        slots[id] = Some(values);
        Ok(())
    }

    /// Take all chunks in id order; errors if any is missing.
    pub fn into_chunks(self) -> Result<Vec<Vec<f32>>> {
        let slots = self
            .slots
            .into_inner()
            .map_err(|_| Error::Coordinator("result board poisoned".into()))?;
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.ok_or_else(|| Error::Coordinator(format!("chunk {i} never completed"))))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_dispenses_each_chunk_once() {
        let p = RowPartition::even(100, 7).unwrap();
        let q = WorkQueue::new(&p);
        let mut seen = Vec::new();
        while let Some((id, r)) = q.pop() {
            seen.push((id, r));
        }
        assert_eq!(seen.len(), 7);
        for (i, (id, r)) in seen.iter().enumerate() {
            assert_eq!(*id, i);
            assert_eq!(*r, p.ranges()[i]);
            assert_eq!(*r, q.ranges()[*id]);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn queue_is_thread_safe() {
        let p = RowPartition::even(1000, 64).unwrap();
        let q = WorkQueue::new(&p);
        let counter = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    while q.pop().is_some() {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn board_round_trip() {
        let b = ResultBoard::new(3);
        b.put(1, vec![1.0]).unwrap();
        b.put(0, vec![0.0]).unwrap();
        b.put(2, vec![2.0]).unwrap();
        let chunks = b.into_chunks().unwrap();
        assert_eq!(chunks, vec![vec![0.0], vec![1.0], vec![2.0]]);
    }

    #[test]
    fn board_rejects_double_and_missing() {
        let b = ResultBoard::new(2);
        b.put(0, vec![1.0]).unwrap();
        assert!(b.put(0, vec![1.0]).is_err());
        assert!(b.put(5, vec![1.0]).is_err());
        assert!(b.into_chunks().is_err()); // chunk 1 missing
    }
}
