//! Work-stealing chunk queue, result board, and the dependency-aware
//! `(chunk, stage)` scheduler shared by the worker fleet.
//!
//! The queue is a lock-free cursor over the partition's ranges: workers
//! `pop()` until drained, which self-balances when chunk costs vary (the
//! bilateral's data-dependent exp() count, PJRT padding overhead on the
//! tail chunk, OS noise). Results land on a mutex-guarded board indexed by
//! chunk id — one short critical section per completed chunk.
//!
//! The fused executor's halo-exchange board
//! ([`crate::coordinator::halo::HaloBoard`]) is built over
//! [`WorkQueue::ranges`] so its cell geometry provably matches the chunk
//! ids this queue dispenses: `pop()` hands out `(id, range)` pairs in index
//! order, and exchange-mode workers publish/fetch against those same ids.
//!
//! ## The stage scheduler ([`StageScheduler`])
//!
//! Exchange-mode fused groups no longer run chunk-at-a-time: the unit of
//! work is one *stage* of one chunk, and a task `(c, k)` is dispatched only
//! once every chunk whose stage-`(k − 1)` boundary rows the gather reaches
//! has already **published** them on the halo board. Workers pull ready
//! tasks instead of blocking inside `HaloBoard::fetch_into`, and the
//! per-chunk value slab lives in scheduler-owned task state, so a chunk
//! migrates freely between workers across stages — the chunk count is no
//! longer capped at the worker count, restoring the same load-balancing
//! over-partitioning that recompute mode enjoys.
//!
//! **Liveness (any chunk count, any worker count):** whenever no task is
//! running and some chunk is unfinished, let `k*` be the minimum `progress`
//! over unfinished chunks and `c` any chunk at `k*`. Every other chunk `d`
//! has `progress[d] ≥ k*`, and completing task `(d, j)` always advances
//! `published[d]` to at least `j + 1` (boundary rows are published *during*
//! the task, and task completion subsumes them), so `published[d] ≥ k*` —
//! exactly the dependency `(c, k*)` needs. A ready task therefore always
//! exists, workers never deadlock, and the condvar wait in
//! [`StageScheduler::next_task`] only rides out in-flight tasks. The wait
//! is still bounded by the same configurable deadline as the halo board,
//! converting any future scheduling bug into an error instead of a hang.

use std::cmp::Reverse;
use std::collections::BTreeSet;
use std::ops::Range;
use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{Condvar, Mutex, NamedCondvar, NamedMutex};
use std::time::{Duration, Instant};

use crate::coordinator::halo::{ABORTED_MSG, WAIT_SLICE};
use crate::error::{Error, Result};
use crate::melt::partition::RowPartition;

/// Lock-free dispenser of partition chunks.
pub struct WorkQueue {
    ranges: Vec<Range<usize>>,
    next: AtomicUsize,
}

impl WorkQueue {
    pub fn new(partition: &RowPartition) -> Self {
        Self {
            ranges: partition.ranges().to_vec(),
            next: AtomicUsize::new(0),
        }
    }

    /// Claim the next chunk: `(chunk id, row range)`.
    pub fn pop(&self) -> Option<(usize, Range<usize>)> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        self.ranges.get(i).map(|r| (i, r.clone()))
    }

    pub fn num_chunks(&self) -> usize {
        self.ranges.len()
    }

    /// The chunk ranges, indexed by the ids `pop()` dispenses — the
    /// geometry the fused executor's halo board is built over.
    pub fn ranges(&self) -> &[Range<usize>] {
        &self.ranges
    }
}

/// Per-chunk result board.
pub struct ResultBoard {
    slots: Mutex<Vec<Option<Vec<f32>>>>,
}

impl ResultBoard {
    pub fn new(num_chunks: usize) -> Self {
        Self {
            slots: Mutex::new_named("coord.results", vec![None; num_chunks]),
        }
    }

    /// Record chunk `id`'s output rows.
    pub fn put(&self, id: usize, values: Vec<f32>) -> Result<()> {
        let mut slots = self.slots.lock().map_err(|_| {
            Error::Coordinator("result board poisoned by a worker panic".into())
        })?;
        if id >= slots.len() {
            return Err(Error::Coordinator(format!(
                "chunk id {id} out of range 0..{}",
                slots.len()
            )));
        }
        if slots[id].is_some() {
            return Err(Error::Coordinator(format!("chunk {id} completed twice")));
        }
        slots[id] = Some(values);
        Ok(())
    }

    /// Take all chunks in id order; errors if any is missing.
    pub fn into_chunks(self) -> Result<Vec<Vec<f32>>> {
        let slots = self
            .slots
            .into_inner()
            .map_err(|_| Error::Coordinator("result board poisoned".into()))?;
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.ok_or_else(|| Error::Coordinator(format!("chunk {i} never completed"))))
            .collect()
    }
}

/// One dispatched unit of exchange-mode work: run `stage` over `chunk`,
/// with the chunk's resident value slab (the previous stage's interior
/// output; empty for stage 0) checked out of the scheduler.
pub struct StageTask {
    pub chunk: usize,
    pub stage: usize,
    pub vals: Vec<f32>,
}

struct SchedState {
    /// Next stage each chunk must run; `== stages` means finished.
    progress: Vec<usize>,
    /// Count of stages whose boundary rows the chunk has made available
    /// (eager publish mid-task, or task completion — whichever first).
    published: Vec<usize>,
    /// Chunk currently checked out by a worker.
    running: Vec<bool>,
    /// Resident per-chunk value slabs (empty while checked out / at start).
    slots: Vec<Vec<f32>>,
    /// Dispatchable tasks, maintained *incrementally* as publishes and
    /// completions land (no full rescan per dispatch): `(stage,
    /// Reverse(chunk))` so `pop_last()` yields the deepest ready stage,
    /// ties to the lowest chunk id. Readiness is monotone — deps only
    /// grow, and a queued chunk's `progress` cannot move until it is
    /// dispatched — so entries never go stale.
    ready: BTreeSet<(usize, Reverse<usize>)>,
    /// Whether the chunk's pending stage sits in `ready`.
    queued: Vec<bool>,
    finished: usize,
    /// Times a worker asked for a task and found none ready.
    stalls: usize,
    /// Monotone count of scheduler events (publishes/completions) — lets
    /// idle waiters distinguish "the fleet is progressing without me" from
    /// a genuine stall, so the watchdog only fires on the latter.
    events: u64,
    poisoned: bool,
}

/// Dependency-aware `(chunk, stage)` task scheduler for exchange-mode
/// fused groups — see the module docs for the dispatch rule and liveness
/// argument.
pub struct StageScheduler {
    ranges: Vec<Range<usize>>,
    /// Per-stage gather reach in flat rows: stage `k` reads at most
    /// `halos[k]` rows beyond the chunk interior.
    halos: Vec<usize>,
    stages: usize,
    rows: usize,
    /// Widest per-stage halo — bounds which chunks a publish/completion
    /// can possibly unblock (overlap is symmetric, so re-checking every
    /// chunk within `max_halo` of the event's chunk is exhaustive).
    max_halo: usize,
    deadline: Duration,
    state: Mutex<SchedState>,
    wakeup: Condvar,
}

impl StageScheduler {
    /// Build over the partition's chunk interiors for an n-stage fused
    /// group (`stages = n`, `halos.len() == n`). `deadline` bounds any
    /// single idle wait in [`Self::next_task`].
    pub fn new(ranges: &[Range<usize>], halos: &[usize], deadline: Duration) -> Self {
        let n_chunks = ranges.len();
        Self {
            ranges: ranges.to_vec(),
            halos: halos.to_vec(),
            stages: halos.len(),
            rows: ranges.last().map_or(0, |r| r.end),
            max_halo: halos.iter().copied().max().unwrap_or(0),
            deadline,
            state: Mutex::new_named("sched.state", SchedState {
                progress: vec![0; n_chunks],
                published: vec![0; n_chunks],
                running: vec![false; n_chunks],
                slots: vec![Vec::new(); n_chunks],
                // stage 0 reads the global melt matrix: every chunk starts
                // dispatchable
                ready: (0..n_chunks).map(|c| (0, Reverse(c))).collect(),
                queued: vec![true; n_chunks],
                finished: 0,
                stalls: 0,
                events: 0,
                poisoned: false,
            }),
            wakeup: Condvar::new_named("sched.wakeup"),
        }
    }

    /// The chunk indices overlapping `[start − pad, end + pad)` — a
    /// contiguous run, found by binary search over the sorted ranges.
    fn overlapping(&self, r: &Range<usize>, pad: usize) -> Range<usize> {
        let lo = r.start.saturating_sub(pad);
        let hi = (r.end + pad).min(self.rows);
        let first = self.ranges.partition_point(|rd| rd.end <= lo);
        let last = self.ranges.partition_point(|rd| rd.start < hi);
        first..last
    }

    /// Whether `(c, k)`'s gathers are satisfiable right now: every chunk
    /// overlapping the halo-extended range must have published stage
    /// `k − 1`. Stage 0 reads the global melt matrix and is always ready.
    fn deps_met(&self, st: &SchedState, c: usize, k: usize) -> bool {
        if k == 0 {
            return true;
        }
        let h = self.halos[k];
        if h == 0 {
            return true;
        }
        self.overlapping(&self.ranges[c], h)
            .all(|d| d == c || st.published[d] >= k)
    }

    /// Queue chunk `c`'s pending stage if it just became dispatchable.
    /// Readiness is monotone, so this is the only place entries are added
    /// and no entry ever has to be revalidated at pop time.
    fn enqueue_if_ready(&self, st: &mut SchedState, c: usize) {
        if st.queued[c] || st.running[c] {
            return;
        }
        let k = st.progress[c];
        if k >= self.stages || !self.deps_met(st, c, k) {
            return;
        }
        st.queued[c] = true;
        st.ready.insert((k, Reverse(c)));
    }

    /// Re-check every chunk a publish/completion at `c` could have
    /// unblocked: a dependant's halo-extended range overlaps `c` exactly
    /// when `c` extended by the same (≤ `max_halo`) reach overlaps it.
    fn wake_neighbours(&self, st: &mut SchedState, c: usize) {
        for d in self.overlapping(&self.ranges[c], self.max_halo) {
            self.enqueue_if_ready(st, d);
        }
    }

    /// Claim the next ready task, blocking while every remaining task
    /// waits on an in-flight neighbour. Returns `Ok(None)` once all chunks
    /// have run all stages. The wait is watchdogged: if the *whole
    /// scheduler* sees no event (publish/completion) for the deadline, the
    /// would-be hang becomes an error — a worker merely idling while the
    /// rest of the fleet progresses never trips it.
    pub fn next_task(&self) -> Result<Option<StageTask>> {
        let mut st = self
            .state
            .lock()
            .map_err(|_| Error::Coordinator("stage scheduler poisoned by a worker panic".into()))?;
        let mut waited: Option<(Instant, u64)> = None;
        loop {
            if st.poisoned {
                return Err(Error::Coordinator(ABORTED_MSG.into()));
            }
            if st.finished == self.ranges.len() {
                return Ok(None);
            }
            // O(log chunks) dispatch off the incrementally-maintained set:
            // deepest ready stage first (ties to the lowest chunk id), so
            // chunks retire — and free their result slabs — early
            if let Some((k, Reverse(c))) = st.ready.pop_last() {
                debug_assert_eq!(st.progress[c], k);
                st.queued[c] = false;
                st.running[c] = true;
                let vals = std::mem::take(&mut st.slots[c]);
                return Ok(Some(StageTask { chunk: c, stage: k, vals }));
            }
            match &mut waited {
                None => {
                    st.stalls += 1; // one stall per dry visit, however long
                    waited = Some((Instant::now(), st.events));
                }
                // fleet progressed since we started waiting: re-arm
                Some((start, seen)) if *seen != st.events => {
                    *start = Instant::now();
                    *seen = st.events;
                }
                Some((start, _)) if start.elapsed() > self.deadline => {
                    return Err(Error::Coordinator(format!(
                        "stage scheduler saw no ready task and no progress for {:?} — \
                         worker stalled or scheduling bug",
                        self.deadline
                    )));
                }
                _ => {}
            }
            let (next, _) = self.wakeup.wait_timeout(st, WAIT_SLICE).map_err(|_| {
                Error::Coordinator("stage scheduler poisoned by a worker panic".into())
            })?;
            st = next;
        }
    }

    /// Eager notification: `chunk` just published its stage-`stage`
    /// boundary rows on the halo board (its interior may still be
    /// computing). Unblocks neighbours waiting to start stage `stage + 1`.
    pub fn mark_published(&self, chunk: usize, stage: usize) {
        if let Ok(mut st) = self.state.lock() {
            if st.published[chunk] < stage + 1 {
                st.published[chunk] = stage + 1;
                st.events += 1;
                self.wake_neighbours(&mut st, chunk);
                self.wakeup.notify_all();
            }
        }
    }

    /// Check a finished task back in: `vals` is the chunk's stage-`stage`
    /// interior output, resident for the next stage. Completion subsumes
    /// publication (the interior contains the boundary rows), so
    /// `published` advances here too — this is what keeps zero-halo stages,
    /// which never touch the board, from wedging the dependency counters.
    pub fn complete(&self, chunk: usize, stage: usize, vals: Vec<f32>) {
        if let Ok(mut st) = self.state.lock() {
            st.progress[chunk] = stage + 1;
            st.published[chunk] = st.published[chunk].max(stage + 1);
            st.running[chunk] = false;
            st.slots[chunk] = vals;
            st.events += 1;
            if stage + 1 == self.stages {
                st.finished += 1;
            }
            // this publication/progress may unblock the chunk itself (its
            // next stage) and any dependant within the halo
            self.wake_neighbours(&mut st, chunk);
            self.wakeup.notify_all();
        }
    }

    /// Mark the run failed and wake every waiter (mirrors
    /// [`HaloBoard::poison`](crate::coordinator::halo::HaloBoard)).
    pub fn poison(&self) {
        if let Ok(mut st) = self.state.lock() {
            st.poisoned = true;
        }
        self.wakeup.notify_all();
    }

    /// Total dry `next_task` visits across the run (the tasks-ready-stall
    /// counter surfaced as `RunMetrics::sched_stalls`).
    pub fn stalls(&self) -> usize {
        self.state.lock().map(|st| st.stalls).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_dispenses_each_chunk_once() {
        let p = RowPartition::even(100, 7).unwrap();
        let q = WorkQueue::new(&p);
        let mut seen = Vec::new();
        while let Some((id, r)) = q.pop() {
            seen.push((id, r));
        }
        assert_eq!(seen.len(), 7);
        for (i, (id, r)) in seen.iter().enumerate() {
            assert_eq!(*id, i);
            assert_eq!(*r, p.ranges()[i]);
            assert_eq!(*r, q.ranges()[*id]);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn queue_is_thread_safe() {
        let p = RowPartition::even(1000, 64).unwrap();
        let q = WorkQueue::new(&p);
        let counter = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    while q.pop().is_some() {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn board_round_trip() {
        let b = ResultBoard::new(3);
        b.put(1, vec![1.0]).unwrap();
        b.put(0, vec![0.0]).unwrap();
        b.put(2, vec![2.0]).unwrap();
        let chunks = b.into_chunks().unwrap();
        assert_eq!(chunks, vec![vec![0.0], vec![1.0], vec![2.0]]);
    }

    #[test]
    fn board_rejects_double_and_missing() {
        let b = ResultBoard::new(2);
        b.put(0, vec![1.0]).unwrap();
        assert!(b.put(0, vec![1.0]).is_err());
        assert!(b.put(5, vec![1.0]).is_err());
        assert!(b.into_chunks().is_err()); // chunk 1 missing
    }

    const DEADLINE: Duration = Duration::from_secs(600);

    fn sched(bounds: &[usize], halos: &[usize]) -> StageScheduler {
        let ranges: Vec<Range<usize>> = bounds.windows(2).map(|w| w[0]..w[1]).collect();
        StageScheduler::new(&ranges, halos, DEADLINE)
    }

    /// Drive a scheduler to completion on one thread, recording dispatch
    /// order and asserting every `(chunk, stage)` runs exactly once with
    /// its dependencies already published.
    #[test]
    fn stage_scheduler_dispenses_every_task_dependency_safe() {
        let (chunks, stages) = (4usize, 3usize);
        let s = sched(&[0, 5, 10, 15, 20], &[0, 2, 2]);
        let mut published = vec![0usize; chunks];
        let mut seen = vec![vec![false; stages]; chunks];
        while let Some(t) = s.next_task().unwrap() {
            assert!(!seen[t.chunk][t.stage], "({}, {}) dispatched twice", t.chunk, t.stage);
            seen[t.chunk][t.stage] = true;
            if t.stage > 0 {
                // the dispatch rule: neighbours within the halo have
                // published the previous stage
                for d in [t.chunk.wrapping_sub(1), t.chunk + 1] {
                    if d < chunks {
                        assert!(
                            published[d] >= t.stage,
                            "({}, {}) dispatched before chunk {d} published",
                            t.chunk,
                            t.stage
                        );
                    }
                }
            }
            // half the tasks publish eagerly, half rely on complete()
            if t.stage + 1 < stages && t.chunk % 2 == 0 {
                s.mark_published(t.chunk, t.stage);
            }
            published[t.chunk] = published[t.chunk].max(t.stage + 1);
            s.complete(t.chunk, t.stage, vec![t.chunk as f32]);
        }
        assert!(seen.iter().all(|c| c.iter().all(|&v| v)));
        // drained schedulers keep answering None
        assert!(s.next_task().unwrap().is_none());
        assert_eq!(s.stalls(), 0);
    }

    #[test]
    fn stage_scheduler_runs_depth_first_once_deps_allow() {
        // 3 chunks × 2 stages, halo 1: after chunks 0 and 1 finish stage
        // 0, chunk 0's stage 1 outranks chunk 2's stage 0
        let s = sched(&[0, 4, 8, 12], &[0, 1]);
        let t = s.next_task().unwrap().unwrap();
        assert_eq!((t.chunk, t.stage), (0, 0));
        s.complete(0, 0, vec![]);
        let t = s.next_task().unwrap().unwrap();
        assert_eq!((t.chunk, t.stage), (1, 0));
        s.complete(1, 0, vec![]);
        let t = s.next_task().unwrap().unwrap();
        assert_eq!((t.chunk, t.stage), (0, 1), "deepest ready task wins");
    }

    #[test]
    fn stage_scheduler_migrates_the_value_slab() {
        let s = sched(&[0, 3, 6], &[0, 1]);
        let t0 = s.next_task().unwrap().unwrap();
        assert_eq!((t0.chunk, t0.stage), (0, 0));
        assert!(t0.vals.is_empty(), "stage 0 starts with no resident slab");
        let tb = s.next_task().unwrap().unwrap();
        assert_eq!((tb.chunk, tb.stage), (1, 0));
        s.complete(t0.chunk, 0, vec![7.0, 8.0, 9.0]);
        s.complete(tb.chunk, 0, vec![1.0, 2.0, 3.0]); // unblocks both stage 1s
        let t1 = s.next_task().unwrap().unwrap();
        assert_eq!((t1.chunk, t1.stage), (0, 1));
        assert_eq!(t1.vals, vec![7.0, 8.0, 9.0], "stage 1 inherits stage 0's output");
    }

    #[test]
    fn stage_scheduler_counts_stalls_and_times_out() {
        // chunk 0 checked out but never completed: chunk 1's stage-1
        // dependency can never be met, so a second worker stalls and the
        // sub-second deadline converts the would-be hang into an error
        let ranges = vec![0..4, 4..8];
        let s = StageScheduler::new(&ranges, &[0, 1], Duration::from_millis(150));
        let t = s.next_task().unwrap().unwrap();
        assert_eq!((t.chunk, t.stage), (0, 0));
        let u = s.next_task().unwrap().unwrap();
        assert_eq!((u.chunk, u.stage), (1, 0));
        s.complete(1, 0, vec![]);
        let err = s.next_task().unwrap_err();
        assert!(err.to_string().contains("no ready task"), "{err}");
        assert!(s.stalls() >= 1);
    }

    #[test]
    fn stage_scheduler_ignores_events_after_poison() {
        // stragglers may still report completions/publishes while the run
        // tears down: they must not panic, revive the queue, or mask the
        // poison — every subsequent next_task stays an error
        let ranges = vec![0..4, 4..8];
        let s = StageScheduler::new(&ranges, &[0, 1], DEADLINE);
        let t = s.next_task().unwrap().unwrap();
        s.poison();
        s.mark_published(t.chunk, t.stage);
        s.complete(t.chunk, t.stage, vec![1.0; 4]);
        let err = s.next_task().unwrap_err();
        assert!(err.to_string().contains("aborted"), "{err}");
        assert!(s.next_task().is_err());
    }

    #[test]
    fn stage_scheduler_poison_wakes_waiters() {
        let ranges = vec![0..4, 4..8];
        let s = StageScheduler::new(&ranges, &[0, 1], DEADLINE);
        // both stage-0 tasks out; a blocked next_task must observe poison
        let a = s.next_task().unwrap().unwrap();
        let b = s.next_task().unwrap().unwrap();
        assert_eq!((a.chunk, b.chunk), (0, 1));
        std::thread::scope(|scope| {
            let s = &s;
            let waiter = scope.spawn(move || s.next_task());
            std::thread::sleep(Duration::from_millis(30));
            s.poison();
            let err = waiter.join().unwrap().unwrap_err();
            assert!(err.to_string().contains("aborted"), "{err}");
        });
        // and every later call fails fast too
        assert!(s.next_task().is_err());
    }
}
