//! L3 coordinator: the paper's parallel-acceleration system contribution,
//! exposed through the lazy **`Plan`** API.
//!
//! ```text
//!  Plan::over(&x).gaussian(..).curvature(..).quantile(..)   (pure recording)
//!       └─ compile ──► planner: fuse streamable stages into groups
//!       └─ execute ──► per group:
//!            leader precomputes one RowGather per stage (boundary
//!            tables only — no melt matrix) ──► RowPartition (work queue)
//!            workers (std::thread::scope, work stealing) pull row chunks
//!            and stream them through ALL member stages while resident,
//!            in cache-sized tiles (ExecOptions::tile_rows) through a
//!            reused per-worker band buffer:
//!                stage 1: tile gather off the shared input + RowKernel
//!                stage k: local band re-melt (halo slab) + RowKernel
//!                halo rows: recomputed locally, or exchanged with the
//!                neighbouring chunks via the halo board ([`halo`],
//!                `ExecOptions::halo_mode`) under a dependency-aware
//!                (chunk, stage) scheduler ([`scheduler`]) that publishes
//!                boundary rows before chunk interiors finish
//!                Backend::Native → kernels::* broadcast cores
//!                Backend::Pjrt   → per-thread runtime::Engine (singleton
//!                                  groups; manifest loaded once, on the
//!                                  leader; materialized melt blocks —
//!                                  fixed-shape artifacts require them)
//!            aggregator reassembles chunks ──► ONE fold ──► group output
//! ```
//!
//! The kernel surface is open ([`kernel::RowKernel`]): gaussian, bilateral,
//! curvature, the `stats` rank reductions and local moments all implement
//! one object-safe trait, and user kernels plug into the same fusion and
//! chunk-streaming machinery. [`Job`]/[`run_job`]/[`run_pipeline`] remain
//! as thin spec-level shims (config files parse to them), with
//! `run_pipeline` doubling as the unfused fold→re-melt baseline.
//!
//! ## The 3-D halo-width rule
//!
//! The whole machinery is rank-general because chunks, halos and the
//! exchange board all live in *flat melt-row* space. For a `Same`-grid
//! `(D, H, W)` volume the flat rows are the voxels in `(z, y, x)`
//! row-major order, so a window of per-axis radii `(r_z, r_y, r_x)`
//! reaches
//!
//! ```text
//! flat_halo = min(r_z, D−1)·H·W + min(r_y, H−1)·W + min(r_x, W−1)
//! ```
//!
//! rows past a chunk boundary ([`crate::melt::melt::flat_halo`]): a chunk
//! is a stack of `(z, y)` lines of `W` voxels, and its halo spans whole
//! neighbouring lines in **both** the z and y directions — `r_z` full
//! slabs plus `r_y` lines plus the `r_x` in-line tail. Exchange-mode
//! boundary segments, recompute budgets and the scheduler's dependency
//! reach all use this one number, which is why 3-D pipelines stream
//! through [`halo::HaloBoard`] / [`scheduler::StageScheduler`] unchanged
//! (property-tested in `tests/integration_volume.rs`). Cut chunks on
//! whole-slab boundaries with [`plan::ChunkPolicy::Aligned`]`{ unit: H *
//! W, .. }`.
//!
//! Setup time (gather-plan build + partition + thread spawn) is metered
//! separately from compute time so Fig 6's "deduct the
//! process-initialization cost" methodology can be reproduced faithfully —
//! and the melt itself now runs *inside* the parallel compute window
//! (tile-streamed per worker; `RunMetrics::gather` meters it) instead of
//! serially on the leader. [`RunMetrics`] additionally counts logical
//! melt/fold passes so fusion is asserted, not assumed, and the gather
//! counters (`gather_rows`, `peak_band_bytes`, `melt_matrix_bytes`) pin
//! the tiled executor's zero-materialization claim.

pub mod aggregator;
pub mod exec;
pub mod halo;
pub mod job;
pub mod kernel;
pub mod metrics;
pub mod pipeline;
pub mod plan;
pub mod scheduler;
pub mod simulate;
pub mod worker;

pub use halo::HaloMode;
pub use job::{Backend, FilterKind, Job};
pub use kernel::{MomentStat, RowKernel};
pub use metrics::{PlanMetrics, RunMetrics};
pub use pipeline::{run_job, run_pipeline, ExecOptions, DEFAULT_TILE_ROWS};
pub use plan::{ChunkPolicy, CompiledPlan, Plan, Stage};
