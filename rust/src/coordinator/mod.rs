//! L3 coordinator: the paper's parallel-acceleration system contribution.
//!
//! The coordinator owns the whole Fig 2 schematic at runtime:
//!
//! ```text
//!  Job (filter spec) ──► plan (quasi-grid + chunking policy)
//!       melt x ──► MeltMatrix ──► RowPartition (work queue)
//!       workers (std::thread::scope, work stealing) pull row blocks:
//!           Backend::Native  → kernels::* broadcast cores
//!           Backend::Pjrt    → per-thread runtime::Engine (AOT artifacts)
//!       aggregator reassembles chunks ──► fold ──► output tensor
//! ```
//!
//! Setup time (melt + partition + thread spawn) is metered separately from
//! compute time so Fig 6's "deduct the process-initialization cost"
//! methodology can be reproduced faithfully.

pub mod aggregator;
pub mod job;
pub mod metrics;
pub mod pipeline;
pub mod plan;
pub mod scheduler;
pub mod simulate;
pub mod worker;

pub use job::{Backend, FilterKind, Job};
pub use metrics::RunMetrics;
pub use pipeline::{run_job, run_pipeline, ExecOptions};
