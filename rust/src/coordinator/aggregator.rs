//! Aggregation: chunk results -> grid tensor, plus partition-exact
//! statistics over results (the §2.4 aggregation-function path).

use crate::error::Result;
use crate::melt::fold::fold_partitions;
use crate::melt::partition::RowPartition;
use crate::stats::descriptive::{moments, Moments};
use crate::tensor::dense::Tensor;

/// Reassemble chunk outputs (in partition order) into the grid tensor.
pub fn assemble(
    chunks: &[Vec<f32>],
    partition: &RowPartition,
    grid_shape: &[usize],
) -> Result<Tensor<f32>> {
    fold_partitions(chunks, partition.ranges(), grid_shape)
}

/// Merge per-chunk moments into the global statistics without touching the
/// assembled tensor — the MapReduce-style combine the paper contrasts with
/// sample-determined statistics.
pub fn merged_moments(chunks: &[Vec<f32>]) -> Moments {
    chunks
        .iter()
        .map(|c| moments(c))
        .fold(Moments::new(), |acc, m| acc.merge(&m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn assemble_round_trips() {
        let partition = RowPartition::even(10, 3).unwrap();
        let data: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let chunks: Vec<Vec<f32>> = partition
            .ranges()
            .iter()
            .map(|r| data[r.clone()].to_vec())
            .collect();
        let t = assemble(&chunks, &partition, &[2, 5]).unwrap();
        assert_eq!(t.data(), &data[..]);
    }

    #[test]
    fn merged_moments_equal_global_property() {
        check_property("chunked moments == global", 25, |rng: &mut SplitMix64| {
            let n = 10 + rng.below(300);
            let data = rng.uniform_vec(n, -50.0, 50.0);
            let partition = RowPartition::even(n, 1 + rng.below(6)).unwrap();
            let chunks: Vec<Vec<f32>> = partition
                .ranges()
                .iter()
                .map(|r| data[r.clone()].to_vec())
                .collect();
            let merged = merged_moments(&chunks);
            let global = moments(&data);
            assert_eq!(merged.count, global.count);
            assert!((merged.mean - global.mean).abs() < 1e-8);
            assert!((merged.variance() - global.variance()).abs() < 1e-6);
        });
    }
}
