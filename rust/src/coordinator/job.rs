//! Job specifications: the declarative *spec* layer over the open
//! [`RowKernel`] trait.
//!
//! A [`Job`] is what configs (TOML/JSON) and the CLI parse into; execution
//! lowers it to a [`Stage`] via [`Job::to_stage`] and runs through the lazy
//! `Plan` machinery — `FilterKind` is no longer the closed execution
//! surface, just a serializable catalogue of the built-in kernels
//! (including the `stats`-layer reductions: rank statistics and local
//! moments).

use std::sync::Arc;

use crate::coordinator::kernel::{
    BilateralRowKernel, CurvatureRowKernel, GaussianRowKernel, LocalMomentKernel, MomentStat,
    RankRowKernel, RowKernel,
};
use crate::coordinator::plan::Stage;
use crate::error::{Error, Result};
use crate::kernels::bilateral::{BilateralParams, RangeSigma};
use crate::kernels::rankfilter::RankKind;
use crate::melt::grid::GridMode;
use crate::melt::melt::BoundaryMode;
use crate::melt::operator::Operator;

/// Which built-in computation a job applies over the melt rows.
#[derive(Clone, Debug, PartialEq)]
pub enum FilterKind {
    /// Global gaussian filter, isotropic `sigma` (paper Fig 6 workload).
    Gaussian { sigma: f32 },
    /// Bilateral with constant σ_r (Fig 3 c/d).
    BilateralConst { sigma_d: f32, sigma_r: f32 },
    /// Bilateral with locally adaptive σ_r (Fig 3 b).
    BilateralAdaptive { sigma_d: f32, floor: f32 },
    /// N-D Gaussian curvature (Figs 4/5).
    Curvature,
    /// Per-row order statistic (the `stats::rank` reduction — §2.4's
    /// sample-determined class, exact under partitioning per row).
    Rank(RankKind),
    /// Per-row descriptive moment (the `stats::descriptive` path).
    LocalMoment(MomentStat),
}

impl FilterKind {
    /// The manifest `kind` string this filter resolves to on the PJRT
    /// path, when an AOT artifact exists for it.
    pub fn artifact_kind(&self) -> Option<&'static str> {
        match self {
            FilterKind::Gaussian { .. } => Some("gaussian"),
            FilterKind::BilateralConst { .. } => Some("bilateral_const"),
            FilterKind::BilateralAdaptive { .. } => Some("bilateral_adaptive"),
            FilterKind::Curvature => Some("curvature"),
            FilterKind::Rank(_) | FilterKind::LocalMoment(_) => None,
        }
    }

    /// Validate numeric parameters.
    pub fn validate(&self) -> Result<()> {
        let ok = match self {
            FilterKind::Gaussian { sigma } => *sigma > 0.0,
            FilterKind::BilateralConst { sigma_d, sigma_r } => *sigma_d > 0.0 && *sigma_r > 0.0,
            FilterKind::BilateralAdaptive { sigma_d, floor } => *sigma_d > 0.0 && *floor > 0.0,
            FilterKind::Curvature | FilterKind::LocalMoment(_) => true,
            FilterKind::Rank(kind) => match kind {
                RankKind::Quantile(q) => (0.0..=1.0).contains(q),
                _ => true,
            },
        };
        if ok {
            Ok(())
        } else {
            Err(Error::Coordinator(format!("invalid filter parameters: {self:?}")))
        }
    }

    /// Lower the spec to an executable [`RowKernel`] for `window`.
    pub fn build_kernel(&self, window: &[usize]) -> Result<Arc<dyn RowKernel>> {
        let kernel: Arc<dyn RowKernel> = match self {
            FilterKind::Gaussian { sigma } => Arc::new(GaussianRowKernel::new(window, *sigma)?),
            FilterKind::BilateralConst { sigma_d, sigma_r } => {
                Arc::new(BilateralRowKernel::constant(window, *sigma_d, *sigma_r)?)
            }
            FilterKind::BilateralAdaptive { sigma_d, floor } => {
                Arc::new(BilateralRowKernel::adaptive(window, *sigma_d, *floor)?)
            }
            FilterKind::Curvature => Arc::new(CurvatureRowKernel::new(window)?),
            FilterKind::Rank(kind) => Arc::new(RankRowKernel::new(*kind)?),
            FilterKind::LocalMoment(stat) => Arc::new(LocalMomentKernel::new(*stat)),
        };
        Ok(kernel)
    }

    /// Native-path bilateral params, if this is a bilateral filter.
    pub fn bilateral_params(&self, window: &[usize]) -> Result<Option<BilateralParams>> {
        Ok(match self {
            FilterKind::BilateralConst { sigma_d, sigma_r } => Some(BilateralParams::isotropic(
                window,
                *sigma_d,
                RangeSigma::Constant(*sigma_r),
            )?),
            FilterKind::BilateralAdaptive { sigma_d, floor } => Some(BilateralParams::isotropic(
                window,
                *sigma_d,
                RangeSigma::Adaptive { floor: *floor },
            )?),
            _ => None,
        })
    }
}

/// Execution backend: the Fig 8 "swap the computing backend under a stable
/// array API" axis. Plans are backend-agnostic — the same stage graph runs
/// on either; the planner only restricts *fusion* to the native backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Rust broadcast kernels (`kernels::*`).
    Native,
    /// AOT-compiled L1 Pallas kernels via PJRT (`runtime::Engine`).
    Pjrt,
}

/// A complete filtering job over one tensor.
#[derive(Clone, Debug)]
pub struct Job {
    pub kind: FilterKind,
    pub window: Vec<usize>,
    pub grid: GridMode,
    pub boundary: BoundaryMode,
}

impl Job {
    fn with_defaults(kind: FilterKind, window: &[usize]) -> Self {
        Self {
            kind,
            window: window.to_vec(),
            grid: GridMode::Same,
            boundary: BoundaryMode::Reflect,
        }
    }

    /// Gaussian job with `Same` grid and reflect boundary (the defaults the
    /// paper's benchmarks use).
    pub fn gaussian(window: &[usize], sigma: f32) -> Self {
        Self::with_defaults(FilterKind::Gaussian { sigma }, window)
    }

    pub fn bilateral_const(window: &[usize], sigma_d: f32, sigma_r: f32) -> Self {
        Self::with_defaults(FilterKind::BilateralConst { sigma_d, sigma_r }, window)
    }

    pub fn bilateral_adaptive(window: &[usize], sigma_d: f32, floor: f32) -> Self {
        Self::with_defaults(FilterKind::BilateralAdaptive { sigma_d, floor }, window)
    }

    pub fn curvature(window: &[usize]) -> Self {
        Self::with_defaults(FilterKind::Curvature, window)
    }

    /// Median filter job (`stats::rank` through the coordinator).
    pub fn median(window: &[usize]) -> Self {
        Self::with_defaults(FilterKind::Rank(RankKind::Median), window)
    }

    /// Per-row quantile job, `q` in `[0, 1]`.
    pub fn quantile(window: &[usize], q: f64) -> Self {
        Self::with_defaults(FilterKind::Rank(RankKind::Quantile(q)), window)
    }

    /// Per-row minimum (morphological erosion) job.
    pub fn rank_min(window: &[usize]) -> Self {
        Self::with_defaults(FilterKind::Rank(RankKind::Min), window)
    }

    /// Per-row maximum (morphological dilation) job.
    pub fn rank_max(window: &[usize]) -> Self {
        Self::with_defaults(FilterKind::Rank(RankKind::Max), window)
    }

    /// Local mean map job (`stats::descriptive` through the coordinator).
    pub fn local_mean(window: &[usize]) -> Self {
        Self::with_defaults(FilterKind::LocalMoment(MomentStat::Mean), window)
    }

    /// Local standard-deviation map job.
    pub fn local_std(window: &[usize]) -> Self {
        Self::with_defaults(FilterKind::LocalMoment(MomentStat::Std), window)
    }

    /// Build the operator and validate the whole spec.
    pub fn operator(&self) -> Result<Operator> {
        self.kind.validate()?;
        Operator::new(&self.window)
    }

    /// Lower this spec into an executable [`Stage`] for the `Plan` path.
    pub fn to_stage(&self) -> Result<Stage> {
        self.kind.validate()?;
        Ok(Stage::new(self.kind.build_kernel(&self.window)?, &self.window)?
            .with_grid(self.grid.clone())
            .with_boundary(self.boundary))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_default_same_reflect() {
        let j = Job::gaussian(&[3, 3, 3], 1.0);
        assert_eq!(j.grid, GridMode::Same);
        assert_eq!(j.boundary, BoundaryMode::Reflect);
        assert_eq!(j.kind.artifact_kind(), Some("gaussian"));
        j.operator().unwrap();
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(Job::gaussian(&[3, 3], 0.0).operator().is_err());
        assert!(Job::bilateral_const(&[3, 3], 1.0, -2.0).operator().is_err());
        assert!(Job::bilateral_adaptive(&[3, 3], 0.0, 1.0).operator().is_err());
        assert!(Job::gaussian(&[4, 4], 1.0).operator().is_err()); // even window
        assert!(Job::quantile(&[3, 3], 1.5).operator().is_err());
        assert!(Job::quantile(&[3, 3], 1.5).to_stage().is_err());
    }

    #[test]
    fn artifact_kind_mapping() {
        assert_eq!(
            Job::bilateral_const(&[5, 5], 1.0, 2.0).kind.artifact_kind(),
            Some("bilateral_const")
        );
        assert_eq!(
            Job::bilateral_adaptive(&[5, 5], 1.0, 2.0).kind.artifact_kind(),
            Some("bilateral_adaptive")
        );
        assert_eq!(Job::curvature(&[3, 3]).kind.artifact_kind(), Some("curvature"));
        // the stats reductions are native-only
        assert_eq!(Job::median(&[3, 3]).kind.artifact_kind(), None);
        assert_eq!(Job::local_std(&[3, 3]).kind.artifact_kind(), None);
    }

    #[test]
    fn bilateral_params_only_for_bilateral() {
        assert!(Job::gaussian(&[3, 3], 1.0)
            .kind
            .bilateral_params(&[3, 3])
            .unwrap()
            .is_none());
        let p = Job::bilateral_const(&[3, 3], 1.5, 10.0)
            .kind
            .bilateral_params(&[3, 3])
            .unwrap()
            .unwrap();
        assert_eq!(p.spatial.len(), 9);
    }

    #[test]
    fn to_stage_carries_geometry_and_kernel() {
        let mut j = Job::quantile(&[3, 3], 0.25);
        j.boundary = BoundaryMode::Nearest;
        j.grid = GridMode::Valid;
        let s = j.to_stage().unwrap();
        assert_eq!(s.kernel().name(), "quantile");
        assert_eq!(s.window(), &[3, 3]);
        assert_eq!(s.grid(), &GridMode::Valid);
        assert_eq!(s.boundary(), BoundaryMode::Nearest);
    }
}
