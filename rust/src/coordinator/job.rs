//! Job specifications: what to compute, on which backend.

use crate::error::{Error, Result};
use crate::kernels::bilateral::{BilateralParams, RangeSigma};
use crate::melt::grid::GridMode;
use crate::melt::melt::BoundaryMode;
use crate::melt::operator::Operator;

/// Which filter a job applies over the melt rows.
#[derive(Clone, Debug, PartialEq)]
pub enum FilterKind {
    /// Global gaussian filter, isotropic `sigma` (paper Fig 6 workload).
    Gaussian { sigma: f32 },
    /// Bilateral with constant σ_r (Fig 3 c/d).
    BilateralConst { sigma_d: f32, sigma_r: f32 },
    /// Bilateral with locally adaptive σ_r (Fig 3 b).
    BilateralAdaptive { sigma_d: f32, floor: f32 },
    /// N-D Gaussian curvature (Figs 4/5).
    Curvature,
}

impl FilterKind {
    /// The manifest `kind` string this filter resolves to on the PJRT path.
    pub fn artifact_kind(&self) -> &'static str {
        match self {
            FilterKind::Gaussian { .. } => "gaussian",
            FilterKind::BilateralConst { .. } => "bilateral_const",
            FilterKind::BilateralAdaptive { .. } => "bilateral_adaptive",
            FilterKind::Curvature => "curvature",
        }
    }

    /// Validate numeric parameters.
    pub fn validate(&self) -> Result<()> {
        let ok = match self {
            FilterKind::Gaussian { sigma } => *sigma > 0.0,
            FilterKind::BilateralConst { sigma_d, sigma_r } => *sigma_d > 0.0 && *sigma_r > 0.0,
            FilterKind::BilateralAdaptive { sigma_d, floor } => *sigma_d > 0.0 && *floor > 0.0,
            FilterKind::Curvature => true,
        };
        if ok {
            Ok(())
        } else {
            Err(Error::Coordinator(format!("invalid filter parameters: {self:?}")))
        }
    }

    /// Native-path bilateral params, if this is a bilateral filter.
    pub fn bilateral_params(&self, window: &[usize]) -> Result<Option<BilateralParams>> {
        Ok(match self {
            FilterKind::BilateralConst { sigma_d, sigma_r } => Some(BilateralParams::isotropic(
                window,
                *sigma_d,
                RangeSigma::Constant(*sigma_r),
            )?),
            FilterKind::BilateralAdaptive { sigma_d, floor } => Some(BilateralParams::isotropic(
                window,
                *sigma_d,
                RangeSigma::Adaptive { floor: *floor },
            )?),
            _ => None,
        })
    }
}

/// Execution backend: the Fig 8 "swap the computing backend under a stable
/// array API" axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Rust broadcast kernels (`kernels::*`).
    Native,
    /// AOT-compiled L1 Pallas kernels via PJRT (`runtime::Engine`).
    Pjrt,
}

/// A complete filtering job over one tensor.
#[derive(Clone, Debug)]
pub struct Job {
    pub kind: FilterKind,
    pub window: Vec<usize>,
    pub grid: GridMode,
    pub boundary: BoundaryMode,
}

impl Job {
    /// Gaussian job with `Same` grid and reflect boundary (the defaults the
    /// paper's benchmarks use).
    pub fn gaussian(window: &[usize], sigma: f32) -> Self {
        Self {
            kind: FilterKind::Gaussian { sigma },
            window: window.to_vec(),
            grid: GridMode::Same,
            boundary: BoundaryMode::Reflect,
        }
    }

    pub fn bilateral_const(window: &[usize], sigma_d: f32, sigma_r: f32) -> Self {
        Self {
            kind: FilterKind::BilateralConst { sigma_d, sigma_r },
            window: window.to_vec(),
            grid: GridMode::Same,
            boundary: BoundaryMode::Reflect,
        }
    }

    pub fn bilateral_adaptive(window: &[usize], sigma_d: f32, floor: f32) -> Self {
        Self {
            kind: FilterKind::BilateralAdaptive { sigma_d, floor },
            window: window.to_vec(),
            grid: GridMode::Same,
            boundary: BoundaryMode::Reflect,
        }
    }

    pub fn curvature(window: &[usize]) -> Self {
        Self {
            kind: FilterKind::Curvature,
            window: window.to_vec(),
            grid: GridMode::Same,
            boundary: BoundaryMode::Reflect,
        }
    }

    /// Build the operator and validate the whole spec.
    pub fn operator(&self) -> Result<Operator> {
        self.kind.validate()?;
        Operator::new(&self.window)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_default_same_reflect() {
        let j = Job::gaussian(&[3, 3, 3], 1.0);
        assert_eq!(j.grid, GridMode::Same);
        assert_eq!(j.boundary, BoundaryMode::Reflect);
        assert_eq!(j.kind.artifact_kind(), "gaussian");
        j.operator().unwrap();
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(Job::gaussian(&[3, 3], 0.0).operator().is_err());
        assert!(Job::bilateral_const(&[3, 3], 1.0, -2.0).operator().is_err());
        assert!(Job::bilateral_adaptive(&[3, 3], 0.0, 1.0).operator().is_err());
        assert!(Job::gaussian(&[4, 4], 1.0).operator().is_err()); // even window
    }

    #[test]
    fn artifact_kind_mapping() {
        assert_eq!(
            Job::bilateral_const(&[5, 5], 1.0, 2.0).kind.artifact_kind(),
            "bilateral_const"
        );
        assert_eq!(
            Job::bilateral_adaptive(&[5, 5], 1.0, 2.0).kind.artifact_kind(),
            "bilateral_adaptive"
        );
        assert_eq!(Job::curvature(&[3, 3]).kind.artifact_kind(), "curvature");
    }

    #[test]
    fn bilateral_params_only_for_bilateral() {
        assert!(Job::gaussian(&[3, 3], 1.0)
            .kind
            .bilateral_params(&[3, 3])
            .unwrap()
            .is_none());
        let p = Job::bilateral_const(&[3, 3], 1.5, 10.0)
            .kind
            .bilateral_params(&[3, 3])
            .unwrap()
            .unwrap();
        assert_eq!(p.spatial.len(), 9);
    }
}
