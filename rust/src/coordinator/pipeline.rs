//! The legacy coordinator entry points, now thin shims over the lazy
//! `Plan` executor.
//!
//! [`run_job`] lowers one [`Job`] spec to a [`Stage`](crate::coordinator::Stage)
//! and runs the barrier path; [`run_pipeline`] chains `run_job` stage by
//! stage — the fold→re-melt baseline the fused
//! [`Plan`](crate::coordinator::Plan) path is benchmarked against
//! (`benches/pipeline_fusion.rs`). New code should prefer
//! `Plan::over(&x).gaussian(..).curvature(..).run(&opts)`: same results
//! bit-for-bit, one global melt/fold per fused group instead of one per
//! stage.

use std::path::PathBuf;
use std::time::Duration;

use crate::coordinator::exec::run_single_stage;
use crate::coordinator::halo::{HaloMode, DEFAULT_WAIT_DEADLINE};
use crate::coordinator::job::{Backend, Job};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::plan::ChunkPolicy;
use crate::error::{Error, Result};
use crate::simd::SimdMode;
use crate::tensor::dense::Tensor;

/// Execution options for a coordinator run.
#[derive(Clone, Debug)]
pub struct ExecOptions {
    /// Parallel worker threads (1 = the paper's "Single" series).
    pub workers: usize,
    /// Native rust kernels or AOT-compiled Pallas kernels via PJRT.
    pub backend: Backend,
    /// Artifact directory (required for [`Backend::Pjrt`]).
    pub artifact_dir: Option<PathBuf>,
    /// Chunking override; defaults to the backend-appropriate policy.
    pub chunk_policy: Option<ChunkPolicy>,
    /// How fused groups handle cross-chunk halo rows: recompute them
    /// locally (default) or exchange them through a
    /// [`HaloBoard`](crate::coordinator::halo) — see the crate-level "halo
    /// accounting" docs.
    pub halo_mode: HaloMode,
    /// Backstop deadline on any single exchange-mode wait (halo-board cell
    /// fetch or scheduler task wait) before the run errors out. Defaults
    /// to 600 s — generous enough to ride out a neighbour's legitimate
    /// compute; drop it (config `halo_wait_secs`, CLI `--halo-wait-secs`)
    /// so a genuine scheduling bug fails fast instead of hanging CI.
    pub halo_wait: Duration,
    /// Rows per gather→kernel tile on the native backend: each worker
    /// melts at most this many rows into its reusable band buffer before
    /// running the stage kernel over them, so band writes and kernel reads
    /// stay cache-resident and per-worker scratch is `O(tile_rows · cols)`
    /// instead of `O(rows · cols)` globally. Output is bit-for-bit
    /// invariant under this knob (kernels are row-independent, §2.4).
    /// Defaults to [`DEFAULT_TILE_ROWS`]; floored at 1 (config
    /// `tile_rows`, CLI `--tile-rows`). PJRT ignores it — fixed-shape
    /// artifacts consume whole materialized row blocks.
    pub tile_rows: usize,
    /// SIMD lane policy for the native row kernels: `Auto` (runtime CPU
    /// dispatch, the default), `ForceScalar` (pin every worker to the
    /// scalar reference loops — config `simd = "scalar"`, CLI `--no-simd`)
    /// or `ForceSimd` (portable lane path even without AVX2, used by the
    /// parity tests and benches). Purely a performance knob: every lane
    /// replays the scalar operation order, so results are bit-for-bit
    /// identical under all three values. Defaults to the `MELTFRAME_SIMD`
    /// environment variable when set (`auto` | `scalar` | `simd`), else
    /// `Auto`.
    pub simd: SimdMode,
}

/// Default gather→kernel tile height: a few hundred rows keeps the band
/// (`tile · cols · 4` bytes — 9 KiB for a 3×3 window, 27 KiB for 3×3×3)
/// and the output slice comfortably inside L2 while amortizing per-tile
/// loop overhead.
pub const DEFAULT_TILE_ROWS: usize = 256;

impl ExecOptions {
    /// Native backend with `workers` threads.
    pub fn native(workers: usize) -> Self {
        Self {
            workers,
            backend: Backend::Native,
            artifact_dir: None,
            chunk_policy: None,
            halo_mode: HaloMode::Recompute,
            halo_wait: DEFAULT_WAIT_DEADLINE,
            tile_rows: DEFAULT_TILE_ROWS,
            simd: SimdMode::env_default(),
        }
    }

    /// PJRT backend over `dir` with `workers` threads.
    pub fn pjrt(workers: usize, dir: impl Into<PathBuf>) -> Self {
        Self {
            workers,
            backend: Backend::Pjrt,
            artifact_dir: Some(dir.into()),
            chunk_policy: None,
            halo_mode: HaloMode::Recompute,
            halo_wait: DEFAULT_WAIT_DEADLINE,
            tile_rows: DEFAULT_TILE_ROWS,
            simd: SimdMode::env_default(),
        }
    }

    /// Builder-style SIMD policy override. Purely a performance knob:
    /// results are bit-for-bit identical under every mode.
    pub fn with_simd(mut self, simd: SimdMode) -> Self {
        self.simd = simd;
        self
    }

    /// Builder-style override of the native gather→kernel tile height,
    /// floored at 1. Purely a performance/footprint knob: results are
    /// bit-for-bit identical for every value.
    pub fn with_tile_rows(mut self, tile_rows: usize) -> Self {
        self.tile_rows = tile_rows.max(1);
        self
    }

    /// Builder-style halo mode override for fused groups.
    pub fn with_halo_mode(mut self, mode: HaloMode) -> Self {
        self.halo_mode = mode;
        self
    }

    /// Builder-style override of the exchange wait deadline, floored at
    /// 1 s — a (near-)zero deadline would turn ordinary scheduling waits
    /// into spurious errors, which is why config (`halo_wait_secs`) and
    /// CLI (`--halo-wait-secs`) reject 0 outright.
    pub fn with_halo_wait(mut self, deadline: Duration) -> Self {
        self.halo_wait = deadline.max(Duration::from_secs(1));
        self
    }

    pub(crate) fn resolve_policy(&self, pjrt_chunk_rows: usize) -> ChunkPolicy {
        if let Some(p) = self.chunk_policy {
            return p;
        }
        match self.backend {
            Backend::Native => ChunkPolicy::native_default(),
            Backend::Pjrt => ChunkPolicy::Fixed {
                chunk_rows: pjrt_chunk_rows,
            },
        }
    }
}

/// Run one job over `x`: melt → partition → parallel execute → aggregate.
/// Thin shim over the single-stage `Plan` executor.
pub fn run_job(
    x: &Tensor<f32>,
    job: &Job,
    opts: &ExecOptions,
) -> Result<(Tensor<f32>, RunMetrics)> {
    if opts.workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    let stage = job.to_stage()?;
    // the legacy shim discards output statistics, so skip collecting them
    let (out, metrics, _moments) = run_single_stage(x, &stage, opts, false)?;
    Ok((out, metrics))
}

/// Run a sequence of jobs, feeding each stage's output to the next, with a
/// full fold → re-melt barrier between stages. Returns the final tensor
/// and per-stage metrics.
///
/// This is the *unfused* baseline: it materializes every intermediate
/// tensor and re-melts it globally. Prefer the lazy
/// [`Plan`](crate::coordinator::Plan), which fuses compatible stages into
/// one melt/fold and streams chunks through all of them worker-resident;
/// its output is bit-for-bit identical (asserted in
/// `tests/integration_plan.rs`).
pub fn run_pipeline(
    x: &Tensor<f32>,
    jobs: &[Job],
    opts: &ExecOptions,
) -> Result<(Tensor<f32>, Vec<RunMetrics>)> {
    if jobs.is_empty() {
        return Err(Error::Coordinator("empty pipeline".into()));
    }
    let mut cur = x.clone();
    let mut all = Vec::with_capacity(jobs.len());
    for job in jobs {
        let (next, metrics) = run_job(&cur, job, opts)?;
        all.push(metrics);
        cur = next;
    }
    Ok((cur, all))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::convolve::gaussian_filter;
    use crate::melt::melt::BoundaryMode;
    use crate::melt::operator::Operator;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    #[test]
    fn single_worker_matches_serial_convolve() {
        let x = Tensor::random(&[12, 13], 0.0, 255.0, 3).unwrap();
        let job = Job::gaussian(&[3, 3], 1.0);
        let (got, metrics) = run_job(&x, &job, &ExecOptions::native(1)).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let want = gaussian_filter(&x, &op, 1.0, BoundaryMode::Reflect).unwrap();
        assert_allclose(got.data(), want.data(), 1e-6, 1e-5);
        assert_eq!(metrics.rows, 12 * 13);
        assert_eq!(metrics.cols, 9);
        assert_eq!(metrics.melts, 1);
        assert_eq!(metrics.folds, 1);
    }

    #[test]
    fn worker_count_does_not_change_results_property() {
        // the §2.4 independence claim, end to end
        check_property("output invariant under worker count", 10, |rng: &mut SplitMix64| {
            let dims = [6 + rng.below(8), 6 + rng.below(8)];
            let x = Tensor::random(&dims, 0.0, 255.0, rng.next_u64()).unwrap();
            let job = match rng.below(4) {
                0 => Job::gaussian(&[3, 3], 1.0),
                1 => Job::bilateral_const(&[3, 3], 1.5, 25.0),
                2 => Job::quantile(&[3, 3], 0.75),
                _ => Job::curvature(&[3, 3]),
            };
            let (base, _) = run_job(&x, &job, &ExecOptions::native(1)).unwrap();
            for workers in [2, 3, 4] {
                let (out, m) = run_job(&x, &job, &ExecOptions::native(workers)).unwrap();
                assert_allclose(out.data(), base.data(), 0.0, 0.0);
                assert_eq!(m.chunks_per_worker.len(), workers);
            }
        });
    }

    #[test]
    fn pipeline_composes_stages() {
        let x = Tensor::random(&[10, 10], 0.0, 255.0, 9).unwrap();
        let jobs = vec![Job::gaussian(&[3, 3], 1.0), Job::curvature(&[3, 3])];
        let (out, metrics) = run_pipeline(&x, &jobs, &ExecOptions::native(2)).unwrap();
        assert_eq!(out.shape(), x.shape());
        assert_eq!(metrics.len(), 2);
        // manual two-stage
        let (s1, _) = run_job(&x, &jobs[0], &ExecOptions::native(1)).unwrap();
        let (s2, _) = run_job(&s1, &jobs[1], &ExecOptions::native(1)).unwrap();
        assert_allclose(out.data(), s2.data(), 0.0, 0.0);
    }

    #[test]
    fn stats_reductions_run_through_the_coordinator() {
        // per-row quantile: previously unreachable from the coordinator
        let x = Tensor::random(&[9, 9], 0.0, 100.0, 12).unwrap();
        let (out, m) = run_job(&x, &Job::quantile(&[3, 3], 0.5), &ExecOptions::native(2)).unwrap();
        assert_eq!(out.shape(), x.shape());
        assert_eq!(m.stages, 1);
        // reference: serial melt + rank filter
        let op = Operator::cubic(3, 2).unwrap();
        let melt = crate::melt::melt::melt(
            &x,
            &op,
            crate::melt::grid::GridMode::Same,
            BoundaryMode::Reflect,
        )
        .unwrap();
        let want = crate::kernels::rankfilter::rank_filter(
            &melt,
            crate::kernels::rankfilter::RankKind::Quantile(0.5),
        )
        .unwrap();
        assert_allclose(out.data(), &want, 0.0, 0.0);
    }

    #[test]
    fn rejects_zero_workers_and_empty_pipeline() {
        let x = Tensor::zeros(&[4, 4]).unwrap();
        assert!(run_job(&x, &Job::gaussian(&[3, 3], 1.0), &ExecOptions::native(0)).is_err());
        assert!(run_pipeline(&x, &[], &ExecOptions::native(1)).is_err());
    }

    #[test]
    fn custom_chunk_policy_respected() {
        let x = Tensor::random(&[16, 16], 0.0, 1.0, 4).unwrap();
        let mut opts = ExecOptions::native(2);
        opts.chunk_policy = Some(ChunkPolicy::Fixed { chunk_rows: 50 });
        let (_, m) = run_job(&x, &Job::gaussian(&[3, 3], 1.0), &opts).unwrap();
        // 256 rows / 50 = 6 chunks
        assert_eq!(m.chunks_per_worker.iter().sum::<usize>(), 6);
    }

    #[test]
    fn halo_wait_defaults_and_overrides() {
        let opts = ExecOptions::native(2);
        assert_eq!(opts.halo_wait, DEFAULT_WAIT_DEADLINE);
        let opts = opts.with_halo_wait(Duration::from_secs(45));
        assert_eq!(opts.halo_wait, Duration::from_secs(45));
        // the builder floors at 1 s: a zero deadline would turn ordinary
        // scheduling waits into spurious errors
        let opts = opts.with_halo_wait(Duration::ZERO);
        assert_eq!(opts.halo_wait, Duration::from_secs(1));
    }

    #[test]
    fn pjrt_requires_artifact_dir() {
        let x = Tensor::zeros(&[4, 4]).unwrap();
        let opts = ExecOptions {
            workers: 1,
            backend: Backend::Pjrt,
            artifact_dir: None,
            chunk_policy: None,
            halo_mode: HaloMode::Recompute,
            halo_wait: DEFAULT_WAIT_DEADLINE,
            tile_rows: DEFAULT_TILE_ROWS,
            simd: SimdMode::Auto,
        };
        assert!(run_job(&x, &Job::gaussian(&[3, 3], 1.0), &opts).is_err());
    }

    #[test]
    fn tile_rows_defaults_and_floors() {
        let opts = ExecOptions::native(2);
        assert_eq!(opts.tile_rows, DEFAULT_TILE_ROWS);
        let opts = opts.with_tile_rows(64);
        assert_eq!(opts.tile_rows, 64);
        // a zero tile would make the tile loop spin; the builder floors it
        assert_eq!(opts.with_tile_rows(0).tile_rows, 1);
    }

    #[test]
    fn simd_mode_never_changes_results_and_counters_partition_rows() {
        // the tentpole's correctness claim at the run_job surface: forced
        // scalar and forced lanes agree bit-for-bit, and the two counters
        // partition the gathered rows exactly
        check_property("output invariant under simd mode", 6, |rng: &mut SplitMix64| {
            let dims = [5 + rng.below(8), 5 + rng.below(8)];
            let x = Tensor::random(&dims, 0.0, 255.0, rng.next_u64()).unwrap();
            let job = Job::gaussian(&[3, 3], 1.2);
            let scalar_opts = ExecOptions::native(2).with_simd(SimdMode::ForceScalar);
            let (base, ms) = run_job(&x, &job, &scalar_opts).unwrap();
            assert_eq!(ms.simd_rows, 0, "pinned-scalar run took a lane path");
            assert_eq!(ms.scalar_rows, ms.gather_rows);
            for mode in [SimdMode::Auto, SimdMode::ForceSimd] {
                let opts = ExecOptions::native(2).with_simd(mode);
                let (out, m) = run_job(&x, &job, &opts).unwrap();
                assert_allclose(out.data(), base.data(), 0.0, 0.0);
                assert_eq!(m.simd_rows + m.scalar_rows, m.gather_rows, "{mode}");
            }
            let forced = run_job(&x, &job, &ExecOptions::native(2).with_simd(SimdMode::ForceSimd))
                .unwrap()
                .1;
            if forced.simd_rows > 0 {
                assert_eq!(forced.simd_lanes, crate::simd::LANES);
            }
        });
    }

    #[test]
    fn tile_rows_never_changes_results_property() {
        // the tentpole's correctness claim at the run_job surface: output
        // is invariant under the tile height, including degenerate tiles
        check_property("output invariant under tile_rows", 8, |rng: &mut SplitMix64| {
            let dims = [5 + rng.below(8), 5 + rng.below(8)];
            let x = Tensor::random(&dims, 0.0, 255.0, rng.next_u64()).unwrap();
            let job = Job::median(&[3, 3]);
            let (base, _) = run_job(&x, &job, &ExecOptions::native(2)).unwrap();
            for tile in [1usize, 7, 100_000] {
                let opts = ExecOptions::native(2).with_tile_rows(tile);
                let (out, m) = run_job(&x, &job, &opts).unwrap();
                assert_allclose(out.data(), base.data(), 0.0, 0.0);
                assert_eq!(m.melt_matrix_bytes, 0, "native runs never materialize");
                assert!(m.gather_rows >= m.rows);
            }
        });
    }
}
