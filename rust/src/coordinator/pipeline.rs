//! The coordinator entry points: run one job or a multi-stage pipeline over
//! a tensor with a worker fleet — the executable form of paper Fig 2.

use std::path::PathBuf;
use std::sync::Barrier;
use std::time::Instant;

use crate::coordinator::aggregator::assemble;
use crate::coordinator::job::{Backend, Job};
use crate::coordinator::metrics::RunMetrics;
use crate::coordinator::plan::ChunkPolicy;
use crate::coordinator::scheduler::{ResultBoard, WorkQueue};
use crate::coordinator::worker::{JobResources, WorkerContext};
use crate::error::{Error, Result};
use crate::melt::grid::QuasiGrid;
use crate::melt::melt::melt_into;
use crate::melt::matrix::MeltMatrix;
use crate::tensor::dense::Tensor;

/// Execution options for a coordinator run.
#[derive(Clone, Debug)]
pub struct ExecOptions {
    /// Parallel worker threads (1 = the paper's "Single" series).
    pub workers: usize,
    /// Native rust kernels or AOT-compiled Pallas kernels via PJRT.
    pub backend: Backend,
    /// Artifact directory (required for [`Backend::Pjrt`]).
    pub artifact_dir: Option<PathBuf>,
    /// Chunking override; defaults to the backend-appropriate policy.
    pub chunk_policy: Option<ChunkPolicy>,
}

impl ExecOptions {
    /// Native backend with `workers` threads.
    pub fn native(workers: usize) -> Self {
        Self {
            workers,
            backend: Backend::Native,
            artifact_dir: None,
            chunk_policy: None,
        }
    }

    /// PJRT backend over `dir` with `workers` threads.
    pub fn pjrt(workers: usize, dir: impl Into<PathBuf>) -> Self {
        Self {
            workers,
            backend: Backend::Pjrt,
            artifact_dir: Some(dir.into()),
            chunk_policy: None,
        }
    }

    fn resolve_policy(&self, pjrt_chunk_rows: usize) -> ChunkPolicy {
        if let Some(p) = self.chunk_policy {
            return p;
        }
        match self.backend {
            Backend::Native => ChunkPolicy::native_default(),
            Backend::Pjrt => ChunkPolicy::Fixed {
                chunk_rows: pjrt_chunk_rows,
            },
        }
    }
}

/// Run one job over `x`: melt → partition → parallel execute → aggregate.
pub fn run_job(x: &Tensor<f32>, job: &Job, opts: &ExecOptions) -> Result<(Tensor<f32>, RunMetrics)> {
    if opts.workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    let t_setup = Instant::now();
    let res = JobResources::prepare(job)?;
    let op = job.operator()?;
    let grid = QuasiGrid::resolve(x.shape(), &op, &job.grid)?;

    // melt (leader-side; row-decoupled by construction); uninitialized
    // buffer is sound — melt_into writes every element (§Perf iteration 4)
    let rows = grid.rows();
    let cols = op.ravel_len();
    let mut data = crate::melt::melt::uninit_buffer(rows * cols);
    melt_into(x, &op, &grid, job.boundary, &mut data)?;
    let m = MeltMatrix::new(data, rows, cols, grid.out_shape().to_vec(), op.window().to_vec())?;

    // partition per policy; PJRT needs the manifest's fixed chunk height
    let pjrt_chunk_rows = match opts.backend {
        Backend::Pjrt => {
            let dir = opts.artifact_dir.as_ref().ok_or_else(|| {
                Error::Coordinator("PJRT backend requires an artifact directory".into())
            })?;
            crate::runtime::artifact::ArtifactManifest::load(dir)?.chunk_rows
        }
        Backend::Native => 0,
    };
    let partition = opts.resolve_policy(pjrt_chunk_rows).partition(rows, opts.workers)?;
    partition.validate()?;

    let queue = WorkQueue::new(&partition);
    let board = ResultBoard::new(queue.num_chunks());
    let mut chunk_counts = vec![0usize; opts.workers];
    // +1: the leader also waits on the barrier to timestamp compute start
    // only after every worker finished its (PJRT) engine build.
    let barrier = Barrier::new(opts.workers + 1);

    let mut setup = t_setup.elapsed();
    let mut compute = std::time::Duration::ZERO;

    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(opts.workers);
        for _ in 0..opts.workers {
            let res = &res;
            let m = &m;
            let queue = &queue;
            let board = &board;
            let barrier = &barrier;
            let opts = &opts;
            handles.push(s.spawn(move || -> Result<(usize, Instant, Instant)> {
                // engine build + artifact compile = setup, not compute
                let ctx = WorkerContext::build(res, opts.backend, opts.artifact_dir.as_ref());
                barrier.wait();
                let ctx = ctx?;
                // workers self-report their compute window: the leader may
                // be descheduled at barrier release, so leader-side clocks
                // would under-measure the parallel phase.
                let t0 = Instant::now();
                let mut done = 0usize;
                while let Some((id, range)) = queue.pop() {
                    let block = m.row_block(range.start, range.end)?;
                    let out = ctx.execute(res, block, range.len())?;
                    board.put(id, out)?;
                    done += 1;
                }
                Ok((done, t0, Instant::now()))
            }));
        }
        barrier.wait();
        setup = t_setup.elapsed();
        let mut first_start: Option<Instant> = None;
        let mut last_end: Option<Instant> = None;
        for (w, h) in handles.into_iter().enumerate() {
            let (done, t0, t1) = h
                .join()
                .map_err(|_| Error::Coordinator(format!("worker {w} panicked")))??;
            chunk_counts[w] = done;
            first_start = Some(first_start.map_or(t0, |f| f.min(t0)));
            last_end = Some(last_end.map_or(t1, |l| l.max(t1)));
        }
        compute = match (first_start, last_end) {
            (Some(a), Some(b)) => b.duration_since(a),
            _ => std::time::Duration::ZERO,
        };
        Ok(())
    })?;

    let t_agg = Instant::now();
    let chunks = board.into_chunks()?;
    let out = assemble(&chunks, &partition, m.grid_shape())?;
    let aggregate = t_agg.elapsed();

    Ok((
        out,
        RunMetrics {
            setup,
            compute,
            aggregate,
            chunks_per_worker: chunk_counts,
            rows,
            cols,
        },
    ))
}

/// Run a sequence of jobs, feeding each stage's output to the next
/// (the "new workflows" composition of the paper's abstract). Returns the
/// final tensor and per-stage metrics.
pub fn run_pipeline(
    x: &Tensor<f32>,
    jobs: &[Job],
    opts: &ExecOptions,
) -> Result<(Tensor<f32>, Vec<RunMetrics>)> {
    if jobs.is_empty() {
        return Err(Error::Coordinator("empty pipeline".into()));
    }
    let mut cur = x.clone();
    let mut all = Vec::with_capacity(jobs.len());
    for job in jobs {
        let (next, metrics) = run_job(&cur, job, opts)?;
        all.push(metrics);
        cur = next;
    }
    Ok((cur, all))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::convolve::gaussian_filter;
    use crate::melt::melt::BoundaryMode;
    use crate::melt::operator::Operator;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    #[test]
    fn single_worker_matches_serial_convolve() {
        let x = Tensor::random(&[12, 13], 0.0, 255.0, 3).unwrap();
        let job = Job::gaussian(&[3, 3], 1.0);
        let (got, metrics) = run_job(&x, &job, &ExecOptions::native(1)).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let want = gaussian_filter(&x, &op, 1.0, BoundaryMode::Reflect).unwrap();
        assert_allclose(got.data(), want.data(), 1e-6, 1e-5);
        assert_eq!(metrics.rows, 12 * 13);
        assert_eq!(metrics.cols, 9);
    }

    #[test]
    fn worker_count_does_not_change_results_property() {
        // the §2.4 independence claim, end to end
        check_property("output invariant under worker count", 10, |rng: &mut SplitMix64| {
            let dims = [6 + rng.below(8), 6 + rng.below(8)];
            let x = Tensor::random(&dims, 0.0, 255.0, rng.next_u64()).unwrap();
            let job = match rng.below(3) {
                0 => Job::gaussian(&[3, 3], 1.0),
                1 => Job::bilateral_const(&[3, 3], 1.5, 25.0),
                _ => Job::curvature(&[3, 3]),
            };
            let (base, _) = run_job(&x, &job, &ExecOptions::native(1)).unwrap();
            for workers in [2, 3, 4] {
                let (out, m) = run_job(&x, &job, &ExecOptions::native(workers)).unwrap();
                assert_allclose(out.data(), base.data(), 0.0, 0.0);
                assert_eq!(m.chunks_per_worker.len(), workers);
            }
        });
    }

    #[test]
    fn pipeline_composes_stages() {
        let x = Tensor::random(&[10, 10], 0.0, 255.0, 9).unwrap();
        let jobs = vec![Job::gaussian(&[3, 3], 1.0), Job::curvature(&[3, 3])];
        let (out, metrics) = run_pipeline(&x, &jobs, &ExecOptions::native(2)).unwrap();
        assert_eq!(out.shape(), x.shape());
        assert_eq!(metrics.len(), 2);
        // manual two-stage
        let (s1, _) = run_job(&x, &jobs[0], &ExecOptions::native(1)).unwrap();
        let (s2, _) = run_job(&s1, &jobs[1], &ExecOptions::native(1)).unwrap();
        assert_allclose(out.data(), s2.data(), 0.0, 0.0);
    }

    #[test]
    fn rejects_zero_workers_and_empty_pipeline() {
        let x = Tensor::zeros(&[4, 4]).unwrap();
        assert!(run_job(&x, &Job::gaussian(&[3, 3], 1.0), &ExecOptions::native(0)).is_err());
        assert!(run_pipeline(&x, &[], &ExecOptions::native(1)).is_err());
    }

    #[test]
    fn custom_chunk_policy_respected() {
        let x = Tensor::random(&[16, 16], 0.0, 1.0, 4).unwrap();
        let mut opts = ExecOptions::native(2);
        opts.chunk_policy = Some(ChunkPolicy::Fixed { chunk_rows: 50 });
        let (_, m) = run_job(&x, &Job::gaussian(&[3, 3], 1.0), &opts).unwrap();
        // 256 rows / 50 = 6 chunks
        assert_eq!(m.chunks_per_worker.iter().sum::<usize>(), 6);
    }

    #[test]
    fn pjrt_requires_artifact_dir() {
        let x = Tensor::zeros(&[4, 4]).unwrap();
        let opts = ExecOptions {
            workers: 1,
            backend: Backend::Pjrt,
            artifact_dir: None,
            chunk_policy: None,
        };
        assert!(run_job(&x, &Job::gaussian(&[3, 3], 1.0), &opts).is_err());
    }
}
