//! The plan executor: Fig 2 at runtime, for lazy stage graphs.
//!
//! ## Tile-streamed, leader-free gathers (native backend)
//!
//! The native hot loop never materializes a melt matrix and has no serial
//! leader melt phase. The leader precomputes one [`RowGather`] per stage
//! (the per-axis boundary tables — cheap, `O(Σ extent·window)`); every
//! worker then gathers its **own** rows straight from the shared input
//! tensor, in cache-sized tiles of [`ExecOptions::tile_rows`] rows: melt
//! `tile × cols` values into a reusable per-worker band buffer, run the
//! stage's [`RowKernel`] over them, advance. Peak gather scratch drops
//! from `O(rows · cols)` (a window-size× blow-up of the input — 9× for
//! 3×3, 27× for 3×3×3) to `O(workers · tile · cols)`, band writes and
//! kernel reads stay in L2, and the melt — previously a single-threaded
//! leader phase that Amdahl-capped every scaling figure — runs inside the
//! workers' parallel compute window ([`RunMetrics::gather`],
//! [`RunMetrics::gather_rows`], [`RunMetrics::peak_band_bytes`] meter it;
//! [`RunMetrics::melt_matrix_bytes`] is exactly 0 on this path). The PJRT
//! backend still materializes row blocks — its fixed-shape artifacts
//! consume whole chunks — and reports the materialized bytes.
//!
//! Singleton groups run the classic barrier path (tiled gather→kernel per
//! chunk → fold on native; global melt → partition → execute → fold on
//! PJRT). Fused groups run the chunk-resident streaming path: stage 1
//! gathers from the input tensor, then each worker pushes its chunk
//! through *all* remaining stages while the intermediate values are
//! resident — stage `k ≥ 2` re-melts locally from a halo-extended value
//! slab of stage `k − 1` (see [`crate::melt::melt::melt_band_into`])
//! instead of waiting for a global fold → re-melt barrier, tile by tile
//! through the same band buffer. The result: a fused n-stage group
//! performs exactly one *logical* melt pass and one global fold, never
//! materializes an intermediate full tensor, and runs every gather in
//! parallel.
//!
//! Halo accounting: stage `k`'s gathers reach at most
//! `flat_halo(grid, op_k)` rows from each output row. Fused groups handle
//! the rows a chunk needs beyond its own interior in one of two ways,
//! selected by [`ExecOptions::halo_mode`]:
//!
//! * [`HaloMode::Recompute`] — chunk `[s, e)` runs every stage over
//!   `[s − B_k, e + B_k)` (clamped), where `B_k = Σ_{j>k} flat_halo(op_j)`
//!   is the *downstream* halo budget. Rows in the overlap are computed by
//!   more than one worker — duplicated kernel work, zero synchronization,
//!   any chunk count (so work stealing stays fully general).
//! * [`HaloMode::Exchange`] — every chunk computes each stage over its
//!   interior only and trades boundary rows with its neighbours through a
//!   [`HaloBoard`](crate::coordinator::halo::HaloBoard). Work is dispatched
//!   one `(chunk, stage)` task at a time by the dependency-aware
//!   [`StageScheduler`](crate::coordinator::scheduler::StageScheduler): a
//!   task starts only after every neighbour it gathers from has published
//!   the previous stage, so workers never block inside the board on the
//!   hot path and chunks migrate freely between workers across stages —
//!   any chunk count is live, and exchange gets the same over-partitioned
//!   load balancing as recompute. Within a task the stage's two boundary
//!   segments are computed *first* and published immediately — the chunk's
//!   interior then overlaps with the neighbours' next stage
//!   ([`RunMetrics::halo_eager_lead`] accumulates the head start). Zero
//!   duplicated kernel work ([`RunMetrics::halo_recomputed_rows`] is
//!   exactly 0); [`RunMetrics::sched_stalls`] counts how often a worker
//!   found no task ready.
//!
//! Bit-for-bit equality with the legacy path holds in both modes because
//! every gather copies the same values through the same boundary mapping
//! and every kernel is row-deterministic (§2.4 row independence) — an
//! exchanged row is the identical value its owner computed for itself.

use std::ops::Range;
use crate::sync::{Arc, Barrier, NamedBarrier};
use std::time::{Duration, Instant};

use crate::coordinator::aggregator::{assemble, merged_moments};
use crate::coordinator::halo::{HaloBoard, HaloMode, HaloStats};
use crate::coordinator::job::Backend;
use crate::coordinator::kernel::RowKernel;
use crate::coordinator::metrics::{PlanMetrics, RunMetrics};
use crate::coordinator::pipeline::ExecOptions;
use crate::coordinator::plan::{fused_partition, plan_groups, Stage};
use crate::coordinator::scheduler::{ResultBoard, StageScheduler, StageTask, WorkQueue};
use crate::coordinator::worker::{JobResources, WorkerContext};
use crate::error::{Error, Result};
use crate::melt::grid::{GridMode, QuasiGrid};
use crate::melt::matrix::MeltMatrix;
use crate::melt::melt::{flat_halo, melt_into, reuse_uninit, uninit_buffer, RowGather};
use crate::melt::operator::Operator;
use crate::serve::cache::{CacheDelta, CachedGroupPlan, PlanCache};
use crate::serve::pool::WorkerPool;
use crate::stats::descriptive::Moments;
use crate::tensor::dense::Tensor;

/// Clamp `range` extended by `budget` rows on both sides to `[0, rows)`.
fn extend(range: &Range<usize>, budget: usize, rows: usize) -> Range<usize> {
    range.start.saturating_sub(budget)..(range.end + budget).min(rows)
}

/// Where a run's workers come from: a fresh `thread::scope` fleet spawned
/// for this run (the one-shot default), or a long-lived
/// [`WorkerPool`](crate::serve::pool::WorkerPool) owned by a serving
/// [`Executor`](crate::serve::Executor). Both have identical semantics —
/// `workers` tasks that may borrow the caller's stack, a leader closure on
/// the calling thread, panic mapped to `Err("worker {w} panicked")` — so
/// every execution path below is fleet-agnostic.
#[derive(Clone, Copy)]
pub(crate) enum Fleet<'p> {
    /// Spawn (and join) a scoped thread per worker, per run.
    Scoped,
    /// Dispatch onto a persistent pool (must have >= `workers` threads).
    Pool(&'p WorkerPool),
}

/// Run `workers` instances of `work` on the fleet plus `leader` on the
/// calling thread; block until all finish. One `Result` per worker, in
/// index order.
fn run_fleet<T, F, L>(fleet: Fleet<'_>, workers: usize, work: F, leader: L) -> Vec<Result<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
    L: FnOnce(),
{
    match fleet {
        Fleet::Pool(pool) => {
            if workers > pool.size() {
                // a barrier across more tasks than pool threads would
                // deadlock — refuse before enqueueing anything
                return (0..workers)
                    .map(|_| {
                        Err(Error::Coordinator(format!(
                            "run needs {workers} workers but the pool has {}",
                            pool.size()
                        )))
                    })
                    .collect();
            }
            pool.run_scoped(workers, work, leader)
        }
        Fleet::Scoped => std::thread::scope(|s| {
            let work = &work;
            let handles: Vec<_> = (0..workers).map(|w| s.spawn(move || work(w))).collect();
            leader();
            handles
                .into_iter()
                .enumerate()
                .map(|(w, h)| {
                    h.join()
                        .unwrap_or_else(|_| Err(Error::Coordinator(format!("worker {w} panicked"))))
                })
                .collect()
        }),
    }
}

/// Build (or fetch from `cache`) the data-independent plan of one native
/// group: resolved grid, per-stage `RowGather` tables, halos and budgets.
/// The build runs outside any cache lock; on a hit nothing is built and
/// the returned [`CacheDelta`] says so.
pub(crate) fn group_plan(
    input_shape: &[usize],
    stages: &[Stage],
    opts: &ExecOptions,
    cache: Option<&PlanCache>,
) -> Result<(Arc<CachedGroupPlan>, CacheDelta)> {
    let build = || -> Result<CachedGroupPlan> {
        let n = stages.len();
        let ops: Vec<Operator> = stages.iter().map(|s| s.operator()).collect::<Result<_>>()?;
        let colsv: Vec<usize> = ops.iter().map(|o| o.ravel_len()).collect();
        // the first stage's quasi-grid defines the group's row space;
        // later stages are Same-mode over it (planner invariant)
        let grid = QuasiGrid::resolve(input_shape, &ops[0], stages[0].grid())?;
        let grid_shape = grid.out_shape().to_vec();
        let rows = grid.rows();
        let mut gathers: Vec<RowGather> = Vec::with_capacity(n);
        gathers.push(RowGather::new(input_shape, &ops[0], &grid, stages[0].boundary())?);
        for k in 1..n {
            let sg = QuasiGrid::resolve(&grid_shape, &ops[k], &GridMode::Same)?;
            gathers.push(RowGather::new(&grid_shape, &ops[k], &sg, stages[k].boundary())?);
        }
        // downstream halo budgets: stage k's output must cover the chunk
        // extended by the halos of every later stage
        let halos: Vec<usize> = ops.iter().map(|o| flat_halo(&grid_shape, o)).collect();
        let mut budget = vec![0usize; n];
        for k in (0..n.saturating_sub(1)).rev() {
            budget[k] = budget[k + 1] + halos[k + 1];
        }
        Ok(CachedGroupPlan {
            gathers,
            grid_shape,
            rows,
            colsv,
            halos,
            budget,
        })
    };
    match cache {
        Some(c) => c.get_or_build(&PlanCache::key_for(input_shape, stages, opts), build),
        None => {
            let plan = build()?;
            let built = plan.stages();
            Ok((
                Arc::new(plan),
                CacheDelta {
                    built,
                    ..Default::default()
                },
            ))
        }
    }
}

/// The gather→kernel tile loop shared by every native execution path:
/// stream rows `range` from `src` (the values of the virtual input tensor
/// from flat element `src_start` — the whole tensor for stage 0, a halo
/// slab for later fused stages) through `g` and `kernel` in `tile`-row
/// slices, writing one value per row into `out` (whose first element is
/// row `out_start`). `band` is the worker's reusable tile buffer — the
/// only melt storage this path ever allocates, metered via
/// `stats.peak_band_bytes`; both it and the touched `out` slice are fully
/// overwritten before any read (gathers cover every cell, kernels write
/// every row), so the uninit reuse is sound (§Perf iteration 4).
#[allow(clippy::too_many_arguments)]
fn run_tiled(
    g: &RowGather,
    src: &[f32],
    src_start: usize,
    kernel: &dyn RowKernel,
    tile: usize,
    range: Range<usize>,
    out_start: usize,
    out: &mut [f32],
    band: &mut Vec<f32>,
    stats: &mut HaloStats,
) -> Result<()> {
    let cols = g.cols();
    let tile = tile.max(1);
    let mut t = range.start;
    while t < range.end {
        let te = (t + tile).min(range.end);
        let n = te - t;
        reuse_uninit(band, n * cols);
        let t_gather = Instant::now();
        g.gather_rows(src, src_start, t..te, &mut band[..])?;
        stats.gather_time += t_gather.elapsed();
        stats.gather_rows += n;
        kernel.execute(&band[..], n, cols, &mut out[t - out_start..te - out_start])?;
        let (lane, scalar) = crate::simd::take_counters();
        stats.simd_rows += lane;
        stats.scalar_rows += scalar;
        if lane > 0 {
            stats.simd_lanes = stats.simd_lanes.max(crate::simd::LANES);
        }
        t = te;
    }
    stats.peak_band_bytes = stats
        .peak_band_bytes
        .max(band.capacity() * std::mem::size_of::<f32>());
    Ok(())
}

/// Execute a planned stage graph group by group, feeding each group's
/// output tensor to the next (one-shot: fresh scoped fleet, no cache).
/// Production callers go through [`execute_groups_with`] (via
/// `CompiledPlan::execute_on`); this shim keeps unit tests on the
/// one-shot signature.
#[cfg(test)]
pub(crate) fn execute_groups(
    x: &Tensor<f32>,
    stages: &[Stage],
    groups: &[Range<usize>],
    opts: &ExecOptions,
) -> Result<(Tensor<f32>, PlanMetrics)> {
    execute_groups_with(x, stages, groups, opts, Fleet::Scoped, None)
}

/// [`execute_groups`] with an explicit worker fleet and optional plan
/// cache — the entry point the serving [`Executor`](crate::serve::Executor)
/// uses to reuse threads and `RowGather` tables across jobs.
pub(crate) fn execute_groups_with(
    x: &Tensor<f32>,
    stages: &[Stage],
    groups: &[Range<usize>],
    opts: &ExecOptions,
    fleet: Fleet<'_>,
    cache: Option<&PlanCache>,
) -> Result<(Tensor<f32>, PlanMetrics)> {
    if opts.workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    if stages.is_empty() || groups.is_empty() {
        return Err(Error::Coordinator("empty plan".into()));
    }
    let mut cur: Option<Tensor<f32>> = None;
    let mut metrics = Vec::with_capacity(groups.len());
    let mut out_moments = Moments::new();
    for (gi, g) in groups.iter().enumerate() {
        // only the final group's statistics are kept — intermediate groups
        // skip the pass entirely
        let last = gi + 1 == groups.len();
        let input = cur.as_ref().unwrap_or(x);
        let (next, m, mom) = if g.len() == 1 {
            run_single_stage_with(input, &stages[g.start], opts, last, fleet, cache)?
        } else {
            run_fused_group_with(input, &stages[g.clone()], opts, last, fleet, cache)?
        };
        metrics.push(m);
        if let Some(mom) = mom {
            out_moments = mom;
        }
        cur = Some(next);
    }
    Ok((
        cur.expect("at least one group executed"),
        PlanMetrics {
            groups: metrics,
            output_moments: out_moments,
        },
    ))
}

/// Lift `s` onto a leading batch axis: prepend a unit window extent (and,
/// for strided grids, a unit stride) so the same kernel runs over a
/// `[N, …shape]` stack of same-shape inputs. The kernel `Arc` is shared,
/// not rebuilt — row kernels see only `cols = ravel_len`, which a unit
/// axis leaves unchanged, and the ravel order of the original window is
/// preserved. A unit extent has zero halo on that axis under **every**
/// boundary mode (the only offset is 0), so no gather ever reads across a
/// batch-member boundary: each slice of the stacked run is bit-for-bit
/// the tensor its own standalone run would produce.
pub(crate) fn lift_stage(s: &Stage) -> Result<Stage> {
    let mut w = Vec::with_capacity(s.window().len() + 1);
    w.push(1);
    w.extend_from_slice(s.window());
    let grid = match s.grid() {
        GridMode::Strided(v) => {
            let mut sv = Vec::with_capacity(v.len() + 1);
            sv.push(1);
            sv.extend_from_slice(v);
            GridMode::Strided(sv)
        }
        g => g.clone(),
    };
    Ok(Stage::new(Arc::clone(s.kernel()), &w)?
        .with_grid(grid)
        .with_boundary(s.boundary()))
}

/// The cross-request batching entry point: stack `inputs` (all the same
/// shape) along a fresh leading batch axis, lift every stage with
/// [`lift_stage`], run the whole stack through [`execute_groups_with`] —
/// one plan lookup, one melt and one fold per fused group for the entire
/// batch — and split the output back into one tensor per input. Each
/// group's [`RunMetrics::batched_jobs`] records the batch size. Note the
/// plan cache keys on the *stacked* shape, so batches of different sizes
/// occupy distinct cache entries.
pub(crate) fn execute_batch_with(
    inputs: &[Tensor<f32>],
    stages: &[Stage],
    opts: &ExecOptions,
    fleet: Fleet<'_>,
    cache: Option<&PlanCache>,
) -> Result<(Vec<Tensor<f32>>, PlanMetrics)> {
    let n = inputs.len();
    if n == 0 {
        return Err(Error::Coordinator("empty batch".into()));
    }
    let shape = inputs[0].shape().to_vec();
    for t in &inputs[1..] {
        if t.shape() != shape {
            return Err(Error::Coordinator(format!(
                "batched inputs must share one shape: {:?} vs {:?}",
                shape,
                t.shape()
            )));
        }
    }
    let per_in = inputs[0].data().len();
    let mut data = Vec::with_capacity(n * per_in);
    for t in inputs {
        data.extend_from_slice(t.data());
    }
    let mut stacked_shape = Vec::with_capacity(shape.len() + 1);
    stacked_shape.push(n);
    stacked_shape.extend_from_slice(&shape);
    let x = Tensor::from_vec(&stacked_shape, data)?;

    let lifted: Vec<Stage> = stages.iter().map(lift_stage).collect::<Result<_>>()?;
    // lifting preserves grid mode and boundary, so the lifted chain fuses
    // into exactly the groups the unlifted chain would
    let groups = plan_groups(&lifted, opts.backend);
    let (out, mut metrics) = execute_groups_with(&x, &lifted, &groups, opts, fleet, cache)?;
    for g in &mut metrics.groups {
        g.batched_jobs = n;
    }

    // the unit window extent and unit stride keep the batch axis at N
    // through every grid mode; anything else is a planner bug
    if out.shape().first() != Some(&n) {
        return Err(Error::Coordinator(format!(
            "batched output lost its batch axis: shape {:?} for a batch of {n}",
            out.shape()
        )));
    }
    let member_shape: Vec<usize> = out.shape()[1..].to_vec();
    let per_out: usize = member_shape.iter().product();
    let data = out.into_vec();
    let mut outs = Vec::with_capacity(n);
    for i in 0..n {
        outs.push(Tensor::from_vec(
            &member_shape,
            data[i * per_out..(i + 1) * per_out].to_vec(),
        )?);
    }
    Ok((outs, metrics))
}

/// The barrier path: one stage, gather → execute → fold, on either
/// backend. Native workers tile-stream their chunks straight from the
/// input tensor (no global melt matrix, no serial leader melt — every
/// boundary mode works, `Wrap` included, because workers read the shared
/// tensor); PJRT materializes the melt matrix on the leader, as its
/// fixed-shape artifacts require. Also the body of the legacy `run_job`
/// shim. `collect_moments` merges per-chunk output statistics (the §2.4
/// aggregation path) — skipped when the caller discards them, and always
/// outside the timed aggregation window.
pub(crate) fn run_single_stage(
    x: &Tensor<f32>,
    stage: &Stage,
    opts: &ExecOptions,
    collect_moments: bool,
) -> Result<(Tensor<f32>, RunMetrics, Option<Moments>)> {
    run_single_stage_with(x, stage, opts, collect_moments, Fleet::Scoped, None)
}

/// [`run_single_stage`] with an explicit fleet and optional plan cache.
pub(crate) fn run_single_stage_with(
    x: &Tensor<f32>,
    stage: &Stage,
    opts: &ExecOptions,
    collect_moments: bool,
    fleet: Fleet<'_>,
    cache: Option<&PlanCache>,
) -> Result<(Tensor<f32>, RunMetrics, Option<Moments>)> {
    if opts.workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    let t_setup = Instant::now();
    let res = JobResources::prepare(stage, opts.backend, opts.artifact_dir.as_ref())?;

    // gather plan vs materialized matrix, by backend: native fetches (or
    // precomputes — cheap boundary tables) the cached group plan and lets
    // every worker gather its own tiles; PJRT must materialize — its
    // artifacts consume whole fixed-height row blocks — and that
    // leader-side melt is metered and never cached
    let mut leader_gather = Duration::ZERO;
    let plan: Option<Arc<CachedGroupPlan>>;
    let delta: CacheDelta;
    let m: Option<MeltMatrix>;
    let (rows, cols, grid_shape): (usize, usize, Vec<usize>);
    match opts.backend {
        Backend::Native => {
            let (p, d) = group_plan(x.shape(), std::slice::from_ref(stage), opts, cache)?;
            rows = p.rows;
            cols = p.colsv[0];
            grid_shape = p.grid_shape.clone();
            plan = Some(p);
            delta = d;
            m = None;
        }
        Backend::Pjrt => {
            let op = stage.operator()?;
            let grid = QuasiGrid::resolve(x.shape(), &op, stage.grid())?;
            rows = grid.rows();
            cols = op.ravel_len();
            grid_shape = grid.out_shape().to_vec();
            let t_melt = Instant::now();
            let mut data = uninit_buffer(rows * cols);
            melt_into(x, &op, &grid, stage.boundary(), &mut data)?;
            leader_gather = t_melt.elapsed();
            m = Some(MeltMatrix::new(
                data,
                rows,
                cols,
                grid_shape.clone(),
                op.window().to_vec(),
            )?);
            plan = None;
            delta = CacheDelta::default();
        }
    }
    let gather = plan.as_ref().map(|p| &p.gathers[0]);

    // partition per policy; PJRT needs the manifest's fixed chunk height —
    // read from the resources loaded once above, not from disk again
    let pjrt_chunk_rows = res.manifest.as_ref().map(|mf| mf.chunk_rows).unwrap_or(0);
    let partition = opts.resolve_policy(pjrt_chunk_rows).partition(rows, opts.workers)?;
    partition.validate()?;

    let queue = WorkQueue::new(&partition);
    let board = ResultBoard::new(queue.num_chunks());
    let mut chunk_counts = vec![0usize; opts.workers];
    // +1: the leader also waits on the barrier to timestamp compute start
    // only after every worker finished its (PJRT) engine build.
    let barrier = Barrier::new_named("exec.fleet.barrier", opts.workers + 1);
    let backend = opts.backend;
    let tile = opts.tile_rows.max(1);

    let mut setup = t_setup.elapsed();
    let mut worker_stats = HaloStats::default();

    let work = |_w: usize| -> Result<(usize, Instant, Instant, HaloStats)> {
        // engine build + artifact compile = setup, not compute
        let ctx = WorkerContext::build(&res, backend);
        barrier.wait();
        // pin the job's SIMD mode on this (possibly pooled, reused) thread
        // and clear any counter residue from a previous job
        crate::simd::enter_job(opts.simd);
        let ctx = ctx?;
        // workers self-report their compute window: the leader may
        // be descheduled at barrier release, so leader-side clocks
        // would under-measure the parallel phase.
        let t0 = Instant::now();
        let mut done = 0usize;
        let mut stats = HaloStats::default();
        match &ctx {
            WorkerContext::Native => {
                let g = gather.expect("native path builds a RowGather");
                let mut band: Vec<f32> = Vec::new();
                while let Some((id, range)) = queue.pop() {
                    // fully overwritten tile by tile before the move
                    let mut out = uninit_buffer(range.len());
                    run_tiled(
                        g,
                        x.data(),
                        0,
                        res.kernel.as_ref(),
                        tile,
                        range.clone(),
                        range.start,
                        &mut out[..],
                        &mut band,
                        &mut stats,
                    )?;
                    board.put(id, out)?;
                    done += 1;
                }
            }
            pjrt => {
                let m = m.as_ref().expect("pjrt path materializes the melt matrix");
                while let Some((id, range)) = queue.pop() {
                    let block = m.row_block(range.start, range.end)?;
                    let out = pjrt.execute(&res, block, range.len())?;
                    board.put(id, out)?;
                    done += 1;
                }
            }
        }
        Ok((done, t0, Instant::now(), stats))
    };
    let results = run_fleet(fleet, opts.workers, work, || {
        barrier.wait();
        setup = t_setup.elapsed();
    });
    let mut first_start: Option<Instant> = None;
    let mut last_end: Option<Instant> = None;
    for (w, r) in results.into_iter().enumerate() {
        let (done, t0, t1, stats) = r?;
        chunk_counts[w] = done;
        worker_stats.add(&stats);
        first_start = Some(first_start.map_or(t0, |f| f.min(t0)));
        last_end = Some(last_end.map_or(t1, |l| l.max(t1)));
    }
    let compute = match (first_start, last_end) {
        (Some(a), Some(b)) => b.duration_since(a),
        _ => Duration::ZERO,
    };

    let t_agg = Instant::now();
    let chunks = board.into_chunks()?;
    let out = assemble(&chunks, &partition, &grid_shape)?;
    let aggregate = t_agg.elapsed();
    let moments = collect_moments.then(|| merged_moments(&chunks));

    // PJRT's melt happened serially on the leader; report it in the
    // gather phase totals so both backends' melt traffic is comparable
    let (gather_rows, gather_time) = match opts.backend {
        Backend::Native => (worker_stats.gather_rows, worker_stats.gather_time),
        Backend::Pjrt => (rows, leader_gather),
    };

    Ok((
        out,
        RunMetrics {
            setup,
            compute,
            aggregate,
            chunks_per_worker: chunk_counts,
            rows,
            cols,
            melts: 1,
            folds: 1,
            stages: 1,
            gather_rows,
            peak_band_bytes: worker_stats.peak_band_bytes,
            melt_matrix_bytes: m.as_ref().map_or(0, |m| m.data().len() * 4),
            gather: gather_time,
            simd_rows: worker_stats.simd_rows,
            scalar_rows: worker_stats.scalar_rows,
            simd_lanes: worker_stats.simd_lanes,
            plan_cache_hits: delta.hits,
            plan_cache_misses: delta.misses,
            plan_cache_evictions: delta.evictions,
            gathers_built: delta.built,
            ..Default::default()
        },
        moments,
    ))
}

/// One-shot shim over [`run_fused_group_with`] for unit tests.
#[cfg(test)]
pub(crate) fn run_fused_group(
    x: &Tensor<f32>,
    stages: &[Stage],
    opts: &ExecOptions,
    collect_moments: bool,
) -> Result<(Tensor<f32>, RunMetrics, Option<Moments>)> {
    run_fused_group_with(x, stages, opts, collect_moments, Fleet::Scoped, None)
}

/// The streaming path: every chunk flows through all member stages inside
/// its worker — stage 0 tile-gathered straight from the shared input
/// tensor (one *logical* melt pass, no materialized matrix, no serial
/// leader phase), later stages re-melting locally from halo slabs — on an
/// explicit fleet, with an optional serving plan cache.
pub(crate) fn run_fused_group_with(
    x: &Tensor<f32>,
    stages: &[Stage],
    opts: &ExecOptions,
    collect_moments: bool,
    fleet: Fleet<'_>,
    cache: Option<&PlanCache>,
) -> Result<(Tensor<f32>, RunMetrics, Option<Moments>)> {
    if stages.len() < 2 {
        return Err(Error::Coordinator("fused groups need at least 2 stages".into()));
    }
    if opts.backend != Backend::Native {
        return Err(Error::Coordinator(
            "fused groups execute on the native backend (the planner keeps PJRT stages in singleton groups)".into(),
        ));
    }
    if opts.workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    for s in &stages[1..] {
        if !s.streamable() {
            return Err(Error::Coordinator(
                "non-streamable stage inside a fused group (planner bug)".into(),
            ));
        }
    }

    let t_setup = Instant::now();
    let n = stages.len();
    let kernels: Vec<Arc<dyn RowKernel>> = stages.iter().map(|s| s.kernel().clone()).collect();

    // the group's whole data-independent plan — resolved grid, one
    // `RowGather` per stage (stage 0 reads the shared input tensor under
    // the group's grid, any boundary, Wrap included; stage k ≥ 1 re-melts
    // Same-grid value slabs of the grid shape), per-stage halos and
    // downstream budgets — fetched from the serving plan cache or built
    // once by the leader (cheap boundary tables). Workers gather their
    // own tiles through the shared plan; no melt matrix is materialized.
    let (plan, delta) = group_plan(x.shape(), stages, opts, cache)?;
    let grid_shape = plan.grid_shape.clone();
    let rows = plan.rows;
    let cols0 = plan.colsv[0];

    // both halo modes share the over-partitioned policy (≥ 1, ≤ 4 chunks
    // per worker): the stage scheduler keeps exchange live at any chunk
    // count, so it load-balances exactly like recompute
    let partition = fused_partition(rows, opts.workers, plan.budget[0], opts.chunk_policy)?;
    partition.validate()?;
    let queue = WorkQueue::new(&partition);
    let board = ResultBoard::new(queue.num_chunks());
    // exchange mode: board geometry mirrors the queue's chunk ranges, one
    // publish-once cell per (inter-stage halo, chunk) — an n-stage group
    // exchanges across its n − 1 stage transitions — plus the dependency
    // scheduler that dispenses (chunk, stage) tasks in gather-safe order
    let (halo_board, stage_sched) = match opts.halo_mode {
        HaloMode::Exchange => (
            Some(HaloBoard::new(queue.ranges(), n - 1, opts.halo_wait)?),
            Some(StageScheduler::new(queue.ranges(), &plan.halos, opts.halo_wait)),
        ),
        HaloMode::Recompute => (None, None),
    };
    let mut chunk_counts = vec![0usize; opts.workers];
    let barrier = Barrier::new_named("exec.fleet.barrier", opts.workers + 1);

    let shared = FusedShared {
        src: x.data(),
        gathers: &plan.gathers,
        kernels: &kernels,
        colsv: &plan.colsv,
        budget: &plan.budget,
        halos: &plan.halos,
        rows,
        tile: opts.tile_rows.max(1),
        queue: &queue,
        board: &board,
        halo: halo_board.as_ref(),
        sched: stage_sched.as_ref(),
    };

    let mut setup = t_setup.elapsed();
    let mut halo_stats = HaloStats::default();

    let work = |_w: usize| -> Result<(usize, Instant, Instant, HaloStats)> {
        barrier.wait();
        // pin the job's SIMD mode on this (possibly pooled, reused) thread
        // and clear any counter residue from a previous job
        crate::simd::enter_job(opts.simd);
        let t0 = Instant::now();
        // a failing worker — Err *or* panic — poisons the exchange
        // board AND the stage scheduler so blocked neighbours error
        // out instead of stalling until the watchdog; the guard
        // covers the unwind path (which a pooled fleet catches, so a
        // poisoned job never kills a pool thread)
        let guard = PoisonOnPanic(&shared);
        let result = fused_worker(&shared);
        std::mem::forget(guard);
        if result.is_err() {
            shared.poison_exchange();
        }
        let (done, stats) = result?;
        Ok((done, t0, Instant::now(), stats))
    };
    let results = run_fleet(fleet, opts.workers, work, || {
        barrier.wait();
        setup = t_setup.elapsed();
    });
    let mut first_start: Option<Instant> = None;
    let mut last_end: Option<Instant> = None;
    // inspect EVERY worker before failing: in exchange mode most workers
    // exit with the board's generic "aborted" error, so propagating the
    // first Err by worker index would mask the root cause — keep the
    // first error that is NOT the secondary abort message (worker panics
    // arrive here already mapped to `Err("worker {w} panicked")`).
    let mut first_err: Option<Error> = None;
    for (w, r) in results.into_iter().enumerate() {
        match r {
            Err(e) => keep_root_cause(e, &mut first_err),
            Ok((done, t0, t1, stats)) => {
                chunk_counts[w] = done;
                halo_stats.add(&stats);
                first_start = Some(first_start.map_or(t0, |f| f.min(t0)));
                last_end = Some(last_end.map_or(t1, |l| l.max(t1)));
            }
        }
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    let compute = match (first_start, last_end) {
        (Some(a), Some(b)) => b.duration_since(a),
        _ => Duration::ZERO,
    };

    let t_agg = Instant::now();
    let chunks = board.into_chunks()?;
    let out = assemble(&chunks, &partition, &grid_shape)?;
    let aggregate = t_agg.elapsed();
    let moments = collect_moments.then(|| merged_moments(&chunks));

    Ok((
        out,
        RunMetrics {
            setup,
            compute,
            aggregate,
            chunks_per_worker: chunk_counts,
            rows,
            cols: cols0,
            melts: 1,
            folds: 1,
            stages: n,
            halo_published_rows: halo_stats.published,
            halo_received_rows: halo_stats.received,
            halo_recomputed_rows: halo_stats.recomputed,
            halo_eager_lead: halo_stats.eager_lead,
            sched_stalls: stage_sched.as_ref().map_or(0, |s| s.stalls()),
            gather_rows: halo_stats.gather_rows,
            peak_band_bytes: halo_stats.peak_band_bytes,
            melt_matrix_bytes: 0,
            gather: halo_stats.gather_time,
            simd_rows: halo_stats.simd_rows,
            scalar_rows: halo_stats.scalar_rows,
            simd_lanes: halo_stats.simd_lanes,
            plan_cache_hits: delta.hits,
            plan_cache_misses: delta.misses,
            plan_cache_evictions: delta.evictions,
            gathers_built: delta.built,
            batched_jobs: 0,
        },
        moments,
    ))
}

/// Whether `e` is the halo board's *secondary* abort error — the one a
/// waiter returns because some OTHER worker failed first.
fn is_secondary_abort(e: &Error) -> bool {
    matches!(e, Error::Coordinator(m) if m == crate::coordinator::halo::ABORTED_MSG)
}

/// Record a worker error, preferring a root cause over the secondary
/// "another worker failed" abort that poisoned neighbours report.
fn keep_root_cause(e: Error, slot: &mut Option<Error>) {
    match slot {
        None => *slot = Some(e),
        Some(prev) if is_secondary_abort(prev) && !is_secondary_abort(&e) => *slot = Some(e),
        _ => {}
    }
}

/// Poisons the halo board and stage scheduler if dropped during a panic
/// unwind, so neighbours blocked on this worker's publishes fail fast
/// instead of waiting out the watchdog. Forgotten on the normal exit path
/// (`Err` poisoning is handled explicitly so the error itself is
/// preserved).
struct PoisonOnPanic<'a>(&'a FusedShared<'a>);

impl Drop for PoisonOnPanic<'_> {
    fn drop(&mut self) {
        self.0.poison_exchange();
    }
}

/// Leader-owned state shared (by reference) with every fused worker.
struct FusedShared<'a> {
    /// The input tensor's values — stage 0's gather source.
    src: &'a [f32],
    /// One precomputed gather per stage: `gathers[0]` reads the input
    /// tensor, `gathers[k ≥ 1]` re-melt value slabs of the grid shape.
    gathers: &'a [RowGather],
    kernels: &'a [Arc<dyn RowKernel>],
    colsv: &'a [usize],
    /// Downstream halo budgets `B_k` (recompute mode).
    budget: &'a [usize],
    /// Per-stage halos `flat_halo(op_k)` (exchange mode).
    halos: &'a [usize],
    rows: usize,
    /// Gather→kernel tile height (`ExecOptions::tile_rows`).
    tile: usize,
    queue: &'a WorkQueue,
    board: &'a ResultBoard,
    halo: Option<&'a HaloBoard>,
    sched: Option<&'a StageScheduler>,
}

impl FusedShared<'_> {
    /// Fail the exchange machinery (no-op in recompute mode).
    fn poison_exchange(&self) {
        if let Some(hb) = self.halo {
            hb.poison();
        }
        if let Some(sc) = self.sched {
            sc.poison();
        }
    }
}

/// One fused worker's lifetime, dispatched per halo mode: recompute pops
/// whole chunks off the work queue; exchange pulls `(chunk, stage)` tasks
/// off the dependency scheduler.
fn fused_worker(sh: &FusedShared<'_>) -> Result<(usize, HaloStats)> {
    match (sh.halo, sh.sched) {
        (Some(hb), Some(sc)) => exchange_worker(sh, hb, sc),
        _ => recompute_worker(sh),
    }
}

/// Recompute-mode worker: pop chunks until the queue drains, pushing each
/// through every member stage chunk-resident. Scratch slabs are reused
/// across chunks; the finished value slab is moved (not cloned) onto the
/// result board.
fn recompute_worker(sh: &FusedShared<'_>) -> Result<(usize, HaloStats)> {
    let mut done = 0usize;
    let mut stats = HaloStats::default();
    // reusable per-worker scratch: current/next value slabs and the local
    // re-melt band
    let mut vals: Vec<f32> = Vec::new();
    let mut next_vals: Vec<f32> = Vec::new();
    let mut band: Vec<f32> = Vec::new();
    while let Some((id, range)) = sh.queue.pop() {
        recompute_chunk(sh, &range, &mut vals, &mut next_vals, &mut band, &mut stats)?;
        debug_assert_eq!(vals.len(), range.len());
        // move the slab out; the next iteration clear()/resize()s it anyway
        sh.board.put(id, std::mem::take(&mut vals))?;
        done += 1;
    }
    Ok((done, stats))
}

/// Exchange-mode worker: pull dependency-satisfied `(chunk, stage)` tasks
/// until every chunk has run every stage. The chunk's value slab travels
/// through the scheduler between stages (chunks migrate across workers);
/// `band`/`slab`/`next_vals` stay worker-local scratch. A worker's "chunk
/// count" is the number of chunks whose *final* stage it ran, keeping the
/// per-worker totals summing to the chunk count as in recompute mode.
fn exchange_worker(
    sh: &FusedShared<'_>,
    hb: &HaloBoard,
    sched: &StageScheduler,
) -> Result<(usize, HaloStats)> {
    let n = sh.kernels.len();
    let mut done = 0usize;
    let mut stats = HaloStats::default();
    let mut next_vals: Vec<f32> = Vec::new();
    let mut band: Vec<f32> = Vec::new();
    let mut slab: Vec<f32> = Vec::new();
    while let Some(task) = sched.next_task()? {
        let StageTask { chunk, stage, mut vals } = task;
        let range = sh.queue.ranges()[chunk].clone();
        exchange_stage(
            sh, hb, sched, chunk, stage, &range, &mut vals, &mut next_vals, &mut band, &mut slab,
            &mut stats,
        )?;
        debug_assert_eq!(vals.len(), range.len());
        if stage + 1 == n {
            sh.board.put(chunk, std::mem::take(&mut vals))?;
            done += 1;
        }
        sched.complete(chunk, stage, vals);
    }
    Ok((done, stats))
}

/// Recompute-mode chunk: every stage runs over the chunk extended by its
/// downstream halo budget, so all gathers resolve locally — tile-streamed
/// through the worker's reused `band` buffer at every stage.
fn recompute_chunk(
    sh: &FusedShared<'_>,
    range: &Range<usize>,
    vals: &mut Vec<f32>,
    next_vals: &mut Vec<f32>,
    band: &mut Vec<f32>,
    stats: &mut HaloStats,
) -> Result<()> {
    // stage 0 over the halo-extended range, gathered tile by tile
    // straight from the shared input tensor
    let ext0 = extend(range, sh.budget[0], sh.rows);
    reuse_uninit(vals, ext0.len());
    run_tiled(
        &sh.gathers[0],
        sh.src,
        0,
        sh.kernels[0].as_ref(),
        sh.tile,
        ext0.clone(),
        ext0.start,
        &mut vals[..],
        band,
        stats,
    )?;
    stats.recomputed += ext0.len() - range.len();
    let mut prev_range = ext0;
    // remaining stages: local band re-melt from the previous slab, then
    // the kernel — all chunk-resident, all tiled
    for k in 1..sh.kernels.len() {
        let ext = extend(range, sh.budget[k], sh.rows);
        reuse_uninit(next_vals, ext.len());
        run_tiled(
            &sh.gathers[k],
            &vals[..],
            prev_range.start,
            sh.kernels[k].as_ref(),
            sh.tile,
            ext.clone(),
            ext.start,
            &mut next_vals[..],
            band,
            stats,
        )?;
        std::mem::swap(vals, next_vals);
        stats.recomputed += ext.len() - range.len();
        prev_range = ext;
    }
    debug_assert_eq!(&prev_range, range);
    Ok(())
}

/// Run stage `k` over the sub-range `rows_sub` of a chunk starting at
/// `chunk_start`, writing into the matching slice of `out` (one value per
/// row). Stage 0 gathers from the shared input tensor; later stages
/// re-melt a local band from `gathered = (source slab, its first row)`.
/// Both go through the tile streamer.
#[allow(clippy::too_many_arguments)]
fn run_stage_rows(
    sh: &FusedShared<'_>,
    k: usize,
    gathered: Option<(&[f32], usize)>,
    rows_sub: Range<usize>,
    chunk_start: usize,
    band: &mut Vec<f32>,
    out: &mut [f32],
    stats: &mut HaloStats,
) -> Result<()> {
    if rows_sub.is_empty() {
        return Ok(());
    }
    let (src, src_start) = gathered.unwrap_or((sh.src, 0));
    run_tiled(
        &sh.gathers[k],
        src,
        src_start,
        sh.kernels[k].as_ref(),
        sh.tile,
        rows_sub,
        chunk_start,
        out,
        band,
        stats,
    )
}

/// Exchange-mode stage task: run stage `stage` over chunk `id`'s interior
/// only — boundary segments first, published to the board the moment they
/// are computed, interior second — with neighbour rows gathered off the
/// board (non-blocking in practice: the scheduler dispatched this task
/// because they are already published).
#[allow(clippy::too_many_arguments)]
fn exchange_stage(
    sh: &FusedShared<'_>,
    hb: &HaloBoard,
    sched: &StageScheduler,
    id: usize,
    stage: usize,
    range: &Range<usize>,
    vals: &mut Vec<f32>,
    next_vals: &mut Vec<f32>,
    band: &mut Vec<f32>,
    slab: &mut Vec<f32>,
    stats: &mut HaloStats,
) -> Result<()> {
    let n = sh.kernels.len();
    let (s, e) = (range.start, range.end);
    let len = range.len();
    // a single chunk has no neighbours to trade with
    let trading = hb.num_chunks() > 1;

    // gather source for this stage: stage 0 reads the input tensor; stage
    // k ≥ 1 reads the resident stage-(k−1) slab, extended by neighbour
    // rows fetched off the board when the halo reaches past the interior
    let gathered: Option<(&[f32], usize)> = if stage == 0 {
        None
    } else {
        let h = sh.halos[stage];
        let lo = s.saturating_sub(h);
        let hi = (e + h).min(sh.rows);
        if lo == s && hi == e {
            Some((&vals[..], s))
        } else {
            // fully overwritten: interior copied, both halo segments
            // fetched — so the zero-fill of resize() is skipped (§Perf
            // iteration 4)
            reuse_uninit(slab, hi - lo);
            slab[s - lo..s - lo + len].copy_from_slice(&vals[..]);
            if lo < s {
                stats.received += hb.fetch_into(stage - 1, lo..s, &mut slab[..s - lo])?;
            }
            if e < hi {
                stats.received += hb.fetch_into(stage - 1, e..hi, &mut slab[s - lo + len..])?;
            }
            Some((&slab[..], lo))
        }
    };

    // every element is written before it is read: the boundary segments
    // by the boundary-first passes (all that publish() copies), the
    // interior by its own pass before the swap hands the slab onward
    reuse_uninit(next_vals, len);

    // the rows a neighbour will gather from this stage: the first/last
    // `flat_halo(op_{stage+1})` interior rows, with the board itself
    // deciding the exact segment widths (single source of truth with
    // HaloBoard::publish — the rows computed first below are exactly the
    // rows publish ships)
    let publishing = trading && stage + 1 < n && sh.halos[stage + 1] > 0;
    let (k_lo, k_hi) = if publishing {
        hb.boundary_segments(id, sh.halos[stage + 1], len)
    } else {
        (0, 0)
    };

    if !publishing {
        // nothing to publish (last stage, zero halo, or single chunk)
        run_stage_rows(sh, stage, gathered, s..e, s, band, &mut next_vals[..], stats)?;
    } else if k_lo + k_hi >= len {
        // narrow chunk: the boundary segments cover the whole interior
        run_stage_rows(sh, stage, gathered, s..e, s, band, &mut next_vals[..], stats)?;
        stats.published += hb.publish(stage, id, sh.halos[stage + 1], &next_vals[..])?;
        sched.mark_published(id, stage);
    } else {
        // boundary first: compute and publish the two segments before the
        // interior so the neighbours' next stage can start immediately
        run_stage_rows(sh, stage, gathered, s..s + k_lo, s, band, &mut next_vals[..], stats)?;
        run_stage_rows(sh, stage, gathered, e - k_hi..e, s, band, &mut next_vals[..], stats)?;
        stats.published += hb.publish(stage, id, sh.halos[stage + 1], &next_vals[..])?;
        sched.mark_published(id, stage);
        let t_pub = Instant::now();
        run_stage_rows(
            sh,
            stage,
            gathered,
            s + k_lo..e - k_hi,
            s,
            band,
            &mut next_vals[..],
            stats,
        )?;
        // the head start the neighbours got over waiting for this interior
        stats.eager_lead += t_pub.elapsed();
    }
    std::mem::swap(vals, next_vals);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Job;
    use crate::coordinator::pipeline::run_pipeline;
    use crate::testing::assert_allclose;

    fn stages_of(jobs: &[Job]) -> Vec<Stage> {
        jobs.iter().map(|j| j.to_stage().unwrap()).collect()
    }

    #[test]
    fn fused_group_matches_legacy_stage_by_stage() {
        let x = Tensor::random(&[12, 13], 0.0, 255.0, 21).unwrap();
        let jobs = vec![
            Job::gaussian(&[3, 3], 1.0),
            Job::curvature(&[3, 3]),
            Job::median(&[3, 3]),
        ];
        let opts = ExecOptions::native(3);
        let (legacy, _) = run_pipeline(&x, &jobs, &ExecOptions::native(1)).unwrap();
        let (fused, m, mom) = run_fused_group(&x, &stages_of(&jobs), &opts, true).unwrap();
        assert!(mom.is_some());
        assert_allclose(fused.data(), legacy.data(), 0.0, 0.0);
        assert_eq!(m.melts, 1);
        assert_eq!(m.folds, 1);
        assert_eq!(m.stages, 3);
        assert_eq!(m.chunks_per_worker.len(), 3);
        // the scratch-accounting claim: native fused runs gather tiles,
        // never a global melt matrix
        assert_eq!(m.melt_matrix_bytes, 0);
        assert!(m.gather_rows >= m.rows * 3, "every stage gathers every row");
        assert!(m.peak_band_bytes > 0);
    }

    #[test]
    fn tile_height_never_changes_fused_results() {
        // tile = 1, a tile straddling every chunk edge, and tile > rows
        // are all bit-for-bit identical, in both halo modes
        let x = Tensor::random(&[11, 9], 0.0, 255.0, 77).unwrap();
        let jobs = vec![
            Job::gaussian(&[3, 3], 1.0),
            Job::curvature(&[3, 3]),
            Job::median(&[3, 3]),
        ];
        let stages = stages_of(&jobs);
        let (base, bm, _) =
            run_fused_group(&x, &stages, &ExecOptions::native(2), false).unwrap();
        assert_eq!(bm.melt_matrix_bytes, 0);
        for tile in [1usize, 7, 1_000_000] {
            for mode in [HaloMode::Recompute, HaloMode::Exchange] {
                let opts = ExecOptions::native(3).with_halo_mode(mode).with_tile_rows(tile);
                let (out, m, _) = run_fused_group(&x, &stages, &opts, false).unwrap();
                assert_allclose(out.data(), base.data(), 0.0, 0.0);
                assert_eq!(m.melt_matrix_bytes, 0);
                // a 1-row tile bounds the band by cols; a huge one by the
                // largest gathered span — both stay far below rows * cols
                if tile == 1 {
                    // all windows are 3x3 (9 cols); 2x slack for the
                    // allocator's amortized capacity rounding
                    assert!(m.peak_band_bytes <= 2 * 9 * 4, "{}", m.peak_band_bytes);
                }
            }
        }
    }

    #[test]
    fn exchange_mode_matches_recompute_with_zero_redo() {
        let x = Tensor::random(&[12, 13], 0.0, 255.0, 33).unwrap();
        let jobs = vec![
            Job::gaussian(&[3, 3], 1.0),
            Job::curvature(&[3, 3]),
            Job::median(&[3, 3]),
        ];
        let stages = stages_of(&jobs);
        let recompute = ExecOptions::native(3);
        let exchange = ExecOptions::native(3).with_halo_mode(HaloMode::Exchange);
        let (base, rm, _) = run_fused_group(&x, &stages, &recompute, false).unwrap();
        let (out, xm, _) = run_fused_group(&x, &stages, &exchange, false).unwrap();
        assert_allclose(out.data(), base.data(), 0.0, 0.0);
        // recompute duplicates halo work and never touches the board …
        assert!(rm.halo_recomputed_rows > 0);
        assert_eq!(rm.halo_published_rows + rm.halo_received_rows, 0);
        assert_eq!(rm.halo_eager_lead, Duration::ZERO);
        assert_eq!(rm.sched_stalls, 0);
        // … exchange trades rows and recomputes exactly none; the 3-stage
        // group publishes boundaries before interiors, so the lead is real
        assert_eq!(xm.halo_recomputed_rows, 0);
        assert!(xm.halo_published_rows > 0);
        assert!(xm.halo_received_rows > 0);
        assert!(xm.halo_eager_lead > Duration::ZERO);
        // a single worker has a single chunk: nothing to trade, still exact
        let solo = ExecOptions::native(1).with_halo_mode(HaloMode::Exchange);
        let (out1, m1, _) = run_fused_group(&x, &stages, &solo, false).unwrap();
        assert_allclose(out1.data(), base.data(), 0.0, 0.0);
        assert_eq!(m1.halo_published_rows + m1.halo_received_rows + m1.halo_recomputed_rows, 0);
    }

    #[test]
    fn exchange_mode_accepts_oversubscribed_partitions() {
        // chunks > workers used to be rejected for liveness; the stage
        // scheduler dispatches dependency-satisfied tasks, so 13 chunks on
        // 2 workers stream exactly — and still recompute nothing
        let x = Tensor::random(&[10, 13], 0.0, 1.0, 2).unwrap();
        let jobs = vec![
            Job::gaussian(&[3, 3], 1.0),
            Job::curvature(&[3, 3]),
            Job::median(&[3, 3]),
        ];
        let stages = stages_of(&jobs);
        let (base, _, _) = run_fused_group(&x, &stages, &ExecOptions::native(1), false).unwrap();
        let mut opts = ExecOptions::native(2).with_halo_mode(HaloMode::Exchange);
        opts.chunk_policy = Some(crate::coordinator::plan::ChunkPolicy::Fixed { chunk_rows: 10 });
        let (out, m, _) = run_fused_group(&x, &stages, &opts, false).unwrap();
        assert_allclose(out.data(), base.data(), 0.0, 0.0);
        assert_eq!(m.chunks_per_worker.iter().sum::<usize>(), 13);
        assert_eq!(m.halo_recomputed_rows, 0);
        assert!(m.halo_published_rows > 0);
        assert!(m.halo_received_rows > 0);
    }

    #[test]
    fn fused_group_rejects_bad_shapes() {
        let x = Tensor::random(&[8, 8], 0.0, 1.0, 1).unwrap();
        let jobs = vec![Job::gaussian(&[3, 3], 1.0), Job::curvature(&[3, 3])];
        // single stage is not a fused group
        assert!(
            run_fused_group(&x, &stages_of(&jobs[..1]), &ExecOptions::native(2), true).is_err()
        );
        // pjrt backend never streams
        let opts = ExecOptions::pjrt(1, "/nowhere");
        assert!(run_fused_group(&x, &stages_of(&jobs), &opts, true).is_err());
        // zero workers
        assert!(run_fused_group(&x, &stages_of(&jobs), &ExecOptions::native(0), true).is_err());
    }

    #[test]
    fn batched_execution_matches_singletons_bit_for_bit() {
        let jobs = vec![
            Job::gaussian(&[3, 3], 1.0),
            Job::curvature(&[3, 3]),
            Job::median(&[3, 3]),
        ];
        let stages = stages_of(&jobs);
        let inputs: Vec<Tensor<f32>> = (0..4)
            .map(|s| Tensor::random(&[12, 13], 0.0, 255.0, 100 + s).unwrap())
            .collect();
        let opts = ExecOptions::native(3);
        let (outs, pm) =
            execute_batch_with(&inputs, &stages, &opts, Fleet::Scoped, None).unwrap();
        assert_eq!(outs.len(), 4);
        // the whole batch is one fused group: one melt, one fold, size 4
        assert_eq!(pm.melts(), 1);
        assert_eq!(pm.folds(), 1);
        assert_eq!(pm.batched_jobs(), 4);
        for (out, x) in outs.iter().zip(&inputs) {
            let (solo, _, _) =
                run_fused_group(x, &stages, &ExecOptions::native(2), false).unwrap();
            assert_eq!(out.shape(), solo.shape());
            assert_allclose(out.data(), solo.data(), 0.0, 0.0);
        }
    }

    #[test]
    fn batched_execution_is_exact_across_grids_and_boundaries() {
        use crate::melt::grid::GridMode;
        use crate::melt::melt::BoundaryMode;
        // Valid grid shrinks the member shape; Wrap would read across the
        // batch seam if the lifted axis ever had a nonzero halo
        for (grid, boundary) in [
            (GridMode::Valid, BoundaryMode::Reflect),
            (GridMode::Same, BoundaryMode::Wrap),
            (GridMode::Strided(vec![2, 3]), BoundaryMode::Nearest),
        ] {
            let mut job = Job::median(&[3, 3]);
            job.grid = grid;
            job.boundary = boundary;
            let stages = stages_of(std::slice::from_ref(&job));
            let inputs: Vec<Tensor<f32>> = (0..3)
                .map(|s| Tensor::random(&[10, 11], -4.0, 9.0, 7 + s).unwrap())
                .collect();
            let opts = ExecOptions::native(2);
            let (outs, pm) =
                execute_batch_with(&inputs, &stages, &opts, Fleet::Scoped, None).unwrap();
            assert_eq!(pm.batched_jobs(), 3);
            for (out, x) in outs.iter().zip(&inputs) {
                let (solo, _, _) =
                    run_single_stage(x, &stages[0], &ExecOptions::native(1), false).unwrap();
                assert_eq!(out.shape(), solo.shape());
                assert_allclose(out.data(), solo.data(), 0.0, 0.0);
            }
        }
    }

    #[test]
    fn batched_execution_rejects_bad_batches() {
        let stages = stages_of(&[Job::median(&[3, 3])]);
        let opts = ExecOptions::native(1);
        // empty batch
        assert!(execute_batch_with(&[], &stages, &opts, Fleet::Scoped, None).is_err());
        // mismatched member shapes
        let a = Tensor::random(&[8, 8], 0.0, 1.0, 1).unwrap();
        let b = Tensor::random(&[8, 9], 0.0, 1.0, 2).unwrap();
        assert!(execute_batch_with(&[a, b], &stages, &opts, Fleet::Scoped, None).is_err());
    }

    #[test]
    fn lift_stage_shares_the_kernel_and_prepends_unit_axes() {
        let mut job = Job::gaussian(&[3, 5], 1.0);
        job.grid = crate::melt::grid::GridMode::Strided(vec![2, 2]);
        let s = job.to_stage().unwrap();
        let l = lift_stage(&s).unwrap();
        assert_eq!(l.window(), &[1, 3, 5]);
        assert_eq!(
            l.grid(),
            &crate::melt::grid::GridMode::Strided(vec![1, 2, 2])
        );
        assert_eq!(l.boundary(), s.boundary());
        assert!(Arc::ptr_eq(s.kernel(), l.kernel()));
    }

    #[test]
    fn execute_groups_chains_group_outputs() {
        // gaussian (Valid grid) as its own group, then a fused pair
        let x = Tensor::random(&[14, 14], 0.0, 255.0, 9).unwrap();
        let mut g = Job::gaussian(&[3, 3], 1.0);
        g.grid = crate::melt::grid::GridMode::Valid;
        let jobs = vec![g, Job::curvature(&[3, 3]), Job::local_std(&[3, 3])];
        let stages = stages_of(&jobs);
        let groups = vec![0..1, 1..3];
        let opts = ExecOptions::native(2);
        let (out, pm) = execute_groups(&x, &stages, &groups, &opts).unwrap();
        assert_eq!(out.shape(), &[12, 12]);
        assert_eq!(pm.groups.len(), 2);
        assert_eq!(pm.melts(), 2);
        assert_eq!(pm.stages(), 3);
        // legacy reference
        let (legacy, _) = run_pipeline(&x, &jobs, &ExecOptions::native(1)).unwrap();
        assert_allclose(out.data(), legacy.data(), 0.0, 0.0);
        // output moments match a direct pass over the result
        let direct = crate::stats::descriptive::moments(out.data());
        assert_eq!(pm.output_moments.count, direct.count);
        assert!((pm.output_moments.mean - direct.mean).abs() < 1e-6);
    }
}
