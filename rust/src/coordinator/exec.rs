//! The plan executor: Fig 2 at runtime, for lazy stage graphs.
//!
//! Singleton groups run the classic barrier path (global melt → partition →
//! parallel execute → fold), on either backend. Fused groups run the
//! chunk-resident streaming path: ONE global melt feeds stage 1, then each
//! worker pushes its chunk through *all* remaining stages while the
//! intermediate values are resident — stage `k ≥ 2` re-melts locally from a
//! halo-extended value slab of stage `k − 1` (see
//! [`crate::melt::melt::melt_band_into`]) instead of waiting for a global
//! fold → re-melt barrier. The result: a fused n-stage group performs
//! exactly one global melt and one global fold, never materializes an
//! intermediate full tensor, and parallelizes the re-melt gathers that the
//! legacy `run_pipeline` executed serially on the leader.
//!
//! Halo accounting: stage `k`'s gathers reach at most
//! `flat_halo(grid, op_k)` rows from each output row, so a chunk `[s, e)`
//! needs stage `k`'s output on `[s − B_k, e + B_k)` (clamped), where
//! `B_k = Σ_{j>k} flat_halo(op_j)` is the *downstream* halo budget. Rows in
//! the overlap are computed by more than one worker — a few halo rows per
//! chunk, traded for the removal of the global barrier and the intermediate
//! tensors. Bit-for-bit equality with the legacy path holds because every
//! gather copies the same values through the same boundary mapping and
//! every kernel is row-deterministic (§2.4 row independence).

use std::ops::Range;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use crate::coordinator::aggregator::{assemble, merged_moments};
use crate::coordinator::job::Backend;
use crate::coordinator::kernel::RowKernel;
use crate::coordinator::metrics::{PlanMetrics, RunMetrics};
use crate::coordinator::pipeline::ExecOptions;
use crate::coordinator::plan::Stage;
use crate::coordinator::scheduler::{ResultBoard, WorkQueue};
use crate::coordinator::worker::{JobResources, WorkerContext};
use crate::error::{Error, Result};
use crate::melt::grid::QuasiGrid;
use crate::melt::matrix::MeltMatrix;
use crate::melt::melt::{flat_halo, melt_band_into, melt_into, uninit_buffer};
use crate::melt::operator::Operator;
use crate::stats::descriptive::Moments;
use crate::tensor::dense::Tensor;

/// Clamp `range` extended by `budget` rows on both sides to `[0, rows)`.
fn extend(range: &Range<usize>, budget: usize, rows: usize) -> Range<usize> {
    range.start.saturating_sub(budget)..(range.end + budget).min(rows)
}

/// Execute a planned stage graph group by group, feeding each group's
/// output tensor to the next.
pub(crate) fn execute_groups(
    x: &Tensor<f32>,
    stages: &[Stage],
    groups: &[Range<usize>],
    opts: &ExecOptions,
) -> Result<(Tensor<f32>, PlanMetrics)> {
    if opts.workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    if stages.is_empty() || groups.is_empty() {
        return Err(Error::Coordinator("empty plan".into()));
    }
    let mut cur: Option<Tensor<f32>> = None;
    let mut metrics = Vec::with_capacity(groups.len());
    let mut out_moments = Moments::new();
    for (gi, g) in groups.iter().enumerate() {
        // only the final group's statistics are kept — intermediate groups
        // skip the pass entirely
        let last = gi + 1 == groups.len();
        let input = cur.as_ref().unwrap_or(x);
        let (next, m, mom) = if g.len() == 1 {
            run_single_stage(input, &stages[g.start], opts, last)?
        } else {
            run_fused_group(input, &stages[g.clone()], opts, last)?
        };
        metrics.push(m);
        if let Some(mom) = mom {
            out_moments = mom;
        }
        cur = Some(next);
    }
    Ok((
        cur.expect("at least one group executed"),
        PlanMetrics {
            groups: metrics,
            output_moments: out_moments,
        },
    ))
}

/// The barrier path: one stage, melt → partition → parallel execute →
/// fold, on either backend. Also the body of the legacy `run_job` shim.
/// `collect_moments` merges per-chunk output statistics (the §2.4
/// aggregation path) — skipped when the caller discards them, and always
/// outside the timed aggregation window.
pub(crate) fn run_single_stage(
    x: &Tensor<f32>,
    stage: &Stage,
    opts: &ExecOptions,
    collect_moments: bool,
) -> Result<(Tensor<f32>, RunMetrics, Option<Moments>)> {
    if opts.workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    let t_setup = Instant::now();
    let res = JobResources::prepare(stage, opts.backend, opts.artifact_dir.as_ref())?;
    let op = stage.operator()?;
    let grid = QuasiGrid::resolve(x.shape(), &op, stage.grid())?;

    // melt (leader-side; row-decoupled by construction); uninitialized
    // buffer is sound — melt_into writes every element (§Perf iteration 4)
    let rows = grid.rows();
    let cols = op.ravel_len();
    let mut data = uninit_buffer(rows * cols);
    melt_into(x, &op, &grid, stage.boundary(), &mut data)?;
    let m = MeltMatrix::new(data, rows, cols, grid.out_shape().to_vec(), op.window().to_vec())?;

    // partition per policy; PJRT needs the manifest's fixed chunk height —
    // read from the resources loaded once above, not from disk again
    let pjrt_chunk_rows = res.manifest.as_ref().map(|mf| mf.chunk_rows).unwrap_or(0);
    let partition = opts.resolve_policy(pjrt_chunk_rows).partition(rows, opts.workers)?;
    partition.validate()?;

    let queue = WorkQueue::new(&partition);
    let board = ResultBoard::new(queue.num_chunks());
    let mut chunk_counts = vec![0usize; opts.workers];
    // +1: the leader also waits on the barrier to timestamp compute start
    // only after every worker finished its (PJRT) engine build.
    let barrier = Barrier::new(opts.workers + 1);
    let backend = opts.backend;

    let mut setup = t_setup.elapsed();
    let mut compute = Duration::ZERO;

    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(opts.workers);
        for _ in 0..opts.workers {
            let res = &res;
            let m = &m;
            let queue = &queue;
            let board = &board;
            let barrier = &barrier;
            handles.push(s.spawn(move || -> Result<(usize, Instant, Instant)> {
                // engine build + artifact compile = setup, not compute
                let ctx = WorkerContext::build(res, backend);
                barrier.wait();
                let ctx = ctx?;
                // workers self-report their compute window: the leader may
                // be descheduled at barrier release, so leader-side clocks
                // would under-measure the parallel phase.
                let t0 = Instant::now();
                let mut done = 0usize;
                while let Some((id, range)) = queue.pop() {
                    let block = m.row_block(range.start, range.end)?;
                    let out = ctx.execute(res, block, range.len())?;
                    board.put(id, out)?;
                    done += 1;
                }
                Ok((done, t0, Instant::now()))
            }));
        }
        barrier.wait();
        setup = t_setup.elapsed();
        let mut first_start: Option<Instant> = None;
        let mut last_end: Option<Instant> = None;
        for (w, h) in handles.into_iter().enumerate() {
            let (done, t0, t1) = h
                .join()
                .map_err(|_| Error::Coordinator(format!("worker {w} panicked")))??;
            chunk_counts[w] = done;
            first_start = Some(first_start.map_or(t0, |f| f.min(t0)));
            last_end = Some(last_end.map_or(t1, |l| l.max(t1)));
        }
        compute = match (first_start, last_end) {
            (Some(a), Some(b)) => b.duration_since(a),
            _ => Duration::ZERO,
        };
        Ok(())
    })?;

    let t_agg = Instant::now();
    let chunks = board.into_chunks()?;
    let out = assemble(&chunks, &partition, m.grid_shape())?;
    let aggregate = t_agg.elapsed();
    let moments = collect_moments.then(|| merged_moments(&chunks));

    Ok((
        out,
        RunMetrics {
            setup,
            compute,
            aggregate,
            chunks_per_worker: chunk_counts,
            rows,
            cols,
            melts: 1,
            folds: 1,
            stages: 1,
        },
        moments,
    ))
}

/// The streaming path: one global melt, then every chunk flows through all
/// member stages inside its worker, re-melting locally from halo slabs.
pub(crate) fn run_fused_group(
    x: &Tensor<f32>,
    stages: &[Stage],
    opts: &ExecOptions,
    collect_moments: bool,
) -> Result<(Tensor<f32>, RunMetrics, Option<Moments>)> {
    if stages.len() < 2 {
        return Err(Error::Coordinator("fused groups need at least 2 stages".into()));
    }
    if opts.backend != Backend::Native {
        return Err(Error::Coordinator(
            "fused groups execute on the native backend (the planner keeps PJRT stages in singleton groups)".into(),
        ));
    }
    if opts.workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    for s in &stages[1..] {
        if !s.streamable() {
            return Err(Error::Coordinator(
                "non-streamable stage inside a fused group (planner bug)".into(),
            ));
        }
    }

    let t_setup = Instant::now();
    let n = stages.len();
    let ops: Vec<Operator> = stages.iter().map(|s| s.operator()).collect::<Result<_>>()?;
    let kernels: Vec<Arc<dyn RowKernel>> = stages.iter().map(|s| s.kernel().clone()).collect();
    let colsv: Vec<usize> = ops.iter().map(|o| o.ravel_len()).collect();

    // the first stage's quasi-grid defines the group's row space; later
    // stages are Same-mode over it (planner invariant checked above)
    let grid = QuasiGrid::resolve(x.shape(), &ops[0], stages[0].grid())?;
    let grid_shape = grid.out_shape().to_vec();
    let rows = grid.rows();
    let cols0 = colsv[0];

    // ONE global melt for the whole group
    let mut data = uninit_buffer(rows * cols0);
    melt_into(x, &ops[0], &grid, stages[0].boundary(), &mut data)?;
    let m = MeltMatrix::new(data, rows, cols0, grid_shape.clone(), ops[0].window().to_vec())?;

    // downstream halo budgets: stage k's output must cover the chunk
    // extended by the halos of every later stage
    let halos: Vec<usize> = ops.iter().map(|o| flat_halo(&grid_shape, o)).collect();
    let mut budget = vec![0usize; n];
    for k in (0..n - 1).rev() {
        budget[k] = budget[k + 1] + halos[k + 1];
    }

    // halo rows are recomputed per chunk, so the default fused partition
    // targets chunks of >= ~8x the total halo budget to keep duplicated
    // work a small fraction. The target is best-effort: the part count is
    // floored at the worker count (idle workers cost more wall-clock than
    // halo recompute) and capped at 4 parts/worker for load balancing, so
    // small inputs trade some redundant kernel work for full utilization.
    let partition = match opts.chunk_policy {
        Some(p) => p.partition(rows, opts.workers)?,
        None => {
            let max_parts = 4 * opts.workers;
            let halo_budget = budget[0].max(1);
            let parts = (rows / (8 * halo_budget)).clamp(opts.workers, max_parts);
            crate::melt::partition::RowPartition::even(rows, parts)?
        }
    };
    partition.validate()?;
    let queue = WorkQueue::new(&partition);
    let board = ResultBoard::new(queue.num_chunks());
    let mut chunk_counts = vec![0usize; opts.workers];
    let barrier = Barrier::new(opts.workers + 1);

    let mut setup = t_setup.elapsed();
    let mut compute = Duration::ZERO;

    std::thread::scope(|s| -> Result<()> {
        let mut handles = Vec::with_capacity(opts.workers);
        for _ in 0..opts.workers {
            let m = &m;
            let queue = &queue;
            let board = &board;
            let barrier = &barrier;
            let kernels = &kernels;
            let colsv = &colsv;
            let budget = &budget;
            let ops = &ops;
            let grid_shape = &grid_shape;
            handles.push(s.spawn(move || -> Result<(usize, Instant, Instant)> {
                barrier.wait();
                let t0 = Instant::now();
                let mut done = 0usize;
                // reusable per-worker scratch: current/next value slabs and
                // the local re-melt band
                let mut vals: Vec<f32> = Vec::new();
                let mut next_vals: Vec<f32> = Vec::new();
                let mut band: Vec<f32> = Vec::new();
                while let Some((id, range)) = queue.pop() {
                    // stage 0 over the halo-extended range, straight off
                    // the global melt matrix
                    let ext0 = extend(&range, budget[0], rows);
                    let block = m.row_block(ext0.start, ext0.end)?;
                    vals.clear();
                    vals.resize(ext0.len(), 0.0);
                    kernels[0].execute(block, ext0.len(), colsv[0], &mut vals)?;
                    let mut prev_range = ext0;
                    // remaining stages: local band re-melt from the
                    // previous slab, then the kernel — all chunk-resident
                    for k in 1..kernels.len() {
                        let ext = extend(&range, budget[k], rows);
                        band.clear();
                        band.resize(ext.len() * colsv[k], 0.0);
                        melt_band_into(
                            &vals,
                            prev_range.start,
                            grid_shape,
                            &ops[k],
                            stages[k].boundary(),
                            ext.clone(),
                            &mut band,
                        )?;
                        next_vals.clear();
                        next_vals.resize(ext.len(), 0.0);
                        kernels[k].execute(&band, ext.len(), colsv[k], &mut next_vals)?;
                        std::mem::swap(&mut vals, &mut next_vals);
                        prev_range = ext;
                    }
                    debug_assert_eq!(prev_range, range);
                    board.put(id, vals.clone())?;
                    done += 1;
                }
                Ok((done, t0, Instant::now()))
            }));
        }
        barrier.wait();
        setup = t_setup.elapsed();
        let mut first_start: Option<Instant> = None;
        let mut last_end: Option<Instant> = None;
        for (w, h) in handles.into_iter().enumerate() {
            let (done, t0, t1) = h
                .join()
                .map_err(|_| Error::Coordinator(format!("worker {w} panicked")))??;
            chunk_counts[w] = done;
            first_start = Some(first_start.map_or(t0, |f| f.min(t0)));
            last_end = Some(last_end.map_or(t1, |l| l.max(t1)));
        }
        compute = match (first_start, last_end) {
            (Some(a), Some(b)) => b.duration_since(a),
            _ => Duration::ZERO,
        };
        Ok(())
    })?;

    let t_agg = Instant::now();
    let chunks = board.into_chunks()?;
    let out = assemble(&chunks, &partition, &grid_shape)?;
    let aggregate = t_agg.elapsed();
    let moments = collect_moments.then(|| merged_moments(&chunks));

    Ok((
        out,
        RunMetrics {
            setup,
            compute,
            aggregate,
            chunks_per_worker: chunk_counts,
            rows,
            cols: cols0,
            melts: 1,
            folds: 1,
            stages: n,
        },
        moments,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Job;
    use crate::coordinator::pipeline::run_pipeline;
    use crate::testing::assert_allclose;

    fn stages_of(jobs: &[Job]) -> Vec<Stage> {
        jobs.iter().map(|j| j.to_stage().unwrap()).collect()
    }

    #[test]
    fn fused_group_matches_legacy_stage_by_stage() {
        let x = Tensor::random(&[12, 13], 0.0, 255.0, 21).unwrap();
        let jobs = vec![
            Job::gaussian(&[3, 3], 1.0),
            Job::curvature(&[3, 3]),
            Job::median(&[3, 3]),
        ];
        let opts = ExecOptions::native(3);
        let (legacy, _) = run_pipeline(&x, &jobs, &ExecOptions::native(1)).unwrap();
        let (fused, m, mom) = run_fused_group(&x, &stages_of(&jobs), &opts, true).unwrap();
        assert!(mom.is_some());
        assert_allclose(fused.data(), legacy.data(), 0.0, 0.0);
        assert_eq!(m.melts, 1);
        assert_eq!(m.folds, 1);
        assert_eq!(m.stages, 3);
        assert_eq!(m.chunks_per_worker.len(), 3);
    }

    #[test]
    fn fused_group_rejects_bad_shapes() {
        let x = Tensor::random(&[8, 8], 0.0, 1.0, 1).unwrap();
        let jobs = vec![Job::gaussian(&[3, 3], 1.0), Job::curvature(&[3, 3])];
        // single stage is not a fused group
        assert!(
            run_fused_group(&x, &stages_of(&jobs[..1]), &ExecOptions::native(2), true).is_err()
        );
        // pjrt backend never streams
        let opts = ExecOptions::pjrt(1, "/nowhere");
        assert!(run_fused_group(&x, &stages_of(&jobs), &opts, true).is_err());
        // zero workers
        assert!(run_fused_group(&x, &stages_of(&jobs), &ExecOptions::native(0), true).is_err());
    }

    #[test]
    fn execute_groups_chains_group_outputs() {
        // gaussian (Valid grid) as its own group, then a fused pair
        let x = Tensor::random(&[14, 14], 0.0, 255.0, 9).unwrap();
        let mut g = Job::gaussian(&[3, 3], 1.0);
        g.grid = crate::melt::grid::GridMode::Valid;
        let jobs = vec![g, Job::curvature(&[3, 3]), Job::local_std(&[3, 3])];
        let stages = stages_of(&jobs);
        let groups = vec![0..1, 1..3];
        let opts = ExecOptions::native(2);
        let (out, pm) = execute_groups(&x, &stages, &groups, &opts).unwrap();
        assert_eq!(out.shape(), &[12, 12]);
        assert_eq!(pm.groups.len(), 2);
        assert_eq!(pm.melts(), 2);
        assert_eq!(pm.stages(), 3);
        // legacy reference
        let (legacy, _) = run_pipeline(&x, &jobs, &ExecOptions::native(1)).unwrap();
        assert_allclose(out.data(), legacy.data(), 0.0, 0.0);
        // output moments match a direct pass over the result
        let direct = crate::stats::descriptive::moments(out.data());
        assert_eq!(pm.output_moments.count, direct.count);
        assert!((pm.output_moments.mean - direct.mean).abs() < 1e-6);
    }
}
