//! Worker-side chunk execution: one melt row block in, one result vector
//! out, on either backend, for any [`RowKernel`].
//!
//! On the native backend the executor no longer ships materialized melt
//! blocks at all — workers tile-stream their own gathers through a shared
//! [`RowGather`](crate::melt::melt::RowGather) plan (see
//! `coordinator::exec`), and [`WorkerContext::Native`] exists for the
//! barrier/setup symmetry with PJRT plus the direct [`execute_native`]
//! path used by the makespan simulator. All stage-level precomputation
//! (gaussian kernel vector, bilateral spatial component, gather tables)
//! happens once on the leader; the worker hot loop is pure compute. The
//! PJRT `ArtifactManifest` is
//! likewise loaded and verified exactly once on the leader, into
//! [`JobResources`], and shared read-only with every worker — previously
//! the leader *and* each worker re-read `manifest.json` from disk. On the
//! PJRT backend every worker thread still builds its own
//! [`Engine`] (the client is `Rc`-backed and `!Send`) from that shared
//! manifest and compiles the one artifact its stage needs — cost the
//! coordinator meters as setup, not compute, matching Fig 6's methodology.

use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::job::{Backend, Job};
use crate::coordinator::kernel::RowKernel;
use crate::coordinator::plan::Stage;
use crate::error::{Error, Result};
use crate::runtime::artifact::ArtifactManifest;
use crate::runtime::executor::{Engine, ExtraInputs, PreparedInputs};

/// Leader-side prepared stage state, shared read-only with all workers.
#[derive(Clone, Debug)]
pub struct JobResources {
    /// The stage's row kernel (parameters precomputed at construction).
    pub kernel: Arc<dyn RowKernel>,
    /// Operator window extents.
    pub window: Vec<usize>,
    /// Melt column count (window ravel length).
    pub cols: usize,
    /// PJRT manifest, loaded and file-verified ONCE on the leader; workers
    /// build their engines from this instead of re-reading disk.
    pub manifest: Option<Arc<ArtifactManifest>>,
}

impl JobResources {
    /// Prepare everything a worker fleet needs for `stage` on `backend`.
    pub fn prepare(
        stage: &Stage,
        backend: Backend,
        artifact_dir: Option<&PathBuf>,
    ) -> Result<Self> {
        let op = stage.operator()?;
        let manifest = match backend {
            Backend::Native => None,
            Backend::Pjrt => {
                let dir = artifact_dir.ok_or_else(|| {
                    Error::Coordinator("PJRT backend requires an artifact directory".into())
                })?;
                let mf = ArtifactManifest::load(dir)?;
                mf.verify_files()?;
                Some(Arc::new(mf))
            }
        };
        Ok(Self {
            kernel: stage.kernel().clone(),
            window: stage.window().to_vec(),
            cols: op.ravel_len(),
            manifest,
        })
    }

    /// Legacy-spec convenience: prepare from a [`Job`].
    pub fn for_job(job: &Job, backend: Backend, artifact_dir: Option<&PathBuf>) -> Result<Self> {
        Self::prepare(&job.to_stage()?, backend, artifact_dir)
    }

    /// Extra PJRT inputs (`inputs[1..]` of the matching artifact).
    pub fn extra_inputs(&self) -> Result<ExtraInputs> {
        self.kernel.extra_inputs()
    }
}

/// Execute one row block natively into `out` (len = rows).
pub fn execute_native(
    res: &JobResources,
    block: &[f32],
    rows: usize,
    out: &mut [f32],
) -> Result<()> {
    res.kernel.execute(block, rows, res.cols, out)
}

/// A worker's execution context for one stage.
pub enum WorkerContext {
    Native,
    Pjrt {
        engine: Engine,
        entry: crate::runtime::artifact::ArtifactEntry,
        /// Job-constant inputs uploaded once at context build (§Perf it. 5).
        prepared: PreparedInputs,
    },
}

impl WorkerContext {
    /// Build (and for PJRT: compile + warm up) the context on the calling
    /// worker thread, from the leader's shared resources.
    pub fn build(res: &JobResources, backend: Backend) -> Result<Self> {
        match backend {
            Backend::Native => Ok(WorkerContext::Native),
            Backend::Pjrt => {
                let manifest = res.manifest.as_ref().ok_or_else(|| {
                    Error::Coordinator("PJRT context requires a leader-loaded manifest".into())
                })?;
                let kind = res.kernel.artifact_kind().ok_or_else(|| {
                    Error::Coordinator(format!(
                        "kernel '{}' has no AOT artifact; run it on Backend::Native",
                        res.kernel.name()
                    ))
                })?;
                let engine = Engine::with_manifest((**manifest).clone())?;
                let entry = engine.manifest().by_kind_window(kind, &res.window)?.clone();
                engine.warmup(&entry.name)?;
                let prepared = engine.prepare_inputs(&entry, &res.extra_inputs()?)?;
                Ok(WorkerContext::Pjrt {
                    engine,
                    entry,
                    prepared,
                })
            }
        }
    }

    /// Execute one row block, returning `rows` results.
    pub fn execute(&self, res: &JobResources, block: &[f32], rows: usize) -> Result<Vec<f32>> {
        match self {
            WorkerContext::Native => {
                let mut out = vec![0.0f32; rows];
                execute_native(res, block, rows, &mut out)?;
                Ok(out)
            }
            WorkerContext::Pjrt {
                engine,
                entry,
                prepared,
            } => engine.execute_prepared(entry, block, rows, prepared),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::melt::grid::GridMode;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::melt::operator::Operator;
    use crate::tensor::dense::Tensor;
    use crate::testing::assert_allclose;

    fn sample_melt() -> crate::melt::matrix::MeltMatrix {
        let x = Tensor::random(&[8, 8], 0.0, 255.0, 11).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap()
    }

    #[test]
    fn prepare_builds_right_resources() {
        let g = JobResources::for_job(&Job::gaussian(&[3, 3], 1.0), Backend::Native, None).unwrap();
        assert_eq!(g.cols, 9);
        assert_eq!(g.kernel.name(), "gaussian");
        assert!(g.manifest.is_none());
        let b = JobResources::for_job(&Job::bilateral_const(&[3, 3], 1.0, 5.0), Backend::Native, None)
            .unwrap();
        assert_eq!(b.kernel.name(), "bilateral_const");
        let q = JobResources::for_job(&Job::quantile(&[3, 3], 0.5), Backend::Native, None).unwrap();
        assert!(q.kernel.artifact_kind().is_none());
    }

    #[test]
    fn extra_inputs_arity_matches_artifacts() {
        // contract with python model.py variant input lists
        let g = JobResources::for_job(&Job::gaussian(&[3, 3], 1.0), Backend::Native, None).unwrap();
        assert_eq!(g.extra_inputs().unwrap().vectors.len(), 1);
        let b = JobResources::for_job(
            &Job::bilateral_adaptive(&[3, 3], 1.0, 0.5),
            Backend::Native,
            None,
        )
        .unwrap();
        let e = b.extra_inputs().unwrap();
        assert_eq!(e.vectors.len(), 2);
        assert_eq!(e.vectors[0].len(), 9);
        assert_eq!(e.vectors[1], vec![0.5]);
        let c = JobResources::for_job(&Job::curvature(&[3, 3]), Backend::Native, None).unwrap();
        let ce = c.extra_inputs().unwrap();
        assert_eq!(ce.vectors.len(), 1); // the stencil matrix (W x ncols)
        assert_eq!(ce.vectors[0].len(), 9 * 5);
    }

    #[test]
    fn native_execution_matches_kernels() {
        let m = sample_melt();
        let res = JobResources::for_job(&Job::gaussian(&[3, 3], 1.0), Backend::Native, None).unwrap();
        let ctx = WorkerContext::build(&res, Backend::Native).unwrap();
        let got = ctx.execute(&res, m.data(), m.rows()).unwrap();
        let want = crate::kernels::paradigm::apply_kernel_broadcast(
            &m,
            &crate::kernels::gaussian::gaussian_kernel(&[3, 3], 1.0),
        );
        assert_allclose(&got, &want, 0.0, 0.0);
    }

    #[test]
    fn pjrt_resources_require_dir_and_manifest() {
        // no artifact dir -> prepare fails on the leader, before any worker
        assert!(
            JobResources::for_job(&Job::gaussian(&[3, 3], 1.0), Backend::Pjrt, None).is_err()
        );
        // native-prepared resources cannot build a PJRT context
        let res =
            JobResources::for_job(&Job::gaussian(&[3, 3], 1.0), Backend::Native, None).unwrap();
        assert!(WorkerContext::build(&res, Backend::Pjrt).is_err());
    }
}
