//! Worker-side chunk execution: one melt row block in, one result vector
//! out, on either backend.
//!
//! All job-level precomputation (gaussian kernel vector, bilateral spatial
//! component) happens once on the leader in [`JobResources::prepare`]; the
//! worker hot loop is pure compute. On the PJRT backend every worker thread
//! builds its own [`Engine`] (the client is `Rc`-backed and `!Send`) and
//! compiles the one artifact its job needs — cost that the coordinator
//! meters as setup, not compute, matching Fig 6's methodology.

use std::path::PathBuf;

use crate::coordinator::job::{Backend, FilterKind, Job};
use crate::error::{Error, Result};
use crate::kernels::bilateral::{bilateral_into, BilateralParams};
use crate::kernels::curvature::curvature_into;
use crate::kernels::gaussian::gaussian_kernel;
use crate::kernels::paradigm::apply_kernel_broadcast_into;
use crate::runtime::executor::{Engine, ExtraInputs, PreparedInputs};

/// Leader-side precomputed job state, shared read-only with all workers.
#[derive(Clone, Debug)]
pub struct JobResources {
    pub job: Job,
    pub cols: usize,
    pub center: usize,
    /// Normalized kernel vector (gaussian jobs).
    pub kernel: Option<Vec<f32>>,
    /// Bilateral parameters (bilateral jobs).
    pub bilateral: Option<BilateralParams>,
}

impl JobResources {
    /// Precompute everything a worker needs for `job`.
    pub fn prepare(job: &Job) -> Result<Self> {
        let op = job.operator()?;
        let cols = op.ravel_len();
        let kernel = match job.kind {
            FilterKind::Gaussian { sigma } => Some(gaussian_kernel(&job.window, sigma)),
            _ => None,
        };
        let bilateral = job.kind.bilateral_params(&job.window)?;
        Ok(Self {
            job: job.clone(),
            cols,
            center: cols / 2,
            kernel,
            bilateral,
        })
    }

    /// Extra PJRT inputs (`inputs[1..]` of the matching artifact).
    pub fn extra_inputs(&self) -> ExtraInputs {
        match &self.job.kind {
            FilterKind::Gaussian { .. } => {
                ExtraInputs::one(self.kernel.clone().expect("prepared gaussian kernel"))
            }
            FilterKind::BilateralConst { sigma_r, .. } => ExtraInputs::two(
                self.bilateral.as_ref().expect("prepared bilateral").spatial.clone(),
                vec![*sigma_r],
            ),
            FilterKind::BilateralAdaptive { floor, .. } => ExtraInputs::two(
                self.bilateral.as_ref().expect("prepared bilateral").spatial.clone(),
                vec![*floor],
            ),
            FilterKind::Curvature => {
                // the stencil matrix is a runtime artifact input: HLO text
                // elides large constants, so it cannot be baked at AOT time
                let s = crate::kernels::stencil::stencil_matrix(&self.job.window)
                    .expect("window validated by prepare");
                ExtraInputs::one(s)
            }
        }
    }
}

/// Execute one row block natively into `out` (len = rows).
pub fn execute_native(
    res: &JobResources,
    block: &[f32],
    rows: usize,
    out: &mut [f32],
) -> Result<()> {
    match &res.job.kind {
        FilterKind::Gaussian { .. } => {
            let k = res.kernel.as_ref().expect("prepared gaussian kernel");
            apply_kernel_broadcast_into(block, rows, res.cols, k, out);
            Ok(())
        }
        FilterKind::BilateralConst { .. } | FilterKind::BilateralAdaptive { .. } => {
            let p = res.bilateral.as_ref().expect("prepared bilateral");
            bilateral_into(block, rows, res.cols, res.center, p, out)
        }
        FilterKind::Curvature => curvature_into(block, rows, res.cols, &res.job.window, out),
    }
}

/// A worker's execution context for one job.
pub enum WorkerContext {
    Native,
    Pjrt {
        engine: Engine,
        entry: crate::runtime::artifact::ArtifactEntry,
        /// Job-constant inputs uploaded once at context build (§Perf it. 5).
        prepared: PreparedInputs,
    },
}

impl WorkerContext {
    /// Build (and for PJRT: compile + warm up) the context on the calling
    /// worker thread.
    pub fn build(res: &JobResources, backend: Backend, artifact_dir: Option<&PathBuf>) -> Result<Self> {
        match backend {
            Backend::Native => Ok(WorkerContext::Native),
            Backend::Pjrt => {
                let dir = artifact_dir.ok_or_else(|| {
                    Error::Coordinator("PJRT backend requires an artifact directory".into())
                })?;
                let engine = Engine::from_dir(dir)?;
                let entry = engine
                    .manifest()
                    .by_kind_window(res.job.kind.artifact_kind(), &res.job.window)?
                    .clone();
                engine.warmup(&entry.name)?;
                let prepared = engine.prepare_inputs(&entry, &res.extra_inputs())?;
                Ok(WorkerContext::Pjrt {
                    engine,
                    entry,
                    prepared,
                })
            }
        }
    }

    /// Execute one row block, returning `rows` results.
    pub fn execute(&self, res: &JobResources, block: &[f32], rows: usize) -> Result<Vec<f32>> {
        match self {
            WorkerContext::Native => {
                let mut out = vec![0.0f32; rows];
                execute_native(res, block, rows, &mut out)?;
                Ok(out)
            }
            WorkerContext::Pjrt { engine, entry, prepared } => {
                engine.execute_prepared(entry, block, rows, prepared)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::melt::grid::GridMode;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::melt::operator::Operator;
    use crate::tensor::dense::Tensor;
    use crate::testing::assert_allclose;

    fn sample_melt() -> crate::melt::matrix::MeltMatrix {
        let x = Tensor::random(&[8, 8], 0.0, 255.0, 11).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap()
    }

    #[test]
    fn prepare_builds_right_resources() {
        let g = JobResources::prepare(&Job::gaussian(&[3, 3], 1.0)).unwrap();
        assert!(g.kernel.is_some() && g.bilateral.is_none());
        assert_eq!(g.cols, 9);
        let b = JobResources::prepare(&Job::bilateral_const(&[3, 3], 1.0, 5.0)).unwrap();
        assert!(b.kernel.is_none() && b.bilateral.is_some());
        let c = JobResources::prepare(&Job::curvature(&[3, 3])).unwrap();
        assert!(c.kernel.is_none() && c.bilateral.is_none());
    }

    #[test]
    fn extra_inputs_arity_matches_artifacts() {
        // contract with python model.py variant input lists
        let g = JobResources::prepare(&Job::gaussian(&[3, 3], 1.0)).unwrap();
        assert_eq!(g.extra_inputs().vectors.len(), 1);
        let b = JobResources::prepare(&Job::bilateral_adaptive(&[3, 3], 1.0, 0.5)).unwrap();
        let e = b.extra_inputs();
        assert_eq!(e.vectors.len(), 2);
        assert_eq!(e.vectors[0].len(), 9);
        assert_eq!(e.vectors[1], vec![0.5]);
        let c = JobResources::prepare(&Job::curvature(&[3, 3])).unwrap();
        let ce = c.extra_inputs();
        assert_eq!(ce.vectors.len(), 1); // the stencil matrix (W x ncols)
        assert_eq!(ce.vectors[0].len(), 9 * 5);
    }

    #[test]
    fn native_execution_matches_kernels() {
        let m = sample_melt();
        let res = JobResources::prepare(&Job::gaussian(&[3, 3], 1.0)).unwrap();
        let ctx = WorkerContext::build(&res, Backend::Native, None).unwrap();
        let got = ctx.execute(&res, m.data(), m.rows()).unwrap();
        let want = crate::kernels::paradigm::apply_kernel_broadcast(
            &m,
            res.kernel.as_ref().unwrap(),
        );
        assert_allclose(&got, &want, 0.0, 0.0);
    }

    #[test]
    fn pjrt_context_requires_dir() {
        let res = JobResources::prepare(&Job::gaussian(&[3, 3], 1.0)).unwrap();
        assert!(WorkerContext::build(&res, Backend::Pjrt, None).is_err());
    }
}
