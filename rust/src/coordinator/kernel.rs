//! The open kernel surface of the lazy [`Plan`](crate::coordinator::Plan)
//! API: an object-safe [`RowKernel`] trait that every row-wise compute
//! implements — the paper's point that *any* neighbourhood-driven
//! computation becomes a broadcast over melt rows, made extensible.
//!
//! The closed `FilterKind` enum survives only as a *spec* (config/TOML
//! parsing, PJRT artifact lookup); execution dispatches through this trait,
//! so user crates can plug custom kernels into the same coordinator,
//! fusion, and chunk-streaming machinery. Built-ins cover the paper's
//! filters (gaussian, bilateral const/adaptive, curvature) plus the
//! `stats`-layer reductions that were previously unreachable from the
//! coordinator: per-row rank statistics ([`RankRowKernel`], backed by
//! `stats::rank`) and per-row descriptive moments ([`LocalMomentKernel`],
//! backed by `stats::descriptive`).
//!
//! Contract: `execute` consumes a row-major melt block of `rows * cols`
//! values and writes exactly one output value per row — row independence
//! (§2.4) is what licenses both the worker partitioning and the fused
//! chunk-resident pipeline in `coordinator::exec`. All parameter
//! precomputation (kernel vectors, spatial components) happens at
//! construction on the leader; `execute` is the pure hot loop.

use std::fmt;

use crate::error::{Error, Result};
use crate::kernels::bilateral::{bilateral_into, BilateralParams, RangeSigma};
use crate::kernels::curvature::curvature_into;
use crate::kernels::gaussian::gaussian_kernel;
use crate::kernels::paradigm::apply_kernel_broadcast_into;
use crate::kernels::rankfilter::{rank_filter_into, RankKind};
use crate::melt::operator::Operator;
use crate::runtime::executor::ExtraInputs;
use crate::simd::LANES;

/// One row-wise computation over a melt block. Object-safe: plans hold
/// `Arc<dyn RowKernel>`, so the kernel set is open — implement this trait
/// to run custom computations through the coordinator unchanged.
pub trait RowKernel: Send + Sync + fmt::Debug {
    /// Stable display name (diagnostics, plan explain output).
    fn name(&self) -> &str;

    /// Compute one output value per melt row of `block` (`rows * cols`
    /// row-major values) into `out` (`rows` values).
    fn execute(&self, block: &[f32], rows: usize, cols: usize, out: &mut [f32]) -> Result<()>;

    /// AOT artifact kind when a PJRT-compiled variant of this kernel
    /// exists (`None` keeps the kernel native-only — backend selection
    /// lives behind the trait, so plans stay backend-agnostic).
    fn artifact_kind(&self) -> Option<&'static str> {
        None
    }

    /// Extra artifact inputs (`inputs[1..]` of the matching manifest
    /// entry) for the PJRT path.
    fn extra_inputs(&self) -> Result<ExtraInputs> {
        Ok(ExtraInputs::none())
    }
}

fn check_block(block: &[f32], rows: usize, cols: usize, out: &[f32]) -> Result<()> {
    if block.len() != rows * cols || out.len() != rows {
        return Err(Error::shape(format!(
            "row kernel block {} vs {rows}x{cols}, out {}",
            block.len(),
            out.len()
        )));
    }
    Ok(())
}

/// Global gaussian filter: normalized isotropic kernel broadcast over rows.
#[derive(Clone, Debug)]
pub struct GaussianRowKernel {
    kernel: Vec<f32>,
}

impl GaussianRowKernel {
    pub fn new(window: &[usize], sigma: f32) -> Result<Self> {
        if sigma <= 0.0 {
            return Err(Error::Operator(format!("sigma must be positive: {sigma}")));
        }
        Operator::new(window)?;
        Ok(Self {
            kernel: gaussian_kernel(window, sigma),
        })
    }
}

impl RowKernel for GaussianRowKernel {
    fn name(&self) -> &str {
        "gaussian"
    }

    fn execute(&self, block: &[f32], rows: usize, cols: usize, out: &mut [f32]) -> Result<()> {
        check_block(block, rows, cols, out)?;
        if self.kernel.len() != cols {
            return Err(Error::shape(format!(
                "gaussian kernel length {} vs melt cols {cols}",
                self.kernel.len()
            )));
        }
        apply_kernel_broadcast_into(block, rows, cols, &self.kernel, out);
        Ok(())
    }

    fn artifact_kind(&self) -> Option<&'static str> {
        Some("gaussian")
    }

    fn extra_inputs(&self) -> Result<ExtraInputs> {
        Ok(ExtraInputs::one(self.kernel.clone()))
    }
}

/// Bilateral filter (eq. 3), constant or locally adaptive σ_r.
#[derive(Clone, Debug)]
pub struct BilateralRowKernel {
    params: BilateralParams,
    /// σ_r (constant) or the adaptive floor — the artifact's scalar input.
    scalar: f32,
    adaptive: bool,
}

impl BilateralRowKernel {
    pub fn constant(window: &[usize], sigma_d: f32, sigma_r: f32) -> Result<Self> {
        if sigma_r <= 0.0 {
            return Err(Error::Operator(format!("sigma_r must be positive: {sigma_r}")));
        }
        Ok(Self {
            params: BilateralParams::isotropic(window, sigma_d, RangeSigma::Constant(sigma_r))?,
            scalar: sigma_r,
            adaptive: false,
        })
    }

    pub fn adaptive(window: &[usize], sigma_d: f32, floor: f32) -> Result<Self> {
        if floor <= 0.0 {
            return Err(Error::Operator(format!("floor must be positive: {floor}")));
        }
        Ok(Self {
            params: BilateralParams::isotropic(window, sigma_d, RangeSigma::Adaptive { floor })?,
            scalar: floor,
            adaptive: true,
        })
    }
}

impl RowKernel for BilateralRowKernel {
    fn name(&self) -> &str {
        if self.adaptive {
            "bilateral_adaptive"
        } else {
            "bilateral_const"
        }
    }

    fn execute(&self, block: &[f32], rows: usize, cols: usize, out: &mut [f32]) -> Result<()> {
        check_block(block, rows, cols, out)?;
        if self.params.spatial.len() != cols {
            return Err(Error::shape(format!(
                "bilateral spatial length {} vs melt cols {cols}",
                self.params.spatial.len()
            )));
        }
        bilateral_into(block, rows, cols, cols / 2, &self.params, out)
    }

    fn artifact_kind(&self) -> Option<&'static str> {
        Some(if self.adaptive {
            "bilateral_adaptive"
        } else {
            "bilateral_const"
        })
    }

    fn extra_inputs(&self) -> Result<ExtraInputs> {
        Ok(ExtraInputs::two(self.params.spatial.clone(), vec![self.scalar]))
    }
}

/// N-D Gaussian curvature (eq. 4–7) via the central-difference stencil.
#[derive(Clone, Debug)]
pub struct CurvatureRowKernel {
    window: Vec<usize>,
}

impl CurvatureRowKernel {
    pub fn new(window: &[usize]) -> Result<Self> {
        Operator::new(window)?;
        Ok(Self {
            window: window.to_vec(),
        })
    }
}

impl RowKernel for CurvatureRowKernel {
    fn name(&self) -> &str {
        "curvature"
    }

    fn execute(&self, block: &[f32], rows: usize, cols: usize, out: &mut [f32]) -> Result<()> {
        check_block(block, rows, cols, out)?;
        if self.window.iter().product::<usize>() != cols {
            return Err(Error::shape(format!(
                "curvature window {:?} vs melt cols {cols}",
                self.window
            )));
        }
        curvature_into(block, rows, cols, &self.window, out)
    }

    fn artifact_kind(&self) -> Option<&'static str> {
        Some("curvature")
    }

    fn extra_inputs(&self) -> Result<ExtraInputs> {
        // the stencil matrix is a runtime artifact input: HLO text elides
        // large constants, so it cannot be baked at AOT time
        Ok(ExtraInputs::one(crate::kernels::stencil::stencil_matrix(
            &self.window,
        )?))
    }
}

/// Per-row order statistic (median / min / max / quantile) — the
/// sample-determined `stats::rank` reduction, now first-class in the
/// coordinator. Row independence holds: each output depends only on its
/// own neighbourhood, so §2.4 partitioning stays exact.
#[derive(Clone, Debug)]
pub struct RankRowKernel {
    kind: RankKind,
}

impl RankRowKernel {
    pub fn new(kind: RankKind) -> Result<Self> {
        if let RankKind::Quantile(q) = kind {
            if !(0.0..=1.0).contains(&q) {
                return Err(Error::Operator(format!("quantile {q} outside [0, 1]")));
            }
        }
        Ok(Self { kind })
    }
}

impl RowKernel for RankRowKernel {
    fn name(&self) -> &str {
        match self.kind {
            RankKind::Median => "median",
            RankKind::Min => "rank_min",
            RankKind::Max => "rank_max",
            RankKind::Quantile(_) => "quantile",
        }
    }

    fn execute(&self, block: &[f32], rows: usize, cols: usize, out: &mut [f32]) -> Result<()> {
        rank_filter_into(block, rows, cols, self.kind, out)
    }
}

/// Which per-row descriptive moment [`LocalMomentKernel`] extracts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MomentStat {
    Mean,
    Std,
    Variance,
}

/// Per-row descriptive moment (local mean / std / variance map) — the
/// partition-aggregable `stats::descriptive` accumulator applied to each
/// neighbourhood, a building block for adaptive filtering and feature maps.
#[derive(Clone, Debug)]
pub struct LocalMomentKernel {
    stat: MomentStat,
}

impl LocalMomentKernel {
    pub fn new(stat: MomentStat) -> Self {
        Self { stat }
    }
}

impl RowKernel for LocalMomentKernel {
    fn name(&self) -> &str {
        match self.stat {
            MomentStat::Mean => "local_mean",
            MomentStat::Std => "local_std",
            MomentStat::Variance => "local_var",
        }
    }

    fn execute(&self, block: &[f32], rows: usize, cols: usize, out: &mut [f32]) -> Result<()> {
        check_block(block, rows, cols, out)?;
        // Runs only the Welford recurrences the requested statistic needs
        // (Mean: just the mean update; Std/Var: mean + m2, no min/max
        // bookkeeping). The mean/m2 recurrences never read min/max, so the
        // trimmed passes are bit-identical to the full
        // `stats::descriptive::moments` accumulator — pinned by a test.
        // LANES rows at a time take the lane path, each lane running the
        // same f64 recurrence in the same element order.
        let lane_rows = if crate::simd::lanes_enabled() {
            (rows / LANES) * LANES
        } else {
            0
        };
        for g in 0..lane_rows / LANES {
            let base = g * LANES;
            moment_rows_lane(
                &block[base * cols..(base + LANES) * cols],
                cols,
                self.stat,
                &mut out[base..base + LANES],
            );
        }
        for r in lane_rows..rows {
            let row = &block[r * cols..(r + 1) * cols];
            out[r] = moment_row(row, self.stat);
        }
        crate::simd::note_lane_rows(lane_rows);
        crate::simd::note_scalar_rows(rows - lane_rows);
        Ok(())
    }
}

/// One row's moment via the trimmed Welford pass: the scalar reference
/// order every lane in [`moment_rows_lane`] replicates exactly.
#[inline(always)]
fn moment_row(row: &[f32], stat: MomentStat) -> f32 {
    let mut mean = 0.0f64;
    if stat == MomentStat::Mean {
        for (j, &x) in row.iter().enumerate() {
            let x = x as f64;
            let d = x - mean;
            mean += d / (j + 1) as f64;
        }
        return mean as f32;
    }
    let mut m2 = 0.0f64;
    for (j, &x) in row.iter().enumerate() {
        let x = x as f64;
        let d = x - mean;
        mean += d / (j + 1) as f64;
        m2 += d * (x - mean);
    }
    if row.is_empty() {
        return f32::NAN;
    }
    let var = m2 / row.len() as f64;
    match stat {
        MomentStat::Variance => var as f32,
        _ => var.sqrt() as f32,
    }
}

/// Trimmed Welford over exactly `LANES` rows: lane `l` runs the scalar
/// recurrence of [`moment_row`] on row `l`, element order preserved.
#[inline(always)]
fn moment_rows_lane(block: &[f32], cols: usize, stat: MomentStat, out: &mut [f32]) {
    let mut mean = [0.0f64; LANES];
    let mut m2 = [0.0f64; LANES];
    if stat == MomentStat::Mean {
        for j in 0..cols {
            for l in 0..LANES {
                let x = block[l * cols + j] as f64;
                let d = x - mean[l];
                mean[l] += d / (j + 1) as f64;
            }
        }
        for l in 0..LANES {
            out[l] = mean[l] as f32;
        }
        return;
    }
    for j in 0..cols {
        for l in 0..LANES {
            let x = block[l * cols + j] as f64;
            let d = x - mean[l];
            mean[l] += d / (j + 1) as f64;
            m2[l] += d * (x - mean[l]);
        }
    }
    for l in 0..LANES {
        if cols == 0 {
            out[l] = f32::NAN;
            continue;
        }
        let var = m2[l] / cols as f64;
        out[l] = match stat {
            MomentStat::Variance => var as f32,
            _ => var.sqrt() as f32,
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::rankfilter::rank_filter;
    use crate::melt::grid::GridMode;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::stats::descriptive::moments;
    use crate::tensor::dense::Tensor;
    use crate::testing::assert_allclose;

    fn sample_melt() -> crate::melt::matrix::MeltMatrix {
        let x = Tensor::random(&[8, 9], 0.0, 255.0, 11).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap()
    }

    #[test]
    fn gaussian_kernel_matches_paradigm_broadcast() {
        let m = sample_melt();
        let k = GaussianRowKernel::new(&[3, 3], 1.0).unwrap();
        let mut got = vec![0.0f32; m.rows()];
        k.execute(m.data(), m.rows(), m.cols(), &mut got).unwrap();
        let want = crate::kernels::paradigm::apply_kernel_broadcast(
            &m,
            &gaussian_kernel(&[3, 3], 1.0),
        );
        assert_allclose(&got, &want, 0.0, 0.0);
        assert_eq!(k.artifact_kind(), Some("gaussian"));
        assert_eq!(k.extra_inputs().unwrap().vectors.len(), 1);
    }

    #[test]
    fn rank_kernel_matches_rank_filter() {
        let m = sample_melt();
        let k = RankRowKernel::new(RankKind::Median).unwrap();
        let mut got = vec![0.0f32; m.rows()];
        k.execute(m.data(), m.rows(), m.cols(), &mut got).unwrap();
        let want = rank_filter(&m, RankKind::Median).unwrap();
        assert_allclose(&got, &want, 0.0, 0.0);
        assert!(k.artifact_kind().is_none());
        assert!(RankRowKernel::new(RankKind::Quantile(1.5)).is_err());
    }

    #[test]
    fn local_moment_kernel_per_row_stats() {
        let m = sample_melt();
        let mut mean = vec![0.0f32; m.rows()];
        let mut std = vec![0.0f32; m.rows()];
        LocalMomentKernel::new(MomentStat::Mean)
            .execute(m.data(), m.rows(), m.cols(), &mut mean)
            .unwrap();
        LocalMomentKernel::new(MomentStat::Std)
            .execute(m.data(), m.rows(), m.cols(), &mut std)
            .unwrap();
        for r in 0..m.rows() {
            let mm = moments(m.row(r));
            assert!((mean[r] - mm.mean as f32).abs() < 1e-4);
            assert!((std[r] - mm.std() as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn trimmed_moment_passes_match_full_accumulator_bitwise() {
        // the stat-specific single/dual-recurrence passes must reproduce
        // the full Moments accumulator bit-for-bit — the trimming only
        // removes state the surviving recurrences never read
        use crate::testing::{check_property, SplitMix64};
        check_property("trimmed vs full moments bits", 30, |rng: &mut SplitMix64| {
            let cols = 1 + rng.below(40);
            let row: Vec<f32> = (0..cols).map(|_| rng.normal() * 50.0).collect();
            let m = moments(&row);
            let pairs = [
                (MomentStat::Mean, m.mean as f32),
                (MomentStat::Std, m.std() as f32),
                (MomentStat::Variance, m.variance() as f32),
            ];
            for (stat, want) in pairs {
                let got = moment_row(&row, stat);
                assert_eq!(got.to_bits(), want.to_bits(), "{stat:?} over {cols} cols");
            }
        });
    }

    #[test]
    fn moment_lane_path_matches_scalar_bitwise() {
        use crate::simd::{self, SimdMode};
        use crate::testing::{check_property, SplitMix64};
        check_property("moment lane vs scalar bits", 25, |rng: &mut SplitMix64| {
            let rows = 1 + rng.below(20);
            let cols = 1 + rng.below(15);
            let block: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 12.0).collect();
            for stat in [MomentStat::Mean, MomentStat::Std, MomentStat::Variance] {
                let k = LocalMomentKernel::new(stat);
                let mut scalar = vec![0.0f32; rows];
                simd::enter_job(SimdMode::ForceScalar);
                k.execute(&block, rows, cols, &mut scalar).unwrap();
                let mut lanes = vec![0.0f32; rows];
                simd::enter_job(SimdMode::ForceSimd);
                k.execute(&block, rows, cols, &mut lanes).unwrap();
                simd::enter_job(SimdMode::Auto);
                for r in 0..rows {
                    assert_eq!(
                        lanes[r].to_bits(),
                        scalar[r].to_bits(),
                        "row {r} of {rows}x{cols} under {stat:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn kernels_validate_inputs() {
        assert!(GaussianRowKernel::new(&[3, 3], 0.0).is_err());
        assert!(GaussianRowKernel::new(&[4, 4], 1.0).is_err());
        assert!(BilateralRowKernel::constant(&[3, 3], 1.0, -2.0).is_err());
        assert!(BilateralRowKernel::adaptive(&[3, 3], 0.0, 1.0).is_err());
        assert!(CurvatureRowKernel::new(&[4, 3]).is_err());
        // cols mismatch surfaces as a shape error, not a panic
        let g = GaussianRowKernel::new(&[3, 3], 1.0).unwrap();
        let mut out = vec![0.0f32; 2];
        assert!(g.execute(&[0.0; 10], 2, 5, &mut out).is_err());
    }

    #[test]
    fn bilateral_kernel_artifact_contract() {
        let c = BilateralRowKernel::constant(&[3, 3], 1.5, 25.0).unwrap();
        assert_eq!(c.artifact_kind(), Some("bilateral_const"));
        let e = c.extra_inputs().unwrap();
        assert_eq!(e.vectors.len(), 2);
        assert_eq!(e.vectors[0].len(), 9);
        assert_eq!(e.vectors[1], vec![25.0]);
        let a = BilateralRowKernel::adaptive(&[3, 3], 1.5, 0.5).unwrap();
        assert_eq!(a.artifact_kind(), Some("bilateral_adaptive"));
        assert_eq!(a.extra_inputs().unwrap().vectors[1], vec![0.5]);
    }
}
