//! Cross-chunk halo exchange for the fused chunk-resident executor.
//!
//! The recompute scheme (PR 1) buys chunk residency by extending every
//! chunk `[s, e)` to `[s − B_k, e + B_k)` at stage `k`: the halo rows are
//! *recomputed* in both neighbouring chunks, and the duplicated kernel work
//! grows with worker count and stage depth. This module implements the
//! alternative named in ROADMAP: after computing stage `k` over its chunk
//! *interior only*, a worker **publishes** the boundary rows its neighbours
//! will gather at stage `k + 1` on a shared [`HaloBoard`], and **fetches**
//! the few rows it needs from them — paying a brief neighbour
//! synchronization instead of redundant compute.
//!
//! Liveness: exchange-mode workers do not block inside [`HaloBoard`] on
//! the hot path. The dependency-aware `(chunk, stage)` scheduler
//! ([`crate::coordinator::scheduler::StageScheduler`]) only dispatches a
//! stage once every neighbour it gathers from has *already published* the
//! previous stage's boundary rows, so any chunk count is live — chunks
//! migrate between workers across stages instead of being pinned one per
//! worker. The board's blocking [`HaloBoard::fetch_into`] wait survives as
//! a fallback/assertion layer: if a fetch ever finds an unpublished cell,
//! either the scheduler mis-ordered a dispatch or halo sizing is wrong,
//! and the bounded wait (configurable via `ExecOptions::halo_wait`, config
//! `halo_wait_secs`, CLI `--halo-wait-secs`) converts that bug into an
//! error instead of a hung fleet.
//!
//! Correctness: published rows are the very values the neighbour computed
//! for its own interior, and every kernel is row-deterministic (§2.4), so
//! exchange mode is bit-for-bit identical to both the recompute path and
//! the legacy per-stage pipeline (property-tested in
//! `tests/integration_halo.rs`).
//!
//! Coverage argument for the two published segments: a chunk `[s, e)` only
//! ever needs stage-`k` rows within `h = flat_halo(op_{k+1})` of its own
//! boundary, and for any other chunk `[s', e')` with `e' ≤ s` those rows
//! satisfy `r ≥ s − h ≥ e' − h` — within `h` of that chunk's *high* end
//! (symmetrically for chunks above). So publishing the first and last
//! `h` interior rows of every chunk covers all cross-chunk gathers, even
//! when chunks are narrower than the halo and a gather spans several of
//! them.

use std::ops::Range;
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{Condvar, Mutex, MutexGuard, NamedCondvar, NamedMutex};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// How the fused executor obtains the halo rows that stage `k + 1` gathers
/// across chunk boundaries.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum HaloMode {
    /// Each chunk recomputes its neighbours' boundary rows locally
    /// (duplicated kernel work, no synchronization; any chunk count).
    #[default]
    Recompute,
    /// Neighbouring chunks exchange computed boundary rows through a
    /// [`HaloBoard`] (zero duplicated kernel work; any chunk count — the
    /// dependency-aware stage scheduler keeps every dispatch satisfiable).
    Exchange,
}

impl HaloMode {
    /// Parse a config / CLI spelling. Case-insensitive, surrounding
    /// whitespace ignored, so `"Exchange"`, `"EXCHANGE"` and padded TOML
    /// values all resolve.
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "recompute" => Ok(HaloMode::Recompute),
            "exchange" => Ok(HaloMode::Exchange),
            other => Err(Error::Config(format!(
                "unknown halo mode '{other}' (recompute|exchange)"
            ))),
        }
    }
}

impl std::fmt::Display for HaloMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            HaloMode::Recompute => "recompute",
            HaloMode::Exchange => "exchange",
        })
    }
}

/// Per-worker halo + gather accounting, summed into
/// [`RunMetrics`](crate::coordinator::metrics::RunMetrics) by the leader.
#[derive(Clone, Copy, Debug, Default)]
pub(crate) struct HaloStats {
    /// Boundary rows published to the board (exchange mode).
    pub published: usize,
    /// Neighbour rows copied off the board (exchange mode).
    pub received: usize,
    /// Halo rows recomputed locally (recompute mode).
    pub recomputed: usize,
    /// Accumulated lead the eager boundary publish buys the neighbours:
    /// the time between a stage's boundary rows landing on the board and
    /// that stage's interior finishing (exchange mode).
    pub eager_lead: Duration,
    /// Melt rows this worker gathered through the tile streamer.
    pub gather_rows: usize,
    /// Peak bytes of this worker's reusable gather tile buffer.
    pub peak_band_bytes: usize,
    /// Time this worker spent inside tile gathers (the parallelized melt).
    pub gather_time: Duration,
    /// Kernel rows computed on the lane-parallel SIMD path.
    pub simd_rows: usize,
    /// Kernel rows computed on the scalar path (remainders, pinned-scalar
    /// runs, and kernels with no lane form).
    pub scalar_rows: usize,
    /// Lane width of the SIMD path when any lane rows ran (else 0).
    pub simd_lanes: usize,
}

impl HaloStats {
    pub fn add(&mut self, other: &HaloStats) {
        self.published += other.published;
        self.received += other.received;
        self.recomputed += other.recomputed;
        self.eager_lead += other.eager_lead;
        self.gather_rows += other.gather_rows;
        // the fleet's scratch footprint is workers × the per-worker peak,
        // so the merged figure keeps the max, not the sum
        self.peak_band_bytes = self.peak_band_bytes.max(other.peak_band_bytes);
        self.gather_time += other.gather_time;
        self.simd_rows += other.simd_rows;
        self.scalar_rows += other.scalar_rows;
        // one lane width per build; merged as max so a scalar-only worker
        // never erases the width reported by a vectorized one
        self.simd_lanes = self.simd_lanes.max(other.simd_lanes);
    }
}

/// The boundary rows one chunk published for one stage: its first and last
/// `halo` interior rows (overlapping when the chunk is narrow).
struct Published {
    lo_start: usize,
    lo: Vec<f32>,
    hi_start: usize,
    hi: Vec<f32>,
}

impl Published {
    fn row(&self, r: usize) -> Option<f32> {
        if r >= self.lo_start && r < self.lo_start + self.lo.len() {
            Some(self.lo[r - self.lo_start])
        } else if r >= self.hi_start && r < self.hi_start + self.hi.len() {
            Some(self.hi[r - self.hi_start])
        } else {
            None
        }
    }
}

struct Cell {
    slot: Mutex<Option<Published>>,
    ready: Condvar,
}

/// The *secondary* error a waiter returns after another worker poisoned
/// the board. The executor's join loop recognises this exact message and
/// prefers the root-cause error from the worker that actually failed.
pub const ABORTED_MSG: &str = "halo exchange aborted: another worker failed";

/// Granularity of the poison/deadline re-check while waiting on a cell.
pub const WAIT_SLICE: Duration = Duration::from_millis(100);
/// Default backstop cap on any single cell/scheduler wait — converts a
/// genuine scheduling bug into an error instead of a hung fleet.
/// Deliberately generous: the wait clock overlaps a neighbour's
/// *legitimate* compute time for one stage over one chunk, and failing
/// workers are handled promptly by poisoning (on error or panic), not by
/// this deadline. Overridable per run via `ExecOptions::halo_wait`
/// (config key `halo_wait_secs`, CLI `--halo-wait-secs`) — tests drop it
/// to sub-second values so the timeout path itself is testable.
pub const DEFAULT_WAIT_DEADLINE: Duration = Duration::from_secs(600);

/// The exchange board: one publish-once cell per (stage, chunk), holding
/// the chunk's boundary rows for that stage. Readers block (bounded) until
/// the owning chunk publishes; a failing worker poisons the board so the
/// whole fleet errors out instead of deadlocking. Under the dependency-
/// aware stage scheduler the blocking wait is a fallback only: dispatched
/// stages find their cells already published.
pub struct HaloBoard {
    ranges: Vec<Range<usize>>,
    cells: Vec<Cell>,
    poisoned: AtomicBool,
    deadline: Duration,
}

impl HaloBoard {
    /// Build a board over the partition's chunk interiors for `stages`
    /// *exchanged* stages — an n-stage fused group trades rows across its
    /// n − 1 stage transitions, so it passes `n - 1`. The ranges must be
    /// ascending and contiguous (every partition the chunk policies emit
    /// is). `deadline` bounds any single blocking wait.
    pub fn new(ranges: &[Range<usize>], stages: usize, deadline: Duration) -> Result<Self> {
        let mut cursor = None;
        for r in ranges {
            if r.is_empty() || cursor.is_some_and(|c| c != r.start) {
                return Err(Error::Coordinator(format!(
                    "halo board needs ascending contiguous chunks, got {ranges:?}"
                )));
            }
            cursor = Some(r.end);
        }
        let cells = (0..stages * ranges.len())
            .map(|_| Cell {
                slot: Mutex::new_named("halo.cell", None),
                ready: Condvar::new_named("halo.cell.ready"),
            })
            .collect();
        Ok(Self {
            ranges: ranges.to_vec(),
            cells,
            poisoned: AtomicBool::new(false),
            deadline,
        })
    }

    pub fn num_chunks(&self) -> usize {
        self.ranges.len()
    }

    fn cell(&self, stage: usize, chunk: usize) -> &Cell {
        &self.cells[stage * self.ranges.len() + chunk]
    }

    /// The (low, high) boundary-segment widths chunk `chunk` publishes for
    /// a stage whose *successor* gathers `halo` rows, given the chunk's
    /// interior length `len`: the halo clamped to the chunk, zeroed on a
    /// side with no neighbour. The single source of truth shared by
    /// [`Self::publish`] and the executor's boundary-first split — the
    /// rows the split computes first are exactly the rows publish ships.
    pub fn boundary_segments(&self, chunk: usize, halo: usize, len: usize) -> (usize, usize) {
        let cap = halo.min(len);
        let k_lo = if chunk == 0 { 0 } else { cap };
        let k_hi = if chunk + 1 == self.ranges.len() { 0 } else { cap };
        (k_lo, k_hi)
    }

    /// Publish chunk `chunk`'s stage-`stage` boundary values out of its
    /// interior slab `vals` (one value per interior row): the first and
    /// last `halo` rows, clamped to the chunk — except that the first
    /// chunk skips its low segment and the last its high segment (no
    /// neighbour exists on that side to fetch them). Returns the number of
    /// distinct rows published. Each cell accepts exactly one publish, and
    /// a poisoned board accepts none: once any worker has failed, the run
    /// is aborting and late publishes fail fast instead of racing the
    /// teardown.
    pub fn publish(&self, stage: usize, chunk: usize, halo: usize, vals: &[f32]) -> Result<usize> {
        if self.poisoned.load(Ordering::Acquire) {
            return Err(Error::Coordinator(ABORTED_MSG.into()));
        }
        let r = self
            .ranges
            .get(chunk)
            .ok_or_else(|| Error::Coordinator(format!("halo publish: no chunk {chunk}")))?
            .clone();
        if vals.len() != r.len() {
            return Err(Error::shape(format!(
                "halo publish: {} values for chunk {chunk} of {} rows",
                vals.len(),
                r.len()
            )));
        }
        let (k_lo, k_hi) = self.boundary_segments(chunk, halo, r.len());
        let published = Published {
            lo_start: r.start,
            lo: vals[..k_lo].to_vec(),
            hi_start: r.end - k_hi,
            hi: vals[r.len() - k_hi..].to_vec(),
        };
        let cell = self.cell(stage, chunk);
        let mut slot = cell
            .slot
            .lock()
            .map_err(|_| Error::Coordinator("halo board poisoned by a worker panic".into()))?;
        if slot.is_some() {
            return Err(Error::Coordinator(format!(
                "halo cell (stage {stage}, chunk {chunk}) published twice"
            )));
        }
        *slot = Some(published);
        cell.ready.notify_all();
        Ok((k_lo + k_hi).min(r.len()))
    }

    /// Copy the stage-`stage` values of absolute rows `rows` into `dst`,
    /// blocking until every owning chunk has published. The rows must lie
    /// outside the caller's own chunk and within each owner's published
    /// boundary segments. Returns the number of rows copied.
    pub fn fetch_into(&self, stage: usize, rows: Range<usize>, dst: &mut [f32]) -> Result<usize> {
        if dst.len() != rows.len() {
            return Err(Error::shape(format!(
                "halo fetch: buffer {} for {} rows",
                dst.len(),
                rows.len()
            )));
        }
        let total = self.ranges.last().map_or(0, |r| r.end);
        if rows.start >= rows.end || rows.end > total {
            return Err(Error::Coordinator(format!(
                "halo fetch: rows {rows:?} outside 0..{total}"
            )));
        }
        let mut chunk = self.ranges.partition_point(|r| r.end <= rows.start);
        let mut row = rows.start;
        while row < rows.end {
            let r = self.ranges[chunk].clone();
            let upto = rows.end.min(r.end);
            let slot = self.wait(stage, chunk)?;
            let published = slot.as_ref().expect("wait returns a published cell");
            for rr in row..upto {
                dst[rr - rows.start] = published.row(rr).ok_or_else(|| {
                    Error::Coordinator(format!(
                        "halo row {rr} of chunk {chunk} (stage {stage}) was not published — \
                         halo sizing bug"
                    ))
                })?;
            }
            row = upto;
            chunk += 1;
        }
        Ok(rows.len())
    }

    fn wait(&self, stage: usize, chunk: usize) -> Result<MutexGuard<'_, Option<Published>>> {
        // a poisoned board serves nothing, published or not: the run is
        // aborting, so every reader fails fast with the secondary error
        if self.poisoned.load(Ordering::Acquire) {
            return Err(Error::Coordinator(ABORTED_MSG.into()));
        }
        let cell = self.cell(stage, chunk);
        let start = Instant::now();
        let mut slot = cell
            .slot
            .lock()
            .map_err(|_| Error::Coordinator("halo board poisoned by a worker panic".into()))?;
        while slot.is_none() {
            if self.poisoned.load(Ordering::Acquire) {
                return Err(Error::Coordinator(ABORTED_MSG.into()));
            }
            if start.elapsed() > self.deadline {
                return Err(Error::Coordinator(format!(
                    "halo wait for (stage {stage}, chunk {chunk}) exceeded {:?} — \
                     neighbour stalled or scheduling bug",
                    self.deadline
                )));
            }
            let (next, _) = cell
                .ready
                .wait_timeout(slot, WAIT_SLICE)
                .map_err(|_| Error::Coordinator("halo board poisoned by a worker panic".into()))?;
            slot = next;
        }
        Ok(slot)
    }

    /// Mark the board failed and wake every waiter: called by a worker on
    /// its way out with an error so blocked neighbours error out too.
    pub fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
        for cell in &self.cells {
            // taking the lock orders the store before any waiter re-checks
            let _guard = cell.slot.lock();
            cell.ready.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ranges(bounds: &[usize]) -> Vec<Range<usize>> {
        bounds.windows(2).map(|w| w[0]..w[1]).collect()
    }

    fn board(bounds: &[usize], stages: usize) -> HaloBoard {
        HaloBoard::new(&ranges(bounds), stages, DEFAULT_WAIT_DEADLINE).unwrap()
    }

    #[test]
    fn halo_mode_parses_and_displays() {
        assert_eq!(HaloMode::parse("recompute").unwrap(), HaloMode::Recompute);
        assert_eq!(HaloMode::parse("exchange").unwrap(), HaloMode::Exchange);
        assert!(HaloMode::parse("psychic").is_err());
        assert_eq!(HaloMode::Exchange.to_string(), "exchange");
        assert_eq!(HaloMode::default(), HaloMode::Recompute);
    }

    #[test]
    fn halo_mode_parse_normalizes_case_and_whitespace() {
        // TOML/CLI spellings users actually type: mixed case and padding
        for s in ["Exchange", "EXCHANGE", " exchange ", "\texchange\n"] {
            assert_eq!(HaloMode::parse(s).unwrap(), HaloMode::Exchange, "{s:?}");
        }
        for s in ["Recompute", "RECOMPUTE", "  recompute  "] {
            assert_eq!(HaloMode::parse(s).unwrap(), HaloMode::Recompute, "{s:?}");
        }
        // normalization does not invent modes
        assert!(HaloMode::parse("ex change").is_err());
        assert!(HaloMode::parse("").is_err());
    }

    #[test]
    fn halo_mode_parse_display_round_trips() {
        for mode in [HaloMode::Recompute, HaloMode::Exchange] {
            assert_eq!(HaloMode::parse(&mode.to_string()).unwrap(), mode);
            // and through the normalizer's worst case
            let shouty = mode.to_string().to_ascii_uppercase();
            assert_eq!(HaloMode::parse(&format!("  {shouty}  ")).unwrap(), mode);
        }
    }

    #[test]
    fn publish_then_fetch_round_trips() {
        let b = board(&[0, 4, 8, 12], 1);
        // chunk i rows hold 10+row; edge chunks publish only the segment a
        // neighbour exists to read (2 rows), the middle chunk both (4)
        assert_eq!(b.publish(0, 0, 2, &[10.0, 11.0, 12.0, 13.0]).unwrap(), 2);
        assert_eq!(b.publish(0, 1, 2, &[14.0, 15.0, 16.0, 17.0]).unwrap(), 4);
        assert_eq!(b.publish(0, 2, 2, &[18.0, 19.0, 20.0, 21.0]).unwrap(), 2);
        // chunk 1 fetches its low halo from chunk 0's high segment
        let mut dst = vec![0.0f32; 2];
        assert_eq!(b.fetch_into(0, 2..4, &mut dst).unwrap(), 2);
        assert_eq!(dst, vec![12.0, 13.0]);
        // chunk 0 fetches its high halo from chunk 1's low segment
        assert_eq!(b.fetch_into(0, 4..6, &mut dst).unwrap(), 2);
        assert_eq!(dst, vec![14.0, 15.0]);
        // chunk 2 reads chunk 1's high segment, chunk 1 reads chunk 2's low
        assert_eq!(b.fetch_into(0, 6..8, &mut dst).unwrap(), 2);
        assert_eq!(dst, vec![16.0, 17.0]);
        assert_eq!(b.fetch_into(0, 8..10, &mut dst).unwrap(), 2);
        assert_eq!(dst, vec![18.0, 19.0]);
    }

    #[test]
    fn fetch_spans_multiple_narrow_chunks() {
        // chunks of 1–2 rows, halo wider than any chunk: a fetch walks
        // several owners, each fully covered by its own segments
        let b = board(&[0, 1, 3, 4, 6], 1);
        b.publish(0, 0, 5, &[0.0]).unwrap();
        b.publish(0, 1, 5, &[1.0, 2.0]).unwrap();
        b.publish(0, 2, 5, &[3.0]).unwrap();
        b.publish(0, 3, 5, &[4.0, 5.0]).unwrap();
        let mut dst = vec![0.0f32; 4];
        b.fetch_into(0, 0..4, &mut dst).unwrap();
        assert_eq!(dst, vec![0.0, 1.0, 2.0, 3.0]);
        b.fetch_into(0, 2..6, &mut dst).unwrap();
        assert_eq!(dst, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn publish_validates() {
        let b = board(&[0, 4, 8], 2);
        // wrong slab length
        assert!(b.publish(0, 0, 1, &[1.0]).is_err());
        // unknown chunk
        assert!(b.publish(0, 7, 1, &[1.0; 4]).is_err());
        // double publish
        b.publish(1, 0, 1, &[1.0; 4]).unwrap();
        assert!(b.publish(1, 0, 1, &[1.0; 4]).is_err());
        // non-contiguous ranges rejected up front
        assert!(HaloBoard::new(&[0..2, 3..4], 1, DEFAULT_WAIT_DEADLINE).is_err());
        assert!(HaloBoard::new(&[0..0, 0..4], 1, DEFAULT_WAIT_DEADLINE).is_err());
    }

    #[test]
    fn multi_stage_cells_are_independent() {
        // a 4-stage fused group exchanges across 3 stage transitions: the
        // same chunk publishes a fresh cell per stage, and stage ≥ 1
        // fetches resolve against the matching stage's values only
        let b = board(&[0, 3, 6], 3);
        for stage in 0..3usize {
            let base = 100.0 * stage as f32;
            b.publish(stage, 0, 2, &[base, base + 1.0, base + 2.0]).unwrap();
            b.publish(stage, 1, 2, &[base + 3.0, base + 4.0, base + 5.0]).unwrap();
        }
        let mut dst = vec![0.0f32; 2];
        // chunk 1's low halo at stage 2 comes from chunk 0's stage-2 cell
        b.fetch_into(2, 1..3, &mut dst).unwrap();
        assert_eq!(dst, vec![201.0, 202.0]);
        // and stage 1 still serves its own (older) values
        b.fetch_into(1, 1..3, &mut dst).unwrap();
        assert_eq!(dst, vec![101.0, 102.0]);
        // stage-0 high fetch unaffected by later publishes
        b.fetch_into(0, 3..5, &mut dst).unwrap();
        assert_eq!(dst, vec![3.0, 4.0]);
    }

    #[test]
    fn wait_deadline_is_configurable_and_errors() {
        // the timeout path was untestable under the hard-coded 600 s
        // backstop; a sub-second deadline exercises it directly
        let b = HaloBoard::new(&ranges(&[0, 2, 4]), 1, Duration::from_millis(150)).unwrap();
        let t0 = Instant::now();
        let mut dst = vec![0.0f32; 2];
        let err = b.fetch_into(0, 2..4, &mut dst).unwrap_err();
        assert!(err.to_string().contains("exceeded"), "{err}");
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(150), "returned early: {waited:?}");
        assert!(waited < Duration::from_secs(30), "deadline ignored: {waited:?}");
    }

    #[test]
    fn fetch_rejects_uncovered_rows() {
        let b = board(&[0, 8, 16], 1);
        b.publish(0, 0, 1, &[1.0; 8]).unwrap();
        // row 4 is interior to chunk 0 and outside its halo-1 segments
        let mut dst = vec![0.0f32; 1];
        assert!(b.fetch_into(0, 4..5, &mut dst).is_err());
        // out-of-range rows and wrong buffer sizes error immediately
        assert!(b.fetch_into(0, 15..17, &mut dst).is_err());
        assert!(b.fetch_into(0, 0..2, &mut dst).is_err());
    }

    #[test]
    fn fetch_blocks_until_publish() {
        let b = board(&[0, 2, 4], 1);
        std::thread::scope(|s| {
            let b = &b;
            let reader = s.spawn(move || {
                let mut dst = vec![0.0f32; 2];
                b.fetch_into(0, 2..4, &mut dst).unwrap();
                dst
            });
            std::thread::sleep(Duration::from_millis(30));
            b.publish(0, 1, 2, &[8.0, 9.0]).unwrap();
            assert_eq!(reader.join().unwrap(), vec![8.0, 9.0]);
        });
    }

    #[test]
    fn publish_after_poison_is_rejected() {
        // once any worker failed, the run is aborting: a straggler's late
        // publish must fail fast with the secondary abort error instead of
        // landing rows no one will ever read
        let b = board(&[0, 4, 8], 1);
        b.publish(0, 0, 1, &[1.0, 2.0, 3.0, 4.0]).unwrap();
        // pre-poison sanity: chunk 0's high segment (row 3) is served
        let mut dst = vec![0.0f32; 1];
        b.fetch_into(0, 3..4, &mut dst).unwrap();
        assert_eq!(dst, vec![4.0]);
        b.poison();
        let err = b.publish(0, 1, 1, &[2.0; 4]).unwrap_err();
        assert!(err.to_string().contains("aborted"), "{err}");
        // a poisoned board serves NOTHING: the very row that succeeded
        // above now aborts, as does a fetch against an unpublished cell
        let err = b.fetch_into(0, 3..4, &mut dst).unwrap_err();
        assert!(err.to_string().contains("aborted"), "{err}");
        assert!(b.fetch_into(0, 4..5, &mut dst).is_err());
    }

    #[test]
    fn double_publish_is_rejected_even_for_identical_rows() {
        // publish-once is a hard invariant: a second publish of the SAME
        // values still errors — re-publishing would mask a scheduler bug
        // that ran a (chunk, stage) task twice
        let b = board(&[0, 3, 6], 2);
        let vals = [7.0f32, 8.0, 9.0];
        b.publish(1, 0, 1, &vals).unwrap();
        let err = b.publish(1, 0, 1, &vals).unwrap_err();
        assert!(err.to_string().contains("published twice"), "{err}");
        // other cells of the same chunk stay usable
        b.publish(0, 0, 1, &vals).unwrap();
    }

    #[test]
    fn poison_wakes_blocked_readers() {
        let b = board(&[0, 2, 4], 1);
        std::thread::scope(|s| {
            let b = &b;
            let reader = s.spawn(move || {
                let mut dst = vec![0.0f32; 2];
                b.fetch_into(0, 2..4, &mut dst)
            });
            std::thread::sleep(Duration::from_millis(30));
            b.poison();
            let err = reader.join().unwrap().unwrap_err();
            assert!(err.to_string().contains("aborted"), "{err}");
        });
    }
}
