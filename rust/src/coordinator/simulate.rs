//! Simulated parallel units (DESIGN.md §Substitutions).
//!
//! The build image exposes a single CPU core, so genuine thread-level
//! speedup is physically unobservable here. The paper's Fig 6 claim is
//! about *row-decoupled partitions scaling with the number of parallel
//! units*; that property is a function of the chunk cost distribution and
//! the §2.4 independence — not of the core count. This module reproduces
//! it faithfully on one core:
//!
//! 1. execute every chunk **serially**, recording per-chunk wall time
//!    (identical compute to a real worker, no co-scheduling noise);
//! 2. replay the chunk stream through a greedy list scheduler — each chunk
//!    goes to the currently least-loaded virtual worker, which is exactly
//!    the behaviour of the work-stealing queue in `scheduler.rs`;
//! 3. the makespan (max virtual-worker busy time) is the parallel compute
//!    time a real N-unit fleet would observe, modulo co-scheduling effects
//!    the paper itself deducts ("resource recovery").
//!
//! On a real multicore host the thread path in `pipeline.rs` measures the
//! same thing directly; `benches/fig6_parallel_scaling.rs` prints both.

use std::time::{Duration, Instant};

use crate::coordinator::job::{Backend, Job};
use crate::coordinator::plan::ChunkPolicy;
use crate::coordinator::worker::{execute_native, JobResources};
use crate::error::{Error, Result};
use crate::melt::grid::QuasiGrid;
use crate::melt::matrix::MeltMatrix;
use crate::melt::melt::melt_into;
use crate::melt::fold::fold_partitions;
use crate::tensor::dense::Tensor;

/// Outcome of a makespan simulation.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Parallel compute time with N virtual units (max busy time).
    pub makespan: Duration,
    /// Busy time per virtual worker.
    pub per_worker: Vec<Duration>,
    /// Total serial compute (sum of chunk times) = 1-unit makespan.
    pub serial_total: Duration,
}

impl SimReport {
    /// serial_total / makespan — the speedup a real fleet would see.
    pub fn speedup(&self) -> f64 {
        if self.makespan.is_zero() {
            return f64::NAN;
        }
        self.serial_total.as_secs_f64() / self.makespan.as_secs_f64()
    }
}

/// Greedy list scheduling of `durations` (in queue order) onto `workers`
/// units: each chunk lands on the least-loaded unit — the deterministic
/// fluid limit of the work-stealing queue.
pub fn list_schedule(durations: &[Duration], workers: usize) -> Result<SimReport> {
    if workers == 0 {
        return Err(Error::Coordinator("workers must be >= 1".into()));
    }
    let mut loads = vec![Duration::ZERO; workers];
    for &d in durations {
        let min = loads
            .iter_mut()
            .min_by_key(|l| **l)
            .expect("workers >= 1");
        *min += d;
    }
    let serial_total: Duration = durations.iter().sum();
    let makespan = loads.iter().max().copied().unwrap_or_default();
    Ok(SimReport {
        makespan,
        per_worker: loads,
        serial_total,
    })
}

/// Run `job` serially, timing every chunk; returns the output tensor and
/// the per-chunk durations (in partition order) for makespan replay.
pub fn run_job_timed_chunks(
    x: &Tensor<f32>,
    job: &Job,
    policy: ChunkPolicy,
) -> Result<(Tensor<f32>, Vec<Duration>)> {
    let res = JobResources::for_job(job, Backend::Native, None)?;
    let op = job.operator()?;
    let grid = QuasiGrid::resolve(x.shape(), &op, &job.grid)?;
    let rows = grid.rows();
    let cols = op.ravel_len();
    let mut data = crate::melt::melt::uninit_buffer(rows * cols);
    melt_into(x, &op, &grid, job.boundary, &mut data)?;
    let m = MeltMatrix::new(data, rows, cols, grid.out_shape().to_vec(), op.window().to_vec())?;

    let partition = policy.partition(rows, 1)?;
    let mut durations = Vec::with_capacity(partition.num_parts());
    let mut chunks = Vec::with_capacity(partition.num_parts());
    for range in partition.ranges() {
        let block = m.row_block(range.start, range.end)?;
        let mut out = vec![0.0f32; range.len()];
        let t = Instant::now();
        execute_native(&res, block, range.len(), &mut out)?;
        durations.push(t.elapsed());
        chunks.push(out);
    }
    let tensor = fold_partitions(&chunks, partition.ranges(), m.grid_shape())?;
    Ok((tensor, durations))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::{run_job, ExecOptions};
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn list_schedule_known_case() {
        // queue order onto 2 units: [4] -> u0, [3] -> u1, [2] -> u1(5? no:
        // u1=3 < u0=4 so u1), [1] -> u0(4 vs u1=5) => loads (5, 5)
        let r = list_schedule(&[ms(4), ms(3), ms(2), ms(1)], 2).unwrap();
        assert_eq!(r.serial_total, ms(10));
        assert_eq!(r.makespan, ms(5));
        assert!((r.speedup() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn one_worker_makespan_is_serial_total() {
        let d = vec![ms(1), ms(2), ms(3)];
        let r = list_schedule(&d, 1).unwrap();
        assert_eq!(r.makespan, r.serial_total);
        assert!(list_schedule(&d, 0).is_err());
    }

    #[test]
    fn makespan_monotone_in_workers_property() {
        check_property("makespan decreases with workers", 30, |rng: &mut SplitMix64| {
            let n = 8 + rng.below(64);
            let d: Vec<Duration> = (0..n)
                .map(|_| Duration::from_micros(10 + rng.below(1000) as u64))
                .collect();
            let mut prev = Duration::MAX;
            for w in 1..=6 {
                let r = list_schedule(&d, w).unwrap();
                assert!(r.makespan <= prev, "w={w}");
                // lower bounds: serial/w and the largest chunk
                let lb = r.serial_total.as_secs_f64() / w as f64;
                assert!(r.makespan.as_secs_f64() >= lb - 1e-12);
                assert!(r.makespan >= d.iter().max().copied().unwrap());
                prev = r.makespan;
            }
        });
    }

    #[test]
    fn timed_chunks_match_threaded_output() {
        let x = Tensor::random(&[12, 12], 0.0, 255.0, 5).unwrap();
        let job = Job::gaussian(&[3, 3], 1.0);
        let (sim, durations) =
            run_job_timed_chunks(&x, &job, ChunkPolicy::Fixed { chunk_rows: 37 }).unwrap();
        assert_eq!(durations.len(), 144usize.div_ceil(37));
        let (thr, _) = run_job(&x, &job, &ExecOptions::native(2)).unwrap();
        assert_allclose(sim.data(), thr.data(), 0.0, 0.0);
    }
}
