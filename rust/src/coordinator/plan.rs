//! Chunking policy: how a melt matrix is partitioned for a worker fleet.
//!
//! Native workers prefer a handful of large contiguous blocks (low queue
//! overhead, good prefetch); the PJRT path must slice at the artifacts'
//! fixed chunk height. Both policies produce a validated [`RowPartition`],
//! so the §2.4 conditions hold by construction.

use crate::error::Result;
use crate::melt::partition::RowPartition;

/// How to split melt rows into work units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkPolicy {
    /// `parts_per_worker * workers` near-equal blocks (native path).
    /// More parts than workers keeps the queue busy under imbalance.
    EvenPerWorker { parts_per_worker: usize },
    /// Fixed-height chunks (PJRT path: the artifact's `chunk_rows`).
    Fixed { chunk_rows: usize },
}

impl ChunkPolicy {
    /// Default native policy: 4 blocks per worker.
    pub fn native_default() -> Self {
        ChunkPolicy::EvenPerWorker { parts_per_worker: 4 }
    }

    /// Resolve into a concrete partition of `rows` for `workers`.
    pub fn partition(&self, rows: usize, workers: usize) -> Result<RowPartition> {
        match self {
            ChunkPolicy::EvenPerWorker { parts_per_worker } => {
                let parts = workers.max(1) * (*parts_per_worker).max(1);
                RowPartition::even(rows, parts)
            }
            ChunkPolicy::Fixed { chunk_rows } => RowPartition::chunked(rows, *chunk_rows),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn native_default_scales_with_workers() {
        let p = ChunkPolicy::native_default().partition(1000, 4).unwrap();
        assert_eq!(p.num_parts(), 16);
        p.validate().unwrap();
    }

    #[test]
    fn fixed_policy_respects_chunk_height() {
        let p = ChunkPolicy::Fixed { chunk_rows: 2048 }.partition(5000, 3).unwrap();
        assert_eq!(p.num_parts(), 3);
        assert_eq!(p.ranges()[0], 0..2048);
        assert_eq!(p.ranges()[2], 4096..5000);
    }

    #[test]
    fn partitions_always_valid_property() {
        check_property("chunk policies emit valid partitions", 40, |rng: &mut SplitMix64| {
            let rows = 1 + rng.below(10_000);
            let workers = 1 + rng.below(8);
            let policy = if rng.below(2) == 0 {
                ChunkPolicy::EvenPerWorker {
                    parts_per_worker: 1 + rng.below(8),
                }
            } else {
                ChunkPolicy::Fixed {
                    chunk_rows: 1 + rng.below(4096),
                }
            };
            let p = policy.partition(rows, workers).unwrap();
            p.validate().unwrap();
            assert_eq!(p.rows(), rows);
        });
    }
}
