//! Planning: how work is shaped before it runs.
//!
//! Two layers live here:
//!
//! * [`ChunkPolicy`] — how a melt matrix is partitioned for a worker fleet
//!   (native: a handful of large blocks; PJRT: the artifacts' fixed chunk
//!   height). Both produce a validated `RowPartition`, so the §2.4
//!   conditions hold by construction.
//! * The lazy [`Plan`] — the crate's execution API. `Plan::over(&x)`
//!   records a *stage graph* instead of executing: each [`Stage`] pairs an
//!   open [`RowKernel`](crate::coordinator::kernel::RowKernel) with its
//!   melt geometry (window, quasi-grid mode, boundary). [`Plan::compile`]
//!   runs the planner, which fuses consecutive compatible stages into
//!   groups that the executor (`coordinator::exec`) streams chunk-resident
//!   through the workers — one global melt, one global fold per group,
//!   instead of the legacy per-stage fold→re-melt barrier.
//!
//! Fusion rule: a stage joins its predecessor's group when it is
//! *streamable* — `GridMode::Same` (the group's row space is unchanged) and
//! a non-`Wrap` boundary (gathers stay within a bounded halo; see
//! [`crate::melt::melt::flat_halo`]) — and the backend is native (PJRT
//! artifacts have fixed chunk shapes, so PJRT stages run as singleton
//! groups). The *first* stage of a group is unconstrained: it is melted
//! globally, so any grid mode or boundary works there.

use std::ops::Range;
use std::sync::Arc;

use crate::coordinator::exec::{execute_groups_with, Fleet};
use crate::coordinator::job::Backend;
use crate::coordinator::kernel::{
    BilateralRowKernel, CurvatureRowKernel, GaussianRowKernel, LocalMomentKernel, MomentStat,
    RankRowKernel, RowKernel,
};
use crate::coordinator::metrics::PlanMetrics;
use crate::coordinator::pipeline::ExecOptions;
use crate::error::{Error, Result};
use crate::kernels::rankfilter::RankKind;
use crate::melt::grid::GridMode;
use crate::melt::melt::BoundaryMode;
use crate::melt::operator::Operator;
use crate::melt::partition::RowPartition;
use crate::tensor::dense::Tensor;

/// How to split melt rows into work units.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkPolicy {
    /// `parts_per_worker * workers` near-equal blocks (native path).
    /// More parts than workers keeps the queue busy under imbalance.
    EvenPerWorker { parts_per_worker: usize },
    /// Fixed-height chunks (PJRT path: the artifact's `chunk_rows`).
    Fixed { chunk_rows: usize },
    /// Near-equal blocks whose boundaries land on multiples of `unit` flat
    /// rows (except the tail, which takes the remainder). For a `(D, H, W)`
    /// volume on a `Same` grid, `unit = H * W` is the **depth-slab**
    /// decomposition: every chunk is a run of whole z-slabs, so the halo a
    /// chunk trades with its neighbours is a stack of complete `(z, y)`
    /// lines of `W` voxels. `unit = W` aligns to single lines instead.
    Aligned { unit: usize, parts_per_worker: usize },
}

impl ChunkPolicy {
    /// Default native policy: 4 blocks per worker.
    pub fn native_default() -> Self {
        ChunkPolicy::EvenPerWorker { parts_per_worker: 4 }
    }

    /// Resolve into a concrete partition of `rows` for `workers`.
    pub fn partition(&self, rows: usize, workers: usize) -> Result<RowPartition> {
        match self {
            ChunkPolicy::EvenPerWorker { parts_per_worker } => {
                let parts = workers.max(1) * (*parts_per_worker).max(1);
                RowPartition::even(rows, parts)
            }
            ChunkPolicy::Fixed { chunk_rows } => RowPartition::chunked(rows, *chunk_rows),
            ChunkPolicy::Aligned { unit, parts_per_worker } => {
                let unit = (*unit).max(1);
                // split whole units near-evenly, then scale back to flat
                // rows; the tail unit may be partial, so clip its end
                let units = rows.div_ceil(unit);
                let parts = (workers.max(1) * (*parts_per_worker).max(1)).min(units.max(1));
                let per_unit = RowPartition::even(units, parts)?;
                let ranges = per_unit
                    .ranges()
                    .iter()
                    .map(|r| (r.start * unit)..(r.end * unit).min(rows))
                    .collect();
                RowPartition::from_ranges(rows, ranges)
            }
        }
    }
}

/// Resolve the partition of a fused group's row space.
///
/// Both halo modes share the same over-partitioned policy: without an
/// explicit [`ChunkPolicy`] the heuristic targets chunks of ≥ ~8× the
/// total halo budget so recompute mode's duplicated halo work stays a
/// small fraction, floored at one chunk per worker (idle workers cost
/// more wall-clock than halo overhead) and capped at 4 chunks per worker
/// for load balancing. Exchange mode used to cap chunks at the worker
/// count for liveness; the dependency-aware
/// [`StageScheduler`](crate::coordinator::scheduler::StageScheduler)
/// dispatches only gather-satisfiable `(chunk, stage)` tasks, so any
/// chunk count is live and custom policies are always accepted.
pub(crate) fn fused_partition(
    rows: usize,
    workers: usize,
    halo_budget: usize,
    policy: Option<ChunkPolicy>,
) -> Result<RowPartition> {
    match policy {
        Some(p) => p.partition(rows, workers),
        None => {
            let max_parts = 4 * workers;
            let halo_budget = halo_budget.max(1);
            let parts = (rows / (8 * halo_budget)).clamp(workers, max_parts);
            RowPartition::even(rows, parts)
        }
    }
}

/// One recorded pipeline stage: an open row kernel plus its melt geometry.
#[derive(Clone, Debug)]
pub struct Stage {
    kernel: Arc<dyn RowKernel>,
    window: Vec<usize>,
    grid: GridMode,
    boundary: BoundaryMode,
}

impl Stage {
    /// Build a stage from any [`RowKernel`] (defaults: `Same` grid,
    /// `Reflect` boundary — the paper's benchmark settings).
    pub fn new(kernel: Arc<dyn RowKernel>, window: &[usize]) -> Result<Self> {
        Operator::new(window)?;
        Ok(Self {
            kernel,
            window: window.to_vec(),
            grid: GridMode::Same,
            boundary: BoundaryMode::Reflect,
        })
    }

    pub fn with_grid(mut self, grid: GridMode) -> Self {
        self.grid = grid;
        self
    }

    pub fn with_boundary(mut self, boundary: BoundaryMode) -> Self {
        self.boundary = boundary;
        self
    }

    pub fn kernel(&self) -> &Arc<dyn RowKernel> {
        &self.kernel
    }

    pub fn window(&self) -> &[usize] {
        &self.window
    }

    pub fn grid(&self) -> &GridMode {
        &self.grid
    }

    pub fn boundary(&self) -> BoundaryMode {
        self.boundary
    }

    pub fn operator(&self) -> Result<Operator> {
        Operator::new(&self.window)
    }

    /// Whether this stage can join a fused group as a *non-first* member:
    /// its gathers must stay within a bounded flat-row halo of each output
    /// row, which holds for `Same` grids with non-periodic boundaries.
    pub(crate) fn streamable(&self) -> bool {
        self.grid == GridMode::Same && !matches!(self.boundary, BoundaryMode::Wrap)
    }
}

/// A lazy, composable execution plan over one input tensor. Building is
/// pure recording; nothing executes until [`Plan::run`] /
/// [`Plan::compile`]. Builder errors (bad window, bad parameters) are
/// deferred and surfaced at compile time so the fluent chain stays clean.
#[derive(Debug)]
pub struct Plan<'a> {
    input: &'a Tensor<f32>,
    stages: Vec<Stage>,
    deferred: Option<Error>,
}

impl<'a> Plan<'a> {
    /// Start a plan over `input`.
    pub fn over(input: &'a Tensor<f32>) -> Self {
        Self {
            input,
            stages: Vec::new(),
            deferred: None,
        }
    }

    /// Start a plan over a rank-3 `(D, H, W)` volume. Identical to
    /// [`Plan::over`] except the rank is validated up front (deferred to
    /// compile time like every builder error), which catches the classic
    /// mistake of feeding a 2-D image to a `[3, 3, 3]`-window pipeline.
    ///
    /// On a `Same` grid the volume's melt rows are the voxels in `(z, y,
    /// x)` row-major order, so a contiguous row chunk is a stack of `(z,
    /// y)` lines of `W` voxels and a window of radii `(r_z, r_y, r_x)`
    /// reaches `r_z·H·W + r_y·W + r_x` flat rows past the chunk — halos
    /// span both z- and y-neighbours (see
    /// [`crate::melt::melt::flat_halo`]). Pair with
    /// [`ChunkPolicy::Aligned`]`{ unit: H * W, .. }` for whole-slab chunks.
    pub fn over_volume(input: &'a Tensor<f32>) -> Self {
        let mut plan = Self::over(input);
        if input.rank() != 3 {
            plan.deferred = Some(Error::shape(format!(
                "over_volume expects a rank-3 (D, H, W) tensor, got shape {:?}",
                input.shape()
            )));
        }
        plan
    }

    /// Append an explicit [`Stage`] (the open-extension path for custom
    /// [`RowKernel`] implementations).
    pub fn stage(mut self, stage: Stage) -> Self {
        if self.deferred.is_none() {
            self.stages.push(stage);
        }
        self
    }

    fn push(mut self, built: Result<Stage>) -> Self {
        if self.deferred.is_none() {
            match built {
                Ok(s) => self.stages.push(s),
                Err(e) => self.deferred = Some(e),
            }
        }
        self
    }

    /// Global gaussian filter stage.
    pub fn gaussian(self, window: &[usize], sigma: f32) -> Self {
        let built = GaussianRowKernel::new(window, sigma)
            .and_then(|k| Stage::new(Arc::new(k), window));
        self.push(built)
    }

    /// Separable gaussian: one axis-factored stage per non-unit axis of
    /// `window` (extents `[3, 3, 3]` record stages `[3, 1, 1]`, `[1, 3,
    /// 1]`, `[1, 1, 3]`). Each 1-D kernel is normalized, so the chain
    /// equals the dense [`Plan::gaussian`] of the same window in exact
    /// arithmetic for every per-axis boundary mode — within float
    /// tolerance in f32 — while costing `Σ w_a` multiplies per grid point
    /// instead of `Π w_a` (27 → 9 for a 3³ window, 125 → 15 for 5³). All
    /// stages are `Same`-grid / `Reflect`, so the whole chain fuses into
    /// one melt/fold group and streams chunk-resident.
    pub fn gaussian_separable(mut self, window: &[usize], sigma: f32) -> Self {
        if window.is_empty() {
            // surfaces the operator's own "empty window" error at compile
            return self.gaussian(window, sigma);
        }
        let rank = window.len();
        let axes: Vec<usize> = (0..rank).filter(|&a| window[a] != 1).collect();
        if axes.is_empty() {
            // all-unit window: a single identity stage keeps the plan
            // non-empty and the output well-defined
            return self.gaussian(&vec![1; rank], sigma);
        }
        for a in axes {
            let mut w = vec![1usize; rank];
            w[a] = window[a];
            self = self.gaussian(&w, sigma);
        }
        self
    }

    /// Bilateral stage with constant σ_r.
    pub fn bilateral_const(self, window: &[usize], sigma_d: f32, sigma_r: f32) -> Self {
        let built = BilateralRowKernel::constant(window, sigma_d, sigma_r)
            .and_then(|k| Stage::new(Arc::new(k), window));
        self.push(built)
    }

    /// Bilateral stage with locally adaptive σ_r.
    pub fn bilateral_adaptive(self, window: &[usize], sigma_d: f32, floor: f32) -> Self {
        let built = BilateralRowKernel::adaptive(window, sigma_d, floor)
            .and_then(|k| Stage::new(Arc::new(k), window));
        self.push(built)
    }

    /// N-D Gaussian curvature stage.
    pub fn curvature(self, window: &[usize]) -> Self {
        let built =
            CurvatureRowKernel::new(window).and_then(|k| Stage::new(Arc::new(k), window));
        self.push(built)
    }

    /// Per-row rank statistic stage (the `stats::rank` reduction).
    pub fn rank(self, window: &[usize], kind: RankKind) -> Self {
        let built = RankRowKernel::new(kind).and_then(|k| Stage::new(Arc::new(k), window));
        self.push(built)
    }

    /// Median filter stage.
    pub fn median(self, window: &[usize]) -> Self {
        self.rank(window, RankKind::Median)
    }

    /// Linear-interpolated per-row quantile stage, `q` in `[0, 1]`.
    pub fn quantile(self, window: &[usize], q: f64) -> Self {
        self.rank(window, RankKind::Quantile(q))
    }

    /// Morphological erosion (per-row min) stage.
    pub fn rank_min(self, window: &[usize]) -> Self {
        self.rank(window, RankKind::Min)
    }

    /// Morphological dilation (per-row max) stage.
    pub fn rank_max(self, window: &[usize]) -> Self {
        self.rank(window, RankKind::Max)
    }

    /// Per-row descriptive moment stage (the `stats::descriptive` path).
    pub fn local_moment(self, window: &[usize], stat: MomentStat) -> Self {
        let built = Stage::new(Arc::new(LocalMomentKernel::new(stat)), window);
        self.push(built)
    }

    /// Local mean map stage.
    pub fn local_mean(self, window: &[usize]) -> Self {
        self.local_moment(window, MomentStat::Mean)
    }

    /// Local standard-deviation map stage.
    pub fn local_std(self, window: &[usize]) -> Self {
        self.local_moment(window, MomentStat::Std)
    }

    /// Override the boundary mode of the most recently added stage.
    pub fn boundary(mut self, boundary: BoundaryMode) -> Self {
        if self.deferred.is_none() {
            match self.stages.last_mut() {
                Some(s) => s.boundary = boundary,
                None => {
                    self.deferred =
                        Some(Error::Coordinator("boundary() before any stage".into()))
                }
            }
        }
        self
    }

    /// Override the grid mode of the most recently added stage.
    pub fn grid(mut self, grid: GridMode) -> Self {
        if self.deferred.is_none() {
            match self.stages.last_mut() {
                Some(s) => s.grid = grid,
                None => {
                    self.deferred = Some(Error::Coordinator("grid() before any stage".into()))
                }
            }
        }
        self
    }

    /// The recorded stages, in order.
    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    pub fn len(&self) -> usize {
        self.stages.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// Run the planner for `backend`: surface deferred builder errors and
    /// fuse consecutive streamable stages into groups.
    pub fn compile(self, backend: Backend) -> Result<CompiledPlan<'a>> {
        if let Some(e) = self.deferred {
            return Err(e);
        }
        if self.stages.is_empty() {
            return Err(Error::Coordinator("empty plan".into()));
        }
        let groups = plan_groups(&self.stages, backend);
        Ok(CompiledPlan {
            input: self.input,
            stages: self.stages,
            groups,
            backend,
        })
    }

    /// Compile and execute in one call.
    pub fn run(self, opts: &ExecOptions) -> Result<(Tensor<f32>, PlanMetrics)> {
        self.compile(opts.backend)?.execute(opts)
    }
}

/// The planner: split `stages` into maximal fusable groups. A stage joins
/// the current group when the backend is native and the stage is
/// streamable; otherwise it starts a new group.
pub(crate) fn plan_groups(stages: &[Stage], backend: Backend) -> Vec<Range<usize>> {
    let mut groups = Vec::new();
    if stages.is_empty() {
        return groups;
    }
    let mut start = 0usize;
    for i in 1..stages.len() {
        let fuse = backend == Backend::Native && stages[i].streamable();
        if !fuse {
            groups.push(start..i);
            start = i;
        }
    }
    groups.push(start..stages.len());
    groups
}

/// A planned stage graph bound to its input: fusion groups are fixed,
/// execution is [`CompiledPlan::execute`].
#[derive(Debug)]
pub struct CompiledPlan<'a> {
    input: &'a Tensor<f32>,
    stages: Vec<Stage>,
    groups: Vec<Range<usize>>,
    backend: Backend,
}

impl CompiledPlan<'_> {
    /// The fusion groups (ranges over the stage list).
    pub fn groups(&self) -> &[Range<usize>] {
        &self.groups
    }

    /// The backend this plan's groups were planned for.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn stages(&self) -> &[Stage] {
        &self.stages
    }

    /// Human-readable plan summary, e.g.
    /// `[gaussian + curvature + median (fused)] [quantile]`.
    pub fn describe(&self) -> String {
        let mut parts = Vec::with_capacity(self.groups.len());
        for g in &self.groups {
            let names: Vec<&str> = self.stages[g.clone()]
                .iter()
                .map(|s| s.kernel().name())
                .collect();
            if g.len() > 1 {
                parts.push(format!("[{} (fused)]", names.join(" + ")));
            } else {
                parts.push(format!("[{}]", names[0]));
            }
        }
        parts.join(" ")
    }

    /// Execute the plan: each fused group performs exactly one global melt
    /// and one global fold, streaming chunks through all member stages
    /// while resident in a worker. The options' backend must match the one
    /// the plan was compiled for (fusion groups are backend-dependent).
    pub fn execute(&self, opts: &ExecOptions) -> Result<(Tensor<f32>, PlanMetrics)> {
        self.execute_on(opts, Fleet::Scoped, None)
    }

    /// [`CompiledPlan::execute`] on an explicit worker fleet with an
    /// optional plan cache — the serving entry point
    /// ([`Executor`](crate::serve::Executor) reuses its pool and
    /// `RowGather` tables across jobs through this).
    pub(crate) fn execute_on(
        &self,
        opts: &ExecOptions,
        fleet: Fleet<'_>,
        cache: Option<&crate::serve::cache::PlanCache>,
    ) -> Result<(Tensor<f32>, PlanMetrics)> {
        if opts.backend != self.backend {
            return Err(Error::Coordinator(format!(
                "plan compiled for {:?} but executed with {:?} options — recompile with \
                 Plan::compile({:?})",
                self.backend, opts.backend, opts.backend
            )));
        }
        execute_groups_with(self.input, &self.stages, &self.groups, opts, fleet, cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn native_default_scales_with_workers() {
        let p = ChunkPolicy::native_default().partition(1000, 4).unwrap();
        assert_eq!(p.num_parts(), 16);
        p.validate().unwrap();
    }

    #[test]
    fn fixed_policy_respects_chunk_height() {
        let p = ChunkPolicy::Fixed { chunk_rows: 2048 }.partition(5000, 3).unwrap();
        assert_eq!(p.num_parts(), 3);
        assert_eq!(p.ranges()[0], 0..2048);
        assert_eq!(p.ranges()[2], 4096..5000);
    }

    #[test]
    fn partitions_always_valid_property() {
        check_property("chunk policies emit valid partitions", 40, |rng: &mut SplitMix64| {
            let rows = 1 + rng.below(10_000);
            let workers = 1 + rng.below(8);
            let policy = match rng.below(3) {
                0 => ChunkPolicy::EvenPerWorker {
                    parts_per_worker: 1 + rng.below(8),
                },
                1 => ChunkPolicy::Fixed {
                    chunk_rows: 1 + rng.below(4096),
                },
                _ => ChunkPolicy::Aligned {
                    unit: 1 + rng.below(512),
                    parts_per_worker: 1 + rng.below(8),
                },
            };
            let p = policy.partition(rows, workers).unwrap();
            p.validate().unwrap();
            assert_eq!(p.rows(), rows);
        });
    }

    #[test]
    fn aligned_policy_lands_on_slab_boundaries() {
        // a (5, 6, 7) volume: unit = H*W = 42, 5 slabs over 2 workers × 2
        // parts — every boundary except the tail is a multiple of 42
        let unit = 42usize;
        let rows = 5 * unit;
        let p = ChunkPolicy::Aligned { unit, parts_per_worker: 2 }
            .partition(rows, 2)
            .unwrap();
        p.validate().unwrap();
        assert_eq!(p.num_parts(), 4);
        for r in p.ranges() {
            assert_eq!(r.start % unit, 0, "chunk start off the slab grid: {r:?}");
        }
        assert_eq!(p.ranges().last().unwrap().end, rows);
        // a partial tail slab is clipped, not dropped: 100 rows = 2 full
        // 42-row slabs + a 16-row tail, split 2 units + 1 unit
        let p = ChunkPolicy::Aligned { unit: 42, parts_per_worker: 1 }
            .partition(100, 2)
            .unwrap();
        p.validate().unwrap();
        assert_eq!(p.ranges(), &[0..84, 84..100]);
        // more parts than units degrades to one unit per chunk
        let p = ChunkPolicy::Aligned { unit: 10, parts_per_worker: 4 }
            .partition(30, 4)
            .unwrap();
        assert_eq!(p.ranges(), &[0..10, 10..20, 20..30]);
    }

    #[test]
    fn over_volume_validates_rank_deferred() {
        let img = Tensor::zeros(&[6, 6]).unwrap();
        let err = Plan::over_volume(&img)
            .gaussian(&[3, 3, 3], 1.0)
            .compile(Backend::Native)
            .unwrap_err();
        assert!(err.to_string().contains("rank-3"), "{err}");
        let vol = Tensor::zeros(&[4, 5, 6]).unwrap();
        let plan = Plan::over_volume(&vol).median(&[3, 3, 3]);
        assert!(plan.compile(Backend::Native).is_ok());
    }

    #[test]
    fn gaussian_separable_records_axis_stages() {
        let vol = Tensor::zeros(&[4, 5, 6]).unwrap();
        let plan = Plan::over_volume(&vol).gaussian_separable(&[3, 3, 3], 1.0);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.stages()[0].window(), &[3, 1, 1]);
        assert_eq!(plan.stages()[1].window(), &[1, 3, 1]);
        assert_eq!(plan.stages()[2].window(), &[1, 1, 3]);
        // all Same/Reflect: the whole chain fuses into one group
        let compiled = plan.compile(Backend::Native).unwrap();
        assert_eq!(compiled.groups(), &[0..3]);
        // unit axes are skipped entirely
        let plan = Plan::over_volume(&vol).gaussian_separable(&[5, 1, 3], 0.8);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.stages()[0].window(), &[5, 1, 1]);
        assert_eq!(plan.stages()[1].window(), &[1, 1, 3]);
        // an all-unit window records a single identity stage
        let plan = Plan::over_volume(&vol).gaussian_separable(&[1, 1, 1], 1.0);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.stages()[0].window(), &[1, 1, 1]);
        // builder errors stay deferred: even extent, bad sigma, empty window
        let x = Tensor::zeros(&[6, 6]).unwrap();
        assert!(Plan::over(&x)
            .gaussian_separable(&[3, 4], 1.0)
            .compile(Backend::Native)
            .is_err());
        assert!(Plan::over(&x)
            .gaussian_separable(&[3, 3], 0.0)
            .compile(Backend::Native)
            .is_err());
        assert!(Plan::over(&x)
            .gaussian_separable(&[], 1.0)
            .compile(Backend::Native)
            .is_err());
    }

    #[test]
    fn fused_partition_over_partitions_for_balance() {
        // shared heuristic (both halo modes): chunks ≥ ~8× the halo
        // budget, floored at one per worker, capped at four per worker
        let p = fused_partition(10_000, 4, 10, None).unwrap();
        assert_eq!(p.num_parts(), 16);
        let p = fused_partition(100, 4, 1_000, None).unwrap();
        assert_eq!(p.num_parts(), 4);
        // parts never exceed the row count
        let p = fused_partition(3, 8, 10, None).unwrap();
        assert_eq!(p.num_parts(), 3);
        // custom policies are always accepted — oversubscription (chunks >
        // workers) is legal in every halo mode now that the stage
        // scheduler keeps exchange live at any chunk count
        let fixed = |rows| Some(ChunkPolicy::Fixed { chunk_rows: rows });
        let p = fused_partition(100, 2, 1, fixed(10)).unwrap();
        assert_eq!(p.num_parts(), 10);
        let p = fused_partition(100, 2, 1, fixed(50)).unwrap();
        assert_eq!(p.num_parts(), 2);
    }

    #[test]
    fn plan_records_without_executing() {
        let x = Tensor::zeros(&[6, 6]).unwrap();
        let plan = Plan::over(&x)
            .gaussian(&[3, 3], 1.0)
            .curvature(&[3, 3])
            .quantile(&[3, 3], 0.5);
        assert_eq!(plan.len(), 3);
        assert_eq!(plan.stages()[0].kernel().name(), "gaussian");
        assert_eq!(plan.stages()[2].kernel().name(), "quantile");
    }

    #[test]
    fn builder_defers_errors_to_compile() {
        let x = Tensor::zeros(&[6, 6]).unwrap();
        // even window: recorded as a deferred error, surfaced at compile
        let plan = Plan::over(&x).gaussian(&[4, 4], 1.0).curvature(&[3, 3]);
        assert!(plan.compile(Backend::Native).is_err());
        // bad quantile
        assert!(Plan::over(&x)
            .quantile(&[3, 3], 2.0)
            .compile(Backend::Native)
            .is_err());
        // modifier before any stage
        assert!(Plan::over(&x)
            .boundary(BoundaryMode::Nearest)
            .gaussian(&[3, 3], 1.0)
            .compile(Backend::Native)
            .is_err());
        // empty plan
        assert!(Plan::over(&x).compile(Backend::Native).is_err());
    }

    #[test]
    fn planner_fuses_streamable_runs() {
        let x = Tensor::zeros(&[6, 6]).unwrap();
        let all_same = Plan::over(&x)
            .gaussian(&[3, 3], 1.0)
            .curvature(&[3, 3])
            .median(&[3, 3])
            .compile(Backend::Native)
            .unwrap();
        assert_eq!(all_same.groups(), &[0..3]);
        assert!(all_same.describe().contains("fused"));

        // a Wrap stage cannot join a group (non-local gathers) …
        let wrapped = Plan::over(&x)
            .gaussian(&[3, 3], 1.0)
            .curvature(&[3, 3])
            .boundary(BoundaryMode::Wrap)
            .median(&[3, 3])
            .compile(Backend::Native)
            .unwrap();
        // … but it can *start* one: groups split at the wrap stage only
        assert_eq!(wrapped.groups(), &[0..1, 1..3]);

        // grid changes split too
        let strided = Plan::over(&x)
            .gaussian(&[3, 3], 1.0)
            .median(&[3, 3])
            .grid(GridMode::Strided(vec![2, 2]))
            .compile(Backend::Native)
            .unwrap();
        assert_eq!(strided.groups(), &[0..1, 1..2]);
    }

    #[test]
    fn execute_rejects_backend_mismatch() {
        let x = Tensor::zeros(&[6, 6]).unwrap();
        let compiled = Plan::over(&x)
            .gaussian(&[3, 3], 1.0)
            .compile(Backend::Pjrt)
            .unwrap();
        assert_eq!(compiled.backend(), Backend::Pjrt);
        let err = compiled.execute(&ExecOptions::native(1)).unwrap_err();
        assert!(err.to_string().contains("compiled for"), "{err}");
    }

    #[test]
    fn planner_never_fuses_on_pjrt() {
        let x = Tensor::zeros(&[6, 6]).unwrap();
        let compiled = Plan::over(&x)
            .gaussian(&[3, 3], 1.0)
            .curvature(&[3, 3])
            .compile(Backend::Pjrt)
            .unwrap();
        assert_eq!(compiled.groups(), &[0..1, 1..2]);
    }
}
