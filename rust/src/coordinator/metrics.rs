//! Run metrics: setup vs compute timing, per-worker chunk counts, and
//! melt/fold pass accounting — enough to regenerate the paper's Fig 6
//! methodology ("deducting the time spent in the process initialization
//! and data partitioning from the total time cost") *and* to assert the
//! lazy `Plan` executor's structural claim: a fused group performs exactly
//! one global melt and one global fold however many stages it streams.

use std::time::Duration;

use crate::stats::descriptive::Moments;

/// Timing and throughput record of one coordinator run (a single stage or
/// one fused group).
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// melt + partition + worker spawn.
    pub setup: Duration,
    /// parallel kernel execution (the Fig 6 "practical time consumption").
    pub compute: Duration,
    /// chunk reassembly + fold.
    pub aggregate: Duration,
    /// chunks completed per worker — work-stealing balance diagnostics.
    /// In exchange-mode fused runs chunks migrate between workers across
    /// stages, so a worker is credited with the chunks whose *final*
    /// stage it ran (totals still sum to the chunk count); per-stage load
    /// balance there is better read from [`RunMetrics::sched_stalls`].
    pub chunks_per_worker: Vec<usize>,
    /// total melt rows processed.
    pub rows: usize,
    /// melt columns of the first stage (window ravel length).
    pub cols: usize,
    /// global melt passes performed (fused groups keep this at 1).
    pub melts: usize,
    /// global fold/assemble passes performed (fused groups keep this at 1).
    pub folds: usize,
    /// stages executed in this run (fused group size; 1 for a single job).
    pub stages: usize,
    /// boundary rows published to the halo-exchange board
    /// ([`HaloMode::Exchange`](crate::coordinator::HaloMode) fused runs).
    pub halo_published_rows: usize,
    /// neighbour rows received from the halo-exchange board.
    pub halo_received_rows: usize,
    /// halo rows recomputed locally
    /// ([`HaloMode::Recompute`](crate::coordinator::HaloMode) fused runs;
    /// exchange runs keep this at exactly 0).
    pub halo_recomputed_rows: usize,
    /// accumulated head start the eager boundary publish gave the
    /// neighbours: time between a stage's boundary rows landing on the
    /// halo board and that stage's interior finishing (exchange runs).
    pub halo_eager_lead: Duration,
    /// times an exchange worker asked the stage scheduler for a task and
    /// found none ready (dependency stalls — idle tail waits included).
    pub sched_stalls: usize,
    /// melt rows gathered through the tile streamer, summed over workers.
    /// Halo-extended rows count each time they are gathered, so recompute
    /// mode reports more than `rows * stages`; the ratio to it is the
    /// gather amplification factor.
    pub gather_rows: usize,
    /// peak bytes of any single worker's reusable gather tile buffer —
    /// the whole scratch footprint of the native melt phase is bounded by
    /// `workers * peak_band_bytes` (vs `rows * cols * 4` materialized).
    pub peak_band_bytes: usize,
    /// bytes of globally materialized melt matrix: exactly 0 on the
    /// native tile-streamed path; `rows * cols * 4` when PJRT
    /// materializes for its fixed-shape artifacts.
    pub melt_matrix_bytes: usize,
    /// accumulated time inside tile gathers — the melt phase, now running
    /// *inside* the workers' compute window instead of serially on the
    /// leader (summed across workers; PJRT reports its leader-side melt
    /// here, which also sits inside `setup`).
    pub gather: Duration,
    /// plan-cache hits this run charged against the serving
    /// [`PlanCache`](crate::serve::PlanCache) (0 on uncached one-shot
    /// runs): a hit means every `RowGather` table of the group was reused.
    pub plan_cache_hits: usize,
    /// plan-cache misses this run charged against the serving cache.
    pub plan_cache_misses: usize,
    /// plan-cache entries evicted (LRU order) while inserting this run's
    /// freshly built plan.
    pub plan_cache_evictions: usize,
    /// `RowGather` tables constructed from scratch for this run — 0 when
    /// the whole group came out of the plan cache, one per stage when it
    /// missed (and always one per native stage on uncached runs).
    pub gathers_built: usize,
    /// Jobs co-executed through this run's leading batch axis (the serving
    /// daemon's cross-request batching): N when N same-shape requests were
    /// stacked and folded together, 0 for an ordinary unbatched run.
    pub batched_jobs: usize,
    /// Kernel rows computed on the lane-parallel SIMD path, summed over
    /// workers. `simd_rows + scalar_rows == gather_rows` on native runs —
    /// every gathered tile row is computed exactly once, on one of the two
    /// paths (both bit-for-bit identical).
    pub simd_rows: usize,
    /// Kernel rows computed on the scalar path: lane-group remainders,
    /// runs pinned scalar (`--no-simd` / `simd = "scalar"`), and kernels
    /// with no lane form (median/quantile quickselect).
    pub scalar_rows: usize,
    /// Lane width of the SIMD path when any lane rows ran this run, else 0.
    pub simd_lanes: usize,
}

impl RunMetrics {
    /// End-to-end wall time.
    pub fn total(&self) -> Duration {
        self.setup + self.compute + self.aggregate
    }

    /// Rows per second through the compute phase.
    pub fn rows_per_sec(&self) -> f64 {
        if self.compute.is_zero() {
            return f64::INFINITY;
        }
        self.rows as f64 / self.compute.as_secs_f64()
    }

    /// Element-multiplies per second (rows * cols / compute) — the broadcast
    /// roofline figure used in EXPERIMENTS.md §Perf.
    pub fn melt_elems_per_sec(&self) -> f64 {
        if self.compute.is_zero() {
            return f64::INFINITY;
        }
        (self.rows as f64 * self.cols as f64) / self.compute.as_secs_f64()
    }

    /// Max/min chunk-count imbalance across workers (1.0 = perfectly even).
    pub fn imbalance(&self) -> f64 {
        let (mut mn, mut mx) = (usize::MAX, 0usize);
        for &c in &self.chunks_per_worker {
            mn = mn.min(c);
            mx = mx.max(c);
        }
        if self.chunks_per_worker.is_empty() || mn == 0 {
            return f64::NAN;
        }
        mx as f64 / mn as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "setup {:.2?} | compute {:.2?} | aggregate {:.2?} | {:.2e} rows/s | {} stage(s), {} melt, {} fold | workers {:?}",
            self.setup,
            self.compute,
            self.aggregate,
            self.rows_per_sec(),
            self.stages,
            self.melts,
            self.folds,
            self.chunks_per_worker
        );
        if self.halo_published_rows + self.halo_received_rows + self.halo_recomputed_rows > 0 {
            s.push_str(&format!(
                " | halo pub {} recv {} redo {}",
                self.halo_published_rows, self.halo_received_rows, self.halo_recomputed_rows
            ));
        }
        if self.halo_eager_lead > Duration::ZERO || self.sched_stalls > 0 {
            s.push_str(&format!(
                " | eager lead {:.2?}, {} stall(s)",
                self.halo_eager_lead, self.sched_stalls
            ));
        }
        if self.gather_rows > 0 {
            s.push_str(&format!(
                " | gather {} rows in {:.2?}, band peak {} B",
                self.gather_rows, self.gather, self.peak_band_bytes
            ));
        }
        if self.melt_matrix_bytes > 0 {
            s.push_str(&format!(" | melt matrix {} B", self.melt_matrix_bytes));
        }
        if self.plan_cache_hits + self.plan_cache_misses > 0 {
            s.push_str(&format!(
                " | plan cache {} hit(s) {} miss(es) {} evicted, {} gather(s) built",
                self.plan_cache_hits,
                self.plan_cache_misses,
                self.plan_cache_evictions,
                self.gathers_built
            ));
        }
        if self.batched_jobs > 0 {
            s.push_str(&format!(" | batch of {} job(s)", self.batched_jobs));
        }
        if self.simd_rows + self.scalar_rows > 0 {
            s.push_str(&format!(
                " | simd {} rows / scalar {} rows (lanes {})",
                self.simd_rows, self.scalar_rows, self.simd_lanes
            ));
        }
        s
    }
}

/// Metrics of one lazy-`Plan` execution: one [`RunMetrics`] per fusion
/// group plus partition-exact output statistics, merged per-chunk at the
/// aggregation barrier (the §2.4 aggregation-function path — free, since
/// the chunks are already in hand).
#[derive(Clone, Debug)]
pub struct PlanMetrics {
    /// One record per executed group, in pipeline order.
    pub groups: Vec<RunMetrics>,
    /// Moments of the final output, merged from per-chunk accumulators.
    pub output_moments: Moments,
}

impl PlanMetrics {
    /// End-to-end wall time across all groups.
    pub fn total(&self) -> Duration {
        self.groups.iter().map(|g| g.total()).sum()
    }

    /// Total global melt passes across the plan.
    pub fn melts(&self) -> usize {
        self.groups.iter().map(|g| g.melts).sum()
    }

    /// Total global fold passes across the plan.
    pub fn folds(&self) -> usize {
        self.groups.iter().map(|g| g.folds).sum()
    }

    /// Total stages executed.
    pub fn stages(&self) -> usize {
        self.groups.iter().map(|g| g.stages).sum()
    }

    /// Total boundary rows published to halo-exchange boards.
    pub fn halo_published(&self) -> usize {
        self.groups.iter().map(|g| g.halo_published_rows).sum()
    }

    /// Total neighbour rows received from halo-exchange boards.
    pub fn halo_received(&self) -> usize {
        self.groups.iter().map(|g| g.halo_received_rows).sum()
    }

    /// Total halo rows recomputed locally (0 for pure exchange-mode plans).
    pub fn halo_recomputed(&self) -> usize {
        self.groups.iter().map(|g| g.halo_recomputed_rows).sum()
    }

    /// Total eager-publish head start across exchange-mode groups.
    pub fn halo_eager_lead(&self) -> Duration {
        self.groups.iter().map(|g| g.halo_eager_lead).sum()
    }

    /// Total scheduler dependency stalls across exchange-mode groups.
    pub fn sched_stalls(&self) -> usize {
        self.groups.iter().map(|g| g.sched_stalls).sum()
    }

    /// Total melt rows gathered through the tile streamer.
    pub fn gather_rows(&self) -> usize {
        self.groups.iter().map(|g| g.gather_rows).sum()
    }

    /// Peak single-worker gather tile buffer across all groups.
    pub fn peak_band_bytes(&self) -> usize {
        self.groups.iter().map(|g| g.peak_band_bytes).max().unwrap_or(0)
    }

    /// Total globally materialized melt-matrix bytes (0 for all-native
    /// plans — the scratch-accounting assertion of the tiled executor).
    pub fn melt_matrix_bytes(&self) -> usize {
        self.groups.iter().map(|g| g.melt_matrix_bytes).sum()
    }

    /// Total time inside tile gathers across all groups and workers.
    pub fn gather_time(&self) -> Duration {
        self.groups.iter().map(|g| g.gather).sum()
    }

    /// Total plan-cache hits across all groups.
    pub fn plan_cache_hits(&self) -> usize {
        self.groups.iter().map(|g| g.plan_cache_hits).sum()
    }

    /// Total plan-cache misses across all groups.
    pub fn plan_cache_misses(&self) -> usize {
        self.groups.iter().map(|g| g.plan_cache_misses).sum()
    }

    /// Total plan-cache LRU evictions triggered by this plan's inserts.
    pub fn plan_cache_evictions(&self) -> usize {
        self.groups.iter().map(|g| g.plan_cache_evictions).sum()
    }

    /// Total `RowGather` tables built from scratch across all groups —
    /// the "repeat traffic melts nothing" assertion reads 0 here.
    pub fn gathers_built(&self) -> usize {
        self.groups.iter().map(|g| g.gathers_built).sum()
    }

    /// Jobs co-executed through the leading batch axis: every group of a
    /// batched plan carries the same batch size, so this is a max (not a
    /// sum, which would multiply-count one batch across its groups). 0 for
    /// unbatched plans.
    pub fn batched_jobs(&self) -> usize {
        self.groups.iter().map(|g| g.batched_jobs).max().unwrap_or(0)
    }

    /// Total kernel rows computed on the lane-parallel SIMD path.
    pub fn simd_rows(&self) -> usize {
        self.groups.iter().map(|g| g.simd_rows).sum()
    }

    /// Total kernel rows computed on the scalar path.
    pub fn scalar_rows(&self) -> usize {
        self.groups.iter().map(|g| g.scalar_rows).sum()
    }

    /// Lane width of the SIMD path across groups (max: a scalar-only
    /// group never erases the width reported by a vectorized one).
    pub fn simd_lanes(&self) -> usize {
        self.groups.iter().map(|g| g.simd_lanes).max().unwrap_or(0)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} group(s) | {} stage(s) | {} melt(s), {} fold(s) | total {:.2?} | out mean {:.4} std {:.4}",
            self.groups.len(),
            self.stages(),
            self.melts(),
            self.folds(),
            self.total(),
            self.output_moments.mean,
            self.output_moments.std()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_rates() {
        let m = RunMetrics {
            setup: Duration::from_millis(10),
            compute: Duration::from_millis(100),
            aggregate: Duration::from_millis(5),
            chunks_per_worker: vec![4, 4],
            rows: 1000,
            cols: 27,
            melts: 1,
            folds: 1,
            stages: 1,
            ..Default::default()
        };
        assert_eq!(m.total(), Duration::from_millis(115));
        assert!((m.rows_per_sec() - 10_000.0).abs() < 1.0);
        assert!((m.melt_elems_per_sec() - 270_000.0).abs() < 30.0);
        assert_eq!(m.imbalance(), 1.0);
        assert!(m.summary().contains("compute"));
        assert!(m.summary().contains("1 melt"));
        // halo counters stay out of the summary until something happens
        assert!(!m.summary().contains("halo"));
        let h = RunMetrics {
            halo_published_rows: 12,
            halo_received_rows: 12,
            ..Default::default()
        };
        assert!(h.summary().contains("halo pub 12 recv 12 redo 0"));
        // scheduler counters stay silent until they fire too
        assert!(!h.summary().contains("eager lead"));
        let s = RunMetrics {
            halo_eager_lead: Duration::from_millis(3),
            sched_stalls: 2,
            ..Default::default()
        };
        assert!(s.summary().contains("eager lead"));
        assert!(s.summary().contains("2 stall(s)"));
    }

    #[test]
    fn gather_counters_surface_in_summary() {
        // quiet until the tile streamer runs …
        let m = RunMetrics::default();
        assert!(!m.summary().contains("gather"));
        assert!(!m.summary().contains("melt matrix"));
        // … then the traffic and the scratch peak are visible
        let g = RunMetrics {
            gather_rows: 1234,
            peak_band_bytes: 9216,
            gather: Duration::from_millis(7),
            ..Default::default()
        };
        let s = g.summary();
        assert!(s.contains("gather 1234 rows"), "{s}");
        assert!(s.contains("band peak 9216 B"), "{s}");
        assert!(!s.contains("melt matrix"), "{s}");
        // a PJRT materialization is called out separately
        let p = RunMetrics {
            melt_matrix_bytes: 4096,
            ..Default::default()
        };
        assert!(p.summary().contains("melt matrix 4096 B"));
    }

    #[test]
    fn cache_counters_surface_in_summary() {
        // silent on uncached one-shot runs …
        let m = RunMetrics::default();
        assert!(!m.summary().contains("plan cache"));
        // … a served hit reports reuse with zero builds
        let hit = RunMetrics {
            plan_cache_hits: 1,
            ..Default::default()
        };
        let s = hit.summary();
        assert!(s.contains("plan cache 1 hit(s) 0 miss(es)"), "{s}");
        assert!(s.contains("0 gather(s) built"), "{s}");
        // … a miss that evicted reports the build and the eviction
        let miss = RunMetrics {
            plan_cache_misses: 1,
            plan_cache_evictions: 1,
            gathers_built: 3,
            ..Default::default()
        };
        let s = miss.summary();
        assert!(s.contains("1 miss(es) 1 evicted"), "{s}");
        assert!(s.contains("3 gather(s) built"), "{s}");
    }

    #[test]
    fn plan_metrics_total_cache_counters() {
        let g1 = RunMetrics {
            plan_cache_misses: 1,
            gathers_built: 3,
            ..Default::default()
        };
        let g2 = RunMetrics {
            plan_cache_hits: 1,
            plan_cache_evictions: 2,
            ..Default::default()
        };
        let pm = PlanMetrics {
            groups: vec![g1, g2],
            output_moments: Moments::new(),
        };
        assert_eq!(pm.plan_cache_hits(), 1);
        assert_eq!(pm.plan_cache_misses(), 1);
        assert_eq!(pm.plan_cache_evictions(), 2);
        assert_eq!(pm.gathers_built(), 3);
    }

    #[test]
    fn batch_counter_surfaces_in_summary_and_totals_as_max() {
        // unbatched runs stay silent …
        let m = RunMetrics::default();
        assert!(!m.summary().contains("batch"));
        // … a batched run reports its size
        let b = RunMetrics {
            batched_jobs: 4,
            ..Default::default()
        };
        assert!(b.summary().contains("batch of 4 job(s)"));
        // every group of one batched plan carries the same size: max, not sum
        let pm = PlanMetrics {
            groups: vec![
                RunMetrics {
                    batched_jobs: 4,
                    ..Default::default()
                },
                RunMetrics {
                    batched_jobs: 4,
                    ..Default::default()
                },
            ],
            output_moments: Moments::new(),
        };
        assert_eq!(pm.batched_jobs(), 4);
        let empty = PlanMetrics {
            groups: vec![],
            output_moments: Moments::new(),
        };
        assert_eq!(empty.batched_jobs(), 0);
    }

    #[test]
    fn simd_counters_surface_in_summary_and_totals() {
        // silent until a kernel row runs …
        let m = RunMetrics::default();
        assert!(!m.summary().contains("simd"));
        // … then the lane/scalar split and the width are visible
        let v = RunMetrics {
            simd_rows: 96,
            scalar_rows: 4,
            simd_lanes: 8,
            ..Default::default()
        };
        let s = v.summary();
        assert!(s.contains("simd 96 rows / scalar 4 rows (lanes 8)"), "{s}");
        // a pinned-scalar run still reports its rows (lanes 0)
        let sc = RunMetrics {
            scalar_rows: 50,
            ..Default::default()
        };
        assert!(sc.summary().contains("simd 0 rows / scalar 50 rows (lanes 0)"));
        // plan totals: rows sum, lane width is a max across groups
        let pm = PlanMetrics {
            groups: vec![v, sc],
            output_moments: Moments::new(),
        };
        assert_eq!(pm.simd_rows(), 96);
        assert_eq!(pm.scalar_rows(), 54);
        assert_eq!(pm.simd_lanes(), 8);
        let empty = PlanMetrics {
            groups: vec![],
            output_moments: Moments::new(),
        };
        assert_eq!(empty.simd_lanes(), 0);
    }

    #[test]
    fn degenerate_cases() {
        let m = RunMetrics::default();
        assert!(m.rows_per_sec().is_infinite());
        assert!(m.imbalance().is_nan());
        let m = RunMetrics {
            chunks_per_worker: vec![0, 3],
            ..Default::default()
        };
        assert!(m.imbalance().is_nan());
    }

    #[test]
    fn imbalance_detects_skew() {
        let m = RunMetrics {
            chunks_per_worker: vec![2, 8],
            ..Default::default()
        };
        assert_eq!(m.imbalance(), 4.0);
    }

    #[test]
    fn plan_metrics_aggregate_groups() {
        let g1 = RunMetrics {
            compute: Duration::from_millis(10),
            melts: 1,
            folds: 1,
            stages: 3,
            halo_published_rows: 40,
            halo_received_rows: 40,
            halo_eager_lead: Duration::from_millis(4),
            sched_stalls: 3,
            gather_rows: 300,
            peak_band_bytes: 4096,
            gather: Duration::from_millis(2),
            ..Default::default()
        };
        let g2 = RunMetrics {
            compute: Duration::from_millis(5),
            melts: 1,
            folds: 1,
            stages: 1,
            halo_recomputed_rows: 9,
            halo_eager_lead: Duration::from_millis(1),
            sched_stalls: 1,
            gather_rows: 100,
            peak_band_bytes: 1024,
            gather: Duration::from_millis(1),
            melt_matrix_bytes: 2048,
            ..Default::default()
        };
        let pm = PlanMetrics {
            groups: vec![g1, g2],
            output_moments: Moments::new(),
        };
        assert_eq!(pm.melts(), 2);
        assert_eq!(pm.folds(), 2);
        assert_eq!(pm.stages(), 4);
        assert_eq!(pm.halo_published(), 40);
        assert_eq!(pm.halo_received(), 40);
        assert_eq!(pm.halo_recomputed(), 9);
        assert_eq!(pm.halo_eager_lead(), Duration::from_millis(5));
        assert_eq!(pm.sched_stalls(), 4);
        assert_eq!(pm.gather_rows(), 400);
        assert_eq!(pm.peak_band_bytes(), 4096); // max, not sum
        assert_eq!(pm.melt_matrix_bytes(), 2048);
        assert_eq!(pm.gather_time(), Duration::from_millis(3));
        assert_eq!(pm.total(), Duration::from_millis(15));
        assert!(pm.summary().contains("2 group(s)"));
    }
}
