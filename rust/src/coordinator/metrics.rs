//! Run metrics: setup vs compute timing, per-worker chunk counts, and a
//! latency histogram — enough to regenerate the paper's Fig 6 methodology
//! ("deducting the time spent in the process initialization and data
//! partitioning from the total time cost").

use std::time::Duration;

/// Timing and throughput record of one coordinator run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// melt + partition + worker spawn.
    pub setup: Duration,
    /// parallel kernel execution (the Fig 6 "practical time consumption").
    pub compute: Duration,
    /// chunk reassembly + fold.
    pub aggregate: Duration,
    /// chunks completed per worker (work-stealing balance diagnostics).
    pub chunks_per_worker: Vec<usize>,
    /// total melt rows processed.
    pub rows: usize,
    /// melt columns (window ravel length).
    pub cols: usize,
}

impl RunMetrics {
    /// End-to-end wall time.
    pub fn total(&self) -> Duration {
        self.setup + self.compute + self.aggregate
    }

    /// Rows per second through the compute phase.
    pub fn rows_per_sec(&self) -> f64 {
        if self.compute.is_zero() {
            return f64::INFINITY;
        }
        self.rows as f64 / self.compute.as_secs_f64()
    }

    /// Element-multiplies per second (rows * cols / compute) — the broadcast
    /// roofline figure used in EXPERIMENTS.md §Perf.
    pub fn melt_elems_per_sec(&self) -> f64 {
        if self.compute.is_zero() {
            return f64::INFINITY;
        }
        (self.rows as f64 * self.cols as f64) / self.compute.as_secs_f64()
    }

    /// Max/min chunk-count imbalance across workers (1.0 = perfectly even).
    pub fn imbalance(&self) -> f64 {
        let (mut mn, mut mx) = (usize::MAX, 0usize);
        for &c in &self.chunks_per_worker {
            mn = mn.min(c);
            mx = mx.max(c);
        }
        if self.chunks_per_worker.is_empty() || mn == 0 {
            return f64::NAN;
        }
        mx as f64 / mn as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "setup {:.2?} | compute {:.2?} | aggregate {:.2?} | {:.2e} rows/s | workers {:?}",
            self.setup,
            self.compute,
            self.aggregate,
            self.rows_per_sec(),
            self.chunks_per_worker
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_rates() {
        let m = RunMetrics {
            setup: Duration::from_millis(10),
            compute: Duration::from_millis(100),
            aggregate: Duration::from_millis(5),
            chunks_per_worker: vec![4, 4],
            rows: 1000,
            cols: 27,
        };
        assert_eq!(m.total(), Duration::from_millis(115));
        assert!((m.rows_per_sec() - 10_000.0).abs() < 1.0);
        assert!((m.melt_elems_per_sec() - 270_000.0).abs() < 30.0);
        assert_eq!(m.imbalance(), 1.0);
        assert!(m.summary().contains("compute"));
    }

    #[test]
    fn degenerate_cases() {
        let m = RunMetrics::default();
        assert!(m.rows_per_sec().is_infinite());
        assert!(m.imbalance().is_nan());
        let m = RunMetrics {
            chunks_per_worker: vec![0, 3],
            ..Default::default()
        };
        assert!(m.imbalance().is_nan());
    }

    #[test]
    fn imbalance_detects_skew() {
        let m = RunMetrics {
            chunks_per_worker: vec![2, 8],
            ..Default::default()
        };
        assert_eq!(m.imbalance(), 4.0);
    }
}
