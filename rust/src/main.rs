//! `meltframe` — the L3 leader binary: CLI over the coordinator.

use std::process::ExitCode;

use meltframe::cli::{parse_args, Command, USAGE};
use meltframe::config::spec::RunConfig;
use meltframe::coordinator::pipeline::{run_pipeline, ExecOptions};
use meltframe::coordinator::Job;
use meltframe::error::Result;
use meltframe::runtime::artifact::ArtifactManifest;
use meltframe::runtime::client::PjrtContext;
use meltframe::tensor::dense::Tensor;
use meltframe::tensor::npy;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = match parse_args(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    match dispatch(cmd) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(cmd: Command) -> Result<()> {
    match cmd {
        Command::Help => {
            println!("{USAGE}");
            Ok(())
        }
        Command::Inspect { artifacts } => {
            let ctx = PjrtContext::cpu()?;
            println!("PJRT: {}", ctx.describe());
            match ArtifactManifest::load(&artifacts) {
                Ok(m) => {
                    println!("artifacts ({}, chunk_rows={}):", artifacts.display(), m.chunk_rows);
                    for e in m.entries() {
                        println!(
                            "  {:<26} kind={:<18} window={:?} inputs={:?}",
                            e.name, e.kind, e.window, e.inputs
                        );
                    }
                    m.verify_files()?;
                    println!("all artifact files present");
                }
                Err(e) => println!("no artifacts: {e}"),
            }
            Ok(())
        }
        Command::Run { config, out } => {
            let cfg = RunConfig::load(&config)?;
            let x = cfg.input.load()?;
            println!(
                "input {:?} | {} stage(s) | {} worker(s) | backend {:?}",
                x.shape(),
                cfg.jobs.len(),
                cfg.options.workers,
                cfg.options.backend
            );
            let (result, metrics) = run_pipeline(&x, &cfg.jobs, &cfg.options)?;
            for (i, m) in metrics.iter().enumerate() {
                println!("stage {}: {}", i + 1, m.summary());
            }
            if let Some(path) = out {
                npy::save(&result, &path)?;
                println!("wrote {}", path.display());
            } else {
                println!(
                    "result shape {:?} mean {:.4} min {:.4} max {:.4}",
                    result.shape(),
                    result.mean(),
                    result.min(),
                    result.max()
                );
            }
            Ok(())
        }
        Command::Demo {
            workers,
            backend,
            artifacts,
        } => {
            // Fig 6 style demonstration: 3-D gaussian over a synthetic volume
            let x = Tensor::synthetic_volume(&[48, 48, 48], 42);
            let job = Job::gaussian(&[3, 3, 3], 1.0);
            let opts = if backend == "pjrt" {
                ExecOptions::pjrt(workers, artifacts)
            } else {
                ExecOptions::native(workers)
            };
            println!("demo: 48^3 volume, 3^3 gaussian, {workers} worker(s), backend {backend}");
            let (result, metrics) = run_pipeline(&x, std::slice::from_ref(&job), &opts)?;
            println!("{}", metrics[0].summary());
            println!(
                "result mean {:.4} (input {:.4}) — smoothing preserves the mean",
                result.mean(),
                x.mean()
            );
            Ok(())
        }
    }
}
