//! `meltframe` — the L3 leader binary: CLI over the lazy Plan coordinator.

use std::process::ExitCode;

use meltframe::cli::{parse_args, Command, USAGE};
use meltframe::config::spec::RunConfig;
use meltframe::coordinator::pipeline::{run_pipeline, ExecOptions};
use meltframe::coordinator::Plan;
use meltframe::error::Result;
use meltframe::runtime::artifact::ArtifactManifest;
use meltframe::runtime::client::PjrtContext;
use meltframe::serve::daemon::{serve, ServeOptions};
use meltframe::serve::executor::Executor;
use meltframe::serve::protocol::{execute_request, parse_request, Request};
use meltframe::tensor::dense::Tensor;
use meltframe::tensor::npy;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = match parse_args(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    match dispatch(cmd) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(cmd: Command) -> Result<()> {
    match cmd {
        Command::Help => {
            println!("{USAGE}");
            Ok(())
        }
        Command::Inspect { artifacts } => {
            match PjrtContext::cpu() {
                Ok(ctx) => println!("PJRT: {}", ctx.describe()),
                Err(e) => println!("PJRT: {e}"),
            }
            match ArtifactManifest::load(&artifacts) {
                Ok(m) => {
                    println!("artifacts ({}, chunk_rows={}):", artifacts.display(), m.chunk_rows);
                    for e in m.entries() {
                        println!(
                            "  {:<26} kind={:<18} window={:?} inputs={:?}",
                            e.name, e.kind, e.window, e.inputs
                        );
                    }
                    m.verify_files()?;
                    println!("all artifact files present");
                }
                Err(e) => println!("no artifacts: {e}"),
            }
            Ok(())
        }
        Command::Run {
            config,
            out,
            legacy,
            halo_mode,
            halo_wait_secs,
            tile_rows,
            no_simd,
        } => {
            let mut cfg = RunConfig::load(&config)?;
            if let Some(mode) = halo_mode {
                cfg.options.halo_mode = mode;
            }
            if let Some(secs) = halo_wait_secs {
                cfg.options.halo_wait = std::time::Duration::from_secs(secs);
            }
            if let Some(tile) = tile_rows {
                cfg.options.tile_rows = tile;
            }
            if no_simd {
                cfg.options.simd = meltframe::simd::SimdMode::ForceScalar;
            }
            let x = cfg.input.load()?;
            let fused = cfg.fused && !legacy;
            println!(
                "input {:?} | {} stage(s) | {} worker(s) | backend {:?} | {}",
                x.shape(),
                cfg.jobs.len(),
                cfg.options.workers,
                cfg.options.backend,
                if fused {
                    format!("fused plan (halo {})", cfg.options.halo_mode)
                } else {
                    "legacy stage-by-stage".to_string()
                }
            );
            let result = if fused {
                let compiled = cfg.plan(&x)?.compile(cfg.options.backend)?;
                println!("plan: {}", compiled.describe());
                let (result, pm) = compiled.execute(&cfg.options)?;
                for (i, g) in pm.groups.iter().enumerate() {
                    println!("group {}: {}", i + 1, g.summary());
                }
                println!("{}", pm.summary());
                result
            } else {
                let (result, metrics) = run_pipeline(&x, &cfg.jobs, &cfg.options)?;
                for (i, m) in metrics.iter().enumerate() {
                    println!("stage {}: {}", i + 1, m.summary());
                }
                result
            };
            if let Some(path) = out {
                npy::save(&result, &path)?;
                println!("wrote {}", path.display());
            } else {
                println!(
                    "result shape {:?} mean {:.4} min {:.4} max {:.4}",
                    result.shape(),
                    result.mean(),
                    result.min(),
                    result.max()
                );
            }
            Ok(())
        }
        Command::Demo {
            workers,
            backend,
            artifacts,
            dims,
        } => {
            // Fig 6 style demonstration, plus the fused Plan on top:
            // gaussian → curvature → median over a synthetic (D, H, W)
            // volume or (H, W) image per --dims (the stats stages are
            // native-only, so the PJRT demo runs the gaussian alone)
            let x = if dims.len() == 3 {
                Tensor::synthetic_volume(&dims, 42)
            } else {
                Tensor::synthetic_image(&[dims[0], dims[1]], 42)
            };
            let window = vec![3usize; dims.len()];
            let kind = if dims.len() == 3 { "volume" } else { "image" };
            let opts = if backend == "pjrt" {
                ExecOptions::pjrt(workers, artifacts)
            } else {
                ExecOptions::native(workers)
            };
            let plan = if backend == "pjrt" {
                println!(
                    "demo: {dims:?} {kind}, gaussian {window:?}, {workers} worker(s), \
                     backend pjrt"
                );
                Plan::over(&x).gaussian(&window, 1.0)
            } else {
                println!(
                    "demo: {dims:?} {kind}, gaussian → curvature → median over {window:?}, \
                     {workers} worker(s), backend native"
                );
                Plan::over(&x)
                    .gaussian(&window, 1.0)
                    .curvature(&window)
                    .median(&window)
            };
            let compiled = plan.compile(opts.backend)?;
            println!("plan: {}", compiled.describe());
            let (result, pm) = compiled.execute(&opts)?;
            for (i, g) in pm.groups.iter().enumerate() {
                println!("group {}: {}", i + 1, g.summary());
            }
            println!("{}", pm.summary());
            println!(
                "result mean {:.4} (input {:.4})",
                result.mean(),
                x.mean()
            );
            Ok(())
        }
        Command::Serve {
            socket,
            workers,
            queue_depth,
            cache_capacity,
            halo_mode,
            halo_wait_secs,
            tile_rows,
            batch_window_ms,
            max_batch,
            executors,
            no_simd,
        } => {
            let mut exec = ExecOptions::native(workers);
            if let Some(mode) = halo_mode {
                exec.halo_mode = mode;
            }
            if let Some(secs) = halo_wait_secs {
                exec.halo_wait = std::time::Duration::from_secs(secs);
            }
            if let Some(tile) = tile_rows {
                exec.tile_rows = tile;
            }
            if no_simd {
                exec.simd = meltframe::simd::SimdMode::ForceScalar;
            }
            let mut opts = ServeOptions::new(socket, exec);
            opts.queue_depth = queue_depth;
            opts.cache_capacity = cache_capacity;
            opts.batch_window_ms = batch_window_ms;
            opts.max_batch = max_batch;
            opts.executors = executors;
            serve(opts)
        }
        Command::Submit {
            socket,
            json,
            request_file,
            oneshot,
            workers,
            shutdown,
        } => {
            let line = if shutdown {
                "{\"op\": \"shutdown\"}".to_string()
            } else if let Some(json) = json {
                json
            } else {
                // parse_args guarantees exactly one payload source
                let path = request_file.expect("submit payload");
                std::fs::read_to_string(path)?.trim().to_string()
            };
            if oneshot {
                // in-process reference path: same protocol, fresh executor
                let req = match parse_request(&line)? {
                    Request::Run(req) => req,
                    other => {
                        return Err(meltframe::error::Error::Config(format!(
                            "--oneshot only executes job requests, got {other:?}"
                        )))
                    }
                };
                let exec = Executor::one_shot(ExecOptions::native(workers));
                println!("{}", execute_request(&req, &exec));
                return Ok(());
            }
            use std::io::{BufRead, BufReader, Write};
            let socket = socket.expect("submit socket"); // parse_args guarantees
            let mut stream = std::os::unix::net::UnixStream::connect(&socket)?;
            writeln!(stream, "{line}")?;
            let mut response = String::new();
            BufReader::new(stream).read_line(&mut response)?;
            print!("{response}");
            Ok(())
        }
    }
}
