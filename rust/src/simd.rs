//! Lane-parallel (SIMD) micro-kernels with bit-for-bit scalar parity.
//!
//! The vectorization model is *across the output axis*: a group of `LANES`
//! output elements is computed per step, and each lane runs the **identical
//! scalar operation order** over its own window. Reductions are never
//! reassociated within a lane — lane `l`'s accumulator sees exactly the
//! additions, in exactly the order, that the scalar path would perform for
//! output element `l`. IEEE-754 arithmetic is deterministic per lane, so the
//! vector path is bit-for-bit equal to the scalar path for every input
//! (including NaN/±0 edge cases: min/max lanes call `f32::min`/`f32::max`,
//! not the subtly-different hardware min instructions, and no primitive uses
//! fused multiply-add, which rounds once where `a * b + c` rounds twice).
//!
//! Three pieces live here:
//!
//! 1. **Fixed-width `[f32; LANES]` primitives** (`mul_add_lanes`,
//!    `min_lanes`, `max_lanes`, `select_lanes`, `gather_lanes`, `splat`)
//!    written as straight-line per-lane loops so stable rustc autovectorizes
//!    them — no nightly features, no dependencies.
//! 2. **A runtime-dispatched AVX2 specialization** of the hottest primitive
//!    (the strip-accumulated row dot that backs the gaussian/convolve
//!    kernels) behind `is_x86_feature_detected!`. The portable body is
//!    always compiled and is the only path on non-x86 targets (aarch64
//!    autovectorizes it to NEON). Dispatch is resolved once and cached.
//! 3. **Per-thread mode + counters**: executors set a [`SimdMode`] for the
//!    worker thread at job entry (pool threads are reused across jobs), and
//!    kernels report how many output rows took the lane path vs the scalar
//!    path. The tile executor drains the counters into `RunMetrics` after
//!    every kernel call, so `simd_rows` / `scalar_rows` / `simd_lanes`
//!    surface per run without any global atomics that would interleave
//!    across concurrent executors.

use std::cell::Cell;
use std::fmt;

use crate::error::{Error, Result};

/// Lane width of the portable primitives: 8 × f32 fills one AVX2/NEON-pair
/// register and is the group size kernels walk output rows in.
pub const LANES: usize = 8;

/// Per-run vectorization policy. `Auto` uses the lane path wherever a
/// kernel has one; `ForceScalar` (the `--no-simd` escape hatch) pins every
/// kernel to the scalar path; `ForceSimd` pins the lane path even for
/// shapes where the heuristics would not bother (tests use it to prove
/// bit-for-bit parity). Results are identical in all three modes — the
/// mode only chooses which instruction sequence computes them.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum SimdMode {
    #[default]
    Auto,
    ForceScalar,
    ForceSimd,
}

impl SimdMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(SimdMode::Auto),
            "scalar" | "off" => Ok(SimdMode::ForceScalar),
            "simd" | "force" | "on" => Ok(SimdMode::ForceSimd),
            other => Err(Error::Config(format!(
                "unknown simd mode '{other}' (auto|scalar|simd)"
            ))),
        }
    }

    /// Process-wide default: `MELTFRAME_SIMD=auto|scalar|simd` when set
    /// (the CI matrix forces both extremes through the full suite),
    /// otherwise `Auto`. An unparsable value falls back to `Auto` rather
    /// than failing late inside a worker thread.
    pub fn env_default() -> Self {
        match std::env::var("MELTFRAME_SIMD") {
            Ok(v) => SimdMode::parse(&v).unwrap_or(SimdMode::Auto),
            Err(_) => SimdMode::Auto,
        }
    }
}

impl fmt::Display for SimdMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            SimdMode::Auto => "auto",
            SimdMode::ForceScalar => "scalar",
            SimdMode::ForceSimd => "simd",
        })
    }
}

thread_local! {
    static MODE: Cell<SimdMode> = const { Cell::new(SimdMode::Auto) };
    static LANE_ROWS: Cell<usize> = const { Cell::new(0) };
    static SCALAR_ROWS: Cell<usize> = const { Cell::new(0) };
}

/// Install `mode` for the current thread and clear any counter residue a
/// previous job (or a direct kernel call outside an executor) left behind.
/// Executors call this at job entry on every worker thread — pool threads
/// outlive jobs, so the mode must be re-asserted per job, not per thread.
pub fn enter_job(mode: SimdMode) {
    MODE.with(|m| m.set(mode));
    LANE_ROWS.with(|c| c.set(0));
    SCALAR_ROWS.with(|c| c.set(0));
}

/// The current thread's vectorization mode.
pub fn thread_mode() -> SimdMode {
    MODE.with(|m| m.get())
}

/// Should kernels take the lane path on this thread?
pub fn lanes_enabled() -> bool {
    thread_mode() != SimdMode::ForceScalar
}

/// Record `n` output rows computed by a lane-parallel path.
pub fn note_lane_rows(n: usize) {
    LANE_ROWS.with(|c| c.set(c.get() + n));
}

/// Record `n` output rows computed by a scalar path.
pub fn note_scalar_rows(n: usize) {
    SCALAR_ROWS.with(|c| c.set(c.get() + n));
}

/// Drain the current thread's `(lane_rows, scalar_rows)` counters. The
/// tile executor calls this after each kernel invocation and folds the
/// deltas into its per-worker stats.
pub fn take_counters() -> (usize, usize) {
    let lanes = LANE_ROWS.with(|c| c.replace(0));
    let scalar = SCALAR_ROWS.with(|c| c.replace(0));
    (lanes, scalar)
}

// ---------------------------------------------------------------------------
// Portable fixed-width primitives
// ---------------------------------------------------------------------------

/// Broadcast one value to every lane.
#[inline(always)]
pub fn splat(x: f32) -> [f32; LANES] {
    [x; LANES]
}

/// Per-lane `acc[l] = acc[l] + a[l] * b[l]`, written as a separate multiply
/// and add (never `f32::mul_add`): the scalar kernels round the product
/// before accumulating, and the lane path must round identically.
#[inline(always)]
pub fn mul_add_lanes(acc: &mut [f32; LANES], a: &[f32; LANES], b: &[f32; LANES]) {
    for l in 0..LANES {
        acc[l] += a[l] * b[l];
    }
}

/// Per-lane `f32::min` — deliberately NOT a hardware min instruction:
/// `_mm256_min_ps` returns the second operand on NaN and distinguishes
/// ±0.0 differently from `f32::min`, which would break parity with the
/// scalar `fold(f32::INFINITY, f32::min)` reduction.
#[inline(always)]
pub fn min_lanes(acc: &mut [f32; LANES], v: &[f32; LANES]) {
    for l in 0..LANES {
        acc[l] = acc[l].min(v[l]);
    }
}

/// Per-lane `f32::max`; see [`min_lanes`] for why this is not an intrinsic.
#[inline(always)]
pub fn max_lanes(acc: &mut [f32; LANES], v: &[f32; LANES]) {
    for l in 0..LANES {
        acc[l] = acc[l].max(v[l]);
    }
}

/// Per-lane blend: `mask[l] ? t[l] : f[l]`.
#[inline(always)]
pub fn select_lanes(mask: &[bool; LANES], t: &[f32; LANES], f: &[f32; LANES]) -> [f32; LANES] {
    let mut out = [0.0f32; LANES];
    for l in 0..LANES {
        out[l] = if mask[l] { t[l] } else { f[l] };
    }
    out
}

/// Gather-by-index: `out[l] = src[idx[l]]`. Callers validate indices; the
/// slice index here keeps the bounds check (this is the boundary-segment
/// path, not the contiguous-run fast path).
#[inline(always)]
pub fn gather_lanes(src: &[f32], idx: &[usize; LANES]) -> [f32; LANES] {
    let mut out = [0.0f32; LANES];
    for l in 0..LANES {
        out[l] = src[idx[l]];
    }
    out
}

// ---------------------------------------------------------------------------
// Strip-accumulated row dot (the gaussian/convolve hot loop)
// ---------------------------------------------------------------------------

/// The scalar strip dot: four parallel accumulators over 4-element strips,
/// combined pairwise, then a scalar remainder. This is the exact operation
/// order of `kernels::paradigm::apply_kernel_broadcast_into` — the lane
/// paths below replicate it per row and must never diverge from it.
#[inline(always)]
fn dot_strips_scalar(row: &[f32], kernel: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let rc = row.chunks_exact(4);
    let kc = kernel.chunks_exact(4);
    let (rrem, krem) = (rc.remainder(), kc.remainder());
    for (rv, kv) in rc.zip(kc) {
        acc[0] += rv[0] * kv[0];
        acc[1] += rv[1] * kv[1];
        acc[2] += rv[2] * kv[2];
        acc[3] += rv[3] * kv[3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (v, k) in rrem.iter().zip(krem.iter()) {
        s += v * k;
    }
    s
}

/// Portable two-row strip dot: both rows keep their own `acc[4]` strip
/// accumulators, advanced in lockstep so the compiler can fuse the pair
/// into wider vector ops; per row the order is exactly
/// [`dot_strips_scalar`]'s.
#[inline(always)]
fn dot2_portable(a: &[f32], b: &[f32], kernel: &[f32]) -> (f32, f32) {
    let strips = kernel.len().min(a.len()).min(b.len()) / 4;
    let mut aa = [0.0f32; 4];
    let mut ab = [0.0f32; 4];
    for t in 0..strips {
        let ra = &a[4 * t..4 * t + 4];
        let rb = &b[4 * t..4 * t + 4];
        let kv = &kernel[4 * t..4 * t + 4];
        for i in 0..4 {
            aa[i] += ra[i] * kv[i];
            ab[i] += rb[i] * kv[i];
        }
    }
    let mut sa = (aa[0] + aa[1]) + (aa[2] + aa[3]);
    let mut sb = (ab[0] + ab[1]) + (ab[2] + ab[3]);
    let n = kernel.len().min(a.len()).min(b.len());
    for j in 4 * strips..n {
        sa += a[j] * kernel[j];
        sb += b[j] * kernel[j];
    }
    (sa, sb)
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 body of the two-row strip dot. One `__m256` carries both rows'
    //! four strip accumulators as `[a0 a1 a2 a3 | b0 b1 b2 b3]`; each strip
    //! issues two 128-bit loads (one per row) combined into one register,
    //! one 128-bit kernel load broadcast to both halves, and a separate
    //! multiply and add — the same round-twice sequence as the scalar
    //! strip loop. The horizontal finish `(acc0+acc1)+(acc2+acc3)` and the
    //! remainder tail run in scalar f32, so every intermediate rounds
    //! exactly like `dot_strips_scalar`.

    use std::arch::x86_64::{
        __m256, _mm256_add_ps, _mm256_castps128_ps256, _mm256_insertf128_ps, _mm256_mul_ps,
        _mm256_setzero_ps, _mm256_storeu_ps, _mm_loadu_ps,
    };

    /// Two-row strip dot on AVX2.
    ///
    /// # Safety
    /// Caller must ensure the CPU supports AVX2 (checked via
    /// `is_x86_feature_detected!("avx2")` by the dispatcher).
    #[target_feature(enable = "avx2")]
    // SAFETY: (caller contract) this fn is only reachable through
    // `simd::dot2`, which calls it after `dispatch()` has observed
    // is_x86_feature_detected!("avx2") succeed on this machine.
    pub unsafe fn dot2(a: &[f32], b: &[f32], kernel: &[f32]) -> (f32, f32) {
        let n = kernel.len().min(a.len()).min(b.len());
        let strips = n / 4;
        // SAFETY: register-only zeroing; AVX2 is guaranteed by this
        // function's target_feature contract.
        let mut acc: __m256 = unsafe { _mm256_setzero_ps() };
        for t in 0..strips {
            let off = 4 * t;
            // SAFETY: off + 4 <= 4*strips <= n <= len of a, b and kernel
            // (clamped by the min() above), so every unaligned 128-bit
            // load reads in-bounds; loadu has no alignment requirement.
            // The cast/insert pair only moves register lanes.
            unsafe {
                let ra = _mm_loadu_ps(a.as_ptr().add(off));
                let rb = _mm_loadu_ps(b.as_ptr().add(off));
                let kv = _mm_loadu_ps(kernel.as_ptr().add(off));
                let rows = _mm256_insertf128_ps(_mm256_castps128_ps256(ra), rb, 1);
                let kk = _mm256_insertf128_ps(_mm256_castps128_ps256(kv), kv, 1);
                // separate mul + add (NOT fmadd): the scalar path rounds
                // the product before accumulating
                acc = _mm256_add_ps(acc, _mm256_mul_ps(rows, kk));
            }
        }
        let mut accs = [0.0f32; 8];
        // SAFETY: `accs` is 8 contiguous f32s, exactly the 32 bytes an
        // unaligned 256-bit store writes.
        unsafe { _mm256_storeu_ps(accs.as_mut_ptr(), acc) };
        // horizontal finish + remainder in scalar f32, in the exact
        // scalar-path order
        let mut sa = (accs[0] + accs[1]) + (accs[2] + accs[3]);
        let mut sb = (accs[4] + accs[5]) + (accs[6] + accs[7]);
        for j in 4 * strips..n {
            sa += a[j] * kernel[j];
            sb += b[j] * kernel[j];
        }
        (sa, sb)
    }
}

/// Which instruction set backs the lane paths on this machine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// Autovectorized portable Rust (the only path off x86_64; NEON via
    /// the compiler on aarch64).
    Portable,
    /// Hand-scheduled AVX2 for the strip dot.
    Avx2,
}

/// Resolve (once) and return the instruction-set dispatch. Runtime
/// detection, not compile-time: the same binary runs the AVX2 body on
/// machines that have it and the portable body everywhere else.
pub fn dispatch() -> Dispatch {
    #[cfg(target_arch = "x86_64")]
    {
        use std::sync::atomic::{AtomicU8, Ordering};
        static CACHED: AtomicU8 = AtomicU8::new(0); // 0 unresolved, 1 portable, 2 avx2
        match CACHED.load(Ordering::Relaxed) {
            1 => Dispatch::Portable,
            2 => Dispatch::Avx2,
            _ => {
                let d = if std::arch::is_x86_feature_detected!("avx2") {
                    Dispatch::Avx2
                } else {
                    Dispatch::Portable
                };
                CACHED.store(if d == Dispatch::Avx2 { 2 } else { 1 }, Ordering::Relaxed);
                d
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        Dispatch::Portable
    }
}

/// Strip dot of `kernel` against two rows at once, dispatching to the AVX2
/// body when the CPU has it. Bit-for-bit equal to running
/// [`dot_strips_scalar`] on each row.
#[inline]
pub fn dot2(a: &[f32], b: &[f32], kernel: &[f32]) -> (f32, f32) {
    #[cfg(target_arch = "x86_64")]
    {
        if dispatch() == Dispatch::Avx2 {
            // SAFETY: dispatch() returned Avx2, which means
            // is_x86_feature_detected!("avx2") succeeded on this machine,
            // satisfying avx2::dot2's only safety requirement.
            return unsafe { avx2::dot2(a, b, kernel) };
        }
    }
    dot2_portable(a, b, kernel)
}

/// Lane-parallel strip dot over all of a block's rows: rows are processed
/// in pairs through [`dot2`], with an odd trailing row finished by the
/// scalar strip order (which is the same order every lane uses, so the
/// whole output is bit-for-bit equal to the scalar row loop). `block` is
/// `out.len()` rows of `cols` contiguous values.
pub fn dot_rows_into(block: &[f32], cols: usize, kernel: &[f32], out: &mut [f32]) {
    let rows = out.len();
    let pairs = rows / 2;
    for p in 0..pairs {
        let (i, j) = (2 * p, 2 * p + 1);
        let row_a = &block[i * cols..(i + 1) * cols];
        let row_b = &block[j * cols..(j + 1) * cols];
        let (sa, sb) = dot2(row_a, row_b, kernel);
        out[i] = sa;
        out[j] = sb;
    }
    if rows % 2 == 1 {
        let i = rows - 1;
        out[i] = dot_strips_scalar(&block[i * cols..(i + 1) * cols], kernel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    fn lanes_from(rng: &mut SplitMix64) -> [f32; LANES] {
        let mut v = [0.0f32; LANES];
        for x in v.iter_mut() {
            *x = rng.normal() * 10.0;
        }
        v
    }

    #[test]
    fn parse_and_display_round_trip() {
        for (s, m) in [
            ("auto", SimdMode::Auto),
            ("scalar", SimdMode::ForceScalar),
            ("off", SimdMode::ForceScalar),
            ("simd", SimdMode::ForceSimd),
            ("force", SimdMode::ForceSimd),
            ("on", SimdMode::ForceSimd),
            (" SIMD ", SimdMode::ForceSimd),
        ] {
            assert_eq!(SimdMode::parse(s).unwrap(), m, "{s}");
        }
        assert!(SimdMode::parse("fast").is_err());
        assert_eq!(SimdMode::Auto.to_string(), "auto");
        assert_eq!(SimdMode::ForceScalar.to_string(), "scalar");
        assert_eq!(SimdMode::ForceSimd.to_string(), "simd");
    }

    #[test]
    fn thread_mode_and_counters() {
        enter_job(SimdMode::ForceScalar);
        assert!(!lanes_enabled());
        note_scalar_rows(3);
        enter_job(SimdMode::ForceSimd); // entry clears residue
        assert!(lanes_enabled());
        note_lane_rows(5);
        note_lane_rows(2);
        note_scalar_rows(1);
        assert_eq!(take_counters(), (7, 1));
        assert_eq!(take_counters(), (0, 0), "take drains");
        enter_job(SimdMode::Auto);
    }

    #[test]
    fn mul_add_matches_scalar_definition() {
        check_property("mul_add_lanes per-lane", 50, |rng: &mut SplitMix64| {
            let (a, b) = (lanes_from(rng), lanes_from(rng));
            let mut acc = lanes_from(rng);
            let want: Vec<f32> = (0..LANES).map(|l| acc[l] + a[l] * b[l]).collect();
            mul_add_lanes(&mut acc, &a, &b);
            for l in 0..LANES {
                assert_eq!(acc[l].to_bits(), want[l].to_bits(), "lane {l}");
            }
        });
    }

    #[test]
    fn min_max_match_f32_semantics() {
        let mut acc = splat(f32::INFINITY);
        let v = [1.0, -2.0, f32::NAN, 0.0, -0.0, 3.5, f32::INFINITY, -1e30];
        min_lanes(&mut acc, &v);
        for l in 0..LANES {
            assert_eq!(
                acc[l].to_bits(),
                f32::INFINITY.min(v[l]).to_bits(),
                "min lane {l}"
            );
        }
        let mut acc = splat(f32::NEG_INFINITY);
        max_lanes(&mut acc, &v);
        for l in 0..LANES {
            assert_eq!(
                acc[l].to_bits(),
                f32::NEG_INFINITY.max(v[l]).to_bits(),
                "max lane {l}"
            );
        }
    }

    #[test]
    fn select_and_gather_primitives() {
        let t = [1.0f32; LANES];
        let f = [2.0f32; LANES];
        let mask = [true, false, true, false, true, false, true, false];
        let s = select_lanes(&mask, &t, &f);
        for l in 0..LANES {
            assert_eq!(s[l], if mask[l] { 1.0 } else { 2.0 });
        }
        let src: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let idx = [0usize, 31, 7, 16, 2, 2, 9, 30];
        let g = gather_lanes(&src, &idx);
        for l in 0..LANES {
            assert_eq!(g[l], src[idx[l]]);
        }
    }

    #[test]
    fn dot2_matches_scalar_strip_order_bitwise() {
        check_property("dot2 vs scalar strips", 100, |rng: &mut SplitMix64| {
            // cols sweeps through every remainder class of the 4-strip
            let cols = 1 + rng.below(40);
            let a: Vec<f32> = (0..cols).map(|_| rng.normal() * 5.0).collect();
            let b: Vec<f32> = (0..cols).map(|_| rng.normal() * 5.0).collect();
            let k: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let (sa, sb) = dot2(&a, &b, &k);
            assert_eq!(sa.to_bits(), dot_strips_scalar(&a, &k).to_bits(), "cols={cols}");
            assert_eq!(sb.to_bits(), dot_strips_scalar(&b, &k).to_bits(), "cols={cols}");
            let (pa, pb) = dot2_portable(&a, &b, &k);
            assert_eq!(pa.to_bits(), sa.to_bits(), "portable row a, cols={cols}");
            assert_eq!(pb.to_bits(), sb.to_bits(), "portable row b, cols={cols}");
        });
    }

    #[test]
    fn dot_rows_handles_odd_row_counts() {
        check_property("dot_rows_into parity", 40, |rng: &mut SplitMix64| {
            let rows = 1 + rng.below(9); // exercises 1 (pure scalar tail) .. 9
            let cols = 1 + rng.below(30);
            let block: Vec<f32> = (0..rows * cols).map(|_| rng.normal()).collect();
            let k: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let mut got = vec![0.0f32; rows];
            dot_rows_into(&block, cols, &k, &mut got);
            for r in 0..rows {
                let want = dot_strips_scalar(&block[r * cols..(r + 1) * cols], &k);
                assert_eq!(got[r].to_bits(), want.to_bits(), "row {r}/{rows} cols {cols}");
            }
        });
    }

    #[test]
    fn dispatch_is_stable() {
        assert_eq!(dispatch(), dispatch());
    }
}
