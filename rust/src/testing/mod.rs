//! Deterministic randomness + property-test helpers.
//!
//! The vendored crate set has no `proptest`/`rand`, so this module provides
//! the minimal substitute the test suite needs: a SplitMix64 PRNG (stable
//! across platforms) and a tiny randomized-property driver that reports the
//! failing seed for reproduction (see DESIGN.md §Substitutions).

/// SplitMix64 — tiny, high-quality 64-bit PRNG (Steele et al., 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f64() as f32
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Vector of uniform f32 values in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.uniform(lo, hi)).collect()
    }
}

/// Run `prop` over `cases` random seeds; panic with the offending seed on the
/// first failure so the case can be replayed deterministically.
pub fn check_property(name: &str, cases: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = SplitMix64::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

/// The SplitMix64 output-mixing function as a standalone hash — a cheap,
/// well-distributed 64-bit finalizer (Steele et al., 2014).
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Position-sensitive, accumulation-order-independent 64-bit digest of an
/// f32 slice — the golden-value fingerprint used by
/// `tests/golden_values.rs`.
///
/// Each element is hashed together with its index and the per-element
/// hashes are combined by **wrapping addition**, so the digest can be
/// accumulated over arbitrary disjoint chunks in any order (parallel
/// workers, out-of-order folds) and still equal the serial digest — while
/// remaining sensitive to both the values and their positions (swapping
/// two unequal elements changes it). `-0.0` is canonicalized to `0.0` and
/// every NaN to the one quiet-NaN pattern, so semantically equal outputs
/// digest equally.
pub fn value_digest(values: &[f32]) -> u64 {
    values
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            let bits = if v == 0.0 {
                0u32 // canonicalize -0.0
            } else if v.is_nan() {
                0x7FC0_0000u32
            } else {
                v.to_bits()
            };
            mix64(u64::from(bits) ^ mix64(i as u64 + 1))
        })
        .fold(0u64, u64::wrapping_add)
}

/// Assert two f32 slices are elementwise close (|a-b| <= atol + rtol*|b|).
#[track_caller]
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_uniform_in_range() {
        let mut rng = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = rng.uniform(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
        }
    }

    #[test]
    fn splitmix_normal_moments() {
        let mut rng = SplitMix64::new(11);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn value_digest_is_chunk_accumulable_property() {
        // the digest of the whole slice equals the wrapping sum of digests
        // computed per chunk with the right index offsets — the property
        // that lets parallel folds fingerprint without ordering
        check_property("digest accumulates over chunks", 20, |rng: &mut SplitMix64| {
            let n = 1 + rng.below(200);
            let xs = rng.uniform_vec(n, -50.0, 50.0);
            let whole = value_digest(&xs);
            // recompute as shifted partial digests
            let cut = rng.below(n);
            let head = value_digest(&xs[..cut]);
            let tail: u64 = xs[cut..]
                .iter()
                .enumerate()
                .map(|(i, &v)| {
                    let bits = if v == 0.0 { 0 } else { v.to_bits() };
                    mix64(u64::from(bits) ^ mix64((cut + i) as u64 + 1))
                })
                .fold(0u64, u64::wrapping_add);
            assert_eq!(whole, head.wrapping_add(tail));
        });
    }

    #[test]
    fn value_digest_detects_value_and_position_drift() {
        let base = vec![1.0f32, 2.0, 3.0, 4.0];
        let copy = base.clone();
        let d = value_digest(&base);
        assert_eq!(d, value_digest(&copy), "deterministic");
        // a changed value changes the digest
        assert_ne!(d, value_digest(&[1.0, 2.0, 3.0, 4.000001]));
        // swapping two positions changes it (position sensitivity)
        assert_ne!(d, value_digest(&[2.0, 1.0, 3.0, 4.0]));
        // a dropped tail changes it
        assert_ne!(d, value_digest(&base[..3]));
        // canonicalization: -0.0 == 0.0, NaN payloads collapse
        assert_eq!(value_digest(&[0.0, 1.0]), value_digest(&[-0.0, 1.0]));
        assert_eq!(
            value_digest(&[f32::NAN]),
            value_digest(&[f32::from_bits(0x7FC0_0001)])
        );
        assert_eq!(value_digest(&[]), 0);
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 0.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn allclose_rejects_distant() {
        assert_allclose(&[1.0], &[2.0], 1e-6, 1e-6);
    }

    #[test]
    fn property_driver_runs_all_cases() {
        let mut count = 0;
        check_property("counts", 17, |_| {
            count += 1;
        });
        assert_eq!(count, 17);
    }
}
