//! Unified error type for the whole framework.

use thiserror::Error;

/// Framework-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the meltframe library.
#[derive(Error, Debug)]
pub enum Error {
    /// Tensor shape/stride violations (rank mismatch, zero extent, ...).
    #[error("shape error: {0}")]
    Shape(String),

    /// Invalid neighbourhood operator (even extent, rank mismatch, ...).
    #[error("operator error: {0}")]
    Operator(String),

    /// Invalid melt-matrix partition (violates the §2.4 conditions).
    #[error("partition error: {0}")]
    Partition(String),

    /// Linear-algebra failures (singular matrix, non-SPD cholesky, ...).
    #[error("linear algebra error: {0}")]
    Linalg(String),

    /// AOT artifact registry problems (missing manifest, bad entry, ...).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT runtime failures, wrapping the `xla` crate's error.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator scheduling/aggregation failures.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// Config / CLI parse failures.
    #[error("config error: {0}")]
    Config(String),

    /// File format failures (.npy, PGM/PPM, manifest JSON).
    #[error("format error: {0}")]
    Format(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

impl Error {
    /// Shorthand constructor used across modules.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Shape("rank 3 vs 2".into());
        assert!(e.to_string().contains("rank 3 vs 2"));
        assert!(e.to_string().contains("shape error"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
