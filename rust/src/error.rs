//! Unified error type for the whole framework.
//!
//! Hand-rolled `Display`/`Error` impls: the build image vendors no registry
//! crates, so `thiserror` is not available (DESIGN.md §Substitutions).

use std::fmt;

/// Framework-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All failure modes of the meltframe library.
#[derive(Debug)]
pub enum Error {
    /// Tensor shape/stride violations (rank mismatch, zero extent, ...).
    Shape(String),

    /// Invalid neighbourhood operator (even extent, rank mismatch, ...).
    Operator(String),

    /// Invalid melt-matrix partition (violates the §2.4 conditions).
    Partition(String),

    /// Linear-algebra failures (singular matrix, non-SPD cholesky, ...).
    Linalg(String),

    /// AOT artifact registry problems (missing manifest, bad entry, ...).
    Artifact(String),

    /// PJRT runtime failures (or the runtime being unavailable entirely).
    Runtime(String),

    /// Coordinator scheduling/aggregation failures.
    Coordinator(String),

    /// Config / CLI parse failures.
    Config(String),

    /// File format failures (.npy, PGM/PPM, manifest JSON).
    Format(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Operator(m) => write!(f, "operator error: {m}"),
            Error::Partition(m) => write!(f, "partition error: {m}"),
            Error::Linalg(m) => write!(f, "linear algebra error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Format(m) => write!(f, "format error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl Error {
    /// Shorthand constructor used across modules.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Shape("rank 3 vs 2".into());
        assert!(e.to_string().contains("rank 3 vs 2"));
        assert!(e.to_string().contains("shape error"));
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(std::error::Error::source(&e).is_some());
    }
}
