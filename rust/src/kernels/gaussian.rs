//! N-D gaussian kernel generation (the `gaussian_kernel` generator of paper
//! §3.2) and the melt-row application used by the global filter.

use crate::error::{Error, Result};
use crate::stats::linalg::Mat;

/// Unnormalized spatial gaussian component exp(-(x-s)ᵀ Σ_d⁻¹ (x-s)/2) over
/// the window ravel — eq. (3)'s first exponential item. `sigma_inv` is the
/// nd×nd inverse covariance (anisotropy support for voxel computation).
/// Column order matches `Operator::offsets` and the python `ref.py`.
pub fn spatial_gaussian(window: &[usize], sigma_inv: &Mat) -> Result<Vec<f32>> {
    let nd = window.len();
    if sigma_inv.rows() != nd || sigma_inv.cols() != nd {
        return Err(Error::shape(format!(
            "sigma_inv {}x{} vs window rank {nd}",
            sigma_inv.rows(),
            sigma_inv.cols()
        )));
    }
    if window.iter().any(|&w| w == 0 || w % 2 == 0) {
        return Err(Error::Operator(format!(
            "window extents must be odd, got {window:?}"
        )));
    }
    let ravel: usize = window.iter().product();
    let mut out = Vec::with_capacity(ravel);
    let mut idx = vec![0usize; nd];
    loop {
        let r: Vec<f64> = idx
            .iter()
            .zip(window)
            .map(|(&i, &w)| i as f64 - (w / 2) as f64)
            .collect();
        out.push((-0.5 * sigma_inv.quad_form(&r)?).exp() as f32);
        // odometer
        let mut a = nd;
        loop {
            if a == 0 {
                return Ok(out);
            }
            a -= 1;
            idx[a] += 1;
            if idx[a] < window[a] {
                break;
            }
            idx[a] = 0;
        }
    }
}

/// Normalized isotropic N-D gaussian kernel over the window ravel.
pub fn gaussian_kernel(window: &[usize], sigma: f32) -> Vec<f32> {
    let nd = window.len();
    let inv = Mat::diag(&vec![1.0 / (sigma as f64 * sigma as f64); nd]);
    let mut k = spatial_gaussian(window, &inv).expect("isotropic inverse is square by construction");
    let sum: f64 = k.iter().map(|&v| v as f64).sum();
    for v in &mut k {
        *v = (*v as f64 / sum) as f32;
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn kernel_normalized_and_positive() {
        for window in [vec![3, 3], vec![5, 5], vec![3, 3, 3], vec![5, 5, 5]] {
            let k = gaussian_kernel(&window, 1.3);
            let sum: f64 = k.iter().map(|&v| v as f64).sum();
            assert!((sum - 1.0).abs() < 1e-6, "{window:?}: sum {sum}");
            assert!(k.iter().all(|&v| v > 0.0));
        }
    }

    #[test]
    fn kernel_peak_at_center() {
        let k = gaussian_kernel(&[5, 5], 1.0);
        let center = k.len() / 2;
        for (i, &v) in k.iter().enumerate() {
            if i != center {
                assert!(v < k[center]);
            }
        }
    }

    #[test]
    fn spatial_symmetry_isotropic() {
        let s = spatial_gaussian(&[5, 5], &Mat::eye(2)).unwrap();
        // transpose symmetry of the 5x5 grid
        for r in 0..5 {
            for c in 0..5 {
                assert!((s[r * 5 + c] - s[c * 5 + r]).abs() < 1e-6);
            }
        }
        assert!((s[12] - 1.0).abs() < 1e-6); // centre value
    }

    #[test]
    fn spatial_anisotropy() {
        // heavier inverse weight on axis 0 -> faster decay off-centre axis 0
        let inv = Mat::diag(&[4.0, 0.25]);
        let s = spatial_gaussian(&[5, 5], &inv).unwrap();
        assert!(s[2] < s[10]); // (0,2) off on axis0 vs (2,0) off on axis1
    }

    #[test]
    fn spatial_rejects_bad_inputs() {
        assert!(spatial_gaussian(&[4, 4], &Mat::eye(2)).is_err()); // even window
        assert!(spatial_gaussian(&[3, 3], &Mat::eye(3)).is_err()); // rank mismatch
    }

    #[test]
    fn sigma_limits_property() {
        // very large sigma -> nearly uniform kernel; very small -> delta
        check_property("gaussian kernel sigma limits", 10, |rng: &mut SplitMix64| {
            let window = [3usize, 3];
            let _ = rng.next_u64();
            let flat = gaussian_kernel(&window, 1e4);
            let spread = flat.iter().cloned().fold(f32::MIN, f32::max)
                - flat.iter().cloned().fold(f32::MAX, f32::min);
            assert!(spread < 1e-6, "flat kernel spread {spread}");
            let sharp = gaussian_kernel(&window, 1e-2);
            assert!(sharp[4] > 0.999, "delta kernel centre {}", sharp[4]);
        });
    }

    #[test]
    fn matches_python_ref_values() {
        // golden values from python ref.gaussian_kernel((3,3), 1.0):
        // corner = exp(-1), edge = exp(-0.5), relative to centre 1.0
        let k = gaussian_kernel(&[3, 3], 1.0);
        let c = k[4];
        assert!((k[0] / c - (-1.0f32).exp()).abs() < 1e-5);
        assert!((k[1] / c - (-0.5f32).exp()).abs() < 1e-5);
    }
}
