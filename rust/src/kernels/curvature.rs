//! N-D Gaussian curvature on melt matrices — paper eq. (4)–(7).
//!
//! K = det(H(I)) / (1 + Σ_a I_a²)², with gradients and Hessian obtained by
//! one stencil contraction per melt row (see [`crate::kernels::stencil`]).
//! Closed-form determinants for nd ≤ 3 (the hot path), general LU beyond —
//! the paper's §3.2 point that the melt matrix caps the working rank at 2
//! regardless of the data's dimension.

use crate::error::{Error, Result};
use crate::kernels::stencil::ncols;
use crate::melt::matrix::MeltMatrix;
use crate::simd::LANES;
use crate::stats::linalg::Mat;

/// Gaussian curvature per melt row for an operator of extents `window`.
pub fn gaussian_curvature(m: &MeltMatrix, window: &[usize]) -> Result<Vec<f32>> {
    let w: usize = window.iter().product();
    if w != m.cols() {
        return Err(Error::shape(format!(
            "window {window:?} ravel {w} vs melt cols {}",
            m.cols()
        )));
    }
    let mut out = vec![0.0f32; m.rows()];
    curvature_into(m.data(), m.rows(), m.cols(), window, &mut out)?;
    Ok(out)
}

/// Allocation-free core over a raw row-major block (coordinator hot path).
pub fn curvature_into(
    data: &[f32],
    rows: usize,
    cols: usize,
    window: &[usize],
    out: &mut [f32],
) -> Result<()> {
    let nd = window.len();
    let dc = ncols(nd);
    // sparse contraction: central-difference stencils are ~90% zeros, so
    // iterating (flat, col, weight) triples beats the dense W x dc loop
    let triples = crate::kernels::stencil::stencil_sparse(window)?;
    if data.len() != rows * cols || out.len() != rows {
        return Err(Error::shape(format!(
            "curvature_into: data {} rows {rows} cols {cols} out {}",
            data.len(),
            out.len()
        )));
    }
    let mut d = vec![0.0f32; dc];
    // lane path: LANES rows share one pass over the sparse triples, each
    // lane accumulating its own packed-differential column strip
    // (`dl[col * LANES + l]`) in the same triple order the scalar loop
    // uses; the per-lane finish (det, |∇|², denominator) then runs the
    // scalar epilogue verbatim, so both paths are bit-for-bit identical.
    let lane_rows = if crate::simd::lanes_enabled() {
        (rows / LANES) * LANES
    } else {
        0
    };
    let mut dl = vec![0.0f32; if lane_rows > 0 { dc * LANES } else { 0 }];
    for g in 0..lane_rows / LANES {
        let base = g * LANES;
        let block = &data[base * cols..(base + LANES) * cols];
        dl.iter_mut().for_each(|v| *v = 0.0);
        for &(flat, col, w) in &triples {
            let fo = flat as usize;
            let co = col as usize * LANES;
            for l in 0..LANES {
                dl[co + l] += block[l * cols + fo] * w;
            }
        }
        for l in 0..LANES {
            for (c, v) in d.iter_mut().enumerate() {
                *v = dl[c * LANES + l];
            }
            let det = hessian_det(&d[nd..], nd)?;
            let g2: f32 = d[..nd].iter().map(|v| v * v).sum();
            let denom = (1.0 + g2) * (1.0 + g2);
            out[base + l] = det / denom;
        }
    }
    for r in lane_rows..rows {
        let row = &data[r * cols..(r + 1) * cols];
        d.iter_mut().for_each(|v| *v = 0.0);
        for &(flat, col, w) in &triples {
            d[col as usize] += row[flat as usize] * w;
        }
        let det = hessian_det(&d[nd..], nd)?;
        let g2: f32 = d[..nd].iter().map(|v| v * v).sum();
        let denom = (1.0 + g2) * (1.0 + g2);
        out[r] = det / denom;
    }
    crate::simd::note_lane_rows(lane_rows);
    crate::simd::note_scalar_rows(rows - lane_rows);
    Ok(())
}

/// det(H) from the packed upper-triangular entries (closed form nd <= 3,
/// LU for higher ranks).
pub fn hessian_det(h: &[f32], nd: usize) -> Result<f32> {
    debug_assert_eq!(h.len(), nd * (nd + 1) / 2);
    match nd {
        1 => Ok(h[0]),
        2 => Ok(h[0] * h[2] - h[1] * h[1]),
        3 => {
            let (hxx, hxy, hxz, hyy, hyz, hzz) = (h[0], h[1], h[2], h[3], h[4], h[5]);
            Ok(hxx * (hyy * hzz - hyz * hyz) - hxy * (hxy * hzz - hyz * hxz)
                + hxz * (hxy * hyz - hyy * hxz))
        }
        _ => {
            let mut full = Mat::zeros(nd, nd);
            let mut k = 0;
            for a in 0..nd {
                for b in a..nd {
                    full.set(a, b, h[k] as f64);
                    full.set(b, a, h[k] as f64);
                    k += 1;
                }
            }
            Ok(full.det()? as f32)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::melt::grid::GridMode;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::melt::operator::Operator;
    use crate::tensor::dense::Tensor;
    use crate::testing::{check_property, SplitMix64};

    fn quadratic_row(window: &[usize], f: impl Fn(&[f64]) -> f64) -> Vec<f32> {
        // evaluate f over the window offsets in ravel order
        let strides = crate::tensor::shape::row_major_strides(window);
        let w: usize = window.iter().product();
        (0..w)
            .map(|flat| {
                let mut rem = flat;
                let off: Vec<f64> = strides
                    .iter()
                    .zip(window)
                    .map(|(&s, &we)| {
                        let i = rem / s;
                        rem %= s;
                        i as f64 - (we / 2) as f64
                    })
                    .collect();
                f(&off) as f32
            })
            .collect()
    }

    #[test]
    fn flat_and_ramp_fields_zero_k() {
        let w9 = quadratic_row(&[3, 3], |_| 5.0);
        assert!((hess_k(&w9, &[3, 3])).abs() < 1e-6);
        let ramp = quadratic_row(&[3, 3], |o| 2.0 * o[0] + 3.0 * o[1]);
        assert!((hess_k(&ramp, &[3, 3])).abs() < 1e-5);
    }

    fn hess_k(row: &[f32], window: &[usize]) -> f32 {
        let m = MeltMatrix::new(row.to_vec(), 1, row.len(), vec![1], window.to_vec()).unwrap();
        gaussian_curvature(&m, window).unwrap()[0]
    }

    #[test]
    fn bowl_and_saddle_analytic_2d() {
        let bowl = quadratic_row(&[3, 3], |o| 0.5 * (o[0] * o[0] + o[1] * o[1]));
        assert!((hess_k(&bowl, &[3, 3]) - 1.0).abs() < 1e-5);
        let saddle = quadratic_row(&[3, 3], |o| o[0] * o[1]);
        assert!((hess_k(&saddle, &[3, 3]) + 1.0).abs() < 1e-5);
    }

    #[test]
    fn bowl_analytic_3d() {
        let bowl = quadratic_row(&[3, 3, 3], |o| 0.5 * o.iter().map(|v| v * v).sum::<f64>());
        assert!((hess_k(&bowl, &[3, 3, 3]) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn gradient_denominator_suppresses() {
        // same Hessian but steep gradient -> smaller K
        let flat_bowl = quadratic_row(&[3, 3], |o| 0.5 * (o[0] * o[0] + o[1] * o[1]));
        let tilted = quadratic_row(&[3, 3], |o| {
            0.5 * (o[0] * o[0] + o[1] * o[1]) + 3.0 * o[0]
        });
        assert!(hess_k(&tilted, &[3, 3]) < hess_k(&flat_bowl, &[3, 3]));
    }

    #[test]
    fn hessian_det_matches_linalg_property() {
        check_property("packed det == full det", 30, |rng: &mut SplitMix64| {
            let nd = 1 + rng.below(4); // exercises nd=4 LU path too
            let packed: Vec<f32> = (0..nd * (nd + 1) / 2).map(|_| rng.normal()).collect();
            let got = hessian_det(&packed, nd).unwrap();
            let mut full = Mat::zeros(nd, nd);
            let mut k = 0;
            for a in 0..nd {
                for b in a..nd {
                    full.set(a, b, packed[k] as f64);
                    full.set(b, a, packed[k] as f64);
                    k += 1;
                }
            }
            let want = full.det().unwrap() as f32;
            assert!(
                (got - want).abs() < 1e-3 * (1.0 + want.abs()),
                "{got} vs {want}"
            );
        });
    }

    #[test]
    fn corners_respond_on_segmentation_mask() {
        // Fig 4: curvature magnitude peaks at mask corners, not on edges
        let mask = Tensor::segmentation_mask(&[32, 32]);
        let op = Operator::cubic(3, 2).unwrap();
        let m = melt(&mask, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        let k = gaussian_curvature(&m, &[3, 3]).unwrap();
        // a rectangle corner (h/5, w/6) = (6, 5) area must respond
        let corner_mag: f32 = (5..8)
            .flat_map(|y| (4..7).map(move |x| (y, x)))
            .map(|(y, x)| k[y * 32 + x].abs())
            .fold(0.0, f32::max);
        // a straight horizontal edge midpoint must respond weakly
        let edge_mag = k[6 * 32 + 12].abs();
        assert!(corner_mag > 5.0 * edge_mag.max(1e-6), "corner {corner_mag} vs edge {edge_mag}");
    }

    #[test]
    fn lane_curvature_matches_scalar_bitwise() {
        use crate::simd::{self, SimdMode};
        check_property("curvature lane vs scalar bits", 20, |rng: &mut SplitMix64| {
            let dims = [3 + rng.below(8), 3 + rng.below(8)];
            let x = Tensor::random(&dims, -20.0, 20.0, rng.next_u64()).unwrap();
            let op = Operator::cubic(3, 2).unwrap();
            let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
            let mut scalar = vec![0.0f32; m.rows()];
            simd::enter_job(SimdMode::ForceScalar);
            curvature_into(m.data(), m.rows(), m.cols(), &[3, 3], &mut scalar).unwrap();
            let mut lanes = vec![0.0f32; m.rows()];
            simd::enter_job(SimdMode::ForceSimd);
            curvature_into(m.data(), m.rows(), m.cols(), &[3, 3], &mut lanes).unwrap();
            simd::enter_job(SimdMode::Auto);
            for r in 0..m.rows() {
                assert_eq!(
                    lanes[r].to_bits(),
                    scalar[r].to_bits(),
                    "row {r} of {} rows",
                    m.rows()
                );
            }
        });
    }

    #[test]
    fn mismatched_window_rejected() {
        let m = MeltMatrix::new(vec![0.0; 27], 3, 9, vec![3], vec![3, 3]).unwrap();
        assert!(gaussian_curvature(&m, &[3, 3, 3]).is_err());
    }
}
