//! Rank-order filters on melt matrices: median / min / max / percentile.
//!
//! These are the *sample-determined* counterparts of the aggregation
//! filters (paper §2.4): each output value is an order statistic of its
//! melt row. They ride the same melt/partition machinery — row independence
//! still holds (each row's statistic depends only on that row), so the
//! §2.4 partitioning remains exact even though combining order statistics
//! *across* rows would not be (see `stats::rank` for that distinction).
//! Median filtering is also the classic salt-and-pepper denoiser the
//! bilateral is usually compared against.

use crate::error::{Error, Result};
use crate::melt::matrix::MeltMatrix;
use crate::simd::LANES;
use crate::stats::rank::{median_exact_with, quantile_with};

/// Which order statistic to extract per melt row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RankKind {
    Median,
    Min,
    Max,
    /// Linear-interpolated quantile, q in [0, 1].
    Quantile(f64),
}

/// Apply a rank filter to every melt row.
pub fn rank_filter(m: &MeltMatrix, kind: RankKind) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; m.rows()];
    rank_filter_into(m.data(), m.rows(), m.cols(), kind, &mut out)?;
    Ok(out)
}

/// Allocation-light core over a raw row-major block (coordinator-style
/// signature, usable from worker loops).
pub fn rank_filter_into(
    data: &[f32],
    rows: usize,
    cols: usize,
    kind: RankKind,
    out: &mut [f32],
) -> Result<()> {
    if data.len() != rows * cols || out.len() != rows {
        return Err(Error::shape(format!(
            "rank_filter_into: data {} rows {rows} cols {cols} out {}",
            data.len(),
            out.len()
        )));
    }
    if let RankKind::Quantile(q) = kind {
        if !(0.0..=1.0).contains(&q) {
            return Err(Error::Operator(format!("quantile {q} outside [0, 1]")));
        }
    }
    // min/max are pure folds, so they take the lane path: LANES rows at a
    // time, each lane folding its own row left-to-right through the exact
    // scalar reduction (`f32::min`/`f32::max` per lane — never a hardware
    // min/max instruction, whose NaN/±0 semantics differ). The lane win is
    // eight independent dependency chains instead of one serial fold.
    // median/quantile run quickselect, a data-dependent permutation with
    // no lane-parallel form — those rows stay (and are counted) scalar.
    match kind {
        RankKind::Min | RankKind::Max => {
            let lane_rows = if crate::simd::lanes_enabled() {
                (rows / LANES) * LANES
            } else {
                0
            };
            for g in 0..lane_rows / LANES {
                let base = g * LANES;
                minmax_rows_lane(
                    &data[base * cols..(base + LANES) * cols],
                    cols,
                    kind,
                    &mut out[base..base + LANES],
                );
            }
            for r in lane_rows..rows {
                let row = &data[r * cols..(r + 1) * cols];
                out[r] = match kind {
                    RankKind::Min => row.iter().copied().fold(f32::INFINITY, f32::min),
                    _ => row.iter().copied().fold(f32::NEG_INFINITY, f32::max),
                };
            }
            crate::simd::note_lane_rows(lane_rows);
            crate::simd::note_scalar_rows(rows - lane_rows);
        }
        RankKind::Median | RankKind::Quantile(_) => {
            // one scratch buffer per block: each row costs a single copy
            // into it and a single quickselect pass (select_adjacent_with
            // yields both order statistics a median/quantile straddles),
            // where the old per-pixel `select` calls copied and
            // partitioned the window twice
            let mut scratch: Vec<f32> = Vec::with_capacity(cols);
            for (row, o) in data.chunks_exact(cols).zip(out.iter_mut()) {
                *o = match kind {
                    RankKind::Median => median_exact_with(&mut scratch, row),
                    RankKind::Quantile(q) => quantile_with(&mut scratch, row, q),
                    _ => unreachable!("outer match covers min/max"),
                };
            }
            crate::simd::note_scalar_rows(rows);
        }
    }
    Ok(())
}

/// Min/max fold over exactly `LANES` rows: lane `l` folds row `l` with the
/// scalar identity and combiner, element order preserved.
#[inline(always)]
fn minmax_rows_lane(block: &[f32], cols: usize, kind: RankKind, out: &mut [f32]) {
    let init = if matches!(kind, RankKind::Min) {
        f32::INFINITY
    } else {
        f32::NEG_INFINITY
    };
    let mut acc = [init; LANES];
    if matches!(kind, RankKind::Min) {
        for j in 0..cols {
            for l in 0..LANES {
                acc[l] = acc[l].min(block[l * cols + j]);
            }
        }
    } else {
        for j in 0..cols {
            for l in 0..LANES {
                acc[l] = acc[l].max(block[l * cols + j]);
            }
        }
    }
    out[..LANES].copy_from_slice(&acc);
}

/// Morphological erosion (min filter) of a tensor via the melt pipeline.
pub fn erode(
    x: &crate::tensor::dense::Tensor<f32>,
    op: &crate::melt::operator::Operator,
) -> Result<crate::tensor::dense::Tensor<f32>> {
    let m = crate::melt::melt::melt(
        x,
        op,
        crate::melt::grid::GridMode::Same,
        crate::melt::melt::BoundaryMode::Nearest,
    )?;
    crate::melt::fold::fold(&rank_filter(&m, RankKind::Min)?, m.grid_shape())
}

/// Morphological dilation (max filter) of a tensor via the melt pipeline.
pub fn dilate(
    x: &crate::tensor::dense::Tensor<f32>,
    op: &crate::melt::operator::Operator,
) -> Result<crate::tensor::dense::Tensor<f32>> {
    let m = crate::melt::melt::melt(
        x,
        op,
        crate::melt::grid::GridMode::Same,
        crate::melt::melt::BoundaryMode::Nearest,
    )?;
    crate::melt::fold::fold(&rank_filter(&m, RankKind::Max)?, m.grid_shape())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::melt::grid::GridMode;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::melt::operator::Operator;
    use crate::tensor::dense::Tensor;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    fn sample(rng: &mut SplitMix64) -> MeltMatrix {
        let dims = [4 + rng.below(6), 4 + rng.below(6)];
        let x = Tensor::random(&dims, -50.0, 50.0, rng.next_u64()).unwrap();
        melt(&x, &Operator::cubic(3, 2).unwrap(), GridMode::Same, BoundaryMode::Reflect).unwrap()
    }

    #[test]
    fn median_matches_sort_property() {
        check_property("row median == sorted middle", 20, |rng: &mut SplitMix64| {
            let m = sample(rng);
            let got = rank_filter(&m, RankKind::Median).unwrap();
            for r in 0..m.rows() {
                let mut row = m.row(r).to_vec();
                row.sort_by(f32::total_cmp);
                assert_eq!(got[r], row[row.len() / 2]);
            }
        });
    }

    #[test]
    fn min_max_bound_the_row() {
        let mut rng = SplitMix64::new(3);
        let m = sample(&mut rng);
        let mins = rank_filter(&m, RankKind::Min).unwrap();
        let maxs = rank_filter(&m, RankKind::Max).unwrap();
        let meds = rank_filter(&m, RankKind::Median).unwrap();
        for r in 0..m.rows() {
            assert!(mins[r] <= meds[r] && meds[r] <= maxs[r]);
            assert_eq!(mins[r], m.row(r).iter().copied().fold(f32::INFINITY, f32::min));
        }
    }

    #[test]
    fn quantile_endpoints_equal_min_max() {
        let mut rng = SplitMix64::new(5);
        let m = sample(&mut rng);
        let q0 = rank_filter(&m, RankKind::Quantile(0.0)).unwrap();
        let q1 = rank_filter(&m, RankKind::Quantile(1.0)).unwrap();
        assert_allclose(&q0, &rank_filter(&m, RankKind::Min).unwrap(), 0.0, 0.0);
        assert_allclose(&q1, &rank_filter(&m, RankKind::Max).unwrap(), 0.0, 0.0);
        assert!(rank_filter(&m, RankKind::Quantile(1.5)).is_err());
    }

    #[test]
    fn median_removes_salt_and_pepper() {
        // classic: impulse noise vanishes under a 3x3 median
        let mut x = Tensor::full(&[12, 12], 100.0).unwrap();
        x.set(&[3, 4], 255.0).unwrap(); // salt
        x.set(&[8, 7], 0.0).unwrap(); // pepper
        let m = melt(&x, &Operator::cubic(3, 2).unwrap(), GridMode::Same, BoundaryMode::Reflect)
            .unwrap();
        let out = rank_filter(&m, RankKind::Median).unwrap();
        assert!(out.iter().all(|&v| v == 100.0));
    }

    #[test]
    fn lane_minmax_matches_scalar_bitwise_including_nan() {
        use crate::simd::{self, SimdMode};
        check_property("rank min/max lane vs scalar bits", 25, |rng: &mut SplitMix64| {
            let rows = 1 + rng.below(20);
            let cols = 1 + rng.below(12);
            let mut data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 30.0).collect();
            // sprinkle the exact edge cases hardware min/max gets wrong
            for _ in 0..3 {
                let i = rng.below(data.len());
                data[i] = [f32::NAN, 0.0, -0.0][rng.below(3)];
            }
            for kind in [RankKind::Min, RankKind::Max] {
                let mut scalar = vec![0.0f32; rows];
                simd::enter_job(SimdMode::ForceScalar);
                rank_filter_into(&data, rows, cols, kind, &mut scalar).unwrap();
                let mut lanes = vec![0.0f32; rows];
                simd::enter_job(SimdMode::ForceSimd);
                rank_filter_into(&data, rows, cols, kind, &mut lanes).unwrap();
                simd::enter_job(SimdMode::Auto);
                for r in 0..rows {
                    assert_eq!(
                        lanes[r].to_bits(),
                        scalar[r].to_bits(),
                        "row {r} of {rows}x{cols} under {kind:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn erosion_dilation_duality() {
        // dilate(x) == -erode(-x) (lattice duality)
        let x = Tensor::random(&[8, 9], -10.0, 10.0, 7).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let d = dilate(&x, &op).unwrap();
        let e = erode(&x.scale(-1.0), &op).unwrap().scale(-1.0);
        assert_allclose(d.data(), e.data(), 0.0, 0.0);
    }

    #[test]
    fn erosion_shrinks_dilation_grows() {
        let mask = Tensor::segmentation_mask(&[32, 32]);
        let op = Operator::cubic(3, 2).unwrap();
        let er = erode(&mask, &op).unwrap();
        let di = dilate(&mask, &op).unwrap();
        assert!(er.sum() < mask.sum());
        assert!(di.sum() > mask.sum());
        // idempotent bounds: erode <= x <= dilate pointwise
        for i in 0..mask.len() {
            assert!(er.data()[i] <= mask.data()[i] && mask.data()[i] <= di.data()[i]);
        }
    }
}
