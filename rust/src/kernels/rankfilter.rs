//! Rank-order filters on melt matrices: median / min / max / percentile.
//!
//! These are the *sample-determined* counterparts of the aggregation
//! filters (paper §2.4): each output value is an order statistic of its
//! melt row. They ride the same melt/partition machinery — row independence
//! still holds (each row's statistic depends only on that row), so the
//! §2.4 partitioning remains exact even though combining order statistics
//! *across* rows would not be (see `stats::rank` for that distinction).
//! Median filtering is also the classic salt-and-pepper denoiser the
//! bilateral is usually compared against.

use crate::error::{Error, Result};
use crate::melt::matrix::MeltMatrix;
use crate::stats::rank::{median_exact_with, quantile_with};

/// Which order statistic to extract per melt row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RankKind {
    Median,
    Min,
    Max,
    /// Linear-interpolated quantile, q in [0, 1].
    Quantile(f64),
}

/// Apply a rank filter to every melt row.
pub fn rank_filter(m: &MeltMatrix, kind: RankKind) -> Result<Vec<f32>> {
    let mut out = vec![0.0f32; m.rows()];
    rank_filter_into(m.data(), m.rows(), m.cols(), kind, &mut out)?;
    Ok(out)
}

/// Allocation-light core over a raw row-major block (coordinator-style
/// signature, usable from worker loops).
pub fn rank_filter_into(
    data: &[f32],
    rows: usize,
    cols: usize,
    kind: RankKind,
    out: &mut [f32],
) -> Result<()> {
    if data.len() != rows * cols || out.len() != rows {
        return Err(Error::shape(format!(
            "rank_filter_into: data {} rows {rows} cols {cols} out {}",
            data.len(),
            out.len()
        )));
    }
    if let RankKind::Quantile(q) = kind {
        if !(0.0..=1.0).contains(&q) {
            return Err(Error::Operator(format!("quantile {q} outside [0, 1]")));
        }
    }
    // one scratch buffer per block: each row costs a single copy into it
    // and a single quickselect pass (select_adjacent_with yields both
    // order statistics a median/quantile straddles), where the old
    // per-pixel `select` calls copied and partitioned the window twice
    let mut scratch: Vec<f32> = Vec::with_capacity(cols);
    for (row, o) in data.chunks_exact(cols).zip(out.iter_mut()) {
        *o = match kind {
            RankKind::Min => row.iter().copied().fold(f32::INFINITY, f32::min),
            RankKind::Max => row.iter().copied().fold(f32::NEG_INFINITY, f32::max),
            RankKind::Median => median_exact_with(&mut scratch, row),
            RankKind::Quantile(q) => quantile_with(&mut scratch, row, q),
        };
    }
    Ok(())
}

/// Morphological erosion (min filter) of a tensor via the melt pipeline.
pub fn erode(
    x: &crate::tensor::dense::Tensor<f32>,
    op: &crate::melt::operator::Operator,
) -> Result<crate::tensor::dense::Tensor<f32>> {
    let m = crate::melt::melt::melt(
        x,
        op,
        crate::melt::grid::GridMode::Same,
        crate::melt::melt::BoundaryMode::Nearest,
    )?;
    crate::melt::fold::fold(&rank_filter(&m, RankKind::Min)?, m.grid_shape())
}

/// Morphological dilation (max filter) of a tensor via the melt pipeline.
pub fn dilate(
    x: &crate::tensor::dense::Tensor<f32>,
    op: &crate::melt::operator::Operator,
) -> Result<crate::tensor::dense::Tensor<f32>> {
    let m = crate::melt::melt::melt(
        x,
        op,
        crate::melt::grid::GridMode::Same,
        crate::melt::melt::BoundaryMode::Nearest,
    )?;
    crate::melt::fold::fold(&rank_filter(&m, RankKind::Max)?, m.grid_shape())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::melt::grid::GridMode;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::melt::operator::Operator;
    use crate::tensor::dense::Tensor;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    fn sample(rng: &mut SplitMix64) -> MeltMatrix {
        let dims = [4 + rng.below(6), 4 + rng.below(6)];
        let x = Tensor::random(&dims, -50.0, 50.0, rng.next_u64()).unwrap();
        melt(&x, &Operator::cubic(3, 2).unwrap(), GridMode::Same, BoundaryMode::Reflect).unwrap()
    }

    #[test]
    fn median_matches_sort_property() {
        check_property("row median == sorted middle", 20, |rng: &mut SplitMix64| {
            let m = sample(rng);
            let got = rank_filter(&m, RankKind::Median).unwrap();
            for r in 0..m.rows() {
                let mut row = m.row(r).to_vec();
                row.sort_by(f32::total_cmp);
                assert_eq!(got[r], row[row.len() / 2]);
            }
        });
    }

    #[test]
    fn min_max_bound_the_row() {
        let mut rng = SplitMix64::new(3);
        let m = sample(&mut rng);
        let mins = rank_filter(&m, RankKind::Min).unwrap();
        let maxs = rank_filter(&m, RankKind::Max).unwrap();
        let meds = rank_filter(&m, RankKind::Median).unwrap();
        for r in 0..m.rows() {
            assert!(mins[r] <= meds[r] && meds[r] <= maxs[r]);
            assert_eq!(mins[r], m.row(r).iter().copied().fold(f32::INFINITY, f32::min));
        }
    }

    #[test]
    fn quantile_endpoints_equal_min_max() {
        let mut rng = SplitMix64::new(5);
        let m = sample(&mut rng);
        let q0 = rank_filter(&m, RankKind::Quantile(0.0)).unwrap();
        let q1 = rank_filter(&m, RankKind::Quantile(1.0)).unwrap();
        assert_allclose(&q0, &rank_filter(&m, RankKind::Min).unwrap(), 0.0, 0.0);
        assert_allclose(&q1, &rank_filter(&m, RankKind::Max).unwrap(), 0.0, 0.0);
        assert!(rank_filter(&m, RankKind::Quantile(1.5)).is_err());
    }

    #[test]
    fn median_removes_salt_and_pepper() {
        // classic: impulse noise vanishes under a 3x3 median
        let mut x = Tensor::full(&[12, 12], 100.0).unwrap();
        x.set(&[3, 4], 255.0).unwrap(); // salt
        x.set(&[8, 7], 0.0).unwrap(); // pepper
        let m = melt(&x, &Operator::cubic(3, 2).unwrap(), GridMode::Same, BoundaryMode::Reflect)
            .unwrap();
        let out = rank_filter(&m, RankKind::Median).unwrap();
        assert!(out.iter().all(|&v| v == 100.0));
    }

    #[test]
    fn erosion_dilation_duality() {
        // dilate(x) == -erode(-x) (lattice duality)
        let x = Tensor::random(&[8, 9], -10.0, 10.0, 7).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let d = dilate(&x, &op).unwrap();
        let e = erode(&x.scale(-1.0), &op).unwrap().scale(-1.0);
        assert_allclose(d.data(), e.data(), 0.0, 0.0);
    }

    #[test]
    fn erosion_shrinks_dilation_grows() {
        let mask = Tensor::segmentation_mask(&[32, 32]);
        let op = Operator::cubic(3, 2).unwrap();
        let er = erode(&mask, &op).unwrap();
        let di = dilate(&mask, &op).unwrap();
        assert!(er.sum() < mask.sum());
        assert!(di.sum() > mask.sum());
        // idempotent bounds: erode <= x <= dilate pointwise
        for i in 0..mask.len() {
            assert!(er.data()[i] <= mask.data()[i] && mask.data()[i] <= di.data()[i]);
        }
    }
}
