//! Generic N-D convolution/filtering via the melt pipeline — the one-call
//! composition (melt → broadcast → fold) of paper Fig 2 that examples and
//! the serial baselines use.

use crate::error::Result;
use crate::kernels::paradigm::{apply_kernel, Paradigm};
use crate::melt::fold::fold;
use crate::melt::grid::GridMode;
use crate::melt::melt::{melt, BoundaryMode};
use crate::melt::operator::Operator;
use crate::tensor::dense::Tensor;

/// Convolve `x` with a kernel given over the ravel of `op`'s window.
/// This is the whole Fig 2 pipeline on a single computing unit.
pub fn convolve(
    x: &Tensor<f32>,
    op: &Operator,
    kernel: &[f32],
    grid_mode: GridMode,
    boundary: BoundaryMode,
    paradigm: Paradigm,
) -> Result<Tensor<f32>> {
    let m = melt(x, op, grid_mode, boundary)?;
    let rows = apply_kernel(&m, kernel, paradigm);
    fold(&rows, m.grid_shape())
}

/// Gaussian filter convenience: isotropic kernel of `sigma` over `op`.
pub fn gaussian_filter(
    x: &Tensor<f32>,
    op: &Operator,
    sigma: f32,
    boundary: BoundaryMode,
) -> Result<Tensor<f32>> {
    let k = crate::kernels::gaussian::gaussian_kernel(op.window(), sigma);
    convolve(x, op, &k, GridMode::Same, boundary, Paradigm::MatBroadcast)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    #[test]
    fn identity_kernel_round_trips() {
        let x = Tensor::random(&[6, 7], -3.0, 3.0, 1).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let mut k = vec![0.0f32; 9];
        k[4] = 1.0;
        let y = convolve(&x, &op, &k, GridMode::Same, BoundaryMode::Reflect, Paradigm::MatBroadcast)
            .unwrap();
        assert_allclose(y.data(), x.data(), 0.0, 0.0);
    }

    #[test]
    fn box_kernel_averages() {
        let x = Tensor::full(&[5, 5], 10.0).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let k = vec![1.0f32 / 9.0; 9];
        let y = convolve(&x, &op, &k, GridMode::Same, BoundaryMode::Reflect, Paradigm::VectorWise)
            .unwrap();
        assert_allclose(y.data(), &vec![10.0; 25], 1e-5, 1e-5);
    }

    #[test]
    fn gaussian_filter_smooths_noise() {
        let x = Tensor::random(&[24, 24], 0.0, 255.0, 7).unwrap();
        let op = Operator::cubic(5, 2).unwrap();
        let y = gaussian_filter(&x, &op, 1.5, BoundaryMode::Reflect).unwrap();
        assert!(y.variance() < x.variance());
        // preserves the mean (normalized kernel, reflect boundary)
        assert!((y.mean() - x.mean()).abs() < 3.0);
    }

    #[test]
    fn valid_mode_shrinks_output() {
        let x = Tensor::random(&[8, 9], 0.0, 1.0, 2).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let k = vec![1.0f32 / 9.0; 9];
        let y = convolve(&x, &op, &k, GridMode::Valid, BoundaryMode::Reflect, Paradigm::MatBroadcast)
            .unwrap();
        assert_eq!(y.shape(), &[6, 7]);
    }

    #[test]
    fn paradigms_agree_end_to_end_property() {
        check_property("convolve invariant under paradigm", 15, |rng: &mut SplitMix64| {
            let x = Tensor::random(&[4 + rng.below(5), 4 + rng.below(5)], -5.0, 5.0, rng.next_u64())
                .unwrap();
            let op = Operator::cubic(3, 2).unwrap();
            let k = crate::kernels::gaussian::gaussian_kernel(&[3, 3], 1.0);
            let a = convolve(&x, &op, &k, GridMode::Same, BoundaryMode::Reflect, Paradigm::ElementWise).unwrap();
            let b = convolve(&x, &op, &k, GridMode::Same, BoundaryMode::Reflect, Paradigm::MatBroadcast).unwrap();
            assert_allclose(a.data(), b.data(), 1e-5, 1e-5);
        });
    }
}
