//! Native compute kernels on melt matrices.
//!
//! These are the rust-side counterparts of the L1 Pallas kernels in
//! `python/compile/kernels/` — same melt-row contract, same column order,
//! same numerics (cross-checked in `rust/tests/`). They serve three roles:
//! the `Backend::Native` execution path, the baselines of the paper's
//! Fig 7 paradigm comparison ([`paradigm`]), and the reference for the
//! PJRT-vs-native equivalence tests.

pub mod bilateral;
pub mod convolve;
pub mod curvature;
pub mod gaussian;
pub mod paradigm;
pub mod rankfilter;
pub mod stencil;
