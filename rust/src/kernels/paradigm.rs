//! The three abstraction levels of paper Fig 7: ElementWise, VectorWise,
//! and MatBroadcast implementations of the same kernel-on-melt computation.
//!
//! "The degree of abstraction attained for the object undergoing iterative
//! processing directly correlates with the efficiency of the computing
//! implementation" — `benches/fig7_paradigms.rs` reproduces the comparison;
//! the tests here pin all three to identical numerics.
//!
//! - **ElementWise**: scalar iteration with per-element index arithmetic —
//!   the naive double loop a pre-array-programming implementation writes.
//!   Indices are recomputed per element through a deliberately generic
//!   (rank-agnostic, bounds-checked) accessor, as an interpreter would.
//! - **VectorWise**: row-at-a-time processing: each melt row is treated as
//!   one vector object, combined with the kernel via an explicit
//!   per-element loop over that vector.
//! - **MatBroadcast**: whole-matrix array programming — the kernel vector is
//!   broadcast against the melt matrix in cache-blocked, unrolled strips
//!   (what numpy's vectorized C loops do under the hood).

use crate::melt::matrix::MeltMatrix;

/// Execution paradigm selector (Fig 7 series).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Paradigm {
    ElementWise,
    VectorWise,
    MatBroadcast,
}

impl Paradigm {
    pub const ALL: [Paradigm; 3] = [
        Paradigm::ElementWise,
        Paradigm::VectorWise,
        Paradigm::MatBroadcast,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Paradigm::ElementWise => "ElementWise",
            Paradigm::VectorWise => "VectorWise",
            Paradigm::MatBroadcast => "MatBroadcast",
        }
    }
}

/// Apply a kernel vector to every melt row under the chosen paradigm.
pub fn apply_kernel(m: &MeltMatrix, kernel: &[f32], paradigm: Paradigm) -> Vec<f32> {
    match paradigm {
        Paradigm::ElementWise => apply_kernel_elementwise(m, kernel),
        Paradigm::VectorWise => apply_kernel_vectorwise(m, kernel),
        Paradigm::MatBroadcast => apply_kernel_broadcast(m, kernel),
    }
}

/// The per-element generic accessor of the ElementWise paradigm. The
/// `#[inline(never)]` is the point: an interpreted environment (the paper's
/// python element-wise loop) performs a dynamic dispatch + bounds check for
/// *every element*; inlining would let the optimizer erase exactly the cost
/// this paradigm exists to measure.
#[inline(never)]
fn element_at(data: &[f32], cols: usize, r: usize, c: usize) -> f32 {
    let flat = r
        .checked_mul(cols)
        .and_then(|v| v.checked_add(c))
        .expect("index overflow");
    *data.get(flat).expect("in range")
}

/// ElementWise: scalar loops, one dispatched generic access per element.
pub fn apply_kernel_elementwise(m: &MeltMatrix, kernel: &[f32]) -> Vec<f32> {
    assert_eq!(kernel.len(), m.cols());
    let (rows, cols) = (m.rows(), m.cols());
    let data = m.data();
    let mut out = vec![0.0f32; rows];
    for (r, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for (c, k) in kernel.iter().enumerate() {
            acc += element_at(data, cols, r, c) * k;
        }
        *o = acc;
    }
    out
}

/// One vector-level operation: a strict-order scalar dot product. Out-lined
/// so each row costs one call (the paradigm's per-vector overhead) and the
/// single accumulator keeps IEEE order — no reassociation, no SIMD.
#[inline(never)]
fn row_dot(row: &[f32], kernel: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (v, k) in row.iter().zip(kernel.iter()) {
        acc += v * k;
    }
    acc
}

/// VectorWise: one melt row = one vector object per iteration step.
pub fn apply_kernel_vectorwise(m: &MeltMatrix, kernel: &[f32]) -> Vec<f32> {
    assert_eq!(kernel.len(), m.cols());
    let mut out = Vec::with_capacity(m.rows());
    for r in 0..m.rows() {
        out.push(row_dot(m.row(r), kernel));
    }
    out
}

/// MatBroadcast: whole-matrix broadcast with 4-way unrolled strips — the
/// array-programming hot path shared by the native backend.
pub fn apply_kernel_broadcast(m: &MeltMatrix, kernel: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m.rows()];
    apply_kernel_broadcast_into(m.data(), m.rows(), m.cols(), kernel, &mut out);
    out
}

/// Allocation-free broadcast core over a raw row-major block (used by both
/// [`apply_kernel_broadcast`] and the coordinator's worker loop). Takes the
/// lane-parallel path (`simd::dot_rows_into`: two rows per step, AVX2 when
/// the CPU has it) unless the thread is pinned to scalar; both paths are
/// bit-for-bit identical — every lane runs the scalar strip order below.
pub fn apply_kernel_broadcast_into(
    data: &[f32],
    rows: usize,
    cols: usize,
    kernel: &[f32],
    out: &mut [f32],
) {
    assert_eq!(data.len(), rows * cols);
    assert_eq!(kernel.len(), cols);
    assert_eq!(out.len(), rows);
    if rows >= 2 && crate::simd::lanes_enabled() {
        crate::simd::dot_rows_into(data, cols, kernel, out);
        crate::simd::note_lane_rows(rows & !1);
        if rows % 2 == 1 {
            crate::simd::note_scalar_rows(1); // odd trailing row
        }
    } else {
        broadcast_scalar_into(data, cols, kernel, out);
        crate::simd::note_scalar_rows(rows);
    }
}

/// The scalar reference body of the broadcast: the operation order every
/// SIMD lane replicates exactly (see `simd` module docs).
fn broadcast_scalar_into(data: &[f32], cols: usize, kernel: &[f32], out: &mut [f32]) {
    for (row, o) in data.chunks_exact(cols).zip(out.iter_mut()) {
        // 4 independent accumulators over bounds-check-free fixed-width
        // strips: the compiler turns this into packed vector lanes.
        let mut acc = [0.0f32; 4];
        let rc = row.chunks_exact(4);
        let kc = kernel.chunks_exact(4);
        let (rrem, krem) = (rc.remainder(), kc.remainder());
        for (rv, kv) in rc.zip(kc) {
            acc[0] += rv[0] * kv[0];
            acc[1] += rv[1] * kv[1];
            acc[2] += rv[2] * kv[2];
            acc[3] += rv[3] * kv[3];
        }
        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        for (v, k) in rrem.iter().zip(krem.iter()) {
            s += v * k;
        }
        *o = s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gaussian::gaussian_kernel;
    use crate::melt::grid::GridMode;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::melt::operator::Operator;
    use crate::tensor::dense::Tensor;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    fn sample_melt(rng: &mut SplitMix64) -> (MeltMatrix, Vec<f32>) {
        let dims = [3 + rng.below(6), 3 + rng.below(6)];
        let x = Tensor::random(&dims, -10.0, 10.0, rng.next_u64()).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        let k = gaussian_kernel(op.window(), 1.0);
        (m, k)
    }

    #[test]
    fn all_paradigms_agree_property() {
        check_property("three paradigms identical numerics", 30, |rng: &mut SplitMix64| {
            let (m, k) = sample_melt(rng);
            let e = apply_kernel_elementwise(&m, &k);
            let v = apply_kernel_vectorwise(&m, &k);
            let b = apply_kernel_broadcast(&m, &k);
            // unroll reorders the sum; allow float tolerance
            assert_allclose(&e, &v, 0.0, 0.0);
            assert_allclose(&v, &b, 1e-5, 1e-4);
        });
    }

    #[test]
    fn dispatcher_matches_direct_calls() {
        let mut rng = SplitMix64::new(3);
        let (m, k) = sample_melt(&mut rng);
        for p in Paradigm::ALL {
            let got = apply_kernel(&m, &k, p);
            let want = match p {
                Paradigm::ElementWise => apply_kernel_elementwise(&m, &k),
                Paradigm::VectorWise => apply_kernel_vectorwise(&m, &k),
                Paradigm::MatBroadcast => apply_kernel_broadcast(&m, &k),
            };
            assert_allclose(&got, &want, 0.0, 0.0);
        }
    }

    #[test]
    fn broadcast_into_block_view() {
        // broadcasting a sub-block equals the corresponding output slice
        let mut rng = SplitMix64::new(9);
        let (m, k) = sample_melt(&mut rng);
        let full = apply_kernel_broadcast(&m, &k);
        let (lo, hi) = (1usize, m.rows() - 1);
        let mut part = vec![0.0f32; hi - lo];
        apply_kernel_broadcast_into(m.row_block(lo, hi).unwrap(), hi - lo, m.cols(), &k, &mut part);
        assert_allclose(&part, &full[lo..hi], 0.0, 0.0);
    }

    #[test]
    fn odd_column_tail_handled() {
        // cols=5 exercises the non-multiple-of-4 tail loop
        let m = MeltMatrix::new((0..15).map(|i| i as f32).collect(), 3, 5, vec![3], vec![5]).unwrap();
        let k = vec![1.0f32; 5];
        let got = apply_kernel_broadcast(&m, &k);
        assert_allclose(&got, &[10.0, 35.0, 60.0], 1e-6, 1e-6);
    }

    #[test]
    fn broadcast_lane_path_matches_scalar_bitwise() {
        use crate::simd::{self, SimdMode};
        check_property("broadcast lane vs scalar bits", 40, |rng: &mut SplitMix64| {
            let rows = 1 + rng.below(17); // both parities, incl. rows == 1
            let cols = 1 + rng.below(30); // every strip-remainder class
            let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 8.0).collect();
            let k: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
            let mut scalar = vec![0.0f32; rows];
            simd::enter_job(SimdMode::ForceScalar);
            apply_kernel_broadcast_into(&data, rows, cols, &k, &mut scalar);
            let mut lanes = vec![0.0f32; rows];
            simd::enter_job(SimdMode::ForceSimd);
            apply_kernel_broadcast_into(&data, rows, cols, &k, &mut lanes);
            simd::enter_job(SimdMode::Auto);
            for r in 0..rows {
                assert_eq!(
                    lanes[r].to_bits(),
                    scalar[r].to_bits(),
                    "row {r} of {rows}x{cols}"
                );
            }
        });
    }

    #[test]
    fn labels_stable() {
        assert_eq!(Paradigm::ElementWise.label(), "ElementWise");
        assert_eq!(Paradigm::VectorWise.label(), "VectorWise");
        assert_eq!(Paradigm::MatBroadcast.label(), "MatBroadcast");
    }
}
