//! Generic N-D bilateral filter on melt matrices — paper eq. (3).
//!
//! W(x, s) ∝ exp(-(x-s)ᵀ Σ_d⁻¹ (x-s)/2 − |I(x)−I(s)|²/2σ_r²), normalized
//! jointly over the window, applied as a weighted mean of the melt row.
//! Matches the L1 Pallas kernels in `python/compile/kernels/bilateral.py`
//! bit-for-contract (same spatial precompute, same adaptive σ_r = row std
//! floored).

use crate::error::{Error, Result};
use crate::melt::matrix::MeltMatrix;
use crate::simd::LANES;
use crate::stats::linalg::Mat;

/// Range-regulator policy for eq. (3)'s second exponential item.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RangeSigma {
    /// Pre-defined constant σ_r (paper Fig 3 c/d).
    Constant(f32),
    /// Locally adaptive σ_r = σ(x, s): the std of the neighbourhood values,
    /// floored (paper Fig 3 b).
    Adaptive { floor: f32 },
}

/// Full parameter set of the generic bilateral filter.
#[derive(Clone, Debug)]
pub struct BilateralParams {
    /// Precomputed unnormalized spatial component over the window ravel
    /// (from [`crate::kernels::gaussian::spatial_gaussian`]).
    pub spatial: Vec<f32>,
    /// Range regulator policy.
    pub range: RangeSigma,
}

impl BilateralParams {
    /// Isotropic helper: Σ_d = σ_d² I over `window`.
    pub fn isotropic(window: &[usize], sigma_d: f32, range: RangeSigma) -> Result<Self> {
        if sigma_d <= 0.0 {
            return Err(Error::Operator(format!("sigma_d must be positive: {sigma_d}")));
        }
        let nd = window.len();
        let inv = Mat::diag(&vec![1.0 / (sigma_d as f64 * sigma_d as f64); nd]);
        Ok(Self {
            spatial: crate::kernels::gaussian::spatial_gaussian(window, &inv)?,
            range,
        })
    }
}

/// Apply the bilateral filter to every melt row; returns one value per row.
pub fn bilateral(m: &MeltMatrix, params: &BilateralParams) -> Result<Vec<f32>> {
    if params.spatial.len() != m.cols() {
        return Err(Error::shape(format!(
            "spatial component length {} vs melt cols {}",
            params.spatial.len(),
            m.cols()
        )));
    }
    let mut out = vec![0.0f32; m.rows()];
    bilateral_into(m.data(), m.rows(), m.cols(), m.center(), params, &mut out)?;
    Ok(out)
}

/// Constant-σ_r convenience wrapper.
pub fn bilateral_const(m: &MeltMatrix, spatial: &[f32], sigma_r: f32) -> Result<Vec<f32>> {
    bilateral(
        m,
        &BilateralParams {
            spatial: spatial.to_vec(),
            range: RangeSigma::Constant(sigma_r),
        },
    )
}

/// Adaptive-σ_r convenience wrapper.
pub fn bilateral_adaptive(m: &MeltMatrix, spatial: &[f32], floor: f32) -> Result<Vec<f32>> {
    bilateral(
        m,
        &BilateralParams {
            spatial: spatial.to_vec(),
            range: RangeSigma::Adaptive { floor },
        },
    )
}

/// Allocation-free core over a raw row-major block (coordinator hot path).
/// Walks the block in [`LANES`]-row groups when the thread's simd mode
/// allows it — each lane runs the scalar per-row operation order below, so
/// the two paths are bit-for-bit identical (the weight `exp` stays a scalar
/// `f32::exp` per lane; the lane win is eight independent dependency
/// chains, not a vector exp).
pub fn bilateral_into(
    data: &[f32],
    rows: usize,
    cols: usize,
    center: usize,
    params: &BilateralParams,
    out: &mut [f32],
) -> Result<()> {
    if data.len() != rows * cols || out.len() != rows || center >= cols {
        return Err(Error::shape(format!(
            "bilateral_into: data {} rows {rows} cols {cols} center {center} out {}",
            data.len(),
            out.len()
        )));
    }
    let spatial = &params.spatial;
    match params.range {
        RangeSigma::Constant(sigma_r) => {
            if sigma_r <= 0.0 {
                return Err(Error::Operator(format!("sigma_r must be positive: {sigma_r}")));
            }
            let inv2 = 1.0 / (2.0 * sigma_r * sigma_r);
            let lane_rows = if crate::simd::lanes_enabled() {
                (rows / LANES) * LANES
            } else {
                0
            };
            for g in 0..lane_rows / LANES {
                let base = g * LANES;
                const_rows_lane(
                    &data[base * cols..(base + LANES) * cols],
                    cols,
                    center,
                    spatial,
                    inv2,
                    &mut out[base..base + LANES],
                );
            }
            for r in lane_rows..rows {
                out[r] = const_row(&data[r * cols..(r + 1) * cols], center, spatial, inv2);
            }
            crate::simd::note_lane_rows(lane_rows);
            crate::simd::note_scalar_rows(rows - lane_rows);
        }
        RangeSigma::Adaptive { floor } => {
            if floor <= 0.0 {
                return Err(Error::Operator(format!("floor must be positive: {floor}")));
            }
            let inv_n = 1.0 / cols as f32;
            let lane_rows = if crate::simd::lanes_enabled() {
                (rows / LANES) * LANES
            } else {
                0
            };
            for g in 0..lane_rows / LANES {
                let base = g * LANES;
                adaptive_rows_lane(
                    &data[base * cols..(base + LANES) * cols],
                    cols,
                    center,
                    spatial,
                    inv_n,
                    floor,
                    &mut out[base..base + LANES],
                );
            }
            for r in lane_rows..rows {
                out[r] = adaptive_row(&data[r * cols..(r + 1) * cols], center, spatial, inv_n, floor);
            }
            crate::simd::note_lane_rows(lane_rows);
            crate::simd::note_scalar_rows(rows - lane_rows);
        }
    }
    Ok(())
}

/// Scalar constant-σ_r body for one row — the reference operation order.
#[inline(always)]
fn const_row(row: &[f32], center: usize, spatial: &[f32], inv2: f32) -> f32 {
    let c = row[center];
    let (mut num, mut den) = (0.0f32, 0.0f32);
    for (v, s) in row.iter().zip(spatial.iter()) {
        let d = v - c;
        let w = s * (-d * d * inv2).exp();
        num += w * v;
        den += w;
    }
    num / den
}

/// Constant-σ_r over exactly `LANES` rows at once: lane `l` performs the
/// operations of [`const_row`] on row `l` in the identical order.
#[inline(always)]
fn const_rows_lane(
    block: &[f32],
    cols: usize,
    center: usize,
    spatial: &[f32],
    inv2: f32,
    out: &mut [f32],
) {
    let mut c = [0.0f32; LANES];
    for l in 0..LANES {
        c[l] = block[l * cols + center];
    }
    let mut num = [0.0f32; LANES];
    let mut den = [0.0f32; LANES];
    for (j, s) in spatial.iter().enumerate().take(cols) {
        for l in 0..LANES {
            let v = block[l * cols + j];
            let d = v - c[l];
            let w = s * (-d * d * inv2).exp();
            num[l] += w * v;
            den[l] += w;
        }
    }
    for l in 0..LANES {
        out[l] = num[l] / den[l];
    }
}

/// Scalar adaptive-σ_r body for one row — the reference operation order.
#[inline(always)]
fn adaptive_row(row: &[f32], center: usize, spatial: &[f32], inv_n: f32, floor: f32) -> f32 {
    // σ_r(x) = population std of the row, floored
    let mut mean = 0.0f32;
    for v in row {
        mean += v;
    }
    mean *= inv_n;
    let mut var = 0.0f32;
    for v in row {
        let d = v - mean;
        var += d * d;
    }
    var *= inv_n;
    let sig = var.sqrt().max(floor);
    let inv2 = 1.0 / (2.0 * sig * sig);
    const_row(row, center, spatial, inv2)
}

/// Adaptive-σ_r over exactly `LANES` rows: per-lane mean, variance, σ and
/// weighted mean, each in [`adaptive_row`]'s exact order.
#[inline(always)]
fn adaptive_rows_lane(
    block: &[f32],
    cols: usize,
    center: usize,
    spatial: &[f32],
    inv_n: f32,
    floor: f32,
    out: &mut [f32],
) {
    let mut mean = [0.0f32; LANES];
    for j in 0..cols {
        for l in 0..LANES {
            mean[l] += block[l * cols + j];
        }
    }
    for m in mean.iter_mut() {
        *m *= inv_n;
    }
    let mut var = [0.0f32; LANES];
    for j in 0..cols {
        for l in 0..LANES {
            let d = block[l * cols + j] - mean[l];
            var[l] += d * d;
        }
    }
    let mut inv2 = [0.0f32; LANES];
    for l in 0..LANES {
        let sig = (var[l] * inv_n).sqrt().max(floor);
        inv2[l] = 1.0 / (2.0 * sig * sig);
    }
    let mut c = [0.0f32; LANES];
    for l in 0..LANES {
        c[l] = block[l * cols + center];
    }
    let mut num = [0.0f32; LANES];
    let mut den = [0.0f32; LANES];
    for (j, s) in spatial.iter().enumerate().take(cols) {
        for l in 0..LANES {
            let v = block[l * cols + j];
            let d = v - c[l];
            let w = s * (-d * d * inv2[l]).exp();
            num[l] += w * v;
            den[l] += w;
        }
    }
    for l in 0..LANES {
        out[l] = num[l] / den[l];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::gaussian::gaussian_kernel;
    use crate::kernels::paradigm::apply_kernel_broadcast;
    use crate::melt::grid::GridMode;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::melt::operator::Operator;
    use crate::tensor::dense::Tensor;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    fn params(window: &[usize], range: RangeSigma) -> BilateralParams {
        BilateralParams::isotropic(window, 1.5, range).unwrap()
    }

    #[test]
    fn constant_region_is_fixed_point() {
        let x = Tensor::full(&[8, 8], 42.0).unwrap();
        let op = Operator::cubic(5, 2).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        for range in [RangeSigma::Constant(3.0), RangeSigma::Adaptive { floor: 1.0 }] {
            let out = bilateral(&m, &params(&[5, 5], range)).unwrap();
            assert_allclose(&out, &vec![42.0; 64], 1e-5, 1e-4);
        }
    }

    #[test]
    fn excessive_sigma_degenerates_to_gaussian() {
        // Fig 3(d): σ_r ≫ ‖Σ_d‖ -> plain spatial gaussian
        let x = Tensor::random(&[10, 10], 0.0, 255.0, 3).unwrap();
        let op = Operator::cubic(5, 2).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        let p = params(&[5, 5], RangeSigma::Constant(1e6));
        let got = bilateral(&m, &p).unwrap();
        // normalized spatial kernel applied as a global filter
        let sum: f32 = p.spatial.iter().sum();
        let k: Vec<f32> = p.spatial.iter().map(|v| v / sum).collect();
        let want = apply_kernel_broadcast(&m, &k);
        assert_allclose(&got, &want, 1e-4, 1e-2);
    }

    #[test]
    fn edge_preservation_vs_gaussian() {
        // Fig 3(c): a step edge survives small-σ_r bilateral, not gaussian
        let mut x = Tensor::zeros(&[12, 12]).unwrap();
        for y in 0..12 {
            for xx in 6..12 {
                x.set(&[y, xx], 200.0).unwrap();
            }
        }
        let op = Operator::cubic(5, 2).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        let bi = bilateral(&m, &params(&[5, 5], RangeSigma::Constant(10.0))).unwrap();
        let ga = apply_kernel_broadcast(&m, &gaussian_kernel(&[5, 5], 1.5));
        // at the edge-adjacent column (5), bilateral stays near 0
        let p_bi = bi[5 * 12 + 5];
        let p_ga = ga[5 * 12 + 5];
        assert!(p_bi < 10.0, "bilateral leaked: {p_bi}");
        assert!(p_ga > 30.0, "gaussian should mix: {p_ga}");
    }

    #[test]
    fn adaptive_denoises_flat_noise_more_than_const_small_sigma() {
        // adaptive σ_r tracks the local noise level, so pure-noise regions
        // are smoothed; a tiny constant σ_r barely averages anything.
        let x = Tensor::random(&[16, 16], 100.0, 130.0, 5).unwrap();
        let op = Operator::cubic(5, 2).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        let adaptive = bilateral(&m, &params(&[5, 5], RangeSigma::Adaptive { floor: 1.0 })).unwrap();
        let tiny = bilateral(&m, &params(&[5, 5], RangeSigma::Constant(0.05))).unwrap();
        let var = |v: &[f32]| {
            let mu = v.iter().sum::<f32>() / v.len() as f32;
            v.iter().map(|a| (a - mu) * (a - mu)).sum::<f32>() / v.len() as f32
        };
        assert!(
            var(&adaptive) < 0.6 * var(&tiny),
            "adaptive {} vs tiny-sigma {}",
            var(&adaptive),
            var(&tiny)
        );
    }

    #[test]
    fn into_matches_wrapper_property() {
        check_property("bilateral_into == bilateral on blocks", 20, |rng: &mut SplitMix64| {
            let dims = [4 + rng.below(5), 4 + rng.below(5)];
            let x = Tensor::random(&dims, 0.0, 255.0, rng.next_u64()).unwrap();
            let op = Operator::cubic(3, 2).unwrap();
            let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
            let p = params(&[3, 3], RangeSigma::Constant(20.0));
            let full = bilateral(&m, &p).unwrap();
            let lo = rng.below(m.rows() / 2);
            let hi = lo + 1 + rng.below(m.rows() - lo - 1);
            let mut part = vec![0.0f32; hi - lo];
            bilateral_into(
                m.row_block(lo, hi).unwrap(),
                hi - lo,
                m.cols(),
                m.center(),
                &p,
                &mut part,
            )
            .unwrap();
            assert_allclose(&part, &full[lo..hi], 1e-6, 1e-5);
        });
    }

    #[test]
    fn lane_path_matches_scalar_bitwise() {
        use crate::simd::{self, SimdMode};
        check_property("bilateral lane vs scalar bits", 25, |rng: &mut SplitMix64| {
            let rows = 1 + rng.below(21); // crosses the LANES=8 group edge
            let cols = 1 + rng.below(15);
            let center = rng.below(cols);
            let data: Vec<f32> = (0..rows * cols).map(|_| rng.normal() * 50.0).collect();
            let spatial: Vec<f32> = (0..cols).map(|_| 0.01 + rng.below(100) as f32 / 100.0).collect();
            for range in [RangeSigma::Constant(20.0), RangeSigma::Adaptive { floor: 1.0 }] {
                let p = BilateralParams { spatial: spatial.clone(), range };
                let mut scalar = vec![0.0f32; rows];
                simd::enter_job(SimdMode::ForceScalar);
                bilateral_into(&data, rows, cols, center, &p, &mut scalar).unwrap();
                let mut lanes = vec![0.0f32; rows];
                simd::enter_job(SimdMode::ForceSimd);
                bilateral_into(&data, rows, cols, center, &p, &mut lanes).unwrap();
                simd::enter_job(SimdMode::Auto);
                for r in 0..rows {
                    assert_eq!(
                        lanes[r].to_bits(),
                        scalar[r].to_bits(),
                        "row {r} of {rows}x{cols} under {range:?}"
                    );
                }
            }
        });
    }

    #[test]
    fn rejects_invalid_params() {
        let m = MeltMatrix::new(vec![0.0; 18], 2, 9, vec![2], vec![3, 3]).unwrap();
        assert!(bilateral_const(&m, &[1.0; 8], 1.0).is_err()); // bad spatial len
        assert!(bilateral_const(&m, &[1.0; 9], 0.0).is_err()); // bad sigma
        assert!(bilateral_adaptive(&m, &[1.0; 9], -1.0).is_err()); // bad floor
        assert!(BilateralParams::isotropic(&[3, 3], 0.0, RangeSigma::Constant(1.0)).is_err());
    }
}
