//! Central-difference stencil matrices over window ravels.
//!
//! `stencil_matrix(window)` is the S of the curvature kernel: applying a
//! melt row gives all first- and second-order partial differentials of the
//! grid point at unit spacing, packed `[g_0..g_{nd-1}, H_00, H_01, ...,
//! H_{nd-1,nd-1}]` (gradients then upper-triangular Hessian). The column
//! order is the shared contract with `python/compile/kernels/ref.py`.

use crate::error::{Error, Result};
use crate::tensor::shape::row_major_strides;

/// Number of packed differential columns for rank `nd`.
pub fn ncols(nd: usize) -> usize {
    nd + nd * (nd + 1) / 2
}

/// Build the stencil matrix: `W x ncols(nd)` in row-major order, where
/// `W = prod(window)`. Every extent must be odd and >= 3.
pub fn stencil_matrix(window: &[usize]) -> Result<Vec<f32>> {
    let nd = window.len();
    if nd == 0 {
        return Err(Error::Operator("empty stencil window".into()));
    }
    if window.iter().any(|&w| w < 3 || w % 2 == 0) {
        return Err(Error::Operator(format!(
            "stencil extents must be odd and >= 3, got {window:?}"
        )));
    }
    let w_total: usize = window.iter().product();
    let cols = ncols(nd);
    let strides = row_major_strides(window);
    let center_flat: usize = window
        .iter()
        .zip(&strides)
        .map(|(&w, &s)| (w / 2) * s)
        .sum();
    let mut s = vec![0.0f32; w_total * cols];

    let mut put = |axis_offsets: &[(usize, isize)], col: usize, val: f32| {
        let mut flat = center_flat as isize;
        for &(a, o) in axis_offsets {
            flat += o * strides[a] as isize;
        }
        s[flat as usize * cols + col] += val;
    };

    // gradients: (f[+e_a] - f[-e_a]) / 2
    for a in 0..nd {
        put(&[(a, 1)], a, 0.5);
        put(&[(a, -1)], a, -0.5);
    }
    // Hessian upper triangle, row-major over (a, b >= a)
    let mut col = nd;
    for a in 0..nd {
        for b in a..nd {
            if a == b {
                put(&[(a, 1)], col, 1.0);
                put(&[], col, -2.0);
                put(&[(a, -1)], col, 1.0);
            } else {
                put(&[(a, 1), (b, 1)], col, 0.25);
                put(&[(a, -1), (b, -1)], col, 0.25);
                put(&[(a, 1), (b, -1)], col, -0.25);
                put(&[(a, -1), (b, 1)], col, -0.25);
            }
            col += 1;
        }
    }
    Ok(s)
}

/// Apply the stencil matrix to one melt row: returns the packed differentials.
pub fn apply_stencil(row: &[f32], stencil: &[f32], cols: usize) -> Vec<f32> {
    debug_assert_eq!(row.len() * cols, stencil.len());
    let mut out = vec![0.0f32; cols];
    for (w, srow) in row.iter().zip(stencil.chunks_exact(cols)) {
        if *w == 0.0 {
            continue;
        }
        for (o, s) in out.iter_mut().zip(srow) {
            *o += w * s;
        }
    }
    out
}

/// Sparse form of the stencil matrix: `(window_flat, col, weight)` triples.
/// Central-difference stencils are ~90% zeros (a 3^3 window has 243 dense
/// entries but only ~40 non-zeros), so the curvature hot loop contracts the
/// sparse triples instead (see `kernels::curvature::curvature_into`).
pub fn stencil_sparse(window: &[usize]) -> Result<Vec<(u32, u32, f32)>> {
    let nd = window.len();
    let cols = ncols(nd);
    let dense = stencil_matrix(window)?;
    let mut out = Vec::new();
    for (flat, row) in dense.chunks_exact(cols).enumerate() {
        for (c, &v) in row.iter().enumerate() {
            if v != 0.0 {
                out.push((flat as u32, c as u32, v));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    #[test]
    fn rejects_bad_windows() {
        assert!(stencil_matrix(&[]).is_err());
        assert!(stencil_matrix(&[1, 3]).is_err()); // extent < 3
        assert!(stencil_matrix(&[4, 3]).is_err()); // even
    }

    #[test]
    fn columns_annihilate_constants() {
        for window in [vec![3, 3], vec![3, 3, 3], vec![5, 5]] {
            let nd = window.len();
            let s = stencil_matrix(&window).unwrap();
            let w: usize = window.iter().product();
            for c in 0..ncols(nd) {
                let col_sum: f32 = (0..w).map(|r| s[r * ncols(nd) + c]).sum();
                assert!(col_sum.abs() < 1e-6, "col {c} sums to {col_sum}");
            }
        }
    }

    #[test]
    fn gradient_1d_central_difference() {
        let s = stencil_matrix(&[3]).unwrap();
        // f = [0, 1, 4]: g = (4-0)/2 = 2, h = 4 - 2 + 0 = 2
        let d = apply_stencil(&[0.0, 1.0, 4.0], &s, ncols(1));
        assert_allclose(&d, &[2.0, 2.0], 1e-6, 1e-6);
    }

    #[test]
    fn exact_on_quadratics_property() {
        // m @ S recovers the exact gradient and Hessian of any quadratic.
        check_property("stencil exact on quadratics", 25, |rng: &mut SplitMix64| {
            let nd = 1 + rng.below(3);
            let window = vec![3usize; nd];
            let w: usize = window.iter().product();
            // random symmetric A and vector b
            let mut a = vec![0.0f64; nd * nd];
            for r in 0..nd {
                for c in 0..=r {
                    let v = rng.normal() as f64;
                    a[r * nd + c] = v;
                    a[c * nd + r] = v;
                }
            }
            let b: Vec<f64> = (0..nd).map(|_| rng.normal() as f64).collect();
            // evaluate the quadratic on the window offsets (ravel order)
            let strides = row_major_strides(&window);
            let mut vals = vec![0.0f32; w];
            for (flat, v) in vals.iter_mut().enumerate() {
                let mut rem = flat;
                let off: Vec<f64> = strides
                    .iter()
                    .zip(&window)
                    .map(|(&s, &we)| {
                        let i = rem / s;
                        rem %= s;
                        i as f64 - (we / 2) as f64
                    })
                    .collect();
                let mut f = 0.0f64;
                for r in 0..nd {
                    f += b[r] * off[r];
                    for c in 0..nd {
                        f += 0.5 * a[r * nd + c] * off[r] * off[c];
                    }
                }
                *v = f as f32;
            }
            let s = stencil_matrix(&window).unwrap();
            let d = apply_stencil(&vals, &s, ncols(nd));
            for r in 0..nd {
                assert!(
                    (d[r] as f64 - b[r]).abs() < 1e-4,
                    "gradient axis {r}: {} vs {}",
                    d[r],
                    b[r]
                );
            }
            let mut col = nd;
            for r in 0..nd {
                for c in r..nd {
                    assert!(
                        (d[col] as f64 - a[r * nd + c]).abs() < 1e-4,
                        "H[{r}{c}]: {} vs {}",
                        d[col],
                        a[r * nd + c]
                    );
                    col += 1;
                }
            }
        });
    }

    #[test]
    fn wider_windows_keep_3point_core() {
        // extents > 3 still place the stencil around the centre
        let s5 = stencil_matrix(&[5]).unwrap();
        let d = apply_stencil(&[0.0, 0.0, 1.0, 4.0, 0.0], &s5, ncols(1));
        // centre index 2: g = (4 - 0)/2 = 2 using +/-1 neighbours
        assert_allclose(&d, &[2.0, 2.0], 1e-6, 1e-6);
    }

    #[test]
    fn ncols_formula() {
        assert_eq!(ncols(1), 2);
        assert_eq!(ncols(2), 5);
        assert_eq!(ncols(3), 9);
        assert_eq!(ncols(4), 14);
    }
}
