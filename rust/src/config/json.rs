//! Minimal recursive-descent JSON parser (objects, arrays, strings, numbers,
//! bools, null) — enough for `artifacts/manifest.json` and test fixtures.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<JsonValue> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Format(format!(
                "trailing garbage at byte {} of JSON document",
                p.pos
            )));
        }
        Ok(v)
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Ok(m),
            other => Err(Error::Format(format!("expected object, got {other:?}"))),
        }
    }

    pub fn as_array(&self) -> Result<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Ok(a),
            other => Err(Error::Format(format!("expected array, got {other:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            JsonValue::String(s) => Ok(s),
            other => Err(Error::Format(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            JsonValue::Number(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
            other => Err(Error::Format(format!("expected unsigned integer, got {other:?}"))),
        }
    }

    /// Any JSON number as f64 (kernel parameters: sigma, q, …).
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            JsonValue::Number(n) => Ok(*n),
            other => Err(Error::Format(format!("expected number, got {other:?}"))),
        }
    }

    /// Field lookup on an object.
    pub fn field(&self, key: &str) -> Result<&JsonValue> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| Error::Format(format!("missing field '{key}'")))
    }

    /// usize vector from a JSON array of integers.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_array()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Format(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )))
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Format(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ))),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Format(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(map));
                }
                other => {
                    return Err(Error::Format(format!(
                        "expected ',' or '}}' in object, found {:?}",
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(items));
                }
                other => {
                    return Err(Error::Format(format!(
                        "expected ',' or ']' in array, found {:?}",
                        other.map(|c| c as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Format("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'u') => {
                            // \uXXXX escapes. Code units in the surrogate
                            // range are not scalar values: a high surrogate
                            // must pair with a following \uDC00-\uDFFF
                            // escape (RFC 8259 §7) and decode to one
                            // supplementary-plane character; anything lone
                            // is rejected rather than smuggled into the
                            // String as a replacement or mangled char.
                            let code = self.hex4()?;
                            let ch = match code {
                                0xD800..=0xDBFF => {
                                    if self.bytes.get(self.pos + 1) != Some(&b'\\')
                                        || self.bytes.get(self.pos + 2) != Some(&b'u')
                                    {
                                        return Err(Error::Format(format!(
                                            "lone high surrogate \\u{code:04X}: a non-BMP \
                                             character needs a \\uDC00-\\uDFFF escape \
                                             immediately after"
                                        )));
                                    }
                                    self.pos += 2; // step onto the pair's 'u'
                                    let low = self.hex4()?;
                                    if !(0xDC00..=0xDFFF).contains(&low) {
                                        return Err(Error::Format(format!(
                                            "high surrogate \\u{code:04X} followed by \
                                             \\u{low:04X}, expected \\uDC00-\\uDFFF"
                                        )));
                                    }
                                    let scalar =
                                        0x1_0000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                                    char::from_u32(scalar).ok_or_else(|| {
                                        Error::Format("invalid codepoint".into())
                                    })?
                                }
                                0xDC00..=0xDFFF => {
                                    return Err(Error::Format(format!(
                                        "lone low surrogate \\u{code:04X}: expected a leading \
                                         \\uD800-\\uDBFF escape before it"
                                    )))
                                }
                                _ => char::from_u32(code)
                                    .ok_or_else(|| Error::Format("invalid codepoint".into()))?,
                            };
                            out.push(ch);
                        }
                        other => {
                            return Err(Error::Format(format!(
                                "unsupported escape {:?}",
                                other.map(|c| c as char)
                            )))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| Error::Format("invalid utf-8 in string".into()))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    /// Read the four hex digits of a `\uXXXX` escape. On entry `pos` is at
    /// the `u`; on exit it is at the last hex digit (the caller's shared
    /// `pos += 1` then steps past it).
    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 >= self.bytes.len() {
            return Err(Error::Format("truncated \\u escape".into()));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
            .map_err(|_| Error::Format("bad \\u escape".into()))?;
        let code = u32::from_str_radix(hex, 16)
            .map_err(|_| Error::Format("bad \\u escape".into()))?;
        self.pos += 4;
        Ok(code)
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(JsonValue::Number)
            .map_err(|_| Error::Format(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "chunk_rows": 2048,
            "dtype": "f32",
            "artifacts": [
                {"name": "gaussian_w27", "window": [3, 3, 3], "inputs": [[2048, 27], [27]]}
            ]
        }"#;
        let v = JsonValue::parse(doc).unwrap();
        assert_eq!(v.field("chunk_rows").unwrap().as_usize().unwrap(), 2048);
        let arts = v.field("artifacts").unwrap().as_array().unwrap();
        assert_eq!(arts[0].field("name").unwrap().as_str().unwrap(), "gaussian_w27");
        assert_eq!(
            arts[0].field("window").unwrap().as_usize_vec().unwrap(),
            vec![3, 3, 3]
        );
    }

    #[test]
    fn scalar_values() {
        assert_eq!(JsonValue::parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(JsonValue::parse("null").unwrap(), JsonValue::Null);
        assert_eq!(JsonValue::parse("-2.5e2").unwrap(), JsonValue::Number(-250.0));
        assert_eq!(
            JsonValue::parse(r#""a\nbA""#).unwrap(),
            JsonValue::String("a\nbA".into())
        );
    }

    #[test]
    fn empty_containers() {
        assert_eq!(JsonValue::parse("[]").unwrap(), JsonValue::Array(vec![]));
        assert_eq!(
            JsonValue::parse("{}").unwrap(),
            JsonValue::Object(Default::default())
        );
    }

    #[test]
    fn rejects_malformed() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1, 2,]").is_err());
        assert!(JsonValue::parse("{'single': 1}").is_err());
        assert!(JsonValue::parse("1 2").is_err());
        assert!(JsonValue::parse("\"unterminated").is_err());
    }

    #[test]
    fn typed_accessors_reject_mismatches() {
        let v = JsonValue::parse(r#"{"a": 1.5, "b": [1, "x"]}"#).unwrap();
        assert!(v.field("a").unwrap().as_usize().is_err()); // fractional
        assert!(v.field("b").unwrap().as_usize_vec().is_err()); // mixed
        assert!(v.field("missing").is_err());
        assert!(v.field("a").unwrap().as_str().is_err());
    }

    #[test]
    fn nested_depth() {
        let v = JsonValue::parse(r#"[[[[1]]]]"#).unwrap();
        let inner = v.as_array().unwrap()[0].as_array().unwrap()[0].as_array().unwrap()[0]
            .as_array()
            .unwrap()[0]
            .as_usize()
            .unwrap();
        assert_eq!(inner, 1);
    }

    #[test]
    fn decodes_utf16_surrogate_pairs() {
        let v = JsonValue::parse(r#""\uD83D\uDE00""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1F600}");
        // pair mid-string, BMP escapes before and after
        let v = JsonValue::parse(r#""a\u00E9\uD834\uDD1Eb""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\u{e9}\u{1D11E}b");
    }

    #[test]
    fn rejects_lone_surrogates_with_clear_errors() {
        let high = JsonValue::parse(r#""\uD83D""#).unwrap_err().to_string();
        assert!(high.contains("lone high surrogate"), "{high}");
        let low = JsonValue::parse(r#""\uDE00""#).unwrap_err().to_string();
        assert!(low.contains("lone low surrogate"), "{low}");
        // high surrogate followed by a non-surrogate escape
        let bad = JsonValue::parse(r#""\uD83D\u0041""#).unwrap_err().to_string();
        assert!(bad.contains("expected \\uDC00-\\uDFFF"), "{bad}");
        // high surrogate followed by a literal char, not an escape
        let trail = JsonValue::parse(r#""\uD83Dx""#).unwrap_err().to_string();
        assert!(trail.contains("lone high surrogate"), "{trail}");
    }

    #[test]
    fn raw_non_bmp_chars_pass_through() {
        let v = JsonValue::parse("\"melt \u{1F600} frame\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "melt \u{1F600} frame");
    }

    #[test]
    fn bmp_escapes_still_decode() {
        let v = JsonValue::parse(r#""\u0041\u00E9\u6F22""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\u{e9}\u{6f22}");
    }
}
