//! Configuration: a minimal JSON parser for the AOT artifact manifest and a
//! TOML-subset parser for run configs, plus the typed config structs.
//!
//! Hand-rolled because the vendored crate set has no serde (DESIGN.md
//! §Substitutions); both grammars are restricted to exactly what this
//! project emits, and both parsers reject anything outside it loudly.

pub mod json;
pub mod spec;
pub mod toml;

pub use json::JsonValue;
pub use spec::RunConfig;
