//! TOML-subset parser for run configs: `[sections]`, `key = value` with
//! strings, integers, floats, booleans, and homogeneous arrays. Comments
//! with `#`. No nested tables, no multi-line strings — run configs don't
//! need them, and anything outside the subset errors with a line number.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed scalar/array value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    String(String),
    Integer(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::String(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Integer(i) if *i >= 0 => Ok(*i as usize),
            other => Err(Error::Config(format!("expected unsigned integer, got {other:?}"))),
        }
    }

    pub fn as_f32(&self) -> Result<f32> {
        match self {
            TomlValue::Float(f) => Ok(*f as f32),
            TomlValue::Integer(i) => Ok(*i as f32),
            other => Err(Error::Config(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        match self {
            TomlValue::Array(items) => items.iter().map(|v| v.as_usize()).collect(),
            other => Err(Error::Config(format!("expected array, got {other:?}"))),
        }
    }
}

/// Parsed document: section name ("" for top level) -> key -> value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| Error::Config(format!("line {}: unterminated section", lineno + 1)))?
                    .trim();
                if name.is_empty() {
                    return Err(Error::Config(format!("line {}: empty section name", lineno + 1)));
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected 'key = value'", lineno + 1))
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            let value = parse_value(value.trim())
                .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
            doc.sections
                .entry(section.clone())
                .or_default()
                .insert(key.to_string(), value);
        }
        Ok(doc)
    }

    /// Look up `section.key`; section "" is the top level.
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    /// Required lookup with a config error naming the path.
    pub fn require(&self, section: &str, key: &str) -> Result<&TomlValue> {
        self.get(section, key).ok_or_else(|| {
            Error::Config(format!(
                "missing config key '{}{}{}'",
                section,
                if section.is_empty() { "" } else { "." },
                key
            ))
        })
    }

    pub fn sections(&self) -> impl Iterator<Item = &String> {
        self.sections.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside a string starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        return Err(Error::Config("empty value".into()));
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| Error::Config(format!("unterminated string {text}")))?;
        if inner.contains('"') {
            return Err(Error::Config(format!("embedded quote in {text}")));
        }
        return Ok(TomlValue::String(inner.to_string()));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| Error::Config(format!("unterminated array {text}")))?;
        let items: Vec<TomlValue> = split_top_level(inner)
            .into_iter()
            .map(|s| parse_value(s.trim()))
            .collect::<Result<_>>()?;
        return Ok(TomlValue::Array(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if text.contains('.') || text.contains('e') || text.contains('E') {
        if let Ok(f) = text.parse::<f64>() {
            return Ok(TomlValue::Float(f));
        }
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Integer(i));
    }
    Err(Error::Config(format!("cannot parse value '{text}'")))
}

fn split_top_level(text: &str) -> Vec<&str> {
    // split on commas not inside nested brackets or strings
    let mut out = Vec::new();
    let (mut depth, mut in_str, mut start) = (0i32, false, 0usize);
    for (i, c) in text.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(&text[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if !text[start..].trim().is_empty() {
        out.push(&text[start..]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_run_config() {
        let doc = TomlDoc::parse(
            r#"
            # pipeline run
            workers = 4
            [job]
            kind = "bilateral_const"   # Fig 3 panel c
            window = [5, 5]
            sigma_r = 30.0
            adaptive = false
            "#,
        )
        .unwrap();
        assert_eq!(doc.require("", "workers").unwrap().as_usize().unwrap(), 4);
        assert_eq!(doc.require("job", "kind").unwrap().as_str().unwrap(), "bilateral_const");
        assert_eq!(doc.require("job", "window").unwrap().as_usize_vec().unwrap(), vec![5, 5]);
        assert_eq!(doc.require("job", "sigma_r").unwrap().as_f32().unwrap(), 30.0);
        assert!(!doc.require("job", "adaptive").unwrap().as_bool().unwrap());
    }

    #[test]
    fn missing_key_names_path() {
        let doc = TomlDoc::parse("[a]\nx = 1").unwrap();
        let err = doc.require("a", "y").unwrap_err().to_string();
        assert!(err.contains("a.y"), "{err}");
    }

    #[test]
    fn value_types() {
        assert_eq!(parse_value("42").unwrap(), TomlValue::Integer(42));
        assert_eq!(parse_value("-1").unwrap(), TomlValue::Integer(-1));
        assert_eq!(parse_value("2.5").unwrap(), TomlValue::Float(2.5));
        assert_eq!(parse_value("1e3").unwrap(), TomlValue::Float(1000.0));
        assert_eq!(parse_value("true").unwrap(), TomlValue::Bool(true));
        assert_eq!(
            parse_value("[1, 2, 3]").unwrap(),
            TomlValue::Array(vec![
                TomlValue::Integer(1),
                TomlValue::Integer(2),
                TomlValue::Integer(3)
            ])
        );
    }

    #[test]
    fn nested_arrays() {
        let v = parse_value("[[1, 2], [3]]").unwrap();
        if let TomlValue::Array(outer) = v {
            assert_eq!(outer.len(), 2);
            assert_eq!(outer[1], TomlValue::Array(vec![TomlValue::Integer(3)]));
        } else {
            panic!("not an array");
        }
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("no equals sign").is_err());
        assert!(TomlDoc::parse("x = ").is_err());
        assert!(TomlDoc::parse("x = \"open").is_err());
        assert!(TomlDoc::parse("[]").is_err());
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = TomlDoc::parse("x = \"a#b\" # trailing").unwrap();
        assert_eq!(doc.require("", "x").unwrap().as_str().unwrap(), "a#b");
    }
}
