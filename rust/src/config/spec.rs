//! Typed run configuration: parses a TOML-subset file into the coordinator's
//! `Job` + `ExecOptions` (the config system behind `meltframe run`).
//!
//! ```toml
//! workers = 4
//! backend = "native"          # or "pjrt"
//! artifacts = "artifacts"     # pjrt only
//! halo_mode = "recompute"     # or "exchange" (fused halo strategy)
//! halo_wait_secs = 600        # exchange-wait watchdog deadline
//! tile_rows = 256             # native gather→kernel tile height
//! simd = "auto"               # auto | scalar | simd (results identical)
//!
//! [input]
//! kind = "volume"             # volume | image | mask | npy
//! dims = [48, 48, 48]
//! seed = 42
//! # path = "input.npy"        # kind = "npy"
//!
//! [[job]] is spelled [job.1], [job.2], ... (subset grammar has no arrays
//! of tables); stages run in order.
//! [job.1]
//! kind = "gaussian"
//! window = [3, 3, 3]
//! sigma = 1.0
//! ```

use std::path::PathBuf;

use crate::config::toml::TomlDoc;
use crate::coordinator::halo::HaloMode;
use crate::coordinator::job::{Backend, Job};
use crate::coordinator::pipeline::ExecOptions;
use crate::coordinator::plan::Plan;
use crate::error::{Error, Result};
use crate::tensor::dense::Tensor;

/// Fully resolved run configuration.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub options: ExecOptions,
    pub input: InputSpec,
    pub jobs: Vec<Job>,
    /// Execute through the fused lazy `Plan` (default) or the legacy
    /// stage-by-stage `run_pipeline` baseline (`fused = false`).
    pub fused: bool,
}

/// Where the input tensor comes from.
#[derive(Clone, Debug, PartialEq)]
pub enum InputSpec {
    SyntheticVolume { dims: Vec<usize>, seed: u64 },
    SyntheticImage { dims: [usize; 2], seed: u64 },
    SegmentationMask { dims: [usize; 2] },
    Npy { path: PathBuf },
}

impl InputSpec {
    /// Materialize the tensor.
    pub fn load(&self) -> Result<Tensor<f32>> {
        match self {
            InputSpec::SyntheticVolume { dims, seed } => {
                if dims.len() != 3 {
                    return Err(Error::Config(format!("volume dims must be 3-D: {dims:?}")));
                }
                Ok(Tensor::synthetic_volume(dims, *seed))
            }
            InputSpec::SyntheticImage { dims, seed } => Ok(Tensor::synthetic_image(dims, *seed)),
            InputSpec::SegmentationMask { dims } => Ok(Tensor::segmentation_mask(dims)),
            InputSpec::Npy { path } => crate::tensor::npy::load(path),
        }
    }
}

impl RunConfig {
    /// Parse a config document.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;

        let workers = doc
            .get("", "workers")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(1);
        let backend = match doc.get("", "backend").map(|v| v.as_str()).transpose()? {
            None | Some("native") => Backend::Native,
            Some("pjrt") => Backend::Pjrt,
            Some(other) => {
                return Err(Error::Config(format!(
                    "unknown backend '{other}' (native|pjrt)"
                )))
            }
        };
        let artifact_dir = doc
            .get("", "artifacts")
            .map(|v| v.as_str().map(PathBuf::from))
            .transpose()?;
        if backend == Backend::Pjrt && artifact_dir.is_none() {
            return Err(Error::Config("backend = \"pjrt\" requires artifacts = \"<dir>\"".into()));
        }

        let fused = doc
            .get("", "fused")
            .map(|v| v.as_bool())
            .transpose()?
            .unwrap_or(true);

        // halo_mode = "recompute" (default) | "exchange": how fused groups
        // handle cross-chunk halo rows (see the crate-level halo docs)
        let halo_mode = match doc.get("", "halo_mode").map(|v| v.as_str()).transpose()? {
            None => HaloMode::Recompute,
            Some(s) => HaloMode::parse(s)?,
        };
        // halo_wait_secs: watchdog deadline on any single exchange wait
        // before the run errors out (default 600 s)
        let halo_wait = match doc.get("", "halo_wait_secs").map(|v| v.as_usize()).transpose()? {
            None => crate::coordinator::halo::DEFAULT_WAIT_DEADLINE,
            Some(0) => {
                return Err(Error::Config("halo_wait_secs must be >= 1".into()));
            }
            Some(secs) => std::time::Duration::from_secs(secs as u64),
        };
        // tile_rows: native gather→kernel tile height (results invariant;
        // purely a cache-footprint knob). Zero would spin the tile loop.
        let tile_rows = match doc.get("", "tile_rows").map(|v| v.as_usize()).transpose()? {
            None => crate::coordinator::pipeline::DEFAULT_TILE_ROWS,
            Some(0) => {
                return Err(Error::Config("tile_rows must be >= 1".into()));
            }
            Some(n) => n,
        };
        // simd = "auto" (default) | "scalar" | "simd": SIMD lane policy of
        // the native kernels (results bit-for-bit invariant under all
        // three). When the key is absent the MELTFRAME_SIMD env var, if
        // set, supplies the process default.
        let simd = match doc.get("", "simd").map(|v| v.as_str()).transpose()? {
            None => crate::simd::SimdMode::env_default(),
            Some(s) => crate::simd::SimdMode::parse(s)?,
        };

        let input = Self::parse_input(&doc)?;
        let jobs = Self::parse_jobs(&doc)?;
        Ok(Self {
            options: ExecOptions {
                workers,
                backend,
                artifact_dir,
                chunk_policy: None,
                halo_mode,
                halo_wait,
                tile_rows,
                simd,
            },
            input,
            jobs,
            fused,
        })
    }

    /// Read + parse a config file.
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Lower the configured job list into a lazy [`Plan`] over `input`.
    pub fn plan<'a>(&self, input: &'a Tensor<f32>) -> Result<Plan<'a>> {
        let mut plan = Plan::over(input);
        for job in &self.jobs {
            plan = plan.stage(job.to_stage()?);
        }
        Ok(plan)
    }

    fn parse_input(doc: &TomlDoc) -> Result<InputSpec> {
        let kind = doc.require("input", "kind")?.as_str()?.to_string();
        let seed = doc
            .get("input", "seed")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(42) as u64;
        match kind.as_str() {
            "volume" => {
                let dims = doc.require("input", "dims")?.as_usize_vec()?;
                if dims.len() != 3 {
                    return Err(Error::Config(format!(
                        "volume dims must be 3-D (D, H, W): {dims:?}"
                    )));
                }
                Ok(InputSpec::SyntheticVolume { dims, seed })
            }
            "image" => {
                let dims = doc.require("input", "dims")?.as_usize_vec()?;
                if dims.len() != 2 {
                    return Err(Error::Config(format!("image dims must be 2-D: {dims:?}")));
                }
                Ok(InputSpec::SyntheticImage {
                    dims: [dims[0], dims[1]],
                    seed,
                })
            }
            "mask" => {
                let dims = doc.require("input", "dims")?.as_usize_vec()?;
                if dims.len() != 2 {
                    return Err(Error::Config(format!("mask dims must be 2-D: {dims:?}")));
                }
                Ok(InputSpec::SegmentationMask {
                    dims: [dims[0], dims[1]],
                })
            }
            "npy" => Ok(InputSpec::Npy {
                path: PathBuf::from(doc.require("input", "path")?.as_str()?),
            }),
            other => Err(Error::Config(format!(
                "unknown input kind '{other}' (volume|image|mask|npy)"
            ))),
        }
    }

    fn parse_jobs(doc: &TomlDoc) -> Result<Vec<Job>> {
        let mut stages: Vec<(usize, String)> = doc
            .sections()
            .filter_map(|s| {
                s.strip_prefix("job.")
                    .and_then(|n| n.parse::<usize>().ok())
                    .map(|n| (n, s.clone()))
            })
            .collect();
        if stages.is_empty() && doc.sections().any(|s| s == "job") {
            stages.push((1, "job".to_string()));
        }
        if stages.is_empty() {
            return Err(Error::Config("no [job] or [job.N] sections".into()));
        }
        stages.sort();
        stages
            .into_iter()
            .map(|(_, section)| Self::parse_job(doc, &section))
            .collect()
    }

    fn parse_job(doc: &TomlDoc, section: &str) -> Result<Job> {
        let kind = doc.require(section, "kind")?.as_str()?.to_string();
        let window = doc.require(section, "window")?.as_usize_vec()?;
        let getf = |key: &str| -> Result<f32> { doc.require(section, key)?.as_f32() };
        let job = match kind.as_str() {
            "gaussian" => Job::gaussian(&window, getf("sigma")?),
            "bilateral_const" => Job::bilateral_const(&window, getf("sigma_d")?, getf("sigma_r")?),
            "bilateral_adaptive" => {
                Job::bilateral_adaptive(&window, getf("sigma_d")?, getf("floor")?)
            }
            "curvature" => Job::curvature(&window),
            "median" => Job::median(&window),
            "quantile" => Job::quantile(&window, getf("q")? as f64),
            "minimum" => Job::rank_min(&window),
            "maximum" => Job::rank_max(&window),
            "local_mean" => Job::local_mean(&window),
            "local_std" => Job::local_std(&window),
            other => {
                return Err(Error::Config(format!(
                    "unknown job kind '{other}' (gaussian|bilateral_const|bilateral_adaptive|\
                     curvature|median|quantile|minimum|maximum|local_mean|local_std)"
                )))
            }
        };
        job.operator()?; // validate now, not at run time
        Ok(job)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::FilterKind;

    const SAMPLE: &str = r#"
        workers = 3
        backend = "native"
        [input]
        kind = "volume"
        dims = [16, 16, 16]
        seed = 7
        [job.1]
        kind = "gaussian"
        window = [3, 3, 3]
        sigma = 1.0
        [job.2]
        kind = "curvature"
        window = [3, 3, 3]
    "#;

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::parse(SAMPLE).unwrap();
        assert_eq!(cfg.options.workers, 3);
        assert_eq!(cfg.options.backend, Backend::Native);
        assert_eq!(cfg.jobs.len(), 2);
        assert!(matches!(cfg.jobs[0].kind, FilterKind::Gaussian { .. }));
        assert!(matches!(cfg.jobs[1].kind, FilterKind::Curvature));
        let x = cfg.input.load().unwrap();
        assert_eq!(x.shape(), &[16, 16, 16]);
    }

    #[test]
    fn parses_stats_jobs_and_fused_flag() {
        let cfg = RunConfig::parse(
            r#"
            workers = 2
            fused = false
            halo_mode = "Exchange"
            halo_wait_secs = 30
            tile_rows = 128
            simd = "scalar"
            [input]
            kind = "image"
            dims = [16, 16]
            [job.1]
            kind = "quantile"
            window = [3, 3]
            q = 0.5
            [job.2]
            kind = "local_std"
            window = [3, 3]
            "#,
        )
        .unwrap();
        assert!(!cfg.fused);
        // mixed-case spelling normalizes, and the watchdog deadline is read
        assert_eq!(cfg.options.halo_mode, HaloMode::Exchange);
        assert_eq!(cfg.options.halo_wait, std::time::Duration::from_secs(30));
        assert_eq!(cfg.options.tile_rows, 128);
        assert_eq!(cfg.options.simd, crate::simd::SimdMode::ForceScalar);
        assert!(matches!(cfg.jobs[0].kind, FilterKind::Rank(_)));
        assert!(matches!(cfg.jobs[1].kind, FilterKind::LocalMoment(_)));
        // the plan lowering records both stages lazily
        let x = cfg.input.load().unwrap();
        let plan = cfg.plan(&x).unwrap();
        assert_eq!(plan.len(), 2);
        // default is fused
        assert!(RunConfig::parse(
            "[input]\nkind = \"mask\"\ndims = [8, 8]\n[job]\nkind = \"median\"\nwindow = [3, 3]"
        )
        .unwrap()
        .fused);
    }

    #[test]
    fn single_job_section() {
        let cfg = RunConfig::parse(
            r#"
            [input]
            kind = "image"
            dims = [32, 32]
            [job]
            kind = "bilateral_const"
            window = [5, 5]
            sigma_d = 1.5
            sigma_r = 30.0
            "#,
        )
        .unwrap();
        assert_eq!(cfg.jobs.len(), 1);
        assert_eq!(cfg.options.workers, 1); // default
        assert_eq!(cfg.options.halo_mode, HaloMode::Recompute); // default
        assert_eq!(
            cfg.options.halo_wait,
            crate::coordinator::halo::DEFAULT_WAIT_DEADLINE
        );
        assert_eq!(
            cfg.options.tile_rows,
            crate::coordinator::pipeline::DEFAULT_TILE_ROWS
        );
    }

    #[test]
    fn stage_ordering_is_numeric() {
        let cfg = RunConfig::parse(
            r#"
            [input]
            kind = "mask"
            dims = [8, 8]
            [job.2]
            kind = "curvature"
            window = [3, 3]
            [job.1]
            kind = "gaussian"
            window = [3, 3]
            sigma = 0.8
            "#,
        )
        .unwrap();
        assert!(matches!(cfg.jobs[0].kind, FilterKind::Gaussian { .. }));
        assert!(matches!(cfg.jobs[1].kind, FilterKind::Curvature));
    }

    #[test]
    fn rejects_bad_configs() {
        // pjrt without artifacts dir
        assert!(RunConfig::parse(
            "backend = \"pjrt\"\n[input]\nkind = \"mask\"\ndims = [8, 8]\n[job]\nkind = \"curvature\"\nwindow = [3, 3]"
        )
        .is_err());
        // unknown kind
        assert!(RunConfig::parse(
            "[input]\nkind = \"mask\"\ndims = [8, 8]\n[job]\nkind = \"sobel\"\nwindow = [3, 3]"
        )
        .is_err());
        // missing jobs
        assert!(RunConfig::parse("[input]\nkind = \"mask\"\ndims = [8, 8]").is_err());
        // unknown halo mode
        assert!(RunConfig::parse(
            "halo_mode = \"telepathy\"\n[input]\nkind = \"mask\"\ndims = [8, 8]\n[job]\nkind = \"median\"\nwindow = [3, 3]"
        )
        .is_err());
        // zero watchdog deadline would disable the hang backstop
        assert!(RunConfig::parse(
            "halo_wait_secs = 0\n[input]\nkind = \"mask\"\ndims = [8, 8]\n[job]\nkind = \"median\"\nwindow = [3, 3]"
        )
        .is_err());
        // zero tile height would spin the tile loop
        assert!(RunConfig::parse(
            "tile_rows = 0\n[input]\nkind = \"mask\"\ndims = [8, 8]\n[job]\nkind = \"median\"\nwindow = [3, 3]"
        )
        .is_err());
        // unknown simd policy rejected at parse time
        assert!(RunConfig::parse(
            "simd = \"warp\"\n[input]\nkind = \"mask\"\ndims = [8, 8]\n[job]\nkind = \"median\"\nwindow = [3, 3]"
        )
        .is_err());
        // even window caught at parse time
        assert!(RunConfig::parse(
            "[input]\nkind = \"mask\"\ndims = [8, 8]\n[job]\nkind = \"curvature\"\nwindow = [4, 4]"
        )
        .is_err());
        // non-3-D volume dims caught at parse time too
        for dims in ["[8, 8]", "[8]", "[8, 8, 8, 8]"] {
            assert!(
                RunConfig::parse(&format!(
                    "[input]\nkind = \"volume\"\ndims = {dims}\n[job]\nkind = \"curvature\"\nwindow = [3, 3]"
                ))
                .is_err(),
                "volume dims {dims} must be rejected"
            );
        }
        // a directly constructed spec still validates at load
        assert!(InputSpec::SyntheticVolume { dims: vec![8, 8], seed: 1 }
            .load()
            .is_err());
    }
}
