//! # meltframe
//!
//! Reproduction of *"Mathematical Computation on High-dimensional Data via
//! Array Programming and Parallel Acceleration"* (Chen Zhang, 2025) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The paper's central object is the **melt matrix**: a rank-2, row-decoupled
//! intermediate derived from an arbitrary-rank dense tensor. Row `i` holds the
//! raveled neighbourhood of output grid point `i`, so every
//! neighbourhood-driven computation (global filtering, bilateral filtering,
//! differential geometry, local statistics) becomes a broadcast over rows —
//! and because rows are computationally independent, the melt matrix can be
//! partitioned row-wise across parallel workers and re-aggregated exactly
//! (paper §2.4, §3.1).
//!
//! The execution API is the lazy **[`Plan`](coordinator::Plan)**: a fluent
//! builder records a stage graph over one input tensor, a planner fuses
//! consecutive compatible stages, and the executor streams each row chunk
//! through *all* fused stages while it is resident in a worker — one global
//! melt and one global fold per fused group, instead of a fold→re-melt
//! barrier per stage. The kernel surface is the open, object-safe
//! [`RowKernel`](coordinator::RowKernel) trait (gaussian, bilateral,
//! curvature, rank statistics, local moments are built in; user kernels
//! plug into the same machinery), and backend selection (native Rust vs
//! AOT-compiled Pallas via PJRT) lives behind it, so plans are
//! backend-agnostic.
//!
//! ## Layer map
//!
//! - [`tensor`] — dense N-D tensor substrate (shapes, strides, ops, `.npy`
//!   and PGM/PPM interchange, synthetic workload generators).
//! - [`melt`] — the paper's contribution: quasi-grid calculus, melt/fold,
//!   band re-melt for chunk-resident pipelines, row partitioning with the
//!   §2.4 validity conditions.
//! - [`kernels`] — native compute cores on melt matrices: gaussian,
//!   bilateral (eq. 3), gaussian curvature (eq. 6/7), rank filters, and the
//!   three execution paradigms of Fig 7.
//! - [`stats`] — mathematical-statistics substrate: small dense linear
//!   algebra, the multivariate gaussian of Table 2, partition-aggregable
//!   descriptive statistics, rank statistics under partitioning — reachable
//!   from the coordinator as plan stages.
//! - [`coordinator`] — L3: the lazy `Plan` (builder → planner → fused
//!   chunk-resident executor), the open `RowKernel` trait, chunk policies,
//!   worker pool scheduling, aggregation, metrics; `Job`/`run_pipeline`
//!   remain as spec-level shims and the unfused baseline.
//! - [`runtime`] — PJRT: loads the AOT artifacts (`artifacts/*.hlo.txt`
//!   lowered from the L1 Pallas kernels by `python/compile/aot.py`),
//!   compiles them once, and executes them from the hot path. Compiles
//!   against a graceful stub when the `xla` bindings are not vendored.
//! - [`serve`] — serving subsystem: a persistent daemon (long-lived worker
//!   pool, LRU plan cache, bounded job queue, Unix-socket line protocol)
//!   behind `meltframe serve` / `meltframe submit`.
//! - [`config`] / [`cli`] — run configuration (TOML subset + JSON manifest
//!   parsing) and the command-line front end.
//! - [`bench_harness`] — measurement harness used by `cargo bench`
//!   (criterion substitute; see DESIGN.md §Substitutions).
//! - [`testing`] — deterministic PRNG + property-test helpers.
//!
//! ## Quickstart
//!
//! ```
//! use meltframe::prelude::*;
//!
//! // a synthetic noisy 3-D volume
//! let vol = Tensor::<f32>::synthetic_volume(&[16, 16, 16], 42);
//!
//! // record a lazy three-stage pipeline — nothing executes yet, and the
//! // final stage is a stats-layer reduction (per-row median)
//! let plan = Plan::over(&vol)
//!     .gaussian(&[3, 3, 3], 1.0)
//!     .curvature(&[3, 3, 3])
//!     .median(&[3, 3, 3]);
//!
//! // the planner fuses all three stages: one melt, one fold, chunks
//! // streamed worker-resident through every stage
//! let (out, metrics) = plan.run(&ExecOptions::native(2)).unwrap();
//! assert_eq!(out.shape(), vol.shape());
//! assert_eq!(metrics.melts(), 1);
//! assert_eq!(metrics.folds(), 1);
//! assert_eq!(metrics.stages(), 3);
//! ```
//!
//! ## Volumes
//!
//! Everything is rank-general — chunks, halos and the exchange board live
//! in flat melt-row space — and volumes are first-class:
//! [`Plan::over_volume`](coordinator::Plan::over_volume) validates the
//! `(D, H, W)` shape up front,
//! [`Plan::gaussian_separable`](coordinator::Plan::gaussian_separable)
//! records the axis-factored gaussian chain (`[3,1,1]·[1,3,1]·[1,1,3]`,
//! `Σw` instead of `Πw` multiplies per voxel, fused into ONE melt/fold),
//! and [`ChunkPolicy::Aligned`](coordinator::ChunkPolicy) cuts chunks on
//! whole z-slab boundaries so every traded halo is a stack of complete
//! `(z, y)` lines. The 3-D halo rule — a window of radii `(r_z, r_y,
//! r_x)` reaches `r_z·H·W + r_y·W + r_x` flat rows, clamped per axis —
//! lives in the [`coordinator`] docs.
//!
//! ```
//! use meltframe::prelude::*;
//!
//! let vol = Tensor::<f32>::synthetic_volume(&[12, 12, 12], 9);
//! let plan = Plan::over_volume(&vol)
//!     .median(&[3, 3, 3])                  // 3-D rank filter
//!     .gaussian_separable(&[3, 3, 3], 1.0); // three fused axis passes
//! let (out, metrics) = plan.run(&ExecOptions::native(2)).unwrap();
//! assert_eq!(out.shape(), vol.shape());
//! assert_eq!(metrics.melts(), 1); // median + 3 axis passes, one melt
//! assert_eq!(metrics.stages(), 4);
//! ```
//!
//! The melt/fold layer remains directly usable for one-off computations:
//!
//! ```
//! use meltframe::prelude::*;
//!
//! let vol = Tensor::<f32>::synthetic_volume(&[8, 8, 8], 7);
//! let op = Operator::cubic(3, 3).unwrap();
//! let m = melt(&vol, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
//! let k = gaussian_kernel(op.window(), 1.0);
//! let out = fold(&apply_kernel_broadcast(&m, &k), m.grid_shape()).unwrap();
//! assert_eq!(out.shape(), vol.shape());
//! ```
//!
//! ## Halo accounting
//!
//! Inside a fused group, stage `k + 1`'s gathers reach at most
//! `flat_halo(op_{k+1})` flat rows beyond each chunk — rows that belong to
//! neighbouring chunks. [`ExecOptions::halo_mode`](coordinator::ExecOptions)
//! selects how they are obtained:
//!
//! * [`HaloMode::Recompute`](coordinator::HaloMode) (default) — each chunk
//!   runs every stage over itself *extended by the downstream halo budget*
//!   `B_k = Σ_{j>k} flat_halo(op_j)`, so all gathers resolve locally. No
//!   synchronization, any chunk count (full work stealing), but the
//!   overlap rows are computed by more than one worker — duplicated kernel
//!   work that grows with worker count and stage depth.
//! * [`HaloMode::Exchange`](coordinator::HaloMode) — each chunk computes
//!   only its interior; boundary rows travel between neighbours over a
//!   cross-chunk halo board (`coordinator::halo`). Work is dispatched as
//!   `(chunk, stage)` tasks by a dependency-aware scheduler
//!   (`coordinator::scheduler::StageScheduler`): a stage starts only once
//!   every chunk its gathers reach has published the previous stage, so
//!   workers never block on the hot path, chunks migrate between workers
//!   across stages, and **any chunk count is live** — exchange
//!   over-partitions for load balancing exactly like recompute. Each
//!   stage computes its two boundary segments *first* and publishes them
//!   before the interior, handing neighbours a measured head start. Zero
//!   duplicated kernel work.
//!
//! Both modes are bit-for-bit identical to each other and to the legacy
//! per-stage pipeline. [`RunMetrics`](coordinator::RunMetrics) accounts
//! for the traffic per group — `halo_published_rows`, `halo_received_rows`,
//! `halo_recomputed_rows` (exactly 0 in exchange mode), the eager-publish
//! head start `halo_eager_lead` and the scheduler's `sched_stalls` — and
//! [`PlanMetrics`](coordinator::PlanMetrics) totals them per plan. The
//! knobs are also exposed as `halo_mode = "recompute" | "exchange"` and
//! `halo_wait_secs` (the exchange watchdog deadline) in run configs, and
//! `--halo-mode` / `--halo-wait-secs` on `meltframe run`.
//!
//! ## Memory traffic
//!
//! A materialized melt matrix is a window-size× blow-up of the input —
//! `rows · cols · 4` bytes, 9× for a 3×3 window, 27× for 3×3×3 — and
//! building it serially on the leader Amdahl-caps every scaling figure.
//! The native executor therefore never materializes it: the leader
//! precomputes one [`RowGather`](melt::melt::RowGather) per stage (cheap
//! per-axis boundary tables), and each worker gathers its own rows
//! straight from the shared input tensor in cache-sized tiles of
//! [`ExecOptions::tile_rows`](coordinator::ExecOptions) rows (default
//! 256), running the stage kernel over each tile while it is hot. Peak
//! gather scratch:
//!
//! ```text
//! materialized:   rows · cols · 4 bytes          (global, leader-built)
//! tile-streamed:  workers · tile_rows · cols · 4 (per-worker band, reused)
//! ```
//!
//! For a 256³ volume under a 3×3×3 window that is ~1.8 GB materialized vs
//! ~27 KB per worker tiled. `tile_rows` is purely a performance knob —
//! outputs are bit-for-bit invariant under it (kernels are
//! row-independent, §2.4) — settable per run (`tile_rows` in configs,
//! `--tile-rows` on the CLI). [`RunMetrics`](coordinator::RunMetrics)
//! meters the traffic: `gather_rows` (tile-gathered melt rows),
//! `peak_band_bytes` (largest per-worker band), `gather` (time inside
//! gathers, now part of the parallel compute window) and
//! `melt_matrix_bytes` — exactly 0 on every native run, which the test
//! suite asserts. The PJRT backend still materializes melt blocks (its
//! AOT artifacts have fixed shapes) and reports the bytes honestly;
//! one-off materialization remains available via [`melt`](melt::melt::melt)
//! and row-range gathers via
//! [`melt_rows_into`](melt::melt::melt_rows_into), which supports every
//! boundary mode including `Wrap` because the whole tensor is readable.
//!
//! ```
//! use meltframe::prelude::*;
//!
//! let vol = Tensor::<f32>::synthetic_volume(&[12, 12, 12], 3);
//! let plan = Plan::over(&vol).gaussian(&[3, 3, 3], 1.0).median(&[3, 3, 3]);
//! let opts = ExecOptions::native(2).with_halo_mode(HaloMode::Exchange);
//! let (out, metrics) = plan.run(&opts).unwrap();
//! assert_eq!(out.shape(), vol.shape());
//! assert_eq!(metrics.halo_recomputed(), 0); // nothing computed twice
//! assert!(metrics.halo_published() > 0);    // boundary rows were traded
//! ```
//!
//! ## Per-core performance
//!
//! With memory traffic tiled away, the remaining lever is instruction
//! throughput inside each worker, and the [`simd`] module pulls it
//! without giving up exactness. The vectorization model is **lane =
//! output element**: kernels walk `block.chunks_exact(cols)` in groups
//! of [`simd::LANES`] output rows, and each lane runs the *identical
//! scalar operation order* over its own window — reductions are never
//! reassociated within a lane, no fused multiply-add is ever issued
//! (it rounds once where `a * b + c` rounds twice), and rank min/max
//! lanes call `f32::min`/`f32::max` rather than the subtly-different
//! hardware min/max instructions. IEEE-754 arithmetic is deterministic
//! per lane, so **the lane path is bit-for-bit equal to the scalar
//! path** for every kernel × boundary × grid — the same invariant the
//! halo modes and the serving batcher already pin, now extended one
//! layer down to instruction selection
//! (`tests/integration_simd.rs` proves it shape-by-shape).
//!
//! Dispatch is resolved at **runtime**, not compile time: the portable
//! `[f32; LANES]` primitives are written so stable rustc autovectorizes
//! them on every target (NEON on aarch64), and the hottest primitive —
//! the strip-accumulated row dot behind gaussian/convolve — additionally
//! carries a hand-scheduled AVX2 body selected once per process via
//! `is_x86_feature_detected!`. Zero new dependencies; the scalar path is
//! always compiled and stays the reference.
//!
//! The knob is [`ExecOptions::simd`](coordinator::ExecOptions)
//! (`simd = "auto" | "scalar" | "simd"` in run configs, `--no-simd` on
//! `meltframe run`/`serve`, `MELTFRAME_SIMD` as the process default —
//! the CI matrix forces both extremes through the full suite), and
//! [`RunMetrics`](coordinator::RunMetrics) meters the split per run:
//! `simd_rows` (output rows computed by a lane path), `scalar_rows`
//! (rows computed by a scalar path — remainder rows, rank
//! median/quantile, forced-scalar runs) and `simd_lanes` (the lane
//! width in use, 0 if no lane path ran), totalled per plan by
//! [`PlanMetrics`](coordinator::PlanMetrics).
//!
//! The footprint model above covers one run. A serving executor adds one
//! term: cache-resident plan memory. Each cached plan holds its group's
//! `RowGather` tables — per-axis index tables plus interior masks, about
//! `Σ_axes (extent · window · 8 + extent · window)` bytes per stage,
//! reported exactly by `RowGather::table_bytes` and totalled in
//! [`CacheStats::resident_bytes`](serve::CacheStats) — bounded by the
//! cache capacity (default 32 entries, LRU-evicted).
//!
//! ## Serving
//!
//! The [`serve`] subsystem amortizes those fixed costs across requests.
//! `meltframe serve` starts a daemon: `--executors N` persistent
//! [`Executor`](serve::Executor) shards (each owning its slice of the
//! worker budget, its own LRU [`PlanCache`](serve::PlanCache), and one
//! dispatcher thread), fronted by a bounded job queue (admission
//! control: a full queue rejects immediately rather than buffering
//! unboundedly) with per-client round-robin **fairness lanes** — a
//! request's optional `"client"` tag picks its lane; untagged requests
//! share a per-connection lane — and a line-delimited JSON protocol over
//! a Unix-domain socket (request lines are capped at 16 MiB; oversized
//! lines are answered with an error). `meltframe submit` is the
//! matching client.
//!
//! **Cross-request batching.** A dispatcher that pops a job sweeps the
//! queue (lingering up to `--batch-window-ms`, `0` = off) for up to
//! `--max-batch − 1` mates sharing its *batch key* — input shape, full
//! op-chain including kernel parameters, grid, boundary, halo mode,
//! tile height; stricter than the plan-cache key because co-batched
//! jobs share one kernel instance. The batch runs as one stacked fold:
//! inputs concatenated along a leading batch axis whose unit window
//! extent guarantees zero cross-member halo under every boundary mode,
//! one plan lookup, one melt, one fold, outputs split per request —
//! each bit-for-bit identical to its standalone run. A batch that
//! errors or panics falls back to singletons so a faulting member fails
//! alone. Each response's `batched_jobs` metric carries its group size,
//! and `{"op": "stats"}` reports a `batching` block (`window_ms`,
//! `max_batch`, `batches`, `batched_jobs`) plus a per-shard `executors`
//! array (`workers`, `jobs`, `batches`, `batched_jobs`).
//!
//! **Cache key contract.** Plans are pure functions of
//! `(input shape, per-stage kernel-name/window/grid/boundary, halo_mode,
//! tile_rows)` — melt geometry never depends on data values (§2.4), so
//! serving results are bit-for-bit identical to one-shot runs and repeat
//! submissions build zero new `RowGather` tables (`RunMetrics` reports
//! `plan_cache_hits` / `plan_cache_misses` / `plan_cache_evictions` /
//! `gathers_built` per run). Kernel *parameters* (σ, q) are deliberately
//! not in the key; changing any keyed field is cache-busting and misses.
//!
//! **Fault isolation.** A job that panics or errors mid-kernel (e.g. the
//! fault-injection layer's detonating kernels) fails only its own
//! request: pool threads catch the unwind, the run lock recovers from
//! poisoning, and the cache holds only data-independent tables — later
//! jobs on the same daemon are unaffected.
//!
//! ```
//! use meltframe::prelude::*;
//! use meltframe::serve::Executor;
//!
//! let img = Tensor::<f32>::synthetic_image(&[32, 32], 5);
//! let exec = Executor::persistent(ExecOptions::native(2), 16);
//! let pipeline = |x: &Tensor<f32>| Plan::over(x).gaussian(&[3, 3], 1.0).median(&[3, 3]);
//! let (first, m1) = exec.run(pipeline(&img)).unwrap();
//! let (second, m2) = exec.run(pipeline(&img)).unwrap();
//! assert_eq!(first.data(), second.data());   // bit-for-bit
//! assert_eq!(m1.plan_cache_misses(), 1);     // first build
//! assert_eq!(m2.plan_cache_hits(), 1);       // served from cache
//! assert_eq!(m2.gathers_built(), 0);         // no new tables
//! ```
//!
//! ## Correctness & analysis
//!
//! The concurrency surface — [`coordinator::halo::HaloBoard`],
//! [`coordinator::scheduler::StageScheduler`],
//! [`serve::WorkerPool`], [`serve::JobQueue`], the daemon's dispatcher
//! hand-off — is hand-rolled Mutex/Condvar protocol code, and it is
//! machine-checked rather than only hand-audited:
//!
//! * **Deterministic model checking.** Every concurrency module imports
//!   its primitives from the [`sync`] facade. Default builds get pure
//!   `std::sync` re-exports (zero overhead); `cargo test --features
//!   model --test model_concurrency` swaps in a cooperative
//!   deterministic-interleaving scheduler (`sync::model`, a
//!   "shuttle-lite") that drives each protocol through hundreds to
//!   thousands of seeded-random and bounded-exhaustive schedules,
//!   detecting deadlocks, lost wakeups, livelocks and cross-schedule
//!   invariant violations. Failing schedules are reproducible from the
//!   seed or DFS prefix embedded in the failure message.
//! * **Lock-order discipline (lockdep).** The facade's third
//!   personality: `--features lockdep` wraps `std::sync` in
//!   order-checked types. Every lock carries a static class
//!   (`Mutex::new_named`), the runtime maintains per-thread held-class
//!   stacks plus a global class-order graph, and the first *possible*
//!   ordering cycle panics with both acquisition sites — no deadlock
//!   required. Condvar waits while doubly-locked and guards leaked
//!   across `WorkerPool` job boundaries (`sync::checkpoint`) are
//!   flagged too. The documented global order lives in the [`sync`]
//!   module docs; `cargo test --features lockdep` runs the full suite
//!   plus the seeded-inversion tests in `tests/lockdep_discipline.rs`.
//! * **Miri.** The `unsafe`-bearing modules (`melt` gather buffers,
//!   `serve::pool`'s scoped-task transmute, `bench_harness`) run under
//!   Miri in CI: `cargo +nightly miri test -p meltframe <filters>`.
//! * **ThreadSanitizer.** The concurrency integration tests run under
//!   `-Zsanitizer=thread` on nightly (see `.github/workflows/ci.yml`).
//! * **Unsafe-audit lint gate.** `python3 scripts/lint_unsafe.py` (a
//!   hard CI step) enforces: every `unsafe` block is annotated with a
//!   `// SAFETY:` comment, concurrency modules never import
//!   `std::sync::{Mutex, Condvar}` directly (which would hide them from
//!   the model checker), and `serve/` + `coordinator/` request paths
//!   contain no `unwrap()`/`expect()` outside tests and an explicit,
//!   staleness-checked allowlist. The compiler enforces
//!   `unsafe_op_in_unsafe_fn` and clippy's
//!   `undocumented_unsafe_blocks`, `mutex_atomic` and `redundant_clone`
//!   at deny level (see `Cargo.toml` `[lints]`).
//! * **Static lock lint.** `python3 scripts/lint_locks.py` (hard CI
//!   step, self-tested against known-bad fixtures first) forbids
//!   anonymous facade locks, checks every class name against its
//!   committed registry (including gate-vs-plain constructor kind) and
//!   fails on cycles in the textually-extracted static lock-order
//!   graph — a zero-toolchain floor under the runtime lockdep checker.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod kernels;
pub mod melt;
pub mod runtime;
pub mod serve;
pub mod simd;
pub mod stats;
pub mod sync;
pub mod tensor;
pub mod testing;

pub mod prelude {
    //! Convenience re-exports of the public API surface.
    pub use crate::coordinator::{
        run_job, run_pipeline, Backend, ExecOptions, FilterKind, HaloMode, Job, MomentStat,
        Plan, PlanMetrics, RowKernel, RunMetrics, Stage,
    };
    pub use crate::error::{Error, Result};
    pub use crate::kernels::bilateral::{bilateral_adaptive, bilateral_const, BilateralParams};
    pub use crate::kernels::curvature::gaussian_curvature;
    pub use crate::kernels::gaussian::{gaussian_kernel, spatial_gaussian};
    pub use crate::kernels::paradigm::{
        apply_kernel_broadcast, apply_kernel_elementwise, apply_kernel_vectorwise, Paradigm,
    };
    pub use crate::kernels::rankfilter::RankKind;
    pub use crate::melt::fold::fold;
    pub use crate::melt::grid::{GridMode, QuasiGrid};
    pub use crate::melt::matrix::MeltMatrix;
    pub use crate::melt::melt::{melt, melt_band_into, melt_rows_into, BoundaryMode, RowGather};
    pub use crate::melt::operator::Operator;
    pub use crate::melt::partition::RowPartition;
    pub use crate::simd::SimdMode;
    pub use crate::tensor::dense::Tensor;
}
