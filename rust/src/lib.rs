//! # meltframe
//!
//! Reproduction of *"Mathematical Computation on High-dimensional Data via
//! Array Programming and Parallel Acceleration"* (Chen Zhang, 2025) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The paper's central object is the **melt matrix**: a rank-2, row-decoupled
//! intermediate derived from an arbitrary-rank dense tensor. Row `i` holds the
//! raveled neighbourhood of output grid point `i`, so every
//! neighbourhood-driven computation (global filtering, bilateral filtering,
//! differential geometry, local statistics) becomes a broadcast over rows —
//! and because rows are computationally independent, the melt matrix can be
//! partitioned row-wise across parallel workers and re-aggregated exactly
//! (paper §2.4, §3.1).
//!
//! ## Layer map
//!
//! - [`tensor`] — dense N-D tensor substrate (shapes, strides, ops, `.npy`
//!   and PGM/PPM interchange, synthetic workload generators).
//! - [`melt`] — the paper's contribution: quasi-grid calculus, melt/fold,
//!   row partitioning with the §2.4 validity conditions.
//! - [`kernels`] — native compute on melt matrices: gaussian, bilateral
//!   (eq. 3), gaussian curvature (eq. 6/7), and the three execution
//!   paradigms of Fig 7.
//! - [`stats`] — mathematical-statistics substrate: small dense linear
//!   algebra, the multivariate gaussian of Table 2, partition-aggregable
//!   descriptive statistics, rank statistics under partitioning.
//! - [`coordinator`] — L3: chunk planning, worker pool scheduling,
//!   aggregation, metrics, multi-stage pipelines.
//! - [`runtime`] — PJRT: loads the AOT artifacts (`artifacts/*.hlo.txt`
//!   lowered from the L1 Pallas kernels by `python/compile/aot.py`),
//!   compiles them once, and executes them from the hot path.
//! - [`config`] / [`cli`] — run configuration (TOML subset + JSON manifest
//!   parsing) and the command-line front end.
//! - [`bench_harness`] — measurement harness used by `cargo bench`
//!   (criterion substitute; see DESIGN.md §Substitutions).
//! - [`testing`] — deterministic PRNG + property-test helpers.
//!
//! ## Quickstart
//!
//! ```no_run
//! use meltframe::prelude::*;
//!
//! // a synthetic noisy 3-D volume
//! let vol = Tensor::<f32>::synthetic_volume(&[32, 32, 32], 42);
//! // melt with a 3^3 operator, same-size grid, reflect boundary
//! let op = Operator::cubic(3, 3).unwrap();
//! let m = melt(&vol, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
//! // gaussian broadcast over rows, folded back to the grid tensor
//! let k = gaussian_kernel(op.window(), 1.0);
//! let out = fold(&apply_kernel_broadcast(&m, &k), m.grid_shape()).unwrap();
//! assert_eq!(out.shape(), vol.shape());
//! ```

pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod kernels;
pub mod melt;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod testing;

pub mod prelude {
    //! Convenience re-exports of the public API surface.
    pub use crate::error::{Error, Result};
    pub use crate::kernels::bilateral::{bilateral_adaptive, bilateral_const, BilateralParams};
    pub use crate::kernels::curvature::gaussian_curvature;
    pub use crate::kernels::gaussian::{gaussian_kernel, spatial_gaussian};
    pub use crate::kernels::paradigm::{
        apply_kernel_broadcast, apply_kernel_elementwise, apply_kernel_vectorwise, Paradigm,
    };
    pub use crate::melt::fold::fold;
    pub use crate::melt::grid::{GridMode, QuasiGrid};
    pub use crate::melt::matrix::MeltMatrix;
    pub use crate::melt::melt::{melt, BoundaryMode};
    pub use crate::melt::operator::Operator;
    pub use crate::melt::partition::RowPartition;
    pub use crate::tensor::dense::Tensor;
}
