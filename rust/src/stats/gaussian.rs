//! The Hilbert-space-generalized gaussian of paper Table 2.
//!
//! The multivariate N(x|μ, Σ) with its gradient is the generic form; the
//! univariate/bivariate densities are degenerated cases. Table 2's claim —
//! that the k=1 multivariate formulas reduce exactly to the familiar
//! univariate ones — is validated in the tests and timed by
//! `benches/table2_gaussian.rs`.

use crate::error::{Error, Result};
use crate::stats::linalg::Mat;

/// A multivariate gaussian N(μ, Σ) with precomputed Σ⁻¹ and |Σ|.
#[derive(Clone, Debug)]
pub struct MultivariateGaussian {
    mu: Vec<f64>,
    sigma_inv: Mat,
    norm: f64, // 1 / ((2π)^{k/2} |Σ|^{1/2})
}

impl MultivariateGaussian {
    /// Construct from mean and covariance; Σ must be SPD.
    pub fn new(mu: Vec<f64>, sigma: Mat) -> Result<Self> {
        let k = mu.len();
        if sigma.rows() != k || sigma.cols() != k {
            return Err(Error::Linalg(format!(
                "covariance {}x{} vs mean dim {k}",
                sigma.rows(),
                sigma.cols()
            )));
        }
        // SPD check via cholesky; |Σ| from the factor's diagonal
        let l = sigma.cholesky().map_err(|e| {
            Error::Linalg(format!("covariance must be SPD: {e}"))
        })?;
        let log_det: f64 = (0..k).map(|i| l.at(i, i).ln()).sum::<f64>() * 2.0;
        let sigma_inv = sigma.inverse()?;
        let norm = (-0.5 * (k as f64 * (2.0 * std::f64::consts::PI).ln() + log_det)).exp();
        Ok(Self {
            mu,
            sigma_inv,
            norm,
        })
    }

    /// Convenience: isotropic N(μ, σ²I).
    pub fn isotropic(mu: Vec<f64>, sigma: f64) -> Result<Self> {
        if sigma <= 0.0 {
            return Err(Error::Linalg(format!("sigma must be positive, got {sigma}")));
        }
        let k = mu.len();
        Self::new(mu, Mat::diag(&vec![sigma * sigma; k]))
    }

    pub fn dim(&self) -> usize {
        self.mu.len()
    }

    /// Density p(x) — Table 2 row 1, multivariate column.
    pub fn pdf(&self, x: &[f64]) -> Result<f64> {
        let d = self.centered(x)?;
        let q = self.sigma_inv.quad_form(&d)?;
        Ok(self.norm * (-0.5 * q).exp())
    }

    /// Gradient ∂p/∂x — Table 2 row 2, multivariate column:
    /// -Σ⁻¹(x-μ) · p(x).
    pub fn grad(&self, x: &[f64]) -> Result<Vec<f64>> {
        let d = self.centered(x)?;
        let p = self.pdf(x)?;
        let siv = self.sigma_inv.matvec(&d)?;
        Ok(siv.iter().map(|v| -v * p).collect())
    }

    fn centered(&self, x: &[f64]) -> Result<Vec<f64>> {
        if x.len() != self.mu.len() {
            return Err(Error::Linalg(format!(
                "x dim {} vs gaussian dim {}",
                x.len(),
                self.mu.len()
            )));
        }
        Ok(x.iter().zip(&self.mu).map(|(a, b)| a - b).collect())
    }
}

/// Closed-form univariate density — Table 2 row 1, univariate column.
/// Kept as the independent comparator for the degeneration tests/bench.
pub fn univariate_pdf(x: f64, mu: f64, sigma: f64) -> f64 {
    let z = (x - mu) / sigma;
    (-0.5 * z * z).exp() / ((2.0 * std::f64::consts::PI).sqrt() * sigma)
}

/// Closed-form univariate gradient — Table 2 row 2, univariate column.
pub fn univariate_grad(x: f64, mu: f64, sigma: f64) -> f64 {
    -(x - mu) / (sigma * sigma) * univariate_pdf(x, mu, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn univariate_degeneration_pdf() {
        // Table 2: the k=1 multivariate reduces exactly to the univariate.
        check_property("k=1 multivariate == univariate pdf", 40, |rng: &mut SplitMix64| {
            let mu = rng.normal() as f64 * 3.0;
            let sigma = 0.2 + rng.next_f64() * 4.0;
            let x = rng.normal() as f64 * 5.0;
            let g = MultivariateGaussian::isotropic(vec![mu], sigma).unwrap();
            let a = g.pdf(&[x]).unwrap();
            let b = univariate_pdf(x, mu, sigma);
            assert!((a - b).abs() < 1e-12 * (1.0 + b), "{a} vs {b}");
        });
    }

    #[test]
    fn univariate_degeneration_grad() {
        check_property("k=1 multivariate == univariate grad", 40, |rng: &mut SplitMix64| {
            let mu = rng.normal() as f64;
            let sigma = 0.2 + rng.next_f64() * 2.0;
            let x = rng.normal() as f64 * 3.0;
            let g = MultivariateGaussian::isotropic(vec![mu], sigma).unwrap();
            let a = g.grad(&[x]).unwrap()[0];
            let b = univariate_grad(x, mu, sigma);
            assert!((a - b).abs() < 1e-12 * (1.0 + b.abs()), "{a} vs {b}");
        });
    }

    #[test]
    fn pdf_integrates_to_one_1d() {
        // trapezoid over [-10σ, 10σ]
        let g = MultivariateGaussian::isotropic(vec![1.5], 0.7).unwrap();
        let n = 4000;
        let (lo, hi) = (1.5 - 7.0, 1.5 + 7.0);
        let h = (hi - lo) / n as f64;
        let mut s = 0.0;
        for i in 0..=n {
            let x = lo + i as f64 * h;
            let w = if i == 0 || i == n { 0.5 } else { 1.0 };
            s += w * g.pdf(&[x]).unwrap();
        }
        assert!((s * h - 1.0).abs() < 1e-6);
    }

    #[test]
    fn pdf_peak_at_mean_and_symmetry() {
        let g = MultivariateGaussian::isotropic(vec![0.0, 0.0], 1.0).unwrap();
        let p0 = g.pdf(&[0.0, 0.0]).unwrap();
        assert!((p0 - 1.0 / (2.0 * std::f64::consts::PI)).abs() < 1e-12);
        let pa = g.pdf(&[1.0, 0.5]).unwrap();
        let pb = g.pdf(&[-1.0, -0.5]).unwrap();
        assert!((pa - pb).abs() < 1e-15);
        assert!(pa < p0);
    }

    #[test]
    fn gradient_matches_finite_difference_property() {
        check_property("grad == finite difference", 25, |rng: &mut SplitMix64| {
            let k = 1 + rng.below(4);
            let mu: Vec<f64> = (0..k).map(|_| rng.normal() as f64).collect();
            // random SPD covariance
            let mut a = Mat::zeros(k, k);
            for r in 0..k {
                for c in 0..k {
                    a.set(r, c, rng.normal() as f64);
                }
            }
            let mut sigma = a.matmul(&a.transpose()).unwrap();
            for i in 0..k {
                sigma.set(i, i, sigma.at(i, i) + 1.0);
            }
            let g = MultivariateGaussian::new(mu, sigma).unwrap();
            let x: Vec<f64> = (0..k).map(|_| rng.normal() as f64).collect();
            let grad = g.grad(&x).unwrap();
            let h = 1e-6;
            for a_ in 0..k {
                let mut xp = x.clone();
                let mut xm = x.clone();
                xp[a_] += h;
                xm[a_] -= h;
                let fd = (g.pdf(&xp).unwrap() - g.pdf(&xm).unwrap()) / (2.0 * h);
                assert!(
                    (grad[a_] - fd).abs() < 1e-6 * (1.0 + fd.abs()),
                    "axis {a_}: {} vs {fd}",
                    grad[a_]
                );
            }
        });
    }

    #[test]
    fn anisotropic_contours() {
        // larger variance on axis 0 -> slower decay along axis 0
        let g = MultivariateGaussian::new(vec![0.0, 0.0], Mat::diag(&[4.0, 0.25])).unwrap();
        assert!(g.pdf(&[1.0, 0.0]).unwrap() > g.pdf(&[0.0, 1.0]).unwrap());
    }

    #[test]
    fn rejects_invalid_inputs() {
        assert!(MultivariateGaussian::isotropic(vec![0.0], 0.0).is_err());
        assert!(MultivariateGaussian::new(
            vec![0.0, 0.0],
            Mat::new(2, 2, vec![1.0, 2.0, 2.0, 1.0]).unwrap() // not SPD
        )
        .is_err());
        let g = MultivariateGaussian::isotropic(vec![0.0, 0.0], 1.0).unwrap();
        assert!(g.pdf(&[0.0]).is_err());
    }
}
