//! Mathematical-statistics substrate.
//!
//! The paper distinguishes *mathematical* statistics (serving downstream
//! analysis) from the descriptive statistics business toolchains optimize
//! for (§1, abstract). This module supplies the mathematical side the
//! framework depends on: small dense linear algebra ([`linalg`]), the
//! Hilbert-space-generalized gaussian of Table 2 ([`gaussian`]),
//! partition-aggregable descriptive moments ([`descriptive`]), and the
//! sample-determined rank statistics whose behaviour under partitioning
//! §2.4 discusses ([`rank`]).

pub mod descriptive;
pub mod gaussian;
pub mod linalg;
pub mod rank;

pub use gaussian::MultivariateGaussian;
pub use linalg::Mat;
