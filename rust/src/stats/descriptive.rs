//! Partition-aggregable descriptive statistics (paper §2.4).
//!
//! "The majority of algorithms that have been demonstrated on distributed
//! systems make use of aggregation functions ... which can be operated
//! directly on both populations and samples." This module models exactly
//! that class: a [`Moments`] accumulator whose `merge` is exact, so any row
//! partition of a melt matrix yields bit-stable statistics regardless of how
//! work was split (Chan et al. parallel-variance formulas).

/// Streaming count/mean/M2/min/max accumulator with exact pairwise merge.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Moments {
    pub count: u64,
    pub mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Default for Moments {
    fn default() -> Self {
        Self::new()
    }
}

impl Moments {
    pub fn new() -> Self {
        Self {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Welford single-value update.
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        let d = x - self.mean;
        self.mean += d / self.count as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Accumulate a slice.
    pub fn push_slice(&mut self, xs: &[f32]) {
        for &x in xs {
            self.push(x as f64);
        }
    }

    /// Exact merge of two accumulators (Chan et al.): the MapReduce combine
    /// step for partitioned melt rows.
    pub fn merge(&self, other: &Moments) -> Moments {
        if self.count == 0 {
            return *other;
        }
        if other.count == 0 {
            return *self;
        }
        let n = (self.count + other.count) as f64;
        let d = other.mean - self.mean;
        Moments {
            count: self.count + other.count,
            mean: self.mean + d * other.count as f64 / n,
            m2: self.m2 + other.m2 + d * d * self.count as f64 * other.count as f64 / n,
            min: self.min.min(other.min),
            max: self.max.max(other.max),
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.m2 / self.count as f64
    }

    /// Sample (Bessel-corrected) variance.
    pub fn sample_variance(&self) -> f64 {
        if self.count < 2 {
            return f64::NAN;
        }
        self.m2 / (self.count - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Compute moments over a slice in one pass.
pub fn moments(xs: &[f32]) -> Moments {
    let mut m = Moments::new();
    m.push_slice(xs);
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn known_values() {
        let m = moments(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.count, 4);
        assert_eq!(m.mean, 2.5);
        assert_eq!(m.variance(), 1.25);
        assert!((m.sample_variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.max, 4.0);
    }

    #[test]
    fn empty_and_single() {
        let e = Moments::new();
        assert!(e.variance().is_nan());
        let mut s = Moments::new();
        s.push(5.0);
        assert_eq!(s.mean, 5.0);
        assert_eq!(s.variance(), 0.0);
        assert!(s.sample_variance().is_nan());
    }

    #[test]
    fn merge_identity() {
        let a = moments(&[1.0, 2.0, 3.0]);
        let e = Moments::new();
        assert_eq!(a.merge(&e), a);
        assert_eq!(e.merge(&a), a);
    }

    #[test]
    fn merge_equals_global_property() {
        // §2.4: aggregation functions are partition-exact.
        check_property("merged moments == global moments", 40, |rng: &mut SplitMix64| {
            let n = 4 + rng.below(200);
            let xs = rng.uniform_vec(n, -100.0, 100.0);
            let parts = 1 + rng.below(5);
            let global = moments(&xs);
            let mut merged = Moments::new();
            let chunk = n.div_ceil(parts);
            for c in xs.chunks(chunk) {
                merged = merged.merge(&moments(c));
            }
            assert_eq!(merged.count, global.count);
            assert!((merged.mean - global.mean).abs() < 1e-9);
            assert!((merged.variance() - global.variance()).abs() < 1e-7);
            assert_eq!(merged.min, global.min);
            assert_eq!(merged.max, global.max);
        });
    }

    #[test]
    fn merge_is_associative_property() {
        check_property("moments merge associativity", 30, |rng: &mut SplitMix64| {
            let (na, nb, nc) = (1 + rng.below(20), 1 + rng.below(20), 1 + rng.below(20));
            let a = moments(&rng.uniform_vec(na, -5.0, 5.0));
            let b = moments(&rng.uniform_vec(nb, -5.0, 5.0));
            let c = moments(&rng.uniform_vec(nc, -5.0, 5.0));
            let l = a.merge(&b).merge(&c);
            let r = a.merge(&b.merge(&c));
            assert_eq!(l.count, r.count);
            assert!((l.mean - r.mean).abs() < 1e-10);
            assert!((l.variance() - r.variance()).abs() < 1e-9);
        });
    }
}
