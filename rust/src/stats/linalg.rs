//! Small dense linear algebra: the square matrices of the framework are
//! operator covariances Σ_d and Hessians — k ≤ ~8 — so an O(k³) LU with
//! partial pivoting in f64 covers every need (det, inverse, solve) with
//! headroom to spare. Cholesky is provided for SPD covariance validation.

use crate::error::{Error, Result};

/// A small dense square-capable matrix in row-major f64 storage.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn new(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if rows == 0 || cols == 0 || data.len() != rows * cols {
            return Err(Error::Linalg(format!(
                "bad Mat dims {rows}x{cols} for {} values",
                data.len()
            )));
        }
        Ok(Self { rows, cols, data })
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Diagonal matrix from entries.
    pub fn diag(d: &[f64]) -> Self {
        let n = d.len();
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = d[i];
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.set(c, r, self.at(r, c));
            }
        }
        t
    }

    /// Matrix product.
    pub fn matmul(&self, other: &Mat) -> Result<Mat> {
        if self.cols != other.rows {
            return Err(Error::Linalg(format!(
                "matmul {}x{} by {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let mut out = Mat::zeros(self.rows, other.cols);
        for r in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(r, k);
                if a == 0.0 {
                    continue;
                }
                for c in 0..other.cols {
                    out.data[r * other.cols + c] += a * other.at(k, c);
                }
            }
        }
        Ok(out)
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Result<Vec<f64>> {
        if v.len() != self.cols {
            return Err(Error::Linalg(format!(
                "matvec {}x{} by len-{}",
                self.rows,
                self.cols,
                v.len()
            )));
        }
        Ok((0..self.rows)
            .map(|r| (0..self.cols).map(|c| self.at(r, c) * v[c]).sum())
            .collect())
    }

    /// Quadratic form vᵀ M v (square only).
    pub fn quad_form(&self, v: &[f64]) -> Result<f64> {
        let mv = self.matvec(v)?;
        Ok(v.iter().zip(&mv).map(|(a, b)| a * b).sum())
    }

    fn require_square(&self) -> Result<usize> {
        if self.rows != self.cols {
            return Err(Error::Linalg(format!(
                "operation requires square matrix, got {}x{}",
                self.rows, self.cols
            )));
        }
        Ok(self.rows)
    }

    /// LU decomposition with partial pivoting; returns (LU, perm, sign).
    fn lu(&self) -> Result<(Vec<f64>, Vec<usize>, f64)> {
        let n = self.require_square()?;
        let mut lu = self.data.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0f64;
        for k in 0..n {
            // pivot
            let mut p = k;
            let mut best = lu[k * n + k].abs();
            for r in k + 1..n {
                let v = lu[r * n + k].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < 1e-300 {
                return Err(Error::Linalg("singular matrix in LU".into()));
            }
            if p != k {
                for c in 0..n {
                    lu.swap(k * n + c, p * n + c);
                }
                perm.swap(k, p);
                sign = -sign;
            }
            let pivot = lu[k * n + k];
            for r in k + 1..n {
                let f = lu[r * n + k] / pivot;
                lu[r * n + k] = f;
                for c in k + 1..n {
                    lu[r * n + c] -= f * lu[k * n + c];
                }
            }
        }
        Ok((lu, perm, sign))
    }

    /// Determinant via LU (exact closed forms for n <= 3 to avoid pivoting
    /// noise on the curvature hot path).
    pub fn det(&self) -> Result<f64> {
        let n = self.require_square()?;
        match n {
            1 => Ok(self.data[0]),
            2 => Ok(self.data[0] * self.data[3] - self.data[1] * self.data[2]),
            3 => {
                let d = &self.data;
                Ok(d[0] * (d[4] * d[8] - d[5] * d[7]) - d[1] * (d[3] * d[8] - d[5] * d[6])
                    + d[2] * (d[3] * d[7] - d[4] * d[6]))
            }
            _ => match self.lu() {
                Ok((lu, _, sign)) => {
                    Ok(sign * (0..n).map(|i| lu[i * n + i]).product::<f64>())
                }
                // a singular matrix has determinant 0
                Err(_) => Ok(0.0),
            },
        }
    }

    /// Solve M x = b via LU.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        let n = self.require_square()?;
        if b.len() != n {
            return Err(Error::Linalg(format!("solve rhs len {} vs n {n}", b.len())));
        }
        let (lu, perm, _) = self.lu()?;
        // forward substitution on permuted rhs
        let mut y = vec![0.0f64; n];
        for r in 0..n {
            let mut s = b[perm[r]];
            for c in 0..r {
                s -= lu[r * n + c] * y[c];
            }
            y[r] = s;
        }
        // back substitution
        let mut x = vec![0.0f64; n];
        for r in (0..n).rev() {
            let mut s = y[r];
            for c in r + 1..n {
                s -= lu[r * n + c] * x[c];
            }
            x[r] = s / lu[r * n + r];
        }
        Ok(x)
    }

    /// Inverse via LU column solves.
    pub fn inverse(&self) -> Result<Mat> {
        let n = self.require_square()?;
        let mut out = Mat::zeros(n, n);
        for c in 0..n {
            let mut e = vec![0.0f64; n];
            e[c] = 1.0;
            let col = self.solve(&e)?;
            for r in 0..n {
                out.set(r, c, col[r]);
            }
        }
        Ok(out)
    }

    /// Cholesky factor L (lower) of an SPD matrix; errors when not SPD.
    pub fn cholesky(&self) -> Result<Mat> {
        let n = self.require_square()?;
        let mut l = Mat::zeros(n, n);
        for r in 0..n {
            for c in 0..=r {
                let mut s = self.at(r, c);
                for k in 0..c {
                    s -= l.at(r, k) * l.at(c, k);
                }
                if r == c {
                    if s <= 0.0 {
                        return Err(Error::Linalg(format!(
                            "matrix not SPD (pivot {s} at {r})"
                        )));
                    }
                    l.set(r, c, s.sqrt());
                } else {
                    l.set(r, c, s / l.at(c, c));
                }
            }
        }
        Ok(l)
    }

    /// Symmetrise: (M + Mᵀ)/2.
    pub fn symmetrize(&self) -> Result<Mat> {
        self.require_square()?;
        let t = self.transpose();
        let mut out = self.clone();
        for i in 0..self.data.len() {
            out.data[i] = (self.data[i] + t.data[i]) / 2.0;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    fn random_spd(rng: &mut SplitMix64, n: usize) -> Mat {
        // A Aᵀ + n I is SPD
        let mut a = Mat::zeros(n, n);
        for r in 0..n {
            for c in 0..n {
                a.set(r, c, rng.normal() as f64);
            }
        }
        let mut spd = a.matmul(&a.transpose()).unwrap();
        for i in 0..n {
            spd.set(i, i, spd.at(i, i) + n as f64);
        }
        spd
    }

    #[test]
    fn construction_and_identity() {
        assert!(Mat::new(2, 2, vec![0.0; 3]).is_err());
        let i = Mat::eye(3);
        assert_eq!(i.det().unwrap(), 1.0);
        assert_eq!(i.matvec(&[1.0, 2.0, 3.0]).unwrap(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn det_closed_forms() {
        let m2 = Mat::new(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(m2.det().unwrap(), -2.0);
        let m3 = Mat::new(3, 3, vec![2.0, 0.0, 1.0, 1.0, 3.0, 0.0, 0.0, 1.0, 4.0]).unwrap();
        assert!((m3.det().unwrap() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn det_lu_matches_closed_form_property() {
        check_property("LU det == cofactor det (n=3)", 40, |rng: &mut SplitMix64| {
            let data: Vec<f64> = (0..9).map(|_| rng.normal() as f64).collect();
            let m = Mat::new(3, 3, data.clone()).unwrap();
            // force the LU path via a 4x4 embedding with unit extra pivot
            let mut big = Mat::eye(4);
            for r in 0..3 {
                for c in 0..3 {
                    big.set(r, c, data[r * 3 + c]);
                }
            }
            let (a, b) = (m.det().unwrap(), big.det().unwrap());
            assert!((a - b).abs() < 1e-9 * (1.0 + a.abs()), "{a} vs {b}");
        });
    }

    #[test]
    fn solve_and_inverse_round_trip_property() {
        check_property("M · M⁻¹ = I; M·solve(b)=b", 30, |rng: &mut SplitMix64| {
            let n = 1 + rng.below(6);
            let m = random_spd(rng, n);
            let inv = m.inverse().unwrap();
            let prod = m.matmul(&inv).unwrap();
            for r in 0..n {
                for c in 0..n {
                    let want = if r == c { 1.0 } else { 0.0 };
                    assert!((prod.at(r, c) - want).abs() < 1e-8);
                }
            }
            let b: Vec<f64> = (0..n).map(|_| rng.normal() as f64).collect();
            let x = m.solve(&b).unwrap();
            let back = m.matvec(&x).unwrap();
            for (u, v) in back.iter().zip(&b) {
                assert!((u - v).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn singular_matrix_rejected() {
        let m = Mat::new(2, 2, vec![1.0, 2.0, 2.0, 4.0]).unwrap();
        assert!(m.solve(&[1.0, 1.0]).is_err());
        assert!(m.inverse().is_err());
        assert_eq!(m.det().unwrap(), 0.0);
    }

    #[test]
    fn cholesky_recomposes_property() {
        check_property("L Lᵀ == M", 25, |rng: &mut SplitMix64| {
            let n = 1 + rng.below(5);
            let m = random_spd(rng, n);
            let l = m.cholesky().unwrap();
            let back = l.matmul(&l.transpose()).unwrap();
            for i in 0..n * n {
                assert!((back.data()[i] - m.data()[i]).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        let m = Mat::new(2, 2, vec![1.0, 2.0, 2.0, 1.0]).unwrap(); // eigenvalues 3, -1
        assert!(m.cholesky().is_err());
    }

    #[test]
    fn quad_form_matches_manual() {
        let m = Mat::new(2, 2, vec![2.0, 1.0, 1.0, 3.0]).unwrap();
        // [1,2] M [1,2]^T = 2 + 2 + 2 + 12 = 18
        assert_eq!(m.quad_form(&[1.0, 2.0]).unwrap(), 18.0);
    }

    #[test]
    fn transpose_symmetrize() {
        let m = Mat::new(2, 2, vec![1.0, 5.0, 3.0, 2.0]).unwrap();
        let s = m.symmetrize().unwrap();
        assert_eq!(s.at(0, 1), 4.0);
        assert_eq!(s.at(1, 0), 4.0);
        assert_eq!(m.transpose().at(0, 1), 3.0);
    }
}
