//! Sample-determined (rank-order) statistics under partitioning (§2.4).
//!
//! Unlike aggregation functions, the median and other rank statistics are
//! *sample-determined*: computing them per partition and combining is
//! biased. The paper's position is that "the application of modern
//! techniques such as randomization to some extent ensures that the
//! statistical results derived from samples converge towards that of the
//! population" — modelled here as (a) the exact selection median, (b) the
//! biased median-of-partition-medians, and (c) a randomized-sample
//! estimator whose convergence the tests check.

use crate::testing::SplitMix64;

/// Exact median via quickselect (O(n) expected); even counts average the
/// two central order statistics. Allocates a fresh scratch buffer — hot
/// loops should hold one and call [`median_exact_with`].
pub fn median_exact(xs: &[f32]) -> f32 {
    median_exact_with(&mut Vec::with_capacity(xs.len()), xs)
}

/// [`median_exact`] over a caller-provided scratch buffer: one copy of
/// `xs` and one quickselect pass total — even counts pull both central
/// order statistics out of the same pass via [`select_adjacent_with`].
pub fn median_exact_with(scratch: &mut Vec<f32>, xs: &[f32]) -> f32 {
    assert!(!xs.is_empty(), "median of empty slice");
    let n = xs.len();
    if n % 2 == 1 {
        select_with(scratch, xs, n / 2)
    } else {
        let (a, b) = select_adjacent_with(scratch, xs, n / 2 - 1);
        (a + b) / 2.0
    }
}

/// k-th smallest (0-based) via quickselect with median-of-three pivoting.
/// Allocates a fresh scratch buffer — hot loops should hold one and call
/// [`select_with`].
pub fn select(xs: &[f32], k: usize) -> f32 {
    select_with(&mut Vec::with_capacity(xs.len()), xs, k)
}

/// [`select`] over a caller-provided scratch buffer (cleared and refilled,
/// so a warm buffer never reallocates).
pub fn select_with(scratch: &mut Vec<f32>, xs: &[f32], k: usize) -> f32 {
    assert!(k < xs.len());
    scratch.clear();
    scratch.extend_from_slice(xs);
    partition_to(&mut scratch[..], k).0
}

/// The `k`-th and `(k + 1)`-th smallest values of `xs` (0-based) from a
/// **single** quickselect pass over a caller-provided scratch buffer.
/// For `k == xs.len() - 1` the pair degenerates to the maximum twice.
///
/// The partition invariant `v[..lo] ≤ v[lo..hi] ≤ v[hi..]` holds at every
/// shrink, so once the k-th value is pinned the (k + 1)-th is either the
/// same pivot (still inside the equal run) or the minimum of the elements
/// proven ≥ it — no second quickselect, which is what makes the
/// even-median/interpolated-quantile kernels one-pass per melt row.
pub fn select_adjacent_with(scratch: &mut Vec<f32>, xs: &[f32], k: usize) -> (f32, f32) {
    let n = xs.len();
    assert!(k < n);
    scratch.clear();
    scratch.extend_from_slice(xs);
    let v = &mut scratch[..];
    let (kth, tail) = partition_to(v, k);
    if k + 1 >= n {
        return (kth, kth);
    }
    match tail {
        None => (kth, kth),
        Some(t) => {
            debug_assert_eq!(t, k + 1);
            let next = v[t..].iter().copied().fold(f32::INFINITY, f32::min);
            (kth, next)
        }
    }
}

/// Quickselect core: partitions `v` in place around the `k`-th smallest
/// value and returns it, plus the start of the suffix proven ≥ it (`None`
/// while the `(k + 1)`-th is pinned to the same pivot value). Callers that
/// only want the k-th value ignore the marker and pay no tail scan.
fn partition_to(v: &mut [f32], k: usize) -> (f32, Option<usize>) {
    let (mut lo, mut hi) = (0usize, v.len());
    loop {
        if hi - lo <= 1 {
            break (v[lo], Some(lo + 1));
        }
        // median-of-three pivot
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (v[lo], v[mid], v[hi - 1]);
        let pivot = a.max(b.min(c)).min(b.max(c));
        let mut lt = lo;
        let mut gt = hi;
        let mut i = lo;
        while i < gt {
            if v[i] < pivot {
                v.swap(lt, i);
                lt += 1;
                i += 1;
            } else if v[i] > pivot {
                gt -= 1;
                v.swap(i, gt);
            } else {
                i += 1;
            }
        }
        if k < lt {
            hi = lt;
        } else if k >= gt {
            lo = gt;
        } else if k + 1 < gt {
            // k + 1 still lands in the equal-to-pivot run
            break (pivot, None);
        } else {
            break (pivot, Some(gt));
        }
    }
}

/// The biased combine: median of per-partition medians. Exposed to make the
/// §2.4 caveat measurable (tests/benches compare it against exact).
pub fn median_of_partition_medians(partitions: &[&[f32]]) -> f32 {
    let mut scratch = Vec::new();
    let meds: Vec<f32> = partitions
        .iter()
        .filter(|p| !p.is_empty())
        .map(|p| median_exact_with(&mut scratch, p))
        .collect();
    median_exact_with(&mut scratch, &meds)
}

/// Randomized estimator: median of a uniform sample of size `sample` drawn
/// across all partitions (the paper's randomization argument). Converges to
/// the population median as `sample` grows.
pub fn median_randomized(partitions: &[&[f32]], sample: usize, seed: u64) -> f32 {
    let total: usize = partitions.iter().map(|p| p.len()).sum();
    assert!(total > 0 && sample > 0);
    let mut rng = SplitMix64::new(seed);
    let mut buf = Vec::with_capacity(sample);
    for _ in 0..sample {
        let mut flat = rng.below(total);
        for p in partitions {
            if flat < p.len() {
                buf.push(p[flat]);
                break;
            }
            flat -= p.len();
        }
    }
    median_exact(&buf)
}

/// Quantile (linear interpolation between order statistics), q in [0, 1].
/// Allocates a fresh scratch buffer — hot loops should hold one and call
/// [`quantile_with`].
pub fn quantile(xs: &[f32], q: f64) -> f32 {
    quantile_with(&mut Vec::with_capacity(xs.len()), xs, q)
}

/// [`quantile`] over a caller-provided scratch buffer. The two order
/// statistics an interpolated quantile straddles are adjacent, so a single
/// [`select_adjacent_with`] pass yields both — half the copies and half
/// the quickselects of the naive `select(lo) … select(hi)` pairing.
pub fn quantile_with(scratch: &mut Vec<f32>, xs: &[f32], q: f64) -> f32 {
    assert!(!xs.is_empty() && (0.0..=1.0).contains(&q));
    let n = xs.len();
    if n == 1 {
        return xs[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let w = (pos - lo as f64) as f32;
    if w == 0.0 {
        // exact order statistic: skip the adjacent-value tail scan
        select_with(scratch, xs, lo)
    } else {
        let (a, b) = select_adjacent_with(scratch, xs, lo);
        a * (1.0 - w) + b * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn median_known_values() {
        assert_eq!(median_exact(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median_exact(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(median_exact(&[7.0]), 7.0);
        assert_eq!(median_exact(&[2.0, 2.0, 2.0, 2.0]), 2.0);
    }

    #[test]
    fn select_matches_sort_property() {
        check_property("quickselect == sort-index", 40, |rng: &mut SplitMix64| {
            let n = 1 + rng.below(200);
            let xs = rng.uniform_vec(n, -50.0, 50.0);
            let k = rng.below(n);
            let mut sorted = xs.clone();
            sorted.sort_by(f32::total_cmp);
            assert_eq!(select(&xs, k), sorted[k]);
        });
    }

    #[test]
    fn select_adjacent_matches_sorted_pairs_property() {
        check_property("adjacent order stats == sorted pairs", 40, |rng: &mut SplitMix64| {
            let n = 1 + rng.below(200);
            // alternate uniform values with duplicate-heavy ones: the
            // latter stress the equal-to-pivot run handling
            let xs: Vec<f32> = if rng.below(2) == 0 {
                rng.uniform_vec(n, -50.0, 50.0)
            } else {
                (0..n).map(|_| rng.below(8) as f32).collect()
            };
            let k = rng.below(n);
            let mut sorted = xs.clone();
            sorted.sort_by(f32::total_cmp);
            let mut scratch = Vec::new();
            let (a, b) = select_adjacent_with(&mut scratch, &xs, k);
            assert_eq!(a, sorted[k]);
            assert_eq!(b, sorted[(k + 1).min(n - 1)]);
            // the scratch buffer is reusable back-to-back
            assert_eq!(select_adjacent_with(&mut scratch, &xs, k), (a, b));
            // the scan-free single-statistic path agrees
            assert_eq!(select_with(&mut scratch, &xs, k), a);
            // and the with-scratch entry points agree with the allocating ones
            assert_eq!(median_exact_with(&mut scratch, &xs), median_exact(&xs));
            let q = rng.below(101) as f64 / 100.0;
            assert_eq!(quantile_with(&mut scratch, &xs, q), quantile(&xs, q));
        });
    }

    #[test]
    fn quantile_endpoints_and_midpoint() {
        let xs = vec![10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(quantile(&xs, 0.0), 10.0);
        assert_eq!(quantile(&xs, 1.0), 50.0);
        assert_eq!(quantile(&xs, 0.5), 30.0);
        assert_eq!(quantile(&xs, 0.25), 20.0);
        // interpolation
        assert!((quantile(&xs, 0.1) - 14.0).abs() < 1e-6);
    }

    #[test]
    fn partition_medians_can_be_biased() {
        // a construction where median-of-medians != exact median
        let a = [1.0f32, 2.0, 100.0];
        let b = [3.0f32, 4.0, 5.0];
        let c = [6.0f32, 7.0, 8.0];
        let exact = median_exact(&[1.0, 2.0, 100.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let mom = median_of_partition_medians(&[&a, &b, &c]);
        assert_eq!(exact, 5.0);
        assert_eq!(mom, 4.0); // demonstrably biased
    }

    #[test]
    fn randomized_median_converges_property() {
        // §2.4's randomization claim: sampled median approaches exact as the
        // sample grows; tolerance shrinks with sample size.
        check_property("randomized median converges", 10, |rng: &mut SplitMix64| {
            let n = 3000;
            let xs = rng.uniform_vec(n, 0.0, 1000.0);
            let cut1 = n / 3;
            let cut2 = 2 * n / 3;
            let parts: Vec<&[f32]> = vec![&xs[..cut1], &xs[cut1..cut2], &xs[cut2..]];
            let exact = median_exact(&xs);
            let small = median_randomized(&parts, 30, 1);
            let large = median_randomized(&parts, 2000, 1);
            // the large-sample estimate must be within ~3% of the range;
            // the small one is allowed to be worse but both must be finite.
            assert!((large - exact).abs() < 30.0, "large {large} vs {exact}");
            assert!(small.is_finite());
        });
    }
}
