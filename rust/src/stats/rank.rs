//! Sample-determined (rank-order) statistics under partitioning (§2.4).
//!
//! Unlike aggregation functions, the median and other rank statistics are
//! *sample-determined*: computing them per partition and combining is
//! biased. The paper's position is that "the application of modern
//! techniques such as randomization to some extent ensures that the
//! statistical results derived from samples converge towards that of the
//! population" — modelled here as (a) the exact selection median, (b) the
//! biased median-of-partition-medians, and (c) a randomized-sample
//! estimator whose convergence the tests check.

use crate::testing::SplitMix64;

/// Exact median via quickselect (O(n) expected); even counts average the
/// two central order statistics.
pub fn median_exact(xs: &[f32]) -> f32 {
    assert!(!xs.is_empty(), "median of empty slice");
    let n = xs.len();
    if n % 2 == 1 {
        select(xs, n / 2)
    } else {
        (select(xs, n / 2 - 1) + select(xs, n / 2)) / 2.0
    }
}

/// k-th smallest (0-based) via quickselect with median-of-three pivoting.
pub fn select(xs: &[f32], k: usize) -> f32 {
    assert!(k < xs.len());
    let mut v = xs.to_vec();
    let (mut lo, mut hi) = (0usize, v.len());
    loop {
        if hi - lo <= 1 {
            return v[lo];
        }
        // median-of-three pivot
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (v[lo], v[mid], v[hi - 1]);
        let pivot = a.max(b.min(c)).min(b.max(c));
        let mut lt = lo;
        let mut gt = hi;
        let mut i = lo;
        while i < gt {
            if v[i] < pivot {
                v.swap(lt, i);
                lt += 1;
                i += 1;
            } else if v[i] > pivot {
                gt -= 1;
                v.swap(i, gt);
            } else {
                i += 1;
            }
        }
        if k < lt {
            hi = lt;
        } else if k >= gt {
            lo = gt;
        } else {
            return pivot;
        }
    }
}

/// The biased combine: median of per-partition medians. Exposed to make the
/// §2.4 caveat measurable (tests/benches compare it against exact).
pub fn median_of_partition_medians(partitions: &[&[f32]]) -> f32 {
    let meds: Vec<f32> = partitions
        .iter()
        .filter(|p| !p.is_empty())
        .map(|p| median_exact(p))
        .collect();
    median_exact(&meds)
}

/// Randomized estimator: median of a uniform sample of size `sample` drawn
/// across all partitions (the paper's randomization argument). Converges to
/// the population median as `sample` grows.
pub fn median_randomized(partitions: &[&[f32]], sample: usize, seed: u64) -> f32 {
    let total: usize = partitions.iter().map(|p| p.len()).sum();
    assert!(total > 0 && sample > 0);
    let mut rng = SplitMix64::new(seed);
    let mut buf = Vec::with_capacity(sample);
    for _ in 0..sample {
        let mut flat = rng.below(total);
        for p in partitions {
            if flat < p.len() {
                buf.push(p[flat]);
                break;
            }
            flat -= p.len();
        }
    }
    median_exact(&buf)
}

/// Quantile (linear interpolation between order statistics), q in [0, 1].
pub fn quantile(xs: &[f32], q: f64) -> f32 {
    assert!(!xs.is_empty() && (0.0..=1.0).contains(&q));
    let n = xs.len();
    if n == 1 {
        return xs[0];
    }
    let pos = q * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        return select(xs, lo);
    }
    let w = (pos - lo as f64) as f32;
    select(xs, lo) * (1.0 - w) + select(xs, hi) * w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn median_known_values() {
        assert_eq!(median_exact(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median_exact(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(median_exact(&[7.0]), 7.0);
        assert_eq!(median_exact(&[2.0, 2.0, 2.0, 2.0]), 2.0);
    }

    #[test]
    fn select_matches_sort_property() {
        check_property("quickselect == sort-index", 40, |rng: &mut SplitMix64| {
            let n = 1 + rng.below(200);
            let xs = rng.uniform_vec(n, -50.0, 50.0);
            let k = rng.below(n);
            let mut sorted = xs.clone();
            sorted.sort_by(f32::total_cmp);
            assert_eq!(select(&xs, k), sorted[k]);
        });
    }

    #[test]
    fn quantile_endpoints_and_midpoint() {
        let xs = vec![10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(quantile(&xs, 0.0), 10.0);
        assert_eq!(quantile(&xs, 1.0), 50.0);
        assert_eq!(quantile(&xs, 0.5), 30.0);
        assert_eq!(quantile(&xs, 0.25), 20.0);
        // interpolation
        assert!((quantile(&xs, 0.1) - 14.0).abs() < 1e-6);
    }

    #[test]
    fn partition_medians_can_be_biased() {
        // a construction where median-of-medians != exact median
        let a = [1.0f32, 2.0, 100.0];
        let b = [3.0f32, 4.0, 5.0];
        let c = [6.0f32, 7.0, 8.0];
        let exact = median_exact(&[1.0, 2.0, 100.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let mom = median_of_partition_medians(&[&a, &b, &c]);
        assert_eq!(exact, 5.0);
        assert_eq!(mom, 4.0); // demonstrably biased
    }

    #[test]
    fn randomized_median_converges_property() {
        // §2.4's randomization claim: sampled median approaches exact as the
        // sample grows; tolerance shrinks with sample size.
        check_property("randomized median converges", 10, |rng: &mut SplitMix64| {
            let n = 3000;
            let xs = rng.uniform_vec(n, 0.0, 1000.0);
            let cut1 = n / 3;
            let cut2 = 2 * n / 3;
            let parts: Vec<&[f32]> = vec![&xs[..cut1], &xs[cut1..cut2], &xs[cut2..]];
            let exact = median_exact(&xs);
            let small = median_randomized(&parts, 30, 1);
            let large = median_randomized(&parts, 2000, 1);
            // the large-sample estimate must be within ~3% of the range;
            // the small one is allowed to be worse but both must be finite.
            assert!((large - exact).abs() < 30.0, "large {large} vs {exact}");
            assert!(small.is_finite());
        });
    }
}
