//! Thin wrapper over the PJRT CPU client with device diagnostics.

use crate::error::Result;

/// A thread-confined PJRT CPU client.
///
/// `xla::PjRtClient` is `Rc`-backed, so this type is deliberately `!Send`;
/// the coordinator builds one per worker thread.
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    /// Create the CPU client (the only backend in this image).
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Human-readable platform summary for `meltframe inspect`.
    pub fn describe(&self) -> String {
        format!(
            "platform={} version={} devices={}",
            self.client.platform_name(),
            self.client.platform_version(),
            self.client.device_count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_constructs_and_describes() {
        let ctx = PjrtContext::cpu().unwrap();
        let d = ctx.describe();
        assert!(d.contains("platform="), "{d}");
        assert!(ctx.client.device_count() >= 1);
    }
}
