//! Thin wrapper over the PJRT CPU client with device diagnostics.

use crate::error::Result;
use crate::runtime::xla_stub as xla;

/// A thread-confined PJRT CPU client.
///
/// `xla::PjRtClient` is `Rc`-backed, so this type is deliberately `!Send`;
/// the coordinator builds one per worker thread.
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    /// Create the CPU client. Fails with [`crate::error::Error::Runtime`]
    /// when the build has no PJRT bindings (see [`crate::runtime::xla_stub`]).
    pub fn cpu() -> Result<Self> {
        Ok(Self {
            client: xla::PjRtClient::cpu()?,
        })
    }

    /// Whether this build can construct a PJRT client at all — lets callers
    /// (CLI `inspect`, benches) probe before committing to `Backend::Pjrt`.
    pub fn available() -> bool {
        Self::cpu().is_ok()
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Human-readable platform summary for `meltframe inspect`.
    pub fn describe(&self) -> String {
        format!(
            "platform={} version={} devices={}",
            self.client.platform_name(),
            self.client.platform_version(),
            self.client.device_count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_constructs_or_reports_unavailable() {
        match PjrtContext::cpu() {
            Ok(ctx) => {
                let d = ctx.describe();
                assert!(d.contains("platform="), "{d}");
                assert!(ctx.client.device_count() >= 1);
            }
            Err(e) => {
                assert!(e.to_string().contains("PJRT unavailable"), "{e}");
                assert!(!PjrtContext::available());
            }
        }
    }
}
