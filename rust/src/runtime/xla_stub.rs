//! Build-time stand-in for the `xla` PJRT bindings.
//!
//! The build image vendors no registry crates, so the real `xla_extension`
//! bindings cannot be linked here. This module mirrors the exact API
//! surface [`crate::runtime::client`] and [`crate::runtime::executor`]
//! consume, with every entry point failing gracefully at *runtime* with
//! [`Error::Runtime`] — the rest of the crate (coordinator, Plan executor,
//! CLI) compiles and runs unchanged on `Backend::Native`, and
//! `Backend::Pjrt` reports a clear, actionable error instead of a build
//! failure.
//!
//! Re-enabling the real runtime is a two-line change: add the `xla`
//! dependency to `Cargo.toml` and swap the `use crate::runtime::xla_stub as
//! xla;` imports in `client.rs`/`executor.rs` back to the crate.

use std::path::Path;

use crate::error::{Error, Result};

/// The message every stubbed entry point returns.
pub const UNAVAILABLE: &str = "PJRT unavailable: the `xla` bindings are not vendored in this \
     build; use Backend::Native, or vendor the xla crate and switch \
     runtime::{client,executor} back to it";

fn unavailable<T>() -> Result<T> {
    Err(Error::Runtime(UNAVAILABLE.into()))
}

/// Stub of `xla::PjRtClient` (Rc-backed and `!Send` in the real crate).
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn platform_version(&self) -> String {
        "unavailable".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

/// Stub of `xla::PjRtBuffer` (a device buffer handle).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        unavailable()
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _priv: () }
    }
}

/// Stub of `xla::Literal` (host-side tensor value).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_gracefully() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        assert!(err.to_string().contains("PJRT unavailable"), "{err}");
        assert!(HloModuleProto::from_text_file("/nope.hlo.txt").is_err());
    }
}
