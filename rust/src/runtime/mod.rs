//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO text + manifest), compiles them on the CPU PJRT client, and executes
//! them from the coordinator hot path.
//!
//! Interchange is HLO *text*: jax >= 0.5 emits serialized protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids. Python never runs at request time — artifacts are compiled
//! once per [`Engine`] and reused.
//!
//! **Dependency gating:** this build vendors no `xla` crate, so `client`
//! and `executor` compile against [`xla_stub`] — the same API surface, with
//! every entry point failing at runtime with a clear `Error::Runtime`.
//! `Backend::Native` is unaffected; `Backend::Pjrt` degrades to an
//! actionable error instead of a link failure. `PjrtContext::available()`
//! lets callers probe.
//!
//! Threading note: `xla::PjRtClient` is `Rc`-backed (not `Send`), so an
//! [`Engine`] is thread-confined; multi-worker PJRT execution gives each
//! worker thread its own engine built from the leader's shared manifest
//! (see `coordinator::worker`).

pub mod artifact;
pub mod client;
pub mod executor;
pub mod xla_stub;

pub use artifact::{ArtifactEntry, ArtifactManifest};
pub use executor::Engine;
