//! The AOT artifact registry: parses `artifacts/manifest.json` (written by
//! `python -m compile.aot`) and validates each entry against the files on
//! disk. The manifest is the L2→L3 contract: variant kind, operator window,
//! fixed chunk height, and all input/output shapes.

use std::path::{Path, PathBuf};

use crate::config::json::JsonValue;
use crate::error::{Error, Result};

/// One AOT-compiled variant.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// Variant name, e.g. `gaussian_w27`.
    pub name: String,
    /// Variant kind: `gaussian` | `bilateral_const` | `bilateral_adaptive`
    /// | `curvature`.
    pub kind: String,
    /// HLO text file path (absolute).
    pub path: PathBuf,
    /// Operator window extents.
    pub window: Vec<usize>,
    /// Fixed chunk height (melt rows per execution).
    pub rows: usize,
    /// Input shapes, first is always the melt chunk `[rows, prod(window)]`.
    pub inputs: Vec<Vec<usize>>,
}

impl ArtifactEntry {
    /// The melt chunk's column count.
    pub fn cols(&self) -> usize {
        self.window.iter().product()
    }
}

/// Parsed manifest with entry lookup.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub chunk_rows: usize,
    entries: Vec<ArtifactEntry>,
}

impl ArtifactManifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` anchors relative artifact files.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let root = JsonValue::parse(text)?;
        let chunk_rows = root.field("chunk_rows")?.as_usize()?;
        if chunk_rows == 0 {
            return Err(Error::Artifact("chunk_rows must be positive".into()));
        }
        let mut entries = Vec::new();
        for item in root.field("artifacts")?.as_array()? {
            let name = item.field("name")?.as_str()?.to_string();
            let kind = item.field("kind")?.as_str()?.to_string();
            let file = item.field("file")?.as_str()?;
            let window = item.field("window")?.as_usize_vec()?;
            let rows = item.field("rows")?.as_usize()?;
            let inputs: Vec<Vec<usize>> = item
                .field("inputs")?
                .as_array()?
                .iter()
                .map(|v| v.as_usize_vec())
                .collect::<Result<_>>()?;
            if window.is_empty() || window.iter().any(|&w| w == 0 || w % 2 == 0) {
                return Err(Error::Artifact(format!(
                    "artifact {name}: invalid window {window:?}"
                )));
            }
            let cols: usize = window.iter().product();
            match inputs.first() {
                Some(first) if first == &vec![rows, cols] => {}
                other => {
                    return Err(Error::Artifact(format!(
                        "artifact {name}: first input {other:?} != melt chunk [{rows}, {cols}]"
                    )))
                }
            }
            entries.push(ArtifactEntry {
                name,
                kind,
                path: dir.join(file),
                window,
                rows,
                inputs,
            });
        }
        if entries.is_empty() {
            return Err(Error::Artifact("manifest has no artifacts".into()));
        }
        Ok(Self { chunk_rows, entries })
    }

    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Find by exact name.
    pub fn by_name(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name).ok_or_else(|| {
            Error::Artifact(format!(
                "no artifact '{name}' (available: {})",
                self.entries
                    .iter()
                    .map(|e| e.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            ))
        })
    }

    /// Find by kind + window (how the coordinator resolves a Job).
    pub fn by_kind_window(&self, kind: &str, window: &[usize]) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.window == window)
            .ok_or_else(|| {
                Error::Artifact(format!("no artifact for kind '{kind}' window {window:?}"))
            })
    }

    /// Check every referenced HLO file exists.
    pub fn verify_files(&self) -> Result<()> {
        for e in &self.entries {
            if !e.path.exists() {
                return Err(Error::Artifact(format!(
                    "artifact file missing: {}",
                    e.path.display()
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "chunk_rows": 2048,
        "dtype": "f32",
        "artifacts": [
            {"name": "gaussian_w27", "kind": "gaussian", "file": "gaussian_w27.hlo.txt",
             "window": [3, 3, 3], "rows": 2048, "inputs": [[2048, 27], [27]], "outputs": [[2048]]},
            {"name": "curvature2d_w9", "kind": "curvature", "file": "curvature2d_w9.hlo.txt",
             "window": [3, 3], "rows": 2048, "inputs": [[2048, 9]], "outputs": [[2048]]}
        ]
    }"#;

    #[test]
    fn parses_and_indexes() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.chunk_rows, 2048);
        assert_eq!(m.entries().len(), 2);
        let g = m.by_name("gaussian_w27").unwrap();
        assert_eq!(g.kind, "gaussian");
        assert_eq!(g.cols(), 27);
        assert_eq!(g.path, Path::new("/tmp/artifacts/gaussian_w27.hlo.txt"));
        let c = m.by_kind_window("curvature", &[3, 3]).unwrap();
        assert_eq!(c.name, "curvature2d_w9");
    }

    #[test]
    fn lookup_errors_name_available() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/x")).unwrap();
        let err = m.by_name("nope").unwrap_err().to_string();
        assert!(err.contains("gaussian_w27"), "{err}");
        assert!(m.by_kind_window("gaussian", &[5, 5]).is_err());
    }

    #[test]
    fn rejects_inconsistent_entries() {
        // first input shape disagreeing with rows x window
        let bad = SAMPLE.replace("[[2048, 27], [27]]", "[[2048, 25], [27]]");
        assert!(ArtifactManifest::parse(&bad, Path::new("/x")).is_err());
        // even window
        let bad = SAMPLE.replace("[3, 3, 3]", "[4, 3, 3]");
        assert!(ArtifactManifest::parse(&bad, Path::new("/x")).is_err());
        // empty artifact list
        assert!(ArtifactManifest::parse(
            r#"{"chunk_rows": 2048, "artifacts": []}"#,
            Path::new("/x")
        )
        .is_err());
    }

    #[test]
    fn verify_files_reports_missing() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/definitely/missing")).unwrap();
        assert!(m.verify_files().is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = ArtifactManifest::load(&dir).unwrap();
        m.verify_files().unwrap();
        assert!(m.by_kind_window("gaussian", &[3, 3, 3]).is_ok());
        assert!(m.by_kind_window("bilateral_const", &[5, 5]).is_ok());
        assert!(m.by_kind_window("bilateral_adaptive", &[3, 3, 3]).is_ok());
        assert!(m.by_kind_window("curvature", &[3, 3]).is_ok());
    }
}
