//! Compile-once execute-many engine over the AOT artifacts.
//!
//! An [`Engine`] owns a PJRT client plus a cache of compiled executables,
//! keyed by artifact name; compilation happens lazily on first use and is
//! then amortized across every chunk of every job (the "compiled executable
//! cache" of DESIGN.md). Execution takes a melt row-block (possibly shorter
//! than the artifact's fixed chunk height — it is zero-padded, and the
//! padding sliced off the result per the coordinator contract).

use std::cell::RefCell;
use std::collections::HashMap;

use crate::error::{Error, Result};
use crate::runtime::artifact::{ArtifactEntry, ArtifactManifest};
use crate::runtime::client::PjrtContext;
use crate::runtime::xla_stub as xla;

/// Extra (non-melt) inputs of a variant, matching `inputs[1..]` of its
/// manifest entry: e.g. the kernel vector for `gaussian`, the spatial
/// component + scalar for the bilateral variants.
#[derive(Clone, Debug, Default)]
pub struct ExtraInputs {
    pub vectors: Vec<Vec<f32>>,
}

impl ExtraInputs {
    pub fn none() -> Self {
        Self { vectors: vec![] }
    }

    pub fn one(v: Vec<f32>) -> Self {
        Self { vectors: vec![v] }
    }

    pub fn two(a: Vec<f32>, b: Vec<f32>) -> Self {
        Self { vectors: vec![a, b] }
    }
}

/// Thread-confined PJRT engine: client + compiled-executable cache.
pub struct Engine {
    ctx: PjrtContext,
    manifest: ArtifactManifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Scratch buffer reused when a short final chunk must be zero-padded
    /// to the artifact's fixed height (avoids a 1 MiB alloc per tail call).
    pad_scratch: RefCell<Vec<f32>>,
}

/// Job-constant inputs pre-uploaded to device buffers once per job
/// (§Perf iteration 5): the kernel/spatial/stencil vectors never change
/// across a job's chunks, so re-marshalling them per chunk is pure waste.
pub struct PreparedInputs {
    buffers: Vec<xla::PjRtBuffer>,
}

impl Engine {
    /// Build an engine over an artifact directory (reads the manifest,
    /// verifies files; compiles lazily).
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(dir)?;
        manifest.verify_files()?;
        Self::with_manifest(manifest)
    }

    /// Build an engine over an already-parsed manifest — the coordinator
    /// loads and verifies the manifest ONCE on the leader (in
    /// `JobResources`) and hands each worker thread a copy, so a fleet of N
    /// workers does one disk read instead of N+1.
    pub fn with_manifest(manifest: ArtifactManifest) -> Result<Self> {
        Ok(Self {
            ctx: PjrtContext::cpu()?,
            manifest,
            cache: RefCell::new(HashMap::new()),
            pad_scratch: RefCell::new(Vec::new()),
        })
    }

    /// Upload the job-constant extra inputs (manifest `inputs[1..]`) to
    /// device buffers, validated against the entry's shapes.
    pub fn prepare_inputs(&self, entry: &ArtifactEntry, extra: &ExtraInputs) -> Result<PreparedInputs> {
        if extra.vectors.len() != entry.inputs.len() - 1 {
            return Err(Error::Runtime(format!(
                "artifact {} expects {} extra inputs, got {}",
                entry.name,
                entry.inputs.len() - 1,
                extra.vectors.len()
            )));
        }
        let mut buffers = Vec::with_capacity(extra.vectors.len());
        for (i, v) in extra.vectors.iter().enumerate() {
            let want = &entry.inputs[i + 1];
            let n: usize = want.iter().product();
            if v.len() != n {
                return Err(Error::Runtime(format!(
                    "extra input {i} for {}: {} values vs shape {want:?}",
                    entry.name,
                    v.len()
                )));
            }
            buffers.push(self.ctx.client().buffer_from_host_buffer(v, want, None)?);
        }
        Ok(PreparedInputs { buffers })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn describe(&self) -> String {
        self.ctx.describe()
    }

    /// Ensure `name` is compiled (useful to front-load compile cost before
    /// timing loops).
    pub fn warmup(&self, name: &str) -> Result<()> {
        let entry = self.manifest.by_name(name)?.clone();
        self.with_compiled(&entry, |_| Ok(()))
    }

    fn with_compiled<T>(
        &self,
        entry: &ArtifactEntry,
        f: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<T>,
    ) -> Result<T> {
        {
            let cache = self.cache.borrow();
            if let Some(exe) = cache.get(&entry.name) {
                return f(exe);
            }
        }
        let proto = xla::HloModuleProto::from_text_file(&entry.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.ctx.client().compile(&comp)?;
        let mut cache = self.cache.borrow_mut();
        let exe = cache.entry(entry.name.clone()).or_insert(exe);
        f(exe)
    }

    /// Execute one melt row-block through artifact `entry`, marshalling the
    /// extra inputs on the spot. Convenience wrapper over
    /// [`Engine::prepare_inputs`] + [`Engine::execute_prepared`]; the
    /// coordinator hot path prepares once per job instead.
    pub fn execute_chunk(
        &self,
        entry: &ArtifactEntry,
        block: &[f32],
        valid_rows: usize,
        extra: &ExtraInputs,
    ) -> Result<Vec<f32>> {
        let prepared = self.prepare_inputs(entry, extra)?;
        self.execute_prepared(entry, block, valid_rows, &prepared)
    }

    /// Execute one melt row-block through artifact `entry` with
    /// pre-uploaded job-constant inputs.
    ///
    /// `block` is `valid_rows * cols` values with `valid_rows <=
    /// entry.rows`; shorter blocks are zero-padded to the fixed chunk
    /// height (rows are independent, so padding is inert) and the result is
    /// truncated back to `valid_rows`. The melt block goes host→device as
    /// one shaped upload (no Literal intermediary — §Perf iteration 5).
    pub fn execute_prepared(
        &self,
        entry: &ArtifactEntry,
        block: &[f32],
        valid_rows: usize,
        prepared: &PreparedInputs,
    ) -> Result<Vec<f32>> {
        let cols = entry.cols();
        if block.len() != valid_rows * cols {
            return Err(Error::Runtime(format!(
                "block of {} values is not {valid_rows} rows x {cols} cols",
                block.len()
            )));
        }
        if valid_rows == 0 || valid_rows > entry.rows {
            return Err(Error::Runtime(format!(
                "valid_rows {valid_rows} outside 1..={}",
                entry.rows
            )));
        }
        if prepared.buffers.len() != entry.inputs.len() - 1 {
            return Err(Error::Runtime(format!(
                "artifact {} expects {} prepared inputs, got {}",
                entry.name,
                entry.inputs.len() - 1,
                prepared.buffers.len()
            )));
        }

        let dims = [entry.rows, cols];
        let melt_buf = if valid_rows == entry.rows {
            self.ctx.client().buffer_from_host_buffer(block, &dims, None)?
        } else {
            // zero-pad the tail chunk in the reusable scratch buffer
            let mut scratch = self.pad_scratch.borrow_mut();
            scratch.clear();
            scratch.resize(entry.rows * cols, 0.0);
            scratch[..block.len()].copy_from_slice(block);
            self.ctx.client().buffer_from_host_buffer(&scratch, &dims, None)?
        };

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + prepared.buffers.len());
        args.push(&melt_buf);
        args.extend(prepared.buffers.iter());

        let out = self.with_compiled(entry, |exe| {
            let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
            Ok(result[0][0].to_literal_sync()?)
        })?;
        // aot.py lowers with return_tuple=True -> a 1-tuple
        let mut values = out.to_tuple1()?.to_vec::<f32>()?;
        values.truncate(valid_rows);
        Ok(values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        // skip when no artifacts are built OR the PJRT bindings are stubbed
        if !PjrtContext::available() {
            return None;
        }
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn engine_loads_and_validates_inputs() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::from_dir(&dir).unwrap();
        let entry = engine.manifest().by_name("gaussian_w27").unwrap().clone();
        // wrong block size
        assert!(engine
            .execute_chunk(&entry, &[0.0; 26], 1, &ExtraInputs::one(vec![0.0; 27]))
            .is_err());
        // wrong extra input count
        assert!(engine
            .execute_chunk(&entry, &[0.0; 27], 1, &ExtraInputs::none())
            .is_err());
        // wrong extra input length
        assert!(engine
            .execute_chunk(&entry, &[0.0; 27], 1, &ExtraInputs::one(vec![0.0; 3]))
            .is_err());
        // zero rows
        assert!(engine
            .execute_chunk(&entry, &[], 0, &ExtraInputs::one(vec![0.0; 27]))
            .is_err());
    }

    #[test]
    fn gaussian_artifact_matches_native_kernel() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::from_dir(&dir).unwrap();
        let entry = engine.manifest().by_name("gaussian_w27").unwrap().clone();
        let rows = 300usize; // deliberately not the fixed chunk height
        let mut rng = crate::testing::SplitMix64::new(42);
        let block = rng.uniform_vec(rows * 27, 0.0, 255.0);
        let kernel = crate::kernels::gaussian::gaussian_kernel(&[3, 3, 3], 1.0);
        let got = engine
            .execute_chunk(&entry, &block, rows, &ExtraInputs::one(kernel.clone()))
            .unwrap();
        assert_eq!(got.len(), rows);
        let mut want = vec![0.0f32; rows];
        crate::kernels::paradigm::apply_kernel_broadcast_into(&block, rows, 27, &kernel, &mut want);
        crate::testing::assert_allclose(&got, &want, 1e-4, 1e-3);
    }
}
