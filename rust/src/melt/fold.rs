//! The fold (coupling) stage: per-row results -> grid tensor (Fig 2's final
//! aggregation), plus partition-aware reassembly used by the coordinator.

use crate::error::{Error, Result};
use crate::tensor::dense::Tensor;

/// Fold a per-row result vector back into the grid tensor of shape `s'`.
pub fn fold(row_results: &[f32], grid_shape: &[usize]) -> Result<Tensor<f32>> {
    let vol: usize = grid_shape.iter().product();
    if row_results.len() != vol {
        return Err(Error::shape(format!(
            "fold: {} results vs grid volume {vol} ({grid_shape:?})",
            row_results.len()
        )));
    }
    Tensor::from_vec(grid_shape, row_results.to_vec())
}

/// Reassemble per-partition result chunks (in partition order) into the grid
/// tensor. `ranges` are the row ranges of the partition; chunks may be padded
/// beyond their range length (fixed-shape PJRT outputs) — the excess is
/// sliced off, mirroring the coordinator's padding contract.
pub fn fold_partitions(
    chunks: &[Vec<f32>],
    ranges: &[std::ops::Range<usize>],
    grid_shape: &[usize],
) -> Result<Tensor<f32>> {
    if chunks.len() != ranges.len() {
        return Err(Error::shape(format!(
            "fold_partitions: {} chunks vs {} ranges",
            chunks.len(),
            ranges.len()
        )));
    }
    let vol: usize = grid_shape.iter().product();
    let mut out = vec![f32::NAN; vol];
    let mut covered = 0usize;
    for (chunk, range) in chunks.iter().zip(ranges) {
        let n = range.len();
        if chunk.len() < n {
            return Err(Error::shape(format!(
                "chunk of {} results cannot fill range {range:?}",
                chunk.len()
            )));
        }
        if range.end > vol {
            return Err(Error::shape(format!(
                "range {range:?} exceeds grid volume {vol}"
            )));
        }
        out[range.start..range.end].copy_from_slice(&chunk[..n]);
        covered += n;
    }
    if covered != vol {
        return Err(Error::Partition(format!(
            "partitions cover {covered} of {vol} grid points"
        )));
    }
    Tensor::from_vec(grid_shape, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::melt::melt::{melt, BoundaryMode};
    use crate::melt::grid::GridMode;
    use crate::melt::operator::Operator;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    #[test]
    fn fold_shapes() {
        let t = fold(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.at(&[1, 2]), 6.0);
        assert!(fold(&[1.0; 5], &[2, 3]).is_err());
    }

    #[test]
    fn melt_then_center_fold_is_identity() {
        // extracting the centre column and folding reproduces the tensor
        let x = Tensor::random(&[4, 5, 3], -2.0, 2.0, 8).unwrap();
        let op = Operator::cubic(3, 3).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        let centers: Vec<f32> = (0..m.rows()).map(|r| m.row(r)[m.center()]).collect();
        let back = fold(&centers, m.grid_shape()).unwrap();
        assert_allclose(back.data(), x.data(), 0.0, 0.0);
    }

    #[test]
    fn fold_partitions_reassembles() {
        let chunks = vec![vec![0.0, 1.0, 2.0], vec![3.0, 4.0, 5.0]];
        let ranges = vec![0..3, 3..6];
        let t = fold_partitions(&chunks, &ranges, &[2, 3]).unwrap();
        assert_eq!(t.data(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn fold_partitions_slices_padding() {
        // a padded fixed-shape chunk (PJRT contract): extra rows discarded
        let chunks = vec![vec![0.0, 1.0, 2.0, 9.0, 9.0], vec![3.0, 4.0, 5.0, 9.0]];
        let ranges = vec![0..3, 3..6];
        let t = fold_partitions(&chunks, &ranges, &[6]).unwrap();
        assert_eq!(t.data(), &[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn fold_partitions_detects_gaps_and_overruns() {
        let chunks = vec![vec![0.0; 2], vec![0.0; 2]];
        assert!(fold_partitions(&chunks, &[0..2, 3..5], &[6]).is_err()); // gap
        assert!(fold_partitions(&chunks, &[0..2, 2..7], &[6]).is_err()); // overrun + short chunk
        assert!(fold_partitions(&chunks, &[0..2], &[4]).is_err()); // count mismatch
    }

    #[test]
    fn partition_order_independence_property() {
        // §2.4: any row partition reassembles to the same tensor
        check_property("fold_partitions == fold", 30, |rng: &mut SplitMix64| {
            let n = 8 + rng.below(40);
            let results = rng.uniform_vec(n, -5.0, 5.0);
            // random contiguous partition
            let mut cuts: Vec<usize> = vec![0, n];
            for _ in 0..rng.below(4) {
                cuts.push(rng.below(n));
            }
            cuts.sort_unstable();
            cuts.dedup();
            let ranges: Vec<std::ops::Range<usize>> = cuts
                .windows(2)
                .filter(|w| w[0] < w[1])
                .map(|w| w[0]..w[1])
                .collect();
            let chunks: Vec<Vec<f32>> = ranges
                .iter()
                .map(|r| results[r.clone()].to_vec())
                .collect();
            let a = fold_partitions(&chunks, &ranges, &[n]).unwrap();
            let b = fold(&results, &[n]).unwrap();
            assert_allclose(a.data(), b.data(), 0.0, 0.0);
        });
    }
}
