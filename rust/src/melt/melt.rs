//! The melt operation: tensor -> melt matrix (the "decoupling" of Fig 2).
//!
//! Implementation notes. The gather is factored per axis: for axis `a` we
//! precompute `table[a][g][w]` = the flat-stride contribution of grid
//! position `g` combined with window offset `w` after boundary mapping.
//! The flat source index of any (grid point, window offset) pair is then a
//! sum of per-axis contributions, so the inner loop is pure integer adds —
//! no division, no per-element boundary branching on the hot path (boundary
//! handling is amortized into the tables). `Constant` mode, whose
//! out-of-range cells have no source index, uses a sentinel-checking path.
//!
//! All of that per-(shape, operator, grid, boundary) precomputation lives
//! in [`RowGather`], built once and reused for any number of row-range
//! gathers — the tile-streamed executor builds one per stage and calls
//! [`RowGather::gather_rows`] per cache-sized tile, so no global melt
//! matrix is ever materialized on the native backend. [`melt_into`],
//! [`melt_rows_into`] and [`melt_band_into`] are thin wrappers for one-off
//! use. Odometer scratch (the window index vector of the boundary path)
//! is allocated once per gather call, never per row.

use crate::error::{Error, Result};
use crate::melt::grid::{GridMode, QuasiGrid};
use crate::melt::matrix::MeltMatrix;
use crate::melt::operator::Operator;
use crate::tensor::dense::Tensor;
use crate::tensor::shape::row_major_strides;

/// Out-of-range handling at tensor borders.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BoundaryMode {
    /// Mirror without repeating the edge sample (numpy `reflect`) — the
    /// default used throughout the paper's experiments and by `ref.py`.
    Reflect,
    /// Clamp to the nearest edge sample (numpy `edge`).
    Nearest,
    /// Fill with a constant.
    Constant(f32),
    /// Periodic wrap (numpy `wrap`).
    Wrap,
}

/// Map coordinate `i` (possibly out of range) into `[0, d)` per `mode`.
/// Returns `None` only for `Constant`.
fn map_coord(i: isize, d: usize, mode: BoundaryMode) -> Option<usize> {
    let d = d as isize;
    if (0..d).contains(&i) {
        return Some(i as usize);
    }
    match mode {
        BoundaryMode::Reflect => {
            if d == 1 {
                return Some(0);
            }
            // period of the reflect pattern is 2(d-1)
            let p = 2 * (d - 1);
            let mut m = i.rem_euclid(p);
            if m >= d {
                m = p - m;
            }
            Some(m as usize)
        }
        BoundaryMode::Nearest => Some(i.clamp(0, d - 1) as usize),
        BoundaryMode::Wrap => Some(i.rem_euclid(d) as usize),
        BoundaryMode::Constant(_) => None,
    }
}

/// Per-axis contribution tables: `tables[a][g * window[a] + w]` holds the
/// stride-scaled mapped index, or -1 for Constant out-of-range.
fn build_tables(
    input_shape: &[usize],
    grid: &QuasiGrid,
    op: &Operator,
    mode: BoundaryMode,
) -> Vec<Vec<i64>> {
    let strides = row_major_strides(input_shape);
    let radius = op.radius();
    let mut tables = Vec::with_capacity(input_shape.len());
    for a in 0..input_shape.len() {
        let w = op.window()[a];
        let ge = grid.out_shape()[a];
        let mut table = vec![0i64; ge * w];
        for g in 0..ge {
            // input-space centre coordinate on this axis
            let centre = grid.to_input(&unit_idx(a, g, grid.out_shape().len()))[a];
            for k in 0..w {
                let coord = centre + k as isize - radius[a] as isize;
                table[g * w + k] = match map_coord(coord, input_shape[a], mode) {
                    Some(c) => (c * strides[a]) as i64,
                    None => -1,
                };
            }
        }
        tables.push(table);
    }
    tables
}

/// Helper: a grid multi-index that is `g` on axis `a` and 0 elsewhere.
fn unit_idx(a: usize, g: usize, rank: usize) -> Vec<usize> {
    let mut idx = vec![0usize; rank];
    idx[a] = g;
    idx
}

/// Allocate an uninitialized f32 buffer that the caller promises to fill
/// completely before reading. `melt_into` writes every element of its
/// output (both gather paths cover all `cols` of every row), so skipping
/// the ~`rows*cols*4`-byte memset is sound and saves a full write pass
/// over the buffer (§Perf iteration 4).
pub(crate) fn uninit_buffer(n: usize) -> Vec<f32> {
    let mut v = Vec::with_capacity(n);
    #[allow(clippy::uninit_vec)]
    // SAFETY: f32 has no drop glue and no invalid bit patterns; every
    // element is overwritten by melt_into before any read.
    unsafe {
        v.set_len(n);
    }
    v
}

/// Re-point a reused scratch vector at `n` elements without the zero-fill
/// `resize(n, 0.0)` would pay: the executor's tile buffers and value slabs
/// are fully overwritten (`gather_rows` covers every melt cell, every
/// `RowKernel` writes one value per row) before any element is read, so
/// the memset is a pure write pass over memory about to be rewritten —
/// same safety argument as [`uninit_buffer`] (§Perf iteration 4).
pub(crate) fn reuse_uninit(v: &mut Vec<f32>, n: usize) {
    v.clear();
    v.reserve(n);
    #[allow(clippy::uninit_vec)]
    // SAFETY: capacity >= n after reserve; f32 has no invalid bit
    // patterns; the caller overwrites all n elements before reading.
    unsafe {
        v.set_len(n);
    }
}

/// Melt `x` under operator `op` on the quasi-grid of `mode`, allocating the
/// output matrix.
pub fn melt(
    x: &Tensor<f32>,
    op: &Operator,
    grid_mode: GridMode,
    boundary: BoundaryMode,
) -> Result<MeltMatrix> {
    let grid = QuasiGrid::resolve(x.shape(), op, &grid_mode)?;
    let rows = grid.rows();
    let cols = op.ravel_len();
    let mut data = uninit_buffer(rows * cols);
    melt_into(x, op, &grid, boundary, &mut data)?;
    MeltMatrix::new(data, rows, cols, grid.out_shape().to_vec(), op.window().to_vec())
}

/// Melt into a caller-provided buffer of exactly `grid.rows() * op.ravel_len()`
/// elements — the allocation-free path for one-shot global melts.
pub fn melt_into(
    x: &Tensor<f32>,
    op: &Operator,
    grid: &QuasiGrid,
    boundary: BoundaryMode,
    out: &mut [f32],
) -> Result<()> {
    let g = RowGather::new(x.shape(), op, grid, boundary)?;
    if out.len() != g.rows() * g.cols() {
        return Err(Error::shape(format!(
            "melt_into buffer length {} != {}x{}",
            out.len(),
            g.rows(),
            g.cols()
        )));
    }
    g.gather_rows(x.data(), 0, 0..g.rows(), out)
}

/// Melt only grid rows `range` directly from the input tensor into `out`
/// (`range.len() * op.ravel_len()` values) — the row-range gather the
/// tile-streamed executor is built on. Every boundary mode is supported,
/// **including [`BoundaryMode::Wrap`]**: the whole tensor is readable, so
/// even non-local periodic gathers resolve (unlike [`melt_band_into`],
/// whose source is a partial value slab).
///
/// One-shot convenience over [`RowGather`]; callers gathering many ranges
/// of the same geometry should build the `RowGather` once and call
/// [`RowGather::gather_rows`] per range to amortize the table
/// precomputation.
pub fn melt_rows_into(
    x: &Tensor<f32>,
    op: &Operator,
    grid: &QuasiGrid,
    boundary: BoundaryMode,
    range: std::ops::Range<usize>,
    out: &mut [f32],
) -> Result<()> {
    RowGather::new(x.shape(), op, grid, boundary)?.gather_rows(x.data(), 0, range, out)
}

/// Maximum flat-row distance between a `Same`-grid point of `shape` and any
/// source row its `op` window can touch after (non-`Wrap`) boundary
/// mapping — the halo height of the chunk-resident pipeline executor.
///
/// Boundary mapping is 1-Lipschitz per axis for `Reflect`/`Nearest` (the
/// reflect triangle wave and the clamp both have slope ±1), and `Constant`
/// never reads out of range, so the per-axis reach is bounded by
/// `min(radius, extent - 1)`; flat rows are row-major over `shape`.
pub fn flat_halo(shape: &[usize], op: &Operator) -> usize {
    let strides = row_major_strides(shape);
    op.radius()
        .iter()
        .zip(shape)
        .zip(strides.iter())
        .map(|((&r, &d), &s)| r.min(d - 1) * s)
        .sum()
}

/// Re-melt a band of rows from a *value slab* instead of a full tensor —
/// the worker-local gather of the chunk-resident pipeline executor.
///
/// `src` holds per-row values for flat rows `[src_start, src_start +
/// src.len())` of a virtual tensor of `shape` (`Same` grid); this writes
/// the melt rows of `range` into `out` (`range.len() * op.ravel_len()`
/// values), reading only inside the slab — the slab must cover `range`
/// extended by [`flat_halo`] (clamped to the tensor). `boundary` must not
/// be [`BoundaryMode::Wrap`]: periodic gathers are non-local, so wrapped
/// stages take the global melt path instead.
pub fn melt_band_into(
    src: &[f32],
    src_start: usize,
    shape: &[usize],
    op: &Operator,
    boundary: BoundaryMode,
    range: std::ops::Range<usize>,
    out: &mut [f32],
) -> Result<()> {
    if matches!(boundary, BoundaryMode::Wrap) {
        return Err(Error::Operator(
            "melt_band_into does not support Wrap boundaries (non-local gathers)".into(),
        ));
    }
    let grid = QuasiGrid::resolve(shape, op, &GridMode::Same)?;
    RowGather::new(shape, op, &grid, boundary)?.gather_rows(src, src_start, range, out)
}

/// Unravel `flat` into a row-major multi-index over `shape`.
fn unravel(mut flat: usize, shape: &[usize]) -> Vec<usize> {
    let mut idx = vec![0usize; shape.len()];
    for a in (0..shape.len()).rev() {
        idx[a] = flat % shape[a];
        flat /= shape[a];
    }
    idx
}

/// Precomputed gather geometry for one `(input shape, operator, quasi-grid,
/// boundary)` tuple: the per-axis contribution tables, interior masks and
/// leading-offset deltas the hot loop needs, built **once** and reused for
/// any number of row-range gathers. This is what makes the tile-streamed
/// executor leader-free: every worker holds a shared reference to the
/// stage's `RowGather` and melts its own cache-sized tiles straight from
/// the source values — no global melt matrix, no serial leader phase, no
/// per-tile table rebuild.
///
/// A gather call reads `src` as the row-major values of the virtual input
/// tensor, starting at flat element `src_offset`. Two source regimes are
/// accepted:
///
/// * the **whole input** (`src_offset == 0`, full length) — any grid mode
///   and any boundary, including the non-local `Wrap`;
/// * a **partial value slab** — only for unit (`Same`-equivalent) grids
///   with non-`Wrap` boundaries, where the gather reach is bounded by
///   [`flat_halo`]; the slab must cover the requested range extended by
///   that halo (clamped to the tensor), as in [`melt_band_into`].
#[derive(Clone, Debug)]
pub struct RowGather {
    /// `tables[a][g * window[a] + w]`: stride-scaled mapped source index
    /// contribution, or -1 for Constant out-of-range.
    tables: Vec<Vec<i64>>,
    /// `interior[a][g]`: window fully in bounds on axis `a` at position `g`.
    interior: Vec<Vec<bool>>,
    /// Source deltas of every leading-axis window-offset combination.
    prefix_deltas: Vec<isize>,
    /// Interior-row copy plan: `prefix_deltas` segments merged into maximal
    /// source-contiguous `(start_delta, len)` runs. When adjacent window
    /// planes touch adjacent memory (innermost extent == innermost window),
    /// one long `copy_from_slice` replaces many `wlast`-sized ones — the
    /// vector units see a straight memcpy instead of short fixed copies.
    runs: Vec<(isize, usize)>,
    window: Vec<usize>,
    radius: Vec<usize>,
    gshape: Vec<usize>,
    grid: QuasiGrid,
    strides_in: Vec<usize>,
    input_numel: usize,
    rows: usize,
    cols: usize,
    fill: f32,
    has_sentinel: bool,
    /// Partial slabs are sound: unit grid (out shape == input shape,
    /// stride 1, origin 0) and a local (non-`Wrap`) boundary.
    slab_ok: bool,
    /// Flat-row gather reach for the slab-coverage check.
    halo: usize,
}

impl RowGather {
    /// Precompute the gather for `input_shape` under `op`/`grid`/`boundary`.
    pub fn new(
        input_shape: &[usize],
        op: &Operator,
        grid: &QuasiGrid,
        boundary: BoundaryMode,
    ) -> Result<Self> {
        let rank = input_shape.len();
        if op.rank() != rank {
            return Err(Error::shape(format!(
                "operator rank {} vs tensor rank {rank}",
                op.rank()
            )));
        }
        let tables = build_tables(input_shape, grid, op, boundary);
        let radius = op.radius();
        let window = op.window().to_vec();
        let strides_in = row_major_strides(input_shape);
        let interior: Vec<Vec<bool>> = (0..rank)
            .map(|a| {
                (0..grid.out_shape()[a])
                    .map(|g| {
                        let c = grid.to_input(&unit_idx(a, g, rank))[a];
                        c >= radius[a] as isize
                            && c + (radius[a] as isize) < input_shape[a] as isize
                    })
                    .collect()
            })
            .collect();
        let mut prefix_deltas: Vec<isize> = vec![0];
        for a in 0..rank - 1 {
            let mut next = Vec::with_capacity(prefix_deltas.len() * window[a]);
            for &d in &prefix_deltas {
                for k in 0..window[a] {
                    next.push(d + (k as isize - radius[a] as isize) * strides_in[a] as isize);
                }
            }
            prefix_deltas = next;
        }
        // merge source-contiguous segments into maximal runs (dst order is
        // prefix_deltas order, so only order-adjacent segments can merge)
        let wlast = window[rank - 1];
        let mut runs: Vec<(isize, usize)> = Vec::with_capacity(prefix_deltas.len());
        for &pd in &prefix_deltas {
            match runs.last_mut() {
                Some((start, len)) if *start + *len as isize == pd => *len += wlast,
                _ => runs.push((pd, wlast)),
            }
        }
        let wrap = matches!(boundary, BoundaryMode::Wrap);
        let unit_grid = grid.out_shape() == input_shape
            && grid.stride().iter().all(|&s| s == 1)
            && grid.to_input(&vec![0; rank]).iter().all(|&c| c == 0);
        Ok(Self {
            interior,
            prefix_deltas,
            radius,
            gshape: grid.out_shape().to_vec(),
            grid: grid.clone(),
            strides_in,
            input_numel: input_shape.iter().product(),
            rows: grid.rows(),
            cols: op.ravel_len(),
            fill: match boundary {
                BoundaryMode::Constant(c) => c,
                _ => 0.0,
            },
            has_sentinel: matches!(boundary, BoundaryMode::Constant(_)),
            slab_ok: unit_grid && !wrap,
            halo: flat_halo(input_shape, op),
            tables,
            window,
            runs,
        })
    }

    /// Total grid rows of this gather.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Melt columns (the operator's ravel length).
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Resident footprint of the precomputed tables — what a plan cache
    /// pays to keep this gather warm (per-axis index tables, interior
    /// masks, and the leading-axis prefix deltas; the struct's scalar
    /// fields are noise by comparison).
    pub fn table_bytes(&self) -> usize {
        let tables: usize = self.tables.iter().map(|t| t.len() * 8).sum();
        let interior: usize = self.interior.iter().map(|m| m.len()).sum();
        tables
            + interior
            + self.prefix_deltas.len() * std::mem::size_of::<isize>()
            + self.runs.len() * std::mem::size_of::<(isize, usize)>()
    }

    /// Gather melt rows `range` from `src` (values of the virtual input
    /// tensor from flat element `src_offset`) into `out`
    /// (`range.len() * cols` values). Validates the range, the output
    /// length, and — for partial slabs — the halo coverage.
    pub fn gather_rows(
        &self,
        src: &[f32],
        src_offset: usize,
        range: std::ops::Range<usize>,
        out: &mut [f32],
    ) -> Result<()> {
        if range.start > range.end || range.end > self.rows {
            return Err(Error::shape(format!(
                "gather range {range:?} outside 0..{}",
                self.rows
            )));
        }
        if out.len() != range.len() * self.cols {
            return Err(Error::shape(format!(
                "gather buffer length {} != {}x{}",
                out.len(),
                range.len(),
                self.cols
            )));
        }
        let full = src_offset == 0 && src.len() == self.input_numel;
        if !full {
            if !self.slab_ok {
                return Err(Error::Operator(
                    "partial value slabs require a unit grid and a non-Wrap boundary \
                     (non-local or re-indexed gathers need the whole input)"
                        .into(),
                ));
            }
            let need_lo = range.start.saturating_sub(self.halo);
            let need_hi = (range.end + self.halo).min(self.rows);
            if src_offset > need_lo || src_offset + src.len() < need_hi {
                return Err(Error::shape(format!(
                    "value slab {src_offset}..{} does not cover rows {need_lo}..{need_hi}",
                    src_offset + src.len()
                )));
            }
        }
        self.gather_unchecked(src, src_offset, range, out);
        Ok(())
    }

    /// The validated hot loop: interior rows take the contiguous-run fast
    /// path, boundary rows the table-odometer slow path. All odometer
    /// scratch (`gidx`, `wtab`, the window index vector) is allocated once
    /// per call — never per row.
    fn gather_unchecked(
        &self,
        src: &[f32],
        src_offset: usize,
        range: std::ops::Range<usize>,
        out: &mut [f32],
    ) {
        let rank = self.gshape.len();
        let cols = self.cols;
        let window = &self.window;
        let wlast = window[rank - 1];
        // odometer over grid indices; per-axis running contributions let
        // us avoid re-deriving the multi-index per row
        let mut gidx = unravel(range.start, &self.gshape);
        let mut wtab: Vec<&[i64]> = (0..rank)
            .map(|a| &self.tables[a][gidx[a] * window[a]..(gidx[a] + 1) * window[a]])
            .collect();
        // window-offset odometer of the slow path, hoisted out of the row
        // loop: a full cycle of `cols` increments returns it to all-zeros,
        // so it needs no per-row reset either
        let mut widx = vec![0usize; rank];
        // running centre flat index for the fast path (absolute, pre-offset)
        let mut centre_flat: isize = {
            let c0 = self.grid.to_input(&gidx);
            (0..rank).map(|a| c0[a] * self.strides_in[a] as isize).sum()
        };
        for (r, dst) in range.clone().zip(out.chunks_exact_mut(cols)) {
            if (0..rank).all(|a| self.interior[a][gidx[a]]) {
                // fast path: contiguous runs, no boundary mapping. When
                // window planes merged into longer runs at construction,
                // one wide copy per run; otherwise the run length is the
                // innermost window extent — typically 3 or 5 — so
                // fixed-width copies beat generic memcpy dispatch.
                let base = centre_flat - self.radius[rank - 1] as isize - src_offset as isize;
                if self.runs.len() < self.prefix_deltas.len() {
                    let mut doff = 0;
                    for &(rd, rl) in &self.runs {
                        let s = (base + rd) as usize;
                        dst[doff..doff + rl].copy_from_slice(&src[s..s + rl]);
                        doff += rl;
                    }
                } else {
                    match wlast {
                        3 => {
                            for (seg, &pd) in
                                dst.chunks_exact_mut(3).zip(self.prefix_deltas.iter())
                            {
                                let s = (base + pd) as usize;
                                let run: [f32; 3] = src[s..s + 3].try_into().unwrap();
                                seg.copy_from_slice(&run);
                            }
                        }
                        5 => {
                            for (seg, &pd) in
                                dst.chunks_exact_mut(5).zip(self.prefix_deltas.iter())
                            {
                                let s = (base + pd) as usize;
                                let run: [f32; 5] = src[s..s + 5].try_into().unwrap();
                                seg.copy_from_slice(&run);
                            }
                        }
                        _ => {
                            for (seg, &pd) in
                                dst.chunks_exact_mut(wlast).zip(self.prefix_deltas.iter())
                            {
                                let s = (base + pd) as usize;
                                seg.copy_from_slice(&src[s..s + wlast]);
                            }
                        }
                    }
                }
            } else {
                debug_assert!(widx.iter().all(|&w| w == 0));
                gather_row_slow(
                    dst,
                    src,
                    src_offset,
                    &wtab,
                    window,
                    rank,
                    self.fill,
                    self.has_sentinel,
                    &mut widx,
                );
            }
            // increment grid odometer and refresh per-axis table slices
            if r + 1 < range.end {
                for a in (0..rank).rev() {
                    gidx[a] += 1;
                    centre_flat += (self.grid.stride()[a] * self.strides_in[a]) as isize;
                    if gidx[a] < self.gshape[a] {
                        wtab[a] = &self.tables[a][gidx[a] * window[a]..(gidx[a] + 1) * window[a]];
                        break;
                    }
                    gidx[a] = 0;
                    centre_flat -=
                        (self.gshape[a] * self.grid.stride()[a] * self.strides_in[a]) as isize;
                    wtab[a] = &self.tables[a][0..window[a]];
                }
            }
        }
    }
}

/// Slow-path gather for one (boundary-touching) row: odometer over the
/// *leading* window axes only, with a branch-light direct scan of the
/// last-axis table per segment — the innermost loop is a straight
/// table-indexed copy the vector units can chew through, instead of a
/// per-element odometer step. Table entries are absolute flat indices;
/// `base` shifts them into slab coordinates. The caller provides the
/// window index vector `widx` (all zeros on entry; the full cycle of
/// leading increments returns it to all zeros on exit) so the scratch is
/// allocated once per gather call, not once per row.
#[allow(clippy::too_many_arguments)]
fn gather_row_slow(
    dst: &mut [f32],
    src: &[f32],
    base: usize,
    wtab: &[&[i64]],
    window: &[usize],
    rank: usize,
    fill: f32,
    has_sentinel: bool,
    widx: &mut [usize],
) {
    let last = wtab[rank - 1];
    let wlast = window[rank - 1];
    // sentinel entries contribute 0 to acc and 1 to neg (leading axes only)
    let lead = &wtab[..rank - 1];
    let mut acc: i64 = lead.iter().map(|t| t[0].max(0)).sum();
    let mut neg = lead.iter().filter(|t| t[0] < 0).count();
    for seg in dst.chunks_exact_mut(wlast) {
        if has_sentinel {
            if neg > 0 {
                seg.iter_mut().for_each(|d| *d = fill);
            } else {
                for (d, &t) in seg.iter_mut().zip(last.iter()) {
                    *d = if t < 0 { fill } else { src[(acc + t) as usize - base] };
                }
            }
        } else {
            for (d, &t) in seg.iter_mut().zip(last.iter()) {
                *d = src[(acc + t) as usize - base];
            }
        }
        // increment the leading-axis odometer
        for a in (0..rank - 1).rev() {
            let t = wtab[a];
            let old = t[widx[a]];
            if old < 0 {
                neg -= 1;
            } else {
                acc -= old;
            }
            widx[a] += 1;
            if widx[a] < window[a] {
                let new = t[widx[a]];
                if new < 0 {
                    neg += 1;
                } else {
                    acc += new;
                }
                break;
            }
            widx[a] = 0;
            let new = t[0];
            if new < 0 {
                neg += 1;
            } else {
                acc += new;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    fn melt_naive(
        x: &Tensor<f32>,
        op: &Operator,
        grid: &QuasiGrid,
        boundary: BoundaryMode,
    ) -> Vec<f32> {
        // direct per-element gather — the obviously-correct oracle
        let mut out = Vec::with_capacity(grid.rows() * op.ravel_len());
        for gidx in grid.shape_obj().iter_indices() {
            let centre = grid.to_input(&gidx);
            for off in op.offsets() {
                let mut idx = Vec::with_capacity(x.rank());
                let mut outside = false;
                for a in 0..x.rank() {
                    match map_coord(centre[a] + off[a], x.shape()[a], boundary) {
                        Some(c) => idx.push(c),
                        None => {
                            outside = true;
                            break;
                        }
                    }
                }
                out.push(if outside {
                    match boundary {
                        BoundaryMode::Constant(c) => c,
                        _ => unreachable!(),
                    }
                } else {
                    x.at(&idx)
                });
            }
        }
        out
    }

    #[test]
    fn map_coord_reflect() {
        // numpy reflect on d=4: -2 -> 2, -1 -> 1, 4 -> 2, 5 -> 1
        assert_eq!(map_coord(-2, 4, BoundaryMode::Reflect), Some(2));
        assert_eq!(map_coord(-1, 4, BoundaryMode::Reflect), Some(1));
        assert_eq!(map_coord(4, 4, BoundaryMode::Reflect), Some(2));
        assert_eq!(map_coord(5, 4, BoundaryMode::Reflect), Some(1));
        assert_eq!(map_coord(0, 1, BoundaryMode::Reflect), Some(0));
        assert_eq!(map_coord(3, 1, BoundaryMode::Reflect), Some(0));
    }

    #[test]
    fn map_coord_other_modes() {
        assert_eq!(map_coord(-3, 4, BoundaryMode::Nearest), Some(0));
        assert_eq!(map_coord(9, 4, BoundaryMode::Nearest), Some(3));
        assert_eq!(map_coord(-1, 4, BoundaryMode::Wrap), Some(3));
        assert_eq!(map_coord(4, 4, BoundaryMode::Wrap), Some(0));
        assert_eq!(map_coord(-1, 4, BoundaryMode::Constant(9.0)), None);
        assert_eq!(map_coord(2, 4, BoundaryMode::Constant(9.0)), Some(2));
    }

    #[test]
    fn center_column_is_input_ravel() {
        let x = Tensor::random(&[5, 6], 0.0, 10.0, 1).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        assert_eq!(m.rows(), 30);
        assert_eq!(m.cols(), 9);
        for r in 0..m.rows() {
            assert_eq!(m.row(r)[m.center()], x.data()[r]);
        }
    }

    #[test]
    fn interior_row_is_exact_neighbourhood() {
        let x = Tensor::random(&[4, 5, 6], -1.0, 1.0, 2).unwrap();
        let op = Operator::cubic(3, 3).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        // interior point (2, 2, 3)
        let flat = x.shape_obj().ravel(&[2, 2, 3]);
        let row = m.row(flat);
        let mut col = 0;
        for dz in -1isize..=1 {
            for dy in -1isize..=1 {
                for dx in -1isize..=1 {
                    let v = x.at(&[
                        (2 + dz) as usize,
                        (2 + dy) as usize,
                        (3 + dx) as usize,
                    ]);
                    assert_eq!(row[col], v, "col {col}");
                    col += 1;
                }
            }
        }
    }

    #[test]
    fn matches_naive_all_modes_property() {
        let modes = [
            BoundaryMode::Reflect,
            BoundaryMode::Nearest,
            BoundaryMode::Wrap,
            BoundaryMode::Constant(-7.5),
        ];
        check_property("melt == naive gather", 40, |rng: &mut SplitMix64| {
            let rank = 1 + rng.below(3);
            let dims: Vec<usize> = (0..rank).map(|_| 3 + rng.below(5)).collect();
            let window: Vec<usize> = (0..rank).map(|_| 1 + 2 * rng.below(2)).collect();
            let n: usize = dims.iter().product();
            let x = Tensor::from_vec(&dims, rng.uniform_vec(n, -9.0, 9.0)).unwrap();
            let op = Operator::new(&window).unwrap();
            let boundary = modes[rng.below(modes.len())];
            let gm = match rng.below(3) {
                0 => GridMode::Same,
                1 => GridMode::Valid,
                _ => GridMode::Strided((0..rank).map(|_| 1 + rng.below(2)).collect()),
            };
            let grid = match QuasiGrid::resolve(&dims, &op, &gm) {
                Ok(g) => g,
                Err(_) => return, // valid mode on small tensors can reject
            };
            let m = melt(&x, &op, gm, boundary).unwrap();
            let want = melt_naive(&x, &op, &grid, boundary);
            assert_allclose(m.data(), &want, 0.0, 0.0);
        });
    }

    #[test]
    fn valid_grid_needs_no_boundary() {
        // in Valid mode every window fits: Constant and Reflect must agree
        let x = Tensor::random(&[6, 7], 0.0, 1.0, 4).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let a = melt(&x, &op, GridMode::Valid, BoundaryMode::Constant(999.0)).unwrap();
        let b = melt(&x, &op, GridMode::Valid, BoundaryMode::Reflect).unwrap();
        assert_allclose(a.data(), b.data(), 0.0, 0.0);
    }

    #[test]
    fn constant_mode_fills_borders() {
        let x = Tensor::full(&[3], 1.0).unwrap();
        let op = Operator::new(&[3]).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Constant(5.0)).unwrap();
        assert_eq!(m.row(0), &[5.0, 1.0, 1.0]);
        assert_eq!(m.row(2), &[1.0, 1.0, 5.0]);
    }

    #[test]
    fn melt_into_rejects_bad_buffer() {
        let x = Tensor::full(&[4], 0.0).unwrap();
        let op = Operator::new(&[3]).unwrap();
        let grid = QuasiGrid::resolve(&[4], &op, &GridMode::Same).unwrap();
        let mut buf = vec![0.0; 5];
        assert!(melt_into(&x, &op, &grid, BoundaryMode::Reflect, &mut buf).is_err());
    }

    #[test]
    fn flat_halo_known_values() {
        // radius * row-major stride, capped at extent - 1 per axis
        let op3 = Operator::cubic(3, 2).unwrap();
        assert_eq!(flat_halo(&[10, 12], &op3), 12 + 1);
        let op5 = Operator::cubic(5, 3).unwrap();
        assert_eq!(flat_halo(&[8, 8, 8], &op5), 2 * 64 + 2 * 8 + 2);
        // window wider than the axis: reach caps at extent - 1
        assert_eq!(flat_halo(&[2, 4], &Operator::new(&[5, 3]).unwrap()), 4 + 1);
    }

    #[test]
    fn band_melt_matches_full_melt_property() {
        // the chunk-resident executor's contract: gathering a band from a
        // halo slab of values reproduces the full melt rows bit-for-bit
        let modes = [
            BoundaryMode::Reflect,
            BoundaryMode::Nearest,
            BoundaryMode::Constant(-3.25),
        ];
        check_property("melt_band_into == melt rows", 40, |rng: &mut SplitMix64| {
            let rank = 1 + rng.below(3);
            let dims: Vec<usize> = (0..rank).map(|_| 3 + rng.below(6)).collect();
            let window: Vec<usize> = (0..rank).map(|_| 1 + 2 * rng.below(2)).collect();
            let rows: usize = dims.iter().product();
            let values = rng.uniform_vec(rows, -9.0, 9.0);
            let op = Operator::new(&window).unwrap();
            let boundary = modes[rng.below(modes.len())];

            // reference: melt the values as a tensor of the grid shape
            let x = Tensor::from_vec(&dims, values.clone()).unwrap();
            let full = melt(&x, &op, GridMode::Same, boundary).unwrap();

            // random band, gathered once from the whole value array and
            // once from the minimal halo slab
            let start = rng.below(rows);
            let end = start + 1 + rng.below(rows - start);
            let cols = op.ravel_len();
            let mut band = vec![0.0f32; (end - start) * cols];
            melt_band_into(&values, 0, &dims, &op, boundary, start..end, &mut band).unwrap();
            assert_allclose(&band, &full.data()[start * cols..end * cols], 0.0, 0.0);

            let halo = flat_halo(&dims, &op);
            let lo = start.saturating_sub(halo);
            let hi = (end + halo).min(rows);
            let mut band2 = vec![0.0f32; (end - start) * cols];
            melt_band_into(&values[lo..hi], lo, &dims, &op, boundary, start..end, &mut band2)
                .unwrap();
            assert_allclose(&band2, &band, 0.0, 0.0);
        });
    }

    #[test]
    fn band_melt_rejects_bad_inputs() {
        let op = Operator::cubic(3, 1).unwrap();
        let values = vec![1.0f32; 8];
        let mut out = vec![0.0f32; 3 * 2];
        // Wrap gathers are non-local
        assert!(
            melt_band_into(&values, 0, &[8], &op, BoundaryMode::Wrap, 0..2, &mut out).is_err()
        );
        // slab too short for the halo
        assert!(melt_band_into(
            &values[..3],
            0,
            &[8],
            &op,
            BoundaryMode::Reflect,
            2..4,
            &mut out
        )
        .is_err());
        // wrong output length
        let mut short = vec![0.0f32; 3];
        assert!(melt_band_into(
            &values,
            0,
            &[8],
            &op,
            BoundaryMode::Reflect,
            0..2,
            &mut short
        )
        .is_err());
        // range outside the grid
        assert!(
            melt_band_into(&values, 0, &[8], &op, BoundaryMode::Reflect, 7..9, &mut out).is_err()
        );
    }

    #[test]
    fn melt_rows_into_matches_full_melt_all_modes_property() {
        // the tile-streamed executor's contract: gathering any row range
        // directly from the input tensor — Wrap included, since the whole
        // tensor is readable — reproduces the full melt rows bit-for-bit
        let modes = [
            BoundaryMode::Reflect,
            BoundaryMode::Nearest,
            BoundaryMode::Wrap,
            BoundaryMode::Constant(4.25),
        ];
        check_property("melt_rows_into == melt rows", 40, |rng: &mut SplitMix64| {
            let rank = 1 + rng.below(3);
            let dims: Vec<usize> = (0..rank).map(|_| 3 + rng.below(6)).collect();
            let window: Vec<usize> = (0..rank).map(|_| 1 + 2 * rng.below(2)).collect();
            let n: usize = dims.iter().product();
            let x = Tensor::from_vec(&dims, rng.uniform_vec(n, -9.0, 9.0)).unwrap();
            let op = Operator::new(&window).unwrap();
            let boundary = modes[rng.below(modes.len())];
            let gm = match rng.below(3) {
                0 => GridMode::Same,
                1 => GridMode::Valid,
                _ => GridMode::Strided((0..rank).map(|_| 1 + rng.below(2)).collect()),
            };
            let grid = match QuasiGrid::resolve(&dims, &op, &gm) {
                Ok(g) => g,
                Err(_) => return,
            };
            let full = melt(&x, &op, gm, boundary).unwrap();
            let rows = grid.rows();
            let cols = op.ravel_len();
            let start = rng.below(rows);
            let end = start + 1 + rng.below(rows - start);
            let mut band = vec![0.0f32; (end - start) * cols];
            melt_rows_into(&x, &op, &grid, boundary, start..end, &mut band).unwrap();
            assert_allclose(&band, &full.data()[start * cols..end * cols], 0.0, 0.0);
        });
    }

    #[test]
    fn row_gather_reuses_across_tiles() {
        // one RowGather, many disjoint tile gathers: together they must
        // equal the one-shot melt — the executor's tile loop in miniature
        let x = Tensor::random(&[9, 7], -5.0, 5.0, 17).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let grid = QuasiGrid::resolve(x.shape(), &op, &GridMode::Same).unwrap();
        let g = RowGather::new(x.shape(), &op, &grid, BoundaryMode::Wrap).unwrap();
        assert_eq!(g.rows(), 63);
        assert_eq!(g.cols(), 9);
        let full = melt(&x, &op, GridMode::Same, BoundaryMode::Wrap).unwrap();
        let mut tiled = vec![0.0f32; 63 * 9];
        for tile in [1usize, 4, 17, 100] {
            tiled.iter_mut().for_each(|v| *v = f32::NAN);
            let mut t = 0;
            while t < 63 {
                let te = (t + tile).min(63);
                g.gather_rows(x.data(), 0, t..te, &mut tiled[t * 9..te * 9]).unwrap();
                t = te;
            }
            assert_allclose(&tiled, full.data(), 0.0, 0.0);
        }
    }

    #[test]
    fn row_gather_validates_inputs() {
        let x = Tensor::full(&[6], 1.0).unwrap();
        let op = Operator::new(&[3]).unwrap();
        let grid = QuasiGrid::resolve(&[6], &op, &GridMode::Same).unwrap();
        let g = RowGather::new(&[6], &op, &grid, BoundaryMode::Reflect).unwrap();
        let mut out = vec![0.0f32; 6];
        // range outside the grid / wrong buffer length
        assert!(g.gather_rows(x.data(), 0, 5..7, &mut out).is_err());
        assert!(g.gather_rows(x.data(), 0, 0..1, &mut out).is_err());
        // partial slabs must cover the halo
        assert!(g.gather_rows(&x.data()[..2], 0, 2..4, &mut out).is_err());
        // Wrap gathers reject partial slabs outright (non-local)
        let gw = RowGather::new(&[6], &op, &grid, BoundaryMode::Wrap).unwrap();
        assert!(gw.gather_rows(&x.data()[..5], 0, 0..2, &mut out).is_err());
        // Strided grids re-index, so partial slabs are rejected there too
        let sg = QuasiGrid::resolve(&[6], &op, &GridMode::Strided(vec![2])).unwrap();
        let gs = RowGather::new(&[6], &op, &sg, BoundaryMode::Reflect).unwrap();
        let mut out3 = vec![0.0f32; 3 * 3];
        assert!(gs.gather_rows(&x.data()[..5], 0, 0..3, &mut out3).is_err());
        assert!(gs.gather_rows(x.data(), 0, 0..3, &mut out3).is_ok());
        // rank mismatch at construction
        assert!(RowGather::new(&[6, 6], &op, &grid, BoundaryMode::Reflect).is_err());
    }

    #[test]
    fn merged_runs_cover_contiguous_planes() {
        // innermost extent == innermost window: the three window planes of
        // an interior row touch adjacent memory, so they merge into one
        // 9-wide run; on a wider tensor nothing merges
        let op = Operator::new(&[3, 3]).unwrap();
        let narrow = QuasiGrid::resolve(&[7, 3], &op, &GridMode::Same).unwrap();
        let g = RowGather::new(&[7, 3], &op, &narrow, BoundaryMode::Reflect).unwrap();
        assert_eq!(g.runs, vec![(-3, 9)]);
        let wide = QuasiGrid::resolve(&[7, 8], &op, &GridMode::Same).unwrap();
        let gw = RowGather::new(&[7, 8], &op, &wide, BoundaryMode::Reflect).unwrap();
        assert_eq!(gw.runs.len(), gw.prefix_deltas.len());
        // and the merged copy plan reproduces the naive gather exactly
        let x = Tensor::random(&[7, 3], -4.0, 4.0, 23).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        let want = melt_naive(&x, &op, &narrow, BoundaryMode::Reflect);
        assert_allclose(m.data(), &want, 0.0, 0.0);
    }

    #[test]
    fn reuse_uninit_tracks_len() {
        let mut v = vec![1.0f32; 4];
        reuse_uninit(&mut v, 9);
        assert_eq!(v.len(), 9);
        v.iter_mut().for_each(|x| *x = 2.0);
        assert!(v.iter().all(|&x| x == 2.0));
        reuse_uninit(&mut v, 2);
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn python_ref_cross_check_2d() {
        // mirror of python tests/test_ref_properties.py::test_melt_reflect_boundary_2d
        let x = Tensor::from_vec(&[3, 3], (0..9).map(|i| i as f32).collect()).unwrap();
        let op = Operator::cubic(3, 2).unwrap();
        let m = melt(&x, &op, GridMode::Same, BoundaryMode::Reflect).unwrap();
        // numpy pad reflect around corner (0,0)
        assert_eq!(m.row(0), &[4.0, 3.0, 4.0, 1.0, 0.0, 1.0, 4.0, 3.0, 4.0]);
    }
}
