//! The neighbourhood operator `m` (paper Fig 2): a user-customized tensor of
//! the same rank as the data, defining the local region each melt row sees.

use crate::error::{Error, Result};

/// A neighbourhood operator: per-axis odd extents centred on the grid point.
///
/// The operator's *ravel vector* `v` (its raveled weights, when it carries
/// weights) and its extents travel with the melt matrix so downstream
/// broadcast/aggregation steps can be built without the original tensor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Operator {
    window: Vec<usize>,
}

impl Operator {
    /// Operator with explicit per-axis extents; all must be odd and >= 1.
    pub fn new(window: &[usize]) -> Result<Self> {
        if window.is_empty() {
            return Err(Error::Operator("empty operator window".into()));
        }
        if window.iter().any(|&w| w == 0 || w % 2 == 0) {
            return Err(Error::Operator(format!(
                "operator extents must be odd and positive, got {window:?}"
            )));
        }
        Ok(Self {
            window: window.to_vec(),
        })
    }

    /// Isotropic operator: `extent` repeated over `rank` axes
    /// (e.g. `cubic(3, 3)` is the 3x3x3 voxel operator).
    pub fn cubic(extent: usize, rank: usize) -> Result<Self> {
        if rank == 0 {
            return Err(Error::Operator("rank-0 operator".into()));
        }
        Self::new(&vec![extent; rank])
    }

    pub fn rank(&self) -> usize {
        self.window.len()
    }

    pub fn window(&self) -> &[usize] {
        &self.window
    }

    /// Number of elements in the operator's ravel vector (melt column count).
    pub fn ravel_len(&self) -> usize {
        self.window.iter().product()
    }

    /// Per-axis half-extents (radius).
    pub fn radius(&self) -> Vec<usize> {
        self.window.iter().map(|w| w / 2).collect()
    }

    /// Flat column index of the operator's centre (the grid point itself).
    pub fn center(&self) -> usize {
        self.ravel_len() / 2 // odd extents -> ravel midpoint
    }

    /// All window offsets relative to the centre, in ravel (row-major) order.
    /// This column order is the contract shared with `python/compile/kernels`.
    pub fn offsets(&self) -> Vec<Vec<isize>> {
        let mut out = Vec::with_capacity(self.ravel_len());
        let mut idx = vec![0usize; self.rank()];
        loop {
            out.push(
                idx.iter()
                    .zip(&self.window)
                    .map(|(&i, &w)| i as isize - (w / 2) as isize)
                    .collect(),
            );
            // odometer
            let mut a = self.rank();
            loop {
                if a == 0 {
                    return out;
                }
                a -= 1;
                idx[a] += 1;
                if idx[a] < self.window[a] {
                    break;
                }
                idx[a] = 0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rejects_even_or_zero_extent() {
        assert!(Operator::new(&[3, 4]).is_err());
        assert!(Operator::new(&[0]).is_err());
        assert!(Operator::new(&[]).is_err());
        assert!(Operator::cubic(3, 0).is_err());
    }

    #[test]
    fn cubic_builds_isotropic() {
        let op = Operator::cubic(5, 3).unwrap();
        assert_eq!(op.window(), &[5, 5, 5]);
        assert_eq!(op.ravel_len(), 125);
        assert_eq!(op.radius(), vec![2, 2, 2]);
        assert_eq!(op.center(), 62);
    }

    #[test]
    fn center_is_zero_offset() {
        for window in [vec![3, 3], vec![5, 3], vec![3, 3, 3], vec![1, 5, 3]] {
            let op = Operator::new(&window).unwrap();
            let offs = op.offsets();
            assert_eq!(offs.len(), op.ravel_len());
            assert!(offs[op.center()].iter().all(|&o| o == 0));
        }
    }

    #[test]
    fn offsets_row_major_order_2d() {
        let op = Operator::new(&[3, 3]).unwrap();
        let offs = op.offsets();
        assert_eq!(offs[0], vec![-1, -1]);
        assert_eq!(offs[1], vec![-1, 0]);
        assert_eq!(offs[3], vec![0, -1]);
        assert_eq!(offs[8], vec![1, 1]);
    }

    #[test]
    fn offsets_symmetric() {
        // window offsets come in +/- pairs summing to zero overall
        let op = Operator::new(&[3, 5, 3]).unwrap();
        let sum: Vec<isize> = op.offsets().iter().fold(vec![0; 3], |mut acc, o| {
            for (a, v) in o.iter().enumerate() {
                acc[a] += v;
            }
            acc
        });
        assert_eq!(sum, vec![0, 0, 0]);
    }

    #[test]
    fn anisotropic_extents() {
        let op = Operator::new(&[1, 5]).unwrap();
        assert_eq!(op.ravel_len(), 5);
        assert_eq!(op.radius(), vec![0, 2]);
    }
}
