//! The melt matrix — the paper's central contribution (§3.1, Figs 1–2).
//!
//! A melt matrix of tensor `x` under neighbourhood operator `m` is the
//! rank-2 array whose row `i` is the raveled `m`-superposed region of `x`
//! at grid point `i` of the quasi-grid `f1(x)`. It simultaneously satisfies
//! the three partition conditions of §2.4 *and* gives row-wise computational
//! independence, which is what licenses parallel acceleration:
//!
//! ```text
//! x (any rank) --melt--> M (rank 2) --partition--> row blocks
//!                                      | broadcast kernel per block
//! out (grid)  <--fold---  per-row results <--aggregate--
//! ```
//!
//! Submodules: [`operator`] (the user tensor `m`), [`grid`] (the quasi-grid
//! `f1`), [`melt`] (the decoupling), [`matrix`] (the intermediate
//! structure), [`fold`] (the coupling back), [`partition`] (row partitions
//! with §2.4 validity).

pub mod fold;
pub mod grid;
pub mod matrix;
#[allow(clippy::module_inception)]
pub mod melt;
pub mod operator;
pub mod partition;

pub use fold::fold;
pub use grid::{GridMode, QuasiGrid};
pub use matrix::MeltMatrix;
pub use melt::{flat_halo, melt, melt_band_into, melt_into, melt_rows_into, BoundaryMode, RowGather};
pub use operator::Operator;
pub use partition::RowPartition;
