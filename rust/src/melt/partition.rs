//! Row partitions of a melt matrix with the §2.4 validity conditions.
//!
//! The paper's three conditions for a columnar partition P of M ∈ R^{n×m}:
//!   1. P_i ∈ R^{k_i × m}, n = Σ k_i, k_i > 0;
//!   2. the parts are disjoint;
//!   3. an invertible (row-permutation) A exists with A·vstack(P) = M.
//!
//! Contiguous row ranges satisfy all three with A = I; the general interface
//! also models permuted partitions (work stealing can complete chunks out of
//! order) and exposes the §2.4 check as [`RowPartition::validate`].

use crate::error::{Error, Result};

/// A partition of `rows` melt rows into non-empty, disjoint, covering parts.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowPartition {
    rows: usize,
    ranges: Vec<std::ops::Range<usize>>,
}

impl RowPartition {
    /// Split `rows` into `parts` near-equal contiguous ranges
    /// (the "row-major matrix blocks" of the paper's Fig 6 benchmark).
    pub fn even(rows: usize, parts: usize) -> Result<Self> {
        if rows == 0 || parts == 0 {
            return Err(Error::Partition(format!(
                "cannot split {rows} rows into {parts} parts"
            )));
        }
        let parts = parts.min(rows);
        let base = rows / parts;
        let extra = rows % parts;
        let mut ranges = Vec::with_capacity(parts);
        let mut start = 0usize;
        for i in 0..parts {
            let k = base + usize::from(i < extra);
            ranges.push(start..start + k);
            start += k;
        }
        Ok(Self { rows, ranges })
    }

    /// Split into chunks of at most `chunk_rows` rows (the PJRT fixed-shape
    /// chunking policy; the final short chunk is padded at execution time).
    pub fn chunked(rows: usize, chunk_rows: usize) -> Result<Self> {
        if rows == 0 || chunk_rows == 0 {
            return Err(Error::Partition(format!(
                "cannot chunk {rows} rows by {chunk_rows}"
            )));
        }
        let mut ranges = Vec::with_capacity(rows.div_ceil(chunk_rows));
        let mut start = 0usize;
        while start < rows {
            let end = (start + chunk_rows).min(rows);
            ranges.push(start..end);
            start = end;
        }
        Ok(Self { rows, ranges })
    }

    /// Build from explicit ranges (validated).
    pub fn from_ranges(rows: usize, ranges: Vec<std::ops::Range<usize>>) -> Result<Self> {
        let p = Self { rows, ranges };
        p.validate()?;
        Ok(p)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn ranges(&self) -> &[std::ops::Range<usize>] {
        &self.ranges
    }

    pub fn num_parts(&self) -> usize {
        self.ranges.len()
    }

    /// Check the §2.4 conditions: non-empty parts (1), pairwise disjoint (2),
    /// and existence of a row permutation reassembling M (3) — equivalent to
    /// the sorted parts exactly covering `0..rows`.
    pub fn validate(&self) -> Result<()> {
        if self.ranges.is_empty() {
            return Err(Error::Partition("empty partition".into()));
        }
        let mut sorted: Vec<_> = self.ranges.clone();
        sorted.sort_by_key(|r| r.start);
        let mut cursor = 0usize;
        for r in &sorted {
            if r.is_empty() {
                return Err(Error::Partition(format!("empty part {r:?} (violates k_i > 0)")));
            }
            if r.start < cursor {
                return Err(Error::Partition(format!(
                    "part {r:?} overlaps previous coverage up to {cursor} (violates disjointness)"
                )));
            }
            if r.start > cursor {
                return Err(Error::Partition(format!(
                    "rows {cursor}..{} uncovered (violates reassembly)",
                    r.start
                )));
            }
            cursor = r.end;
        }
        if cursor != self.rows {
            return Err(Error::Partition(format!(
                "parts cover 0..{cursor}, matrix has {} rows",
                self.rows
            )));
        }
        Ok(())
    }

    /// The permutation A of condition 3: `perm[i]` is the original row index
    /// of row `i` of vstack(P). For sorted contiguous partitions this is the
    /// identity; for out-of-order completion it reorders chunks.
    pub fn permutation(&self) -> Vec<usize> {
        let mut perm = Vec::with_capacity(self.rows);
        for r in &self.ranges {
            perm.extend(r.clone());
        }
        perm
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn even_split_balances() {
        let p = RowPartition::even(10, 3).unwrap();
        assert_eq!(p.ranges(), &[0..4, 4..7, 7..10]);
        p.validate().unwrap();
        let p = RowPartition::even(9, 3).unwrap();
        assert_eq!(p.ranges(), &[0..3, 3..6, 6..9]);
    }

    #[test]
    fn even_split_caps_parts_at_rows() {
        let p = RowPartition::even(2, 8).unwrap();
        assert_eq!(p.num_parts(), 2);
        p.validate().unwrap();
    }

    #[test]
    fn chunked_split() {
        let p = RowPartition::chunked(10, 4).unwrap();
        assert_eq!(p.ranges(), &[0..4, 4..8, 8..10]);
        p.validate().unwrap();
        assert!(RowPartition::chunked(0, 4).is_err());
        assert!(RowPartition::chunked(4, 0).is_err());
    }

    #[test]
    fn validate_rejects_violations() {
        // overlap (condition 2)
        assert!(RowPartition::from_ranges(6, vec![0..4, 3..6]).is_err());
        // gap (condition 3)
        assert!(RowPartition::from_ranges(6, vec![0..2, 3..6]).is_err());
        // empty part (condition 1)
        assert!(RowPartition::from_ranges(6, vec![0..0, 0..6]).is_err());
        // over-coverage
        assert!(RowPartition::from_ranges(6, vec![0..7]).is_err());
    }

    #[test]
    fn out_of_order_ranges_are_valid() {
        // work stealing may record parts out of order; §2.4 only demands a
        // permutation A exists.
        let p = RowPartition::from_ranges(6, vec![3..6, 0..3]).unwrap();
        assert_eq!(p.permutation(), vec![3, 4, 5, 0, 1, 2]);
    }

    #[test]
    fn permutation_is_bijective_property() {
        check_property("partition permutation is a bijection", 30, |rng: &mut SplitMix64| {
            let rows = 4 + rng.below(60);
            let parts = 1 + rng.below(6);
            let p = RowPartition::even(rows, parts).unwrap();
            let mut perm = p.permutation();
            assert_eq!(perm.len(), rows);
            perm.sort_unstable();
            assert!(perm.iter().enumerate().all(|(i, &v)| i == v));
        });
    }

    #[test]
    fn chunked_part_sizes_bounded_property() {
        check_property("chunk sizes bounded", 30, |rng: &mut SplitMix64| {
            let rows = 1 + rng.below(500);
            let chunk = 1 + rng.below(64);
            let p = RowPartition::chunked(rows, chunk).unwrap();
            p.validate().unwrap();
            for r in p.ranges() {
                assert!(r.len() <= chunk && !r.is_empty());
            }
        });
    }
}
