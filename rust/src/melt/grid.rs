//! The quasi-grid `f1` (paper Fig 2): computes which grid points (and hence
//! how many melt rows) a traversal of tensor `x` under operator `m` visits.
//!
//! The paper's three ravel regimes (Fig 1) map to:
//! - `Same`    — global filtering: the grid is `x`'s own structure (d_e);
//! - `Valid`   — shrinking manipulations: only fully-interior points (d_l);
//! - `Strided` — hyperplane families expanded with pre-defined stride
//!   distances along their coordinates (d_g, e.g. pooling/downsampling).

use crate::error::{Error, Result};
use crate::melt::operator::Operator;
use crate::tensor::shape::Shape;

/// Grid construction mode.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum GridMode {
    /// One grid point per input element (output shape == input shape).
    Same,
    /// Only positions where the whole operator fits inside the tensor.
    Valid,
    /// `Same` semantics but sampling every `stride[a]`-th point on axis `a`.
    Strided(Vec<usize>),
}

/// A resolved quasi-grid: output shape + per-axis start offset and stride
/// mapping grid coordinates back to input coordinates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuasiGrid {
    out_shape: Vec<usize>,
    origin: Vec<isize>,
    stride: Vec<usize>,
}

impl QuasiGrid {
    /// Resolve a grid for `input_shape` under `op` and `mode`.
    pub fn resolve(input_shape: &[usize], op: &Operator, mode: &GridMode) -> Result<Self> {
        if input_shape.len() != op.rank() {
            return Err(Error::shape(format!(
                "operator rank {} vs tensor rank {}",
                op.rank(),
                input_shape.len()
            )));
        }
        let radius = op.radius();
        match mode {
            GridMode::Same => Ok(Self {
                out_shape: input_shape.to_vec(),
                origin: vec![0; input_shape.len()],
                stride: vec![1; input_shape.len()],
            }),
            GridMode::Valid => {
                let mut out = Vec::with_capacity(input_shape.len());
                for (a, (&d, &r)) in input_shape.iter().zip(&radius).enumerate() {
                    if d < 2 * r + 1 {
                        return Err(Error::shape(format!(
                            "axis {a}: extent {d} smaller than operator window {}",
                            2 * r + 1
                        )));
                    }
                    out.push(d - 2 * r);
                }
                Ok(Self {
                    out_shape: out,
                    origin: radius.iter().map(|&r| r as isize).collect(),
                    stride: vec![1; input_shape.len()],
                })
            }
            GridMode::Strided(strides) => {
                if strides.len() != input_shape.len() {
                    return Err(Error::shape(format!(
                        "stride rank {} vs tensor rank {}",
                        strides.len(),
                        input_shape.len()
                    )));
                }
                if strides.iter().any(|&s| s == 0) {
                    return Err(Error::shape("zero stride"));
                }
                // crossover points of the expanded hyperplane families:
                // ceil(d / stride) sample points per axis, starting at 0.
                let out: Vec<usize> = input_shape
                    .iter()
                    .zip(strides)
                    .map(|(&d, &s)| d.div_ceil(s))
                    .collect();
                Ok(Self {
                    out_shape: out,
                    origin: vec![0; input_shape.len()],
                    stride: strides.clone(),
                })
            }
        }
    }

    /// The grid tensor's shape `s'` (defines the melt row count).
    pub fn out_shape(&self) -> &[usize] {
        &self.out_shape
    }

    /// Total number of grid points (= melt matrix rows).
    pub fn rows(&self) -> usize {
        self.out_shape.iter().product()
    }

    /// Per-axis grid-to-input stride.
    pub fn stride(&self) -> &[usize] {
        &self.stride
    }

    /// Map a grid multi-index to the input-space coordinates of its centre.
    pub fn to_input(&self, grid_idx: &[usize]) -> Vec<isize> {
        grid_idx
            .iter()
            .zip(&self.origin)
            .zip(&self.stride)
            .map(|((&g, &o), &s)| o + (g * s) as isize)
            .collect()
    }

    /// Shape object for ravel/unravel over the grid.
    pub fn shape_obj(&self) -> Shape {
        Shape::new(&self.out_shape).expect("grid shapes are validated non-empty")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(window: &[usize]) -> Operator {
        Operator::new(window).unwrap()
    }

    #[test]
    fn same_grid_is_input_shape() {
        let g = QuasiGrid::resolve(&[10, 12], &op(&[3, 3]), &GridMode::Same).unwrap();
        assert_eq!(g.out_shape(), &[10, 12]);
        assert_eq!(g.rows(), 120);
        assert_eq!(g.to_input(&[0, 0]), vec![0, 0]);
        assert_eq!(g.to_input(&[9, 11]), vec![9, 11]);
    }

    #[test]
    fn valid_grid_shrinks_by_window() {
        let g = QuasiGrid::resolve(&[10, 12], &op(&[3, 5]), &GridMode::Valid).unwrap();
        assert_eq!(g.out_shape(), &[8, 8]);
        // first valid centre is the radius
        assert_eq!(g.to_input(&[0, 0]), vec![1, 2]);
        assert_eq!(g.to_input(&[7, 7]), vec![8, 9]);
    }

    #[test]
    fn valid_grid_rejects_small_tensor() {
        assert!(QuasiGrid::resolve(&[2, 10], &op(&[3, 3]), &GridMode::Valid).is_err());
    }

    #[test]
    fn strided_grid_ceil_semantics() {
        let g = QuasiGrid::resolve(&[10, 9], &op(&[3, 3]), &GridMode::Strided(vec![2, 3])).unwrap();
        assert_eq!(g.out_shape(), &[5, 3]);
        assert_eq!(g.to_input(&[1, 1]), vec![2, 3]);
        assert_eq!(g.to_input(&[4, 2]), vec![8, 6]);
    }

    #[test]
    fn strided_rejects_bad_strides() {
        assert!(QuasiGrid::resolve(&[10], &op(&[3]), &GridMode::Strided(vec![0])).is_err());
        assert!(QuasiGrid::resolve(&[10], &op(&[3]), &GridMode::Strided(vec![1, 1])).is_err());
    }

    #[test]
    fn rank_mismatch_rejected() {
        assert!(QuasiGrid::resolve(&[10, 10], &op(&[3]), &GridMode::Same).is_err());
    }

    #[test]
    fn stride_one_equals_same() {
        let a = QuasiGrid::resolve(&[7, 8], &op(&[3, 3]), &GridMode::Same).unwrap();
        let b = QuasiGrid::resolve(&[7, 8], &op(&[3, 3]), &GridMode::Strided(vec![1, 1])).unwrap();
        assert_eq!(a.out_shape(), b.out_shape());
        assert_eq!(a.to_input(&[3, 4]), b.to_input(&[3, 4]));
    }
}
