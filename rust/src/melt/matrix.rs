//! The melt matrix intermediate structure (paper Fig 1/2).
//!
//! Besides the rank-2 data, the structure carries the grid shape `s'` and
//! the operator's ravel metadata — "for the facilitation for subsequent
//! partition, broadcast operations ... as well as further aggregation
//! manipulations" (paper §3.1).

use crate::error::{Error, Result};
use crate::tensor::dense::Tensor;

/// Row-decoupled melt matrix: `rows x cols` f32 in row-major order, plus the
/// metadata needed to fold results back and to re-melt on workers.
#[derive(Clone, Debug, PartialEq)]
pub struct MeltMatrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
    grid_shape: Vec<usize>,
    window: Vec<usize>,
}

impl MeltMatrix {
    /// Assemble from parts (checked).
    pub fn new(
        data: Vec<f32>,
        rows: usize,
        cols: usize,
        grid_shape: Vec<usize>,
        window: Vec<usize>,
    ) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::shape(format!(
                "melt data length {} != {rows}x{cols}",
                data.len()
            )));
        }
        if grid_shape.iter().product::<usize>() != rows {
            return Err(Error::shape(format!(
                "grid shape {grid_shape:?} volume != rows {rows}"
            )));
        }
        if window.iter().product::<usize>() != cols {
            return Err(Error::shape(format!(
                "window {window:?} ravel length != cols {cols}"
            )));
        }
        Ok(Self {
            data,
            rows,
            cols,
            grid_shape,
            window,
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// The grid tensor shape `s'` results fold back to.
    pub fn grid_shape(&self) -> &[usize] {
        &self.grid_shape
    }

    /// The operator extents this matrix was melted with.
    pub fn window(&self) -> &[usize] {
        &self.window
    }

    /// Flat column index of the operator centre.
    pub fn center(&self) -> usize {
        self.cols / 2
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// One row (the raveled neighbourhood of grid point `r`).
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Zero-copy view of a contiguous row block `[start, end)` — the unit of
    /// work the coordinator ships to workers.
    pub fn row_block(&self, start: usize, end: usize) -> Result<&[f32]> {
        if start > end || end > self.rows {
            return Err(Error::shape(format!(
                "row block {start}..{end} out of range 0..{}",
                self.rows
            )));
        }
        Ok(&self.data[start * self.cols..end * self.cols])
    }

    /// Owned sub-matrix over a row range (used when a partition must be
    /// shipped across an ownership boundary, e.g. into a PJRT literal).
    pub fn sub_matrix(&self, start: usize, end: usize) -> Result<MeltMatrix> {
        let block = self.row_block(start, end)?.to_vec();
        MeltMatrix::new(
            block,
            end - start,
            self.cols,
            vec![end - start],
            self.window.clone(),
        )
    }

    /// View the melt matrix as a rank-2 tensor (copies).
    pub fn to_tensor(&self) -> Tensor<f32> {
        Tensor::from_vec(&[self.rows, self.cols], self.data.clone())
            .expect("melt dims are consistent by construction")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MeltMatrix {
        MeltMatrix::new((0..24).map(|i| i as f32).collect(), 8, 3, vec![2, 4], vec![3]).unwrap()
    }

    #[test]
    fn construction_validates() {
        assert!(MeltMatrix::new(vec![0.0; 10], 5, 2, vec![5], vec![3]).is_err()); // window
        assert!(MeltMatrix::new(vec![0.0; 10], 5, 2, vec![4], vec![1, 1, 2]).is_err()); // grid
        assert!(MeltMatrix::new(vec![0.0; 9], 5, 2, vec![5], vec![1, 1, 2]).is_err()); // len
    }

    #[test]
    fn rows_and_blocks() {
        let m = sample();
        assert_eq!(m.row(2), &[6.0, 7.0, 8.0]);
        assert_eq!(m.row_block(1, 3).unwrap(), &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        assert!(m.row_block(7, 9).is_err());
        assert!(m.row_block(3, 2).is_err());
    }

    #[test]
    fn sub_matrix_is_self_contained() {
        let m = sample();
        let s = m.sub_matrix(2, 5).unwrap();
        assert_eq!(s.rows(), 3);
        assert_eq!(s.cols(), 3);
        assert_eq!(s.row(0), m.row(2));
        assert_eq!(s.window(), m.window());
    }

    #[test]
    fn to_tensor_shape() {
        let t = sample().to_tensor();
        assert_eq!(t.shape(), &[8, 3]);
        assert_eq!(t.at(&[1, 1]), 4.0);
    }

    #[test]
    fn center_column() {
        let m = MeltMatrix::new(vec![0.0; 45], 5, 9, vec![5], vec![3, 3]).unwrap();
        assert_eq!(m.center(), 4);
    }
}
