//! Minimal `.npy` (NumPy format 1.0) reader/writer for f32 tensors.
//!
//! This is the interchange format between the rust substrate and the python
//! build path: python tests can emit golden tensors, and examples can dump
//! results that `numpy.load` opens directly. Only little-endian f32,
//! C-order, format version 1.0 — exactly what both sides produce.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::dense::Tensor;

const MAGIC: &[u8; 6] = b"\x93NUMPY";

/// Serialize a tensor to `.npy` bytes.
///
/// Errors with [`Error::Format`] if the padded header exceeds the u16
/// length field of format 1.0 (a shape tuple tens of thousands of
/// characters long); truncating the length silently would make the
/// writer emit bytes its own reader misparses.
pub fn to_npy_bytes(t: &Tensor<f32>) -> Result<Vec<u8>> {
    let shape_str = match t.shape().len() {
        1 => format!("({},)", t.shape()[0]),
        _ => format!(
            "({})",
            t.shape()
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad header so that magic(6)+version(2)+len(2)+header is a multiple of 64
    let unpadded = 6 + 2 + 2 + header.len() + 1; // +1 for the trailing \n
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let hlen = u16::try_from(header.len()).map_err(|_| {
        Error::Format(format!(
            "npy header is {} bytes; format 1.0 caps it at {} (shape rank too high)",
            header.len(),
            u16::MAX
        ))
    })?;

    let mut out = Vec::with_capacity(10 + header.len() + t.len() * 4);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&[1u8, 0u8]); // version 1.0
    out.extend_from_slice(&hlen.to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for &v in t.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    Ok(out)
}

/// Write a tensor to a `.npy` file.
pub fn save(t: &Tensor<f32>, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&to_npy_bytes(t)?)?;
    Ok(())
}

/// Parse `.npy` bytes into a tensor (little-endian f32, C-order only).
pub fn from_npy_bytes(bytes: &[u8]) -> Result<Tensor<f32>> {
    if bytes.len() < 10 || &bytes[..6] != MAGIC {
        return Err(Error::Format("not an npy file (bad magic)".into()));
    }
    let (major, _minor) = (bytes[6], bytes[7]);
    if major != 1 {
        return Err(Error::Format(format!("unsupported npy version {major}")));
    }
    let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
    if bytes.len() < 10 + hlen {
        return Err(Error::Format("truncated npy header".into()));
    }
    let header = std::str::from_utf8(&bytes[10..10 + hlen])
        .map_err(|_| Error::Format("npy header not utf-8".into()))?;
    if !header.contains("'<f4'") {
        return Err(Error::Format(format!("unsupported dtype in header: {header}")));
    }
    if header.contains("'fortran_order': True") {
        return Err(Error::Format("fortran-order npy not supported".into()));
    }
    let dims = parse_shape(header)?;
    let n: usize = dims.iter().product();
    let body = &bytes[10 + hlen..];
    if body.len() < n * 4 {
        return Err(Error::Format(format!(
            "npy body too short: {} bytes for {n} f32",
            body.len()
        )));
    }
    // an npy data section is exactly shape-volume × itemsize bytes;
    // trailing bytes mean a corrupt header or a concatenated/truncated
    // write, so reject instead of silently dropping them
    if body.len() > n * 4 {
        return Err(Error::Format(format!(
            "npy body has {} trailing bytes after {n} f32",
            body.len() - n * 4
        )));
    }
    let data: Vec<f32> = body[..n * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Tensor::from_vec(&dims, data)
}

/// Read a `.npy` file.
pub fn load(path: impl AsRef<Path>) -> Result<Tensor<f32>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    from_npy_bytes(&bytes)
}

fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let start = header
        .find("'shape':")
        .ok_or_else(|| Error::Format("npy header missing shape".into()))?;
    let rest = &header[start..];
    let open = rest
        .find('(')
        .ok_or_else(|| Error::Format("npy shape missing '('".into()))?;
    let close = rest
        .find(')')
        .ok_or_else(|| Error::Format("npy shape missing ')'".into()))?;
    let inner = &rest[open + 1..close];
    let dims: Vec<usize> = inner
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse::<usize>()
                .map_err(|_| Error::Format(format!("bad npy extent '{s}'")))
        })
        .collect::<Result<_>>()?;
    if dims.is_empty() {
        return Err(Error::Format("rank-0 npy not supported".into()));
    }
    Ok(dims)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn round_trip_2d() {
        let t = Tensor::from_vec(&[3, 4], (0..12).map(|i| i as f32 * 0.5).collect()).unwrap();
        let back = from_npy_bytes(&to_npy_bytes(&t).unwrap()).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn round_trip_1d_trailing_comma() {
        let t = Tensor::from_vec(&[5], vec![1.0, -2.0, 3.5, 0.0, 9.0]).unwrap();
        let bytes = to_npy_bytes(&t).unwrap();
        // 1-D shapes serialize with the python tuple trailing comma
        let header = String::from_utf8_lossy(&bytes[10..]).to_string();
        assert!(header.contains("(5,)"));
        assert_eq!(from_npy_bytes(&bytes).unwrap(), t);
    }

    #[test]
    fn header_alignment_is_64() {
        let t = Tensor::<f32>::zeros(&[7, 7, 7]).unwrap();
        let bytes = to_npy_bytes(&t).unwrap();
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + hlen) % 64, 0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(from_npy_bytes(b"not npy at all").is_err());
        let t = Tensor::<f32>::zeros(&[2, 2]).unwrap();
        let mut bytes = to_npy_bytes(&t).unwrap();
        bytes.truncate(bytes.len() - 4); // drop one f32
        assert!(from_npy_bytes(&bytes).is_err());
    }

    /// Forge an npy byte stream with an arbitrary header string.
    fn forged(header: &str, body_f32: usize) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&[1u8, 0u8]);
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&vec![0u8; body_f32 * 4]);
        out
    }

    #[test]
    fn rejects_truncated_header() {
        // header length field claims more bytes than the stream carries
        let mut bytes = to_npy_bytes(&Tensor::<f32>::zeros(&[3]).unwrap()).unwrap();
        let hlen = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        bytes.truncate(10 + hlen - 5);
        let err = from_npy_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("truncated npy header"), "{err}");
        // ... and a header that is not utf-8
        let mut bytes = to_npy_bytes(&Tensor::<f32>::zeros(&[3]).unwrap()).unwrap();
        bytes[12] = 0xFF;
        assert!(from_npy_bytes(&bytes).is_err());
    }

    #[test]
    fn rejects_wrong_dtype_order_and_version() {
        // fortran (column-major) order
        let h = "{'descr': '<f4', 'fortran_order': True, 'shape': (2, 2), }\n";
        let err = from_npy_bytes(&forged(h, 4)).unwrap_err();
        assert!(err.to_string().contains("fortran"), "{err}");
        // f64 dtype
        let h = "{'descr': '<f8', 'fortran_order': False, 'shape': (4,), }\n";
        let err = from_npy_bytes(&forged(h, 8)).unwrap_err();
        assert!(err.to_string().contains("dtype"), "{err}");
        // big-endian f32
        let h = "{'descr': '>f4', 'fortran_order': False, 'shape': (4,), }\n";
        assert!(from_npy_bytes(&forged(h, 4)).is_err());
        // format version 2.x (u32 header length — unsupported)
        let mut bytes = to_npy_bytes(&Tensor::<f32>::zeros(&[2]).unwrap()).unwrap();
        bytes[6] = 2;
        let err = from_npy_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn rejects_malformed_shapes() {
        let wrap = |shape: &str| {
            forged(
                &format!("{{'descr': '<f4', 'fortran_order': False, 'shape': {shape}, }}\n"),
                64,
            )
        };
        // rank-0 scalar
        assert!(from_npy_bytes(&wrap("()")).is_err());
        // non-numeric extent
        assert!(from_npy_bytes(&wrap("(x, 3)")).is_err());
        // missing parens entirely
        let h = "{'descr': '<f4', 'fortran_order': False, }\n";
        assert!(from_npy_bytes(&forged(h, 4)).is_err());
        // zero extent: volume 0 never matches a non-empty body
        assert!(from_npy_bytes(&wrap("(0, 3)")).is_err());
    }

    #[test]
    fn rejects_trailing_bytes() {
        // a body longer than the shape volume is corruption, not padding
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let mut bytes = to_npy_bytes(&t).unwrap();
        bytes.extend_from_slice(&7.5f32.to_le_bytes());
        let err = from_npy_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn oversized_header_errors_instead_of_truncating() {
        // A shape tuple of ~22k unit extents pads the header past the
        // u16 length field of format 1.0. The old writer emitted
        // `header.len() as u16` — a silently wrapped length whose stream
        // the reader then misparses; now it must refuse to serialize.
        let dims = vec![1usize; 22_000];
        let t = Tensor::from_vec(&dims, vec![1.0]).unwrap();
        let err = to_npy_bytes(&t).unwrap_err();
        assert!(matches!(err, Error::Format(_)), "{err}");
        assert!(err.to_string().contains("npy header"), "{err}");
        // a forged stream mimicking the old truncated-length output is
        // rejected by the reader rather than misparsed
        let mut huge = String::from("{'descr': '<f4', 'fortran_order': False, 'shape': (");
        huge.push_str(&vec!["1"; 22_000].join(", "));
        huge.push_str("), }\n");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&[1u8, 0u8]);
        bytes.extend_from_slice(&(huge.len() as u16).to_le_bytes()); // wraps
        bytes.extend_from_slice(huge.as_bytes());
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        assert!(from_npy_bytes(&bytes).is_err());
    }

    #[test]
    fn file_round_trip() {
        let t = Tensor::random(&[4, 6], -3.0, 3.0, 77).unwrap();
        let path = std::env::temp_dir().join("meltframe_npy_test.npy");
        save(&t, &path).unwrap();
        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, t);
    }

    #[test]
    fn round_trip_property() {
        check_property("npy round trip", 20, |rng: &mut SplitMix64| {
            let rank = 1 + rng.below(4);
            let dims: Vec<usize> = (0..rank).map(|_| 1 + rng.below(6)).collect();
            let n: usize = dims.iter().product();
            let t = Tensor::from_vec(&dims, rng.uniform_vec(n, -100.0, 100.0)).unwrap();
            let back = from_npy_bytes(&to_npy_bytes(&t).unwrap()).unwrap();
            assert_eq!(back, t);
        });
    }
}
