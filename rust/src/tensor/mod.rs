//! Dense N-D tensor substrate.
//!
//! The paper's generic container (§2.3) is the dense array in row-major
//! (C-order) layout. This module supplies the shape/stride calculus, the
//! owned [`dense::Tensor`] type, elementwise/reduction/broadcast ops, the
//! `.npy` + PGM/PPM interchange formats, and the deterministic synthetic
//! workload generators used by examples, benches, and the e2e driver.

pub mod broadcast;
pub mod dense;
pub mod image;
pub mod npy;
pub mod ops;
pub mod shape;

pub use dense::Tensor;
pub use shape::Shape;
