//! PGM (P5) image I/O + grayscale render helpers.
//!
//! Examples write their Fig 3/4/5 panels as binary PGM — viewable anywhere,
//! zero dependencies. Values are min/max normalized to 8-bit on save.

use std::io::{BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::dense::Tensor;

/// Normalize a 2-D tensor to u8 levels (min -> 0, max -> 255).
pub fn to_gray8(t: &Tensor<f32>) -> Result<Vec<u8>> {
    if t.rank() != 2 {
        return Err(Error::shape("to_gray8 requires a rank-2 tensor"));
    }
    let (mn, mx) = (t.min(), t.max());
    let span = if mx > mn { mx - mn } else { 1.0 };
    Ok(t.data()
        .iter()
        .map(|&v| (((v - mn) / span) * 255.0).round().clamp(0.0, 255.0) as u8)
        .collect())
}

/// Save a 2-D tensor as binary PGM (P5), min/max normalized.
pub fn save_pgm(t: &Tensor<f32>, path: impl AsRef<Path>) -> Result<()> {
    let gray = to_gray8(t)?;
    let (h, w) = (t.shape()[0], t.shape()[1]);
    let mut f = BufWriter::new(std::fs::File::create(path)?);
    write!(f, "P5\n{w} {h}\n255\n")?;
    f.write_all(&gray)?;
    Ok(())
}

/// Load a binary PGM (P5) as a f32 tensor with values in [0, 255].
pub fn load_pgm(path: impl AsRef<Path>) -> Result<Tensor<f32>> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    parse_pgm(&bytes)
}

fn parse_pgm(bytes: &[u8]) -> Result<Tensor<f32>> {
    if !bytes.starts_with(b"P5") {
        return Err(Error::Format("not a binary PGM (P5)".into()));
    }
    // tokenise the header: magic, width, height, maxval (comments allowed)
    let mut pos = 2usize;
    let mut fields = Vec::with_capacity(3);
    while fields.len() < 3 && pos < bytes.len() {
        // skip whitespace and comment lines
        while pos < bytes.len() {
            if bytes[pos] == b'#' {
                while pos < bytes.len() && bytes[pos] != b'\n' {
                    pos += 1;
                }
            } else if bytes[pos].is_ascii_whitespace() {
                pos += 1;
            } else {
                break;
            }
        }
        let start = pos;
        while pos < bytes.len() && !bytes[pos].is_ascii_whitespace() {
            pos += 1;
        }
        if start == pos {
            return Err(Error::Format("truncated PGM header".into()));
        }
        let tok = std::str::from_utf8(&bytes[start..pos])
            .map_err(|_| Error::Format("PGM header not ascii".into()))?;
        fields.push(
            tok.parse::<usize>()
                .map_err(|_| Error::Format(format!("bad PGM field '{tok}'")))?,
        );
    }
    if fields.len() != 3 {
        return Err(Error::Format("incomplete PGM header".into()));
    }
    let (w, h, maxval) = (fields[0], fields[1], fields[2]);
    if maxval > 255 {
        return Err(Error::Format("16-bit PGM not supported".into()));
    }
    pos += 1; // single whitespace after maxval
    if bytes.len() < pos + w * h {
        return Err(Error::Format("PGM body too short".into()));
    }
    let data: Vec<f32> = bytes[pos..pos + w * h].iter().map(|&b| b as f32).collect();
    Tensor::from_vec(&[h, w], data)
}

/// Side-by-side montage of equally sized 2-D tensors (for Fig 3 panels).
pub fn montage(panels: &[&Tensor<f32>], gap: usize) -> Result<Tensor<f32>> {
    if panels.is_empty() {
        return Err(Error::shape("montage of zero panels"));
    }
    let (h, w) = (panels[0].shape()[0], panels[0].shape()[1]);
    for p in panels {
        if p.shape() != [h, w] {
            return Err(Error::shape("montage panels must share shape"));
        }
    }
    let total_w = w * panels.len() + gap * (panels.len() - 1);
    let mut out = Tensor::full(&[h, total_w], 255.0)?;
    for (k, p) in panels.iter().enumerate() {
        let x0 = k * (w + gap);
        for y in 0..h {
            for x in 0..w {
                out.set(&[y, x0 + x], p.at(&[y, x]))?;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gray8_normalizes_full_range() {
        let t = Tensor::from_vec(&[1, 3], vec![-1.0, 0.0, 1.0]).unwrap();
        assert_eq!(to_gray8(&t).unwrap(), vec![0, 128, 255]);
    }

    #[test]
    fn gray8_constant_image_no_nan() {
        let t = Tensor::full(&[2, 2], 5.0).unwrap();
        assert_eq!(to_gray8(&t).unwrap(), vec![0, 0, 0, 0]);
    }

    #[test]
    fn pgm_round_trip() {
        let t = Tensor::from_vec(&[2, 3], vec![0.0, 51.0, 102.0, 153.0, 204.0, 255.0]).unwrap();
        let path = std::env::temp_dir().join("meltframe_pgm_test.pgm");
        save_pgm(&t, &path).unwrap();
        let back = load_pgm(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.shape(), &[2, 3]);
        // save normalizes; 0..255 input is preserved exactly
        assert_eq!(back.data(), t.data());
    }

    #[test]
    fn pgm_parser_handles_comments() {
        let body: Vec<u8> = vec![1, 2, 3, 4];
        let mut bytes = b"P5\n# a comment\n2 2\n255\n".to_vec();
        bytes.extend(&body);
        let t = parse_pgm(&bytes).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn pgm_rejects_bad_input() {
        assert!(parse_pgm(b"P6\n1 1\n255\nx").is_err());
        assert!(parse_pgm(b"P5\n4 4\n255\nxx").is_err()); // short body
    }

    #[test]
    fn montage_layout() {
        let a = Tensor::full(&[2, 2], 0.0).unwrap();
        let b = Tensor::full(&[2, 2], 100.0).unwrap();
        let m = montage(&[&a, &b], 1).unwrap();
        assert_eq!(m.shape(), &[2, 5]);
        assert_eq!(m.at(&[0, 0]), 0.0);
        assert_eq!(m.at(&[0, 2]), 255.0); // gap filler
        assert_eq!(m.at(&[0, 3]), 100.0);
        let c = Tensor::full(&[3, 2], 0.0).unwrap();
        assert!(montage(&[&a, &c], 1).is_err());
    }
}
