//! NumPy-style broadcasting for dense tensors.
//!
//! Array programming's "syntax sugar" (paper §2.3/§4) is mostly broadcast
//! semantics; the melt-matrix MatBroadcast paradigm relies on the same
//! rules, so they are implemented once here and reused by `kernels::paradigm`.

use crate::error::{Error, Result};
use crate::tensor::dense::Tensor;
use crate::tensor::shape::row_major_strides;

/// Compute the broadcast result shape of two extent lists (NumPy rules:
/// right-align, each pair must be equal or one of them 1).
pub fn broadcast_shape(a: &[usize], b: &[usize]) -> Result<Vec<usize>> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = match (da, db) {
            (x, y) if x == y => x,
            (1, y) => y,
            (x, 1) => x,
            (x, y) => {
                return Err(Error::shape(format!(
                    "cannot broadcast {a:?} with {b:?} (axis {i}: {x} vs {y})"
                )))
            }
        };
    }
    Ok(out)
}

/// Strides of `dims` virtually expanded to `out`: broadcast axes get stride 0.
fn broadcast_strides(dims: &[usize], out: &[usize]) -> Vec<usize> {
    let base = row_major_strides(dims);
    let offset = out.len() - dims.len();
    let mut strides = vec![0usize; out.len()];
    for i in 0..dims.len() {
        strides[offset + i] = if dims[i] == 1 { 0 } else { base[i] };
    }
    strides
}

/// Elementwise combine with full NumPy broadcasting.
pub fn broadcast_zip(
    a: &Tensor<f32>,
    b: &Tensor<f32>,
    f: impl Fn(f32, f32) -> f32,
) -> Result<Tensor<f32>> {
    let out_dims = broadcast_shape(a.shape(), b.shape())?;
    let sa = broadcast_strides(a.shape(), &out_dims);
    let sb = broadcast_strides(b.shape(), &out_dims);
    let n: usize = out_dims.iter().product();
    let mut data = Vec::with_capacity(n);
    let mut idx = vec![0usize; out_dims.len()];
    let (da, db) = (a.data(), b.data());
    let (mut fa, mut fb) = (0usize, 0usize);
    for _ in 0..n {
        data.push(f(da[fa], db[fb]));
        // odometer increment, updating flat offsets incrementally
        for ax in (0..out_dims.len()).rev() {
            idx[ax] += 1;
            fa += sa[ax];
            fb += sb[ax];
            if idx[ax] < out_dims[ax] {
                break;
            }
            fa -= sa[ax] * out_dims[ax];
            fb -= sb[ax] * out_dims[ax];
            idx[ax] = 0;
        }
    }
    Tensor::from_vec(&out_dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    #[test]
    fn shape_rules() {
        assert_eq!(broadcast_shape(&[2, 3], &[2, 3]).unwrap(), vec![2, 3]);
        assert_eq!(broadcast_shape(&[2, 1], &[1, 3]).unwrap(), vec![2, 3]);
        assert_eq!(broadcast_shape(&[3], &[4, 3]).unwrap(), vec![4, 3]);
        assert_eq!(broadcast_shape(&[5, 1, 7], &[6, 1]).unwrap(), vec![5, 6, 7]);
        assert!(broadcast_shape(&[2, 3], &[4, 3]).is_err());
    }

    #[test]
    fn row_vector_times_matrix() {
        let m = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let v = Tensor::from_vec(&[3], vec![10.0, 100.0, 1000.0]).unwrap();
        let out = broadcast_zip(&m, &v, |a, b| a * b).unwrap();
        assert_eq!(out.data(), &[10.0, 200.0, 3000.0, 40.0, 500.0, 6000.0]);
    }

    #[test]
    fn column_broadcast() {
        let m = Tensor::from_vec(&[2, 3], vec![1.0; 6]).unwrap();
        let c = Tensor::from_vec(&[2, 1], vec![5.0, 7.0]).unwrap();
        let out = broadcast_zip(&m, &c, |a, b| a + b).unwrap();
        assert_eq!(out.data(), &[6.0, 6.0, 6.0, 8.0, 8.0, 8.0]);
    }

    #[test]
    fn scalar_like_broadcast() {
        let m = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let s = Tensor::from_vec(&[1], vec![2.0]).unwrap();
        let out = broadcast_zip(&m, &s, |a, b| a * b).unwrap();
        assert_eq!(out.data(), &[2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn equal_shape_matches_zip_map_property() {
        check_property("broadcast == zip_map on equal shapes", 25, |rng: &mut SplitMix64| {
            let h = 1 + rng.below(6);
            let w = 1 + rng.below(6);
            let a = Tensor::from_vec(&[h, w], rng.uniform_vec(h * w, -5.0, 5.0)).unwrap();
            let b = Tensor::from_vec(&[h, w], rng.uniform_vec(h * w, -5.0, 5.0)).unwrap();
            let x = broadcast_zip(&a, &b, |p, q| p + q).unwrap();
            let y = a.zip_map(&b, |p, q| p + q).unwrap();
            assert_allclose(x.data(), y.data(), 0.0, 0.0);
        });
    }

    #[test]
    fn broadcast_commutes_with_transposed_roles() {
        // f(a, b) with a: [1,3], b: [2,1] equals f evaluated pointwise.
        let a = Tensor::from_vec(&[1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_vec(&[2, 1], vec![10.0, 20.0]).unwrap();
        let out = broadcast_zip(&a, &b, |x, y| x + y).unwrap();
        assert_eq!(out.shape(), &[2, 3]);
        assert_eq!(out.data(), &[11.0, 12.0, 13.0, 21.0, 22.0, 23.0]);
    }
}
