//! Elementwise arithmetic and reductions over dense tensors.
//!
//! Reductions come in two flavours mirroring the paper's §2.4 distinction:
//! *aggregation functions* (sum/min/max/mean/var) that combine exactly
//! across partitions, and axis reductions used by the fold stage.

use crate::error::{Error, Result};
use crate::tensor::dense::Tensor;

impl Tensor<f32> {
    /// Elementwise sum with shape check.
    pub fn add(&self, other: &Self) -> Result<Self> {
        self.zip_map(other, |a, b| a + b)
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Self) -> Result<Self> {
        self.zip_map(other, |a, b| a - b)
    }

    /// Elementwise product (Hadamard).
    pub fn mul(&self, other: &Self) -> Result<Self> {
        self.zip_map(other, |a, b| a * b)
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f32) -> Self {
        self.map(|v| v * s)
    }

    /// Scalar offset.
    pub fn offset(&self, s: f32) -> Self {
        self.map(|v| v + s)
    }

    /// Sum of all elements (f64 accumulator for stability).
    pub fn sum(&self) -> f64 {
        self.data().iter().map(|&v| v as f64).sum()
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> f64 {
        self.sum() / self.len() as f64
    }

    /// Population variance (f64 accumulator).
    pub fn variance(&self) -> f64 {
        let m = self.mean();
        self.data()
            .iter()
            .map(|&v| {
                let d = v as f64 - m;
                d * d
            })
            .sum::<f64>()
            / self.len() as f64
    }

    /// Minimum element.
    pub fn min(&self) -> f32 {
        self.data().iter().copied().fold(f32::INFINITY, f32::min)
    }

    /// Maximum element.
    pub fn max(&self) -> f32 {
        self.data().iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// L2 norm of the flattened tensor.
    pub fn norm(&self) -> f64 {
        self.data()
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Mean squared error against another tensor (shape-checked).
    pub fn mse(&self, other: &Self) -> Result<f64> {
        if self.shape() != other.shape() {
            return Err(Error::shape(format!(
                "mse shape mismatch: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        let s: f64 = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| {
                let d = a as f64 - b as f64;
                d * d
            })
            .sum();
        Ok(s / self.len() as f64)
    }

    /// Peak signal-to-noise ratio in dB for a given peak value.
    pub fn psnr(&self, other: &Self, peak: f32) -> Result<f64> {
        let mse = self.mse(other)?;
        if mse == 0.0 {
            return Ok(f64::INFINITY);
        }
        Ok(10.0 * ((peak as f64 * peak as f64) / mse).log10())
    }

    /// Extract the 2-D slice at position `pos` along `axis` of a 3-D tensor
    /// (the Fig 5 "forced planar operator" path and render helper).
    pub fn slice_plane(&self, axis: usize, pos: usize) -> Result<Self> {
        if self.rank() != 3 {
            return Err(Error::shape("slice_plane requires a rank-3 tensor"));
        }
        let d = self.shape().to_vec();
        if axis >= 3 || pos >= d[axis] {
            return Err(Error::shape(format!(
                "slice_plane axis {axis} pos {pos} out of range for {d:?}"
            )));
        }
        let keep: Vec<usize> = (0..3).filter(|&a| a != axis).collect();
        let out_dims = [d[keep[0]], d[keep[1]]];
        let mut out = Vec::with_capacity(out_dims[0] * out_dims[1]);
        let mut idx = [0usize; 3];
        idx[axis] = pos;
        for i in 0..out_dims[0] {
            for j in 0..out_dims[1] {
                idx[keep[0]] = i;
                idx[keep[1]] = j;
                out.push(self.at(&idx));
            }
        }
        Tensor::from_vec(&out_dims, out)
    }

    /// Insert a 2-D plane into a 3-D tensor at `pos` along `axis`
    /// (inverse of [`slice_plane`]; used to stack per-slice 2-D results).
    pub fn set_plane(&mut self, axis: usize, pos: usize, plane: &Self) -> Result<()> {
        if self.rank() != 3 || plane.rank() != 2 {
            return Err(Error::shape("set_plane requires rank-3 target, rank-2 plane"));
        }
        let d = self.shape().to_vec();
        let keep: Vec<usize> = (0..3).filter(|&a| a != axis).collect();
        if plane.shape() != [d[keep[0]], d[keep[1]]] {
            return Err(Error::shape(format!(
                "plane shape {:?} does not fit axis {axis} of {d:?}",
                plane.shape()
            )));
        }
        let mut idx = [0usize; 3];
        idx[axis] = pos;
        for i in 0..plane.shape()[0] {
            for j in 0..plane.shape()[1] {
                idx[keep[0]] = i;
                idx[keep[1]] = j;
                let v = plane.at(&[i, j]);
                self.set(&idx, v)?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, check_property, SplitMix64};

    fn t(dims: &[usize], data: Vec<f32>) -> Tensor<f32> {
        Tensor::from_vec(dims, data).unwrap()
    }

    #[test]
    fn arithmetic_basics() {
        let a = t(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = t(&[2, 2], vec![4.0, 3.0, 2.0, 1.0]);
        assert_eq!(a.add(&b).unwrap().data(), &[5.0; 4]);
        assert_eq!(a.sub(&b).unwrap().data(), &[-3.0, -1.0, 1.0, 3.0]);
        assert_eq!(a.mul(&b).unwrap().data(), &[4.0, 6.0, 6.0, 4.0]);
        assert_eq!(a.scale(2.0).data(), &[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(a.offset(1.0).data(), &[2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn reductions() {
        let a = t(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.sum(), 10.0);
        assert_eq!(a.mean(), 2.5);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
        assert!((a.variance() - 1.25).abs() < 1e-12);
        assert!((a.norm() - 30.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn mse_psnr() {
        let a = t(&[2], vec![0.0, 0.0]);
        let b = t(&[2], vec![3.0, 4.0]);
        assert_eq!(a.mse(&b).unwrap(), 12.5);
        assert_eq!(a.psnr(&a, 255.0).unwrap(), f64::INFINITY);
        let p = a.psnr(&b, 255.0).unwrap();
        assert!((p - 10.0 * (255.0f64 * 255.0 / 12.5).log10()).abs() < 1e-9);
    }

    #[test]
    fn slice_set_plane_round_trip() {
        let vol = Tensor::random(&[4, 5, 6], 0.0, 1.0, 2).unwrap();
        for axis in 0..3 {
            let pos = 1;
            let plane = vol.slice_plane(axis, pos).unwrap();
            let mut copy = Tensor::zeros(vol.shape()).unwrap();
            copy.set_plane(axis, pos, &plane).unwrap();
            let back = copy.slice_plane(axis, pos).unwrap();
            assert_allclose(back.data(), plane.data(), 0.0, 0.0);
        }
        assert!(vol.slice_plane(3, 0).is_err());
        assert!(vol.slice_plane(0, 10).is_err());
    }

    #[test]
    fn plane_extraction_matches_manual_indexing() {
        let vol = Tensor::random(&[3, 4, 5], 0.0, 1.0, 5).unwrap();
        let p = vol.slice_plane(1, 2).unwrap(); // fix axis1=2 -> shape [3,5]
        assert_eq!(p.shape(), &[3, 5]);
        for i in 0..3 {
            for j in 0..5 {
                assert_eq!(p.at(&[i, j]), vol.at(&[i, 2, j]));
            }
        }
    }

    #[test]
    fn partitioned_sum_equals_global_property() {
        // §2.4: aggregation functions combine exactly across partitions.
        check_property("partitioned sum == global sum", 30, |rng: &mut SplitMix64| {
            let n = 16 + rng.below(64);
            let data = rng.uniform_vec(n, -10.0, 10.0);
            let a = t(&[n], data.clone());
            let cut = 1 + rng.below(n - 1);
            let left = t(&[cut], data[..cut].to_vec());
            let right = t(&[n - cut], data[cut..].to_vec());
            let err = (a.sum() - (left.sum() + right.sum())).abs();
            assert!(err < 1e-6, "err {err}");
        });
    }
}
