//! The owned dense tensor: contiguous row-major storage + a [`Shape`].

use crate::error::{Error, Result};
use crate::tensor::shape::Shape;
use crate::testing::SplitMix64;

/// Dense N-D tensor with contiguous row-major storage.
///
/// This is the paper's "generic container" (§2.3): all higher machinery
/// (melt matrices, grids, filters) treats it as an opaque (shape, buffer)
/// pair, which is also exactly what crosses the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    shape: Shape,
    data: Vec<T>,
}

impl<T: Copy> Tensor<T> {
    /// Build from an explicit buffer; `data.len()` must equal the shape volume.
    pub fn from_vec(dims: &[usize], data: Vec<T>) -> Result<Self> {
        let shape = Shape::new(dims)?;
        if data.len() != shape.len() {
            return Err(Error::shape(format!(
                "buffer length {} != shape volume {} for {dims:?}",
                data.len(),
                shape.len()
            )));
        }
        Ok(Self { shape, data })
    }

    /// Constant-filled tensor.
    pub fn full(dims: &[usize], value: T) -> Result<Self> {
        let shape = Shape::new(dims)?;
        let n = shape.len();
        Ok(Self {
            shape,
            data: vec![value; n],
        })
    }

    pub fn shape(&self) -> &[usize] {
        self.shape.dims()
    }

    pub fn rank(&self) -> usize {
        self.shape.rank()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Shape object (strides, ravel/unravel).
    pub fn shape_obj(&self) -> &Shape {
        &self.shape
    }

    /// Value at a multi-index (unchecked in release; use `get` for checked).
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.shape.ravel(idx)]
    }

    /// Checked access.
    pub fn get(&self, idx: &[usize]) -> Result<T> {
        Ok(self.data[self.shape.ravel_checked(idx)?])
    }

    /// Checked write.
    pub fn set(&mut self, idx: &[usize], value: T) -> Result<()> {
        let flat = self.shape.ravel_checked(idx)?;
        self.data[flat] = value;
        Ok(())
    }

    /// Reshape without moving data (volume must match).
    pub fn reshape(&self, dims: &[usize]) -> Result<Self> {
        let shape = Shape::new(dims)?;
        if shape.len() != self.len() {
            return Err(Error::shape(format!(
                "cannot reshape volume {} into {dims:?}",
                self.len()
            )));
        }
        Ok(Self {
            shape,
            data: self.data.clone(),
        })
    }

    /// Apply `f` elementwise, producing a new tensor.
    pub fn map<U: Copy>(&self, f: impl Fn(T) -> U) -> Tensor<U> {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Combine elementwise with another tensor of identical shape.
    pub fn zip_map(&self, other: &Self, f: impl Fn(T, T) -> T) -> Result<Self> {
        if self.shape() != other.shape() {
            return Err(Error::shape(format!(
                "zip_map shape mismatch: {:?} vs {:?}",
                self.shape(),
                other.shape()
            )));
        }
        Ok(Self {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }
}

impl Tensor<f32> {
    /// Zero-filled tensor.
    pub fn zeros(dims: &[usize]) -> Result<Self> {
        Self::full(dims, 0.0)
    }

    /// Deterministic uniform-noise tensor in [lo, hi) — workload generator.
    pub fn random(dims: &[usize], lo: f32, hi: f32, seed: u64) -> Result<Self> {
        let shape = Shape::new(dims)?;
        let mut rng = SplitMix64::new(seed);
        let data = rng.uniform_vec(shape.len(), lo, hi);
        Ok(Self { shape, data })
    }

    /// Synthetic "natural image": smooth low-frequency field + two sharp
    /// plateaus (edges) + texture + additive noise. Deterministic in `seed`.
    ///
    /// This replaces the paper's pixnio.com photographs (Fig 3): bilateral
    /// regimes depend only on the edge/noise structure, which this
    /// generator controls explicitly (DESIGN.md §Substitutions).
    pub fn synthetic_image(dims: &[usize; 2], seed: u64) -> Self {
        let (h, w) = (dims[0], dims[1]);
        let mut rng = SplitMix64::new(seed);
        let mut data = Vec::with_capacity(h * w);
        for y in 0..h {
            for x in 0..w {
                let (fy, fx) = (y as f32 / h as f32, x as f32 / w as f32);
                // smooth background
                let mut v = 90.0 + 50.0 * (2.0 * std::f32::consts::PI * fy).sin() * (std::f32::consts::PI * fx).cos();
                // bright plateau (sharp edges) in the upper-left quadrant
                if fy < 0.45 && fx < 0.45 {
                    v = 210.0;
                }
                // dark disc
                let (cy, cx) = (fy - 0.7, fx - 0.65);
                if cy * cy + cx * cx < 0.04 {
                    v = 30.0;
                }
                // fine texture + noise
                v += 6.0 * ((x as f32 * 0.9).sin() * (y as f32 * 1.1).cos());
                v += 12.0 * rng.normal();
                data.push(v.clamp(0.0, 255.0));
            }
        }
        Tensor {
            shape: Shape::new(&[h, w]).unwrap(),
            data,
        }
    }

    /// Synthetic 3-D volume: an axis-aligned bright cuboid in a noisy field
    /// (the Fig 5 cube workload), deterministic in `seed`.
    pub fn synthetic_volume(dims: &[usize], seed: u64) -> Self {
        assert_eq!(dims.len(), 3, "synthetic_volume is 3-D");
        let shape = Shape::new(dims).unwrap();
        let mut rng = SplitMix64::new(seed);
        let mut data = Vec::with_capacity(shape.len());
        for idx in shape.iter_indices() {
            let inside = idx
                .iter()
                .zip(dims)
                .all(|(&i, &d)| i >= d / 4 && i < d - d / 4);
            let v = if inside { 200.0 } else { 40.0 };
            data.push(v + 8.0 * rng.normal());
        }
        Tensor { shape, data }
    }

    /// Binary polygon mask (the Fig 4 "2-D geometrical segmentation"):
    /// an axis-aligned rectangle union a right triangle, values {0, 1}.
    pub fn segmentation_mask(dims: &[usize; 2]) -> Self {
        let (h, w) = (dims[0], dims[1]);
        let mut data = vec![0.0f32; h * w];
        for y in 0..h {
            for x in 0..w {
                let rect = y >= h / 5 && y < 3 * h / 5 && x >= w / 6 && x < w / 2;
                let tri = y >= h / 2 && x >= w / 2 && (x - w / 2) <= (y - h / 2) && y < 9 * h / 10;
                if rect || tri {
                    data[y * w + x] = 1.0;
                }
            }
        }
        Tensor {
            shape: Shape::new(&[h, w]).unwrap(),
            data,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates_volume() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0f32; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0f32; 5]).is_err());
    }

    #[test]
    fn indexing_round_trip() {
        let mut t = Tensor::<f32>::zeros(&[3, 4, 5]).unwrap();
        t.set(&[2, 1, 3], 7.5).unwrap();
        assert_eq!(t.at(&[2, 1, 3]), 7.5);
        assert_eq!(t.get(&[2, 1, 3]).unwrap(), 7.5);
        assert!(t.get(&[3, 0, 0]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 6], (0..12).map(|i| i as f32).collect()).unwrap();
        let r = t.reshape(&[3, 4]).unwrap();
        assert_eq!(r.shape(), &[3, 4]);
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn map_and_zip_map() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0f32, 2.0, 3.0, 4.0]).unwrap();
        let b = a.map(|v| v * 2.0);
        assert_eq!(b.data(), &[2.0, 4.0, 6.0, 8.0]);
        let c = a.zip_map(&b, |x, y| y - x).unwrap();
        assert_eq!(c.data(), &[1.0, 2.0, 3.0, 4.0]);
        let wrong = Tensor::<f32>::zeros(&[4]).unwrap();
        assert!(a.zip_map(&wrong, |x, _| x).is_err());
    }

    #[test]
    fn random_is_deterministic_and_bounded() {
        let a = Tensor::random(&[4, 4], -1.0, 1.0, 9).unwrap();
        let b = Tensor::random(&[4, 4], -1.0, 1.0, 9).unwrap();
        assert_eq!(a, b);
        assert!(a.data().iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn synthetic_image_has_edges_and_range() {
        let img = Tensor::synthetic_image(&[64, 64], 3);
        assert_eq!(img.shape(), &[64, 64]);
        let (mn, mx) = img
            .data()
            .iter()
            .fold((f32::MAX, f32::MIN), |(a, b), &v| (a.min(v), b.max(v)));
        assert!(mn >= 0.0 && mx <= 255.0);
        assert!(mx - mn > 100.0, "needs strong edges, got range {}", mx - mn);
    }

    #[test]
    fn synthetic_volume_cube_contrast() {
        let vol = Tensor::synthetic_volume(&[16, 16, 16], 1);
        // centre voxel inside cuboid, corner outside
        assert!(vol.at(&[8, 8, 8]) > 150.0);
        assert!(vol.at(&[0, 0, 0]) < 90.0);
    }

    #[test]
    fn segmentation_mask_binary() {
        let m = Tensor::segmentation_mask(&[64, 64]);
        assert!(m.data().iter().all(|&v| v == 0.0 || v == 1.0));
        let ones = m.data().iter().filter(|&&v| v == 1.0).count();
        assert!(ones > 200, "mask should have interior, got {ones}");
    }
}
