//! Shape/stride calculus for row-major dense tensors.
//!
//! Everything downstream (melt grids, partitions, PJRT literal shapes)
//! reduces to this module's ravel/unravel arithmetic, so it is kept
//! dependency-free and heavily tested.

use crate::error::{Error, Result};

/// An N-D extent list with its derived row-major strides.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape {
    dims: Vec<usize>,
    strides: Vec<usize>,
}

impl Shape {
    /// Build a shape; every extent must be non-zero.
    pub fn new(dims: &[usize]) -> Result<Self> {
        if dims.is_empty() {
            return Err(Error::shape("rank-0 shapes are not supported"));
        }
        if dims.iter().any(|&d| d == 0) {
            return Err(Error::shape(format!("zero extent in {dims:?}")));
        }
        Ok(Self {
            strides: row_major_strides(dims),
            dims: dims.to_vec(),
        })
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn strides(&self) -> &[usize] {
        &self.strides
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        false // zero extents are rejected at construction
    }

    /// Row-major flat index of a multi-index.
    pub fn ravel(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.rank());
        idx.iter().zip(&self.strides).map(|(i, s)| i * s).sum()
    }

    /// Checked ravel: errors on rank mismatch or out-of-range coordinates.
    pub fn ravel_checked(&self, idx: &[usize]) -> Result<usize> {
        if idx.len() != self.rank() {
            return Err(Error::shape(format!(
                "index rank {} vs shape rank {}",
                idx.len(),
                self.rank()
            )));
        }
        for (a, (&i, &d)) in idx.iter().zip(&self.dims).enumerate().map(|(a, p)| (a, p)) {
            if i >= d {
                return Err(Error::shape(format!("index {i} >= extent {d} on axis {a}")));
            }
        }
        Ok(self.ravel(idx))
    }

    /// Multi-index of a row-major flat index.
    pub fn unravel(&self, mut flat: usize) -> Vec<usize> {
        debug_assert!(flat < self.len());
        let mut idx = vec![0usize; self.rank()];
        for (a, &s) in self.strides.iter().enumerate() {
            idx[a] = flat / s;
            flat %= s;
        }
        idx
    }

    /// Iterate all multi-indices in row-major order.
    pub fn iter_indices(&self) -> IndexIter {
        IndexIter {
            dims: self.dims.clone(),
            cur: vec![0; self.rank()],
            done: false,
        }
    }
}

/// Row-major (C-order) strides of an extent list.
pub fn row_major_strides(dims: &[usize]) -> Vec<usize> {
    let mut strides = vec![1usize; dims.len()];
    for a in (0..dims.len().saturating_sub(1)).rev() {
        strides[a] = strides[a + 1] * dims[a + 1];
    }
    strides
}

/// Row-major multi-index iterator (odometer order).
pub struct IndexIter {
    dims: Vec<usize>,
    cur: Vec<usize>,
    done: bool,
}

impl Iterator for IndexIter {
    type Item = Vec<usize>;

    fn next(&mut self) -> Option<Vec<usize>> {
        if self.done {
            return None;
        }
        let out = self.cur.clone();
        // odometer increment from the last axis
        for a in (0..self.dims.len()).rev() {
            self.cur[a] += 1;
            if self.cur[a] < self.dims[a] {
                return Some(out);
            }
            self.cur[a] = 0;
        }
        self.done = true;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check_property, SplitMix64};

    #[test]
    fn strides_row_major() {
        assert_eq!(row_major_strides(&[4, 5, 6]), vec![30, 6, 1]);
        assert_eq!(row_major_strides(&[7]), vec![1]);
    }

    #[test]
    fn rejects_zero_extent_and_rank0() {
        assert!(Shape::new(&[3, 0, 2]).is_err());
        assert!(Shape::new(&[]).is_err());
    }

    #[test]
    fn ravel_matches_manual() {
        let s = Shape::new(&[4, 5, 6]).unwrap();
        assert_eq!(s.ravel(&[0, 0, 0]), 0);
        assert_eq!(s.ravel(&[1, 2, 3]), 30 + 12 + 3);
        assert_eq!(s.ravel(&[3, 4, 5]), s.len() - 1);
    }

    #[test]
    fn ravel_checked_bounds() {
        let s = Shape::new(&[2, 3]).unwrap();
        assert!(s.ravel_checked(&[1, 2]).is_ok());
        assert!(s.ravel_checked(&[2, 0]).is_err());
        assert!(s.ravel_checked(&[0]).is_err());
    }

    #[test]
    fn unravel_inverts_ravel_property() {
        check_property("unravel∘ravel = id", 50, |rng: &mut SplitMix64| {
            let rank = 1 + rng.below(4);
            let dims: Vec<usize> = (0..rank).map(|_| 1 + rng.below(7)).collect();
            let s = Shape::new(&dims).unwrap();
            let flat = rng.below(s.len());
            assert_eq!(s.ravel(&s.unravel(flat)), flat);
        });
    }

    #[test]
    fn iter_indices_row_major_order() {
        let s = Shape::new(&[2, 3]).unwrap();
        let idxs: Vec<Vec<usize>> = s.iter_indices().collect();
        assert_eq!(
            idxs,
            vec![
                vec![0, 0],
                vec![0, 1],
                vec![0, 2],
                vec![1, 0],
                vec![1, 1],
                vec![1, 2]
            ]
        );
    }

    #[test]
    fn iter_indices_count_matches_len() {
        let s = Shape::new(&[3, 4, 2]).unwrap();
        assert_eq!(s.iter_indices().count(), s.len());
    }
}
