//! Synchronization facade: the one import path for every concurrency
//! primitive the coordinator and serving subsystems use.
//!
//! The facade has **three personalities**, selected by feature flag:
//!
//! * **default** — nothing but re-exports of `std::sync` (and
//!   `std::thread` for the worker-pool spawn path): zero wrappers, zero
//!   overhead, the exact types the standard library hands out. The named
//!   constructors below compile to plain `Mutex::new` — the class name
//!   is discarded at compile time — so `cargo build` with default
//!   features produces the same machine code as before the facade
//!   existed.
//!
//! * **`model`** — the same names resolve to the instrumented types in
//!   [`model`]: a cooperative deterministic-interleaving model checker
//!   ("shuttle-lite"). Every lock acquire, condvar wait/notify, atomic
//!   access and thread spawn becomes a yield point at which a per-run
//!   scheduler — seeded pseudo-random or bounded exhaustive DFS — picks
//!   which thread runs next, so `rust/tests/model_concurrency.rs` can
//!   drive the `HaloBoard`, `StageScheduler`, `JobQueue` and
//!   `WorkerPool` protocols through hundreds-to-thousands of distinct
//!   schedules and detect deadlocks (all threads blocked, none runnable)
//!   and lost wakeups. It explores interleavings of *scripted
//!   scenarios*: coverage is exactly the schedules of the protocols the
//!   test file drives.
//!
//! * **`lockdep`** — the same names resolve to the class-checked types
//!   in [`lockdep`]: a runtime lock-*order* checker. Every primitive is
//!   constructed with a static lock class; per-thread held stacks and a
//!   global class-order graph flag a *potential* AB/BA deadlock the
//!   first time the two orders are ever observed — on any run, under
//!   any schedule, even if the deadlock never manifests — plus condvar/
//!   barrier waits while double-locked and guards leaked across
//!   `WorkerPool` job boundaries. Unlike `model`, it checks whatever
//!   actually runs: the integration suite, the serve smoke, production
//!   traffic. Run the model checker when changing a protocol's logic;
//!   run lockdep (CI runs the whole default suite plus the serve smoke
//!   under it) to police lock ordering on every path anything exercises.
//!
//! `model` and `lockdep` are mutually exclusive (enforced below): each
//! replaces the facade types wholesale.
//!
//! ## Global lock order
//!
//! Classes are ordered by the documented hierarchy below; the lockdep
//! personality proves at runtime that no execution violates it, and
//! `scripts/lint_locks.py` proves statically that no site is born
//! outside it (every construction must use a registered class name, and
//! textually nested scopes must be acyclic).
//!
//! ```text
//! serve.exec.run (gate)                 executor: serializes whole runs
//!   ├─> serve.cache.plans               plan-cache map
//!   ├─> serve.pool.queue                worker-pool task queue
//!   └─> serve.pool.latch                per-run completion latch
//! (leaves — never held while acquiring another facade lock)
//!   halo.cell, coord.results, sched.state, sched.wakeup,
//!   serve.response.line, serve.queue.jobs, exec.fleet.barrier
//! ```
//!
//! `serve.exec.run` is the single **gate** class: it is designed to be
//! held by the run leader across an entire barrier-coordinated job,
//! including condvar and barrier waits, and is therefore exempt from the
//! wait-while-holding checks (only — it participates in the order graph
//! like any other class). Everything else is a leaf: acquire, touch the
//! guarded state, release. New subsystems must either slot under the
//! gate or stay leaves; anything else extends this diagram first.
//!
//! ## Module contract
//!
//! Enforced by `scripts/lint_unsafe.py` and `scripts/lint_locks.py`,
//! both hard CI gates: the concurrency modules — `coordinator::{halo,
//! scheduler, exec}` and everything under `serve` — import
//! `Mutex`/`Condvar` (and friends) from here, never from `std::sync`
//! directly, and construct them through the named-class constructors
//! ([`NamedMutex`], [`NamedCondvar`], [`NamedBarrier`]) with a class
//! name registered in `lint_locks.py`. A primitive that bypasses the
//! facade is invisible to both checkers, which silently shrinks the
//! verified surface; an anonymous one is invisible to the order
//! discipline.

#[cfg(all(feature = "model", feature = "lockdep"))]
compile_error!(
    "features `model` and `lockdep` are mutually exclusive: each replaces the \
     sync facade types wholesale (run the two suites as separate builds)"
);

#[cfg(feature = "model")]
pub mod model;

#[cfg(all(feature = "lockdep", not(feature = "model")))]
pub mod lockdep;

#[cfg(not(any(feature = "model", feature = "lockdep")))]
pub use std::sync::{
    Arc, Barrier, BarrierWaitResult, Condvar, LockResult, Mutex, MutexGuard, PoisonError,
    WaitTimeoutResult,
};

#[cfg(not(any(feature = "model", feature = "lockdep")))]
pub use std::sync::atomic;

#[cfg(not(any(feature = "model", feature = "lockdep")))]
pub use std::thread;

#[cfg(feature = "model")]
pub use model::{
    atomic, thread, Barrier, BarrierWaitResult, Condvar, Mutex, MutexGuard, WaitTimeoutResult,
};

#[cfg(feature = "model")]
pub use std::sync::{Arc, LockResult, PoisonError};

#[cfg(all(feature = "lockdep", not(feature = "model")))]
pub use lockdep::{checkpoint, Barrier, Condvar, Mutex, MutexGuard};

#[cfg(all(feature = "lockdep", not(feature = "model")))]
pub use std::sync::{
    atomic, Arc, BarrierWaitResult, LockResult, PoisonError, WaitTimeoutResult,
};

#[cfg(all(feature = "lockdep", not(feature = "model")))]
pub use std::thread;

/// Job-boundary assertion point. Under `lockdep` this panics if the
/// calling thread still holds any facade lock (a guard leaked across a
/// `WorkerPool` task boundary); in the other personalities it is a
/// no-op that compiles away.
#[cfg(not(all(feature = "lockdep", not(feature = "model"))))]
#[inline(always)]
pub fn checkpoint(_label: &'static str) {}

/// Named-class mutex construction: `Mutex::new_named("halo.cell", v)`
/// at every facade-governed site (the anonymous `Mutex::new` is
/// forbidden there by `scripts/lint_locks.py`).
///
/// Under the default and `model` personalities the class name is
/// discarded at compile time — `new_named` is `Mutex::new` with an
/// ignored argument, inlined to nothing extra. Under `lockdep` the name
/// becomes the lock class consulted on every acquisition.
pub trait NamedMutex<T>: Sized {
    /// A mutex of lock class `class` (see the global lock order above).
    fn new_named(class: &'static str, value: T) -> Self;

    /// A job-serialization **gate** of class `class`: exempt from
    /// lockdep's wait-while-holding checks (it is designed to be held
    /// across a whole coordinated run) but a full participant in the
    /// order graph. meltframe has exactly one: `serve.exec.run`.
    fn new_gate(class: &'static str, value: T) -> Self;
}

/// Named-class condvar construction; the class names the condvar in
/// lockdep violation reports (condvars do not join the order graph).
pub trait NamedCondvar: Sized {
    fn new_named(class: &'static str) -> Self;
}

/// Named-class barrier construction; the class names the barrier in
/// lockdep violation reports.
pub trait NamedBarrier: Sized {
    fn new_named(class: &'static str, n: usize) -> Self;
}

#[cfg(not(any(feature = "model", feature = "lockdep")))]
impl<T> NamedMutex<T> for Mutex<T> {
    #[inline(always)]
    fn new_named(_class: &'static str, value: T) -> Self {
        Mutex::new(value)
    }

    #[inline(always)]
    fn new_gate(_class: &'static str, value: T) -> Self {
        Mutex::new(value)
    }
}

#[cfg(not(any(feature = "model", feature = "lockdep")))]
impl NamedCondvar for Condvar {
    #[inline(always)]
    fn new_named(_class: &'static str) -> Self {
        Condvar::new()
    }
}

#[cfg(not(any(feature = "model", feature = "lockdep")))]
impl NamedBarrier for Barrier {
    #[inline(always)]
    fn new_named(_class: &'static str, n: usize) -> Self {
        Barrier::new(n)
    }
}

// Under the model checker the class name is likewise discarded: lock
// *ordering* is lockdep's job; the model scheduler needs only the yield
// points the instrumented types already provide.
#[cfg(feature = "model")]
impl<T> NamedMutex<T> for Mutex<T> {
    #[inline(always)]
    fn new_named(_class: &'static str, value: T) -> Self {
        Mutex::new(value)
    }

    #[inline(always)]
    fn new_gate(_class: &'static str, value: T) -> Self {
        Mutex::new(value)
    }
}

#[cfg(feature = "model")]
impl NamedCondvar for Condvar {
    #[inline(always)]
    fn new_named(_class: &'static str) -> Self {
        Condvar::new()
    }
}

#[cfg(feature = "model")]
impl NamedBarrier for Barrier {
    #[inline(always)]
    fn new_named(_class: &'static str, n: usize) -> Self {
        Barrier::new(n)
    }
}
