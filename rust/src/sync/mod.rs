//! Synchronization facade: the one import path for every concurrency
//! primitive the coordinator and serving subsystems use.
//!
//! In a **normal build** this module is nothing but re-exports of
//! `std::sync` (and `std::thread` for the worker-pool spawn path): zero
//! wrappers, zero overhead, the exact types the standard library hands
//! out. `cargo build` with default features compiles every `Mutex`,
//! `Condvar`, `Barrier` and atomic in the tree to the same machine code
//! as before the facade existed.
//!
//! With the **`model` feature** enabled, the same names resolve to the
//! instrumented types in [`model`]: a cooperative deterministic-
//! interleaving model checker ("shuttle-lite"). Every lock acquire,
//! condvar wait/notify, atomic access and thread spawn becomes a yield
//! point at which a per-run scheduler — seeded pseudo-random or bounded
//! exhaustive DFS — picks which thread runs next, so
//! `rust/tests/model_concurrency.rs` can drive the `HaloBoard`,
//! `StageScheduler`, `JobQueue` and `WorkerPool` protocols through
//! hundreds-to-thousands of distinct schedules and detect deadlocks
//! (all threads blocked, none runnable) and lost wakeups (progress
//! possible only through a timeout nobody should need). Outside an
//! active [`model::explore`] run the instrumented types fall back to
//! plain `std::sync` behaviour, so the rest of the test suite still
//! passes under `--features model`.
//!
//! **Module contract** (enforced by `scripts/lint_unsafe.py`, a hard CI
//! gate): the concurrency modules — `coordinator::{halo, scheduler,
//! exec}` and everything under `serve` — import `Mutex`/`Condvar` (and
//! friends) from here, never from `std::sync` directly. A primitive that
//! bypasses the facade is invisible to the model checker, which silently
//! shrinks the verified surface.

#[cfg(feature = "model")]
pub mod model;

#[cfg(not(feature = "model"))]
pub use std::sync::{
    Arc, Barrier, BarrierWaitResult, Condvar, LockResult, Mutex, MutexGuard, PoisonError,
    WaitTimeoutResult,
};

#[cfg(not(feature = "model"))]
pub use std::sync::atomic;

#[cfg(not(feature = "model"))]
pub use std::thread;

#[cfg(feature = "model")]
pub use model::{
    atomic, thread, Barrier, BarrierWaitResult, Condvar, Mutex, MutexGuard, WaitTimeoutResult,
};

#[cfg(feature = "model")]
pub use std::sync::{Arc, LockResult, PoisonError};
