//! Deterministic-interleaving model checker behind the [`crate::sync`]
//! facade (a "shuttle-lite").
//!
//! # How it works
//!
//! [`explore`] runs a closure many times. Each run spawns **real OS
//! threads**, but a per-run token scheduler serializes them: only the
//! thread holding the token executes, and every instrumented operation —
//! lock acquire/release, condvar wait/notify, barrier, atomic access,
//! spawn, join, sleep — is a *yield point* where the scheduler picks
//! which thread runs next. The sequence of picks is either drawn from a
//! seeded [`SplitMix64`] stream (random exploration) or replayed from a
//! choice prefix (bounded exhaustive DFS), so a failing schedule is
//! reproducible bit-for-bit from its seed or prefix.
//!
//! Detected failures:
//! - **deadlock** — no thread is runnable and none is in a timed wait;
//! - **lost wakeup** — the only way to make progress is to deliver a
//!   `wait_timeout` timeout (with [`Config::fail_on_timeout_wakeup`],
//!   the default, this fails immediately: a correct protocol notifies
//!   its waiters and never leans on the watchdog timeout);
//! - **livelock** — timeout deliveries or choice points exceed their
//!   budgets;
//! - **panic** — any model thread (or the root closure) panics with a
//!   real panic (scheduler-initiated [`ModelAbort`] teardowns are not
//!   failures).
//!
//! # Soundness layering
//!
//! Every model primitive wraps the *real* `std::sync` primitive for its
//! data (`Mutex<T>` holds a `std::sync::Mutex<T>`; the model-level state
//! only decides *scheduling*). Even if the scheduler were buggy, user
//! data stays behind a genuine lock — a checker bug cannot corrupt the
//! checked program, and std's poisoning semantics carry over unchanged.
//!
//! Outside an active [`explore`] run (no scheduler in thread-local
//! context) every type falls back to plain `std::sync` behaviour, so the
//! whole test suite still passes under `--features model`.

use std::any::Any;
use std::cell::RefCell;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashSet;
use std::hash::{Hash, Hasher};
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, panic_any, AssertUnwindSafe};
use std::sync::{
    Arc, Barrier as StdBarrier, Condvar as StdCondvar, LockResult, Mutex as StdMutex,
    MutexGuard as StdMutexGuard, Once, PoisonError,
};
use std::time::Duration;

use crate::testing::SplitMix64;

/// `SchedState::current` value meaning "no thread holds the token".
const NO_THREAD: usize = usize::MAX;

/// Panic payload used to unwind model threads when a run has already
/// failed. Never reported as a failure itself.
struct ModelAbort;

fn is_model_abort(payload: &(dyn Any + Send)) -> bool {
    payload.downcast_ref::<ModelAbort>().is_some()
}

fn payload_text(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Per-thread context
// ---------------------------------------------------------------------------

#[derive(Clone)]
struct Ctx {
    sched: Arc<Sched>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<Ctx>> = RefCell::new(None);
}

fn cur_ctx() -> Option<Ctx> {
    CTX.with(|c| c.borrow().clone())
}

fn set_ctx(ctx: Option<Ctx>) {
    CTX.with(|c| *c.borrow_mut() = ctx);
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum BlockKind {
    Mutex,
    Cond { timed: bool },
    Barrier,
    Join(usize),
}

#[derive(Clone, Copy, Debug)]
enum Status {
    Runnable,
    Blocked(BlockKind),
    Finished,
}

struct ThreadRec {
    status: Status,
    name: String,
}

/// One recorded branch point: `chosen` out of `options` (> 1) candidates.
#[derive(Clone, Copy, Debug, Hash)]
struct Choice {
    options: usize,
    chosen: usize,
}

enum Mode {
    Random(SplitMix64),
    Replay { prefix: Vec<usize>, cursor: usize },
}

struct SchedState {
    threads: Vec<ThreadRec>,
    /// tid holding the execution token, or `NO_THREAD`.
    current: usize,
    mode: Mode,
    trace: Vec<Choice>,
    steps: usize,
    timeout_wakeups: usize,
    failure: Option<String>,
    handles: Vec<Option<std::thread::JoinHandle<()>>>,
}

struct Sched {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
    cfg: Config,
}

impl Sched {
    fn new(cfg: Config, mode: Mode) -> Self {
        Self {
            state: StdMutex::new(SchedState {
                threads: Vec::new(),
                current: NO_THREAD,
                mode,
                trace: Vec::new(),
                steps: 0,
                timeout_wakeups: 0,
                failure: None,
                handles: Vec::new(),
            }),
            cv: StdCondvar::new(),
            cfg,
        }
    }

    fn lock_state(&self) -> StdMutexGuard<'_, SchedState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn register(&self, name: String) -> usize {
        let mut st = self.lock_state();
        let tid = st.threads.len();
        st.threads.push(ThreadRec {
            status: Status::Runnable,
            name,
        });
        st.handles.push(None);
        if tid == 0 {
            st.current = 0;
        }
        tid
    }

    fn store_handle(&self, tid: usize, h: std::thread::JoinHandle<()>) {
        self.lock_state().handles[tid] = Some(h);
    }

    fn take_handles(&self) -> Vec<std::thread::JoinHandle<()>> {
        self.lock_state()
            .handles
            .iter_mut()
            .filter_map(|h| h.take())
            .collect()
    }

    fn failed(&self) -> bool {
        self.lock_state().failure.is_some()
    }

    fn fail_locked(st: &mut SchedState, msg: String) {
        if st.failure.is_none() {
            st.failure = Some(msg);
        }
        st.current = NO_THREAD;
    }

    fn record_panic(&self, tid: usize, msg: String) {
        let mut st = self.lock_state();
        let name = st.threads[tid].name.clone();
        Self::fail_locked(&mut st, format!("thread '{name}' panicked: {msg}"));
        drop(st);
        self.cv.notify_all();
    }

    /// Tear down the calling thread of a failed run. Unwinds with
    /// [`ModelAbort`] — unless the thread is *already* unwinding and
    /// stuck in a blocking wait, in which case there is no way to both
    /// make progress and stay alive (the peers it waits on are being
    /// aborted); print the failure and abort the process loudly rather
    /// than hang CI or trip an undiagnosable double panic.
    fn abort_thread(&self, msg: Option<String>) -> ! {
        if std::thread::panicking() {
            eprintln!(
                "meltframe model checker: fatal: run failed while a thread was unwinding \
                 through a blocking wait: {}",
                msg.unwrap_or_else(|| "<no message>".into())
            );
            std::process::abort();
        }
        panic_any(ModelAbort)
    }

    /// Block until this thread holds the execution token (thread start).
    fn acquire_token(&self, tid: usize) {
        let mut st = self.lock_state();
        loop {
            if st.failure.is_some() {
                drop(st);
                panic_any(ModelAbort);
            }
            if st.current == tid {
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Non-blocking schedule point: hand the token to a scheduler-chosen
    /// runnable thread (possibly ourselves) and wait to get it back.
    /// During an unwind of a failed run this degrades to a no-op — the
    /// caller can safely keep unwinding without the token.
    fn yield_point(&self, tid: usize) {
        let mut st = self.lock_state();
        if st.failure.is_some() {
            drop(st);
            if std::thread::panicking() {
                return;
            }
            panic_any(ModelAbort);
        }
        self.pick_next(&mut st);
        loop {
            if st.failure.is_some() {
                drop(st);
                if std::thread::panicking() {
                    return;
                }
                panic_any(ModelAbort);
            }
            if st.current == tid {
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Blocking schedule point: mark this thread blocked on `kind`, give
    /// the token away, and return once a peer has made us runnable and
    /// the scheduler picked us again. Diverges if the run fails.
    fn block(&self, tid: usize, kind: BlockKind) {
        let mut st = self.lock_state();
        if st.failure.is_some() {
            let msg = st.failure.clone();
            drop(st);
            self.abort_thread(msg);
        }
        st.threads[tid].status = Status::Blocked(kind);
        self.pick_next(&mut st);
        loop {
            if st.failure.is_some() {
                let msg = st.failure.clone();
                drop(st);
                self.abort_thread(msg);
            }
            if st.current == tid {
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(|p| p.into_inner());
        }
    }

    /// Wake a blocked thread (it still runs only when picked).
    fn make_runnable(&self, tid: usize) {
        let mut st = self.lock_state();
        if matches!(st.threads[tid].status, Status::Blocked(_)) {
            st.threads[tid].status = Status::Runnable;
        }
    }

    /// Mark `tid` finished, wake its joiners, pass the token on.
    fn finish(&self, tid: usize) {
        let mut st = self.lock_state();
        st.threads[tid].status = Status::Finished;
        for i in 0..st.threads.len() {
            if let Status::Blocked(BlockKind::Join(target)) = st.threads[i].status {
                if target == tid {
                    st.threads[i].status = Status::Runnable;
                }
            }
        }
        if st.failure.is_none() {
            self.pick_next(&mut st);
        } else {
            st.current = NO_THREAD;
        }
        drop(st);
        self.cv.notify_all();
    }

    /// Wait (model-level) for `target` to finish.
    fn join_wait(&self, me: usize, target: usize) {
        self.yield_point(me);
        loop {
            {
                let st = self.lock_state();
                if st.failure.is_some() {
                    drop(st);
                    if std::thread::panicking() {
                        // joining an already-aborting thread while
                        // unwinding: the real join in `explore` reaps it
                        return;
                    }
                    panic_any(ModelAbort);
                }
                if matches!(st.threads[target].status, Status::Finished) {
                    return;
                }
            }
            self.block(me, BlockKind::Join(target));
        }
    }

    /// Record a branch point with `n` candidates and return the pick.
    fn choose(&self, st: &mut SchedState, n: usize) -> usize {
        st.steps += 1;
        if st.steps > self.cfg.max_steps {
            Self::fail_locked(
                st,
                format!(
                    "schedule budget exceeded ({} choice points) — livelock?",
                    self.cfg.max_steps
                ),
            );
            self.cv.notify_all();
            return 0;
        }
        if n <= 1 {
            return 0;
        }
        let pick = match &mut st.mode {
            Mode::Random(rng) => rng.below(n),
            Mode::Replay { prefix, cursor } => {
                let p = if *cursor < prefix.len() {
                    prefix[*cursor].min(n - 1)
                } else {
                    0
                };
                *cursor += 1;
                p
            }
        };
        st.trace.push(Choice {
            options: n,
            chosen: pick,
        });
        pick
    }

    /// Branch point driven from outside the scheduler lock (e.g. which
    /// condvar waiter `notify_one` wakes).
    fn choose_among(&self, n: usize) -> usize {
        if n <= 1 {
            return 0;
        }
        let mut st = self.lock_state();
        if st.failure.is_some() {
            return 0;
        }
        self.choose(&mut st, n)
    }

    /// Core scheduling decision: hand the token to a runnable thread, or
    /// deliver a timeout, or declare deadlock.
    fn pick_next(&self, st: &mut SchedState) {
        if st.failure.is_some() {
            st.current = NO_THREAD;
            self.cv.notify_all();
            return;
        }
        let runnable: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| matches!(t.status, Status::Runnable))
            .map(|(i, _)| i)
            .collect();
        if !runnable.is_empty() {
            let idx = self.choose(st, runnable.len());
            st.current = runnable[idx];
            self.cv.notify_all();
            return;
        }
        if st
            .threads
            .iter()
            .all(|t| matches!(t.status, Status::Finished))
        {
            st.current = NO_THREAD;
            self.cv.notify_all();
            return;
        }
        let timed: Vec<usize> = st
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                matches!(t.status, Status::Blocked(BlockKind::Cond { timed: true }))
            })
            .map(|(i, _)| i)
            .collect();
        if timed.is_empty() {
            let dump = Self::dump(st);
            Self::fail_locked(
                st,
                format!("deadlock: no runnable thread and no timed waiter\n{dump}"),
            );
            self.cv.notify_all();
            return;
        }
        // The only possible progress is waking a wait_timeout waiter by
        // timeout — i.e. somebody missed a notify.
        st.timeout_wakeups += 1;
        if self.cfg.fail_on_timeout_wakeup {
            let dump = Self::dump(st);
            Self::fail_locked(
                st,
                format!(
                    "lost wakeup: progress is only possible by delivering a wait_timeout \
                     timeout\n{dump}"
                ),
            );
            self.cv.notify_all();
            return;
        }
        if st.timeout_wakeups > self.cfg.max_timeout_wakeups {
            Self::fail_locked(
                st,
                format!(
                    "livelock: exceeded {} timeout wakeups without other progress",
                    self.cfg.max_timeout_wakeups
                ),
            );
            self.cv.notify_all();
            return;
        }
        let idx = self.choose(st, timed.len());
        let t = timed[idx];
        st.threads[t].status = Status::Runnable;
        st.current = t;
        self.cv.notify_all();
    }

    fn dump(st: &SchedState) -> String {
        st.threads
            .iter()
            .enumerate()
            .map(|(i, t)| format!("  [{i}] {}: {:?}", t.name, t.status))
            .collect::<Vec<_>>()
            .join("\n")
    }

    fn outcome(&self) -> (Vec<Choice>, Option<String>, usize) {
        let st = self.lock_state();
        (st.trace.clone(), st.failure.clone(), st.timeout_wakeups)
    }
}

// ---------------------------------------------------------------------------
// Mutex
// ---------------------------------------------------------------------------

struct MState {
    held: bool,
    waiters: Vec<usize>,
}

/// Model-aware mutex. Data always lives behind a real `std::sync::Mutex`
/// (see module docs on soundness layering); the model state only decides
/// who gets scheduled.
pub struct Mutex<T> {
    inner: StdMutex<T>,
    mstate: StdMutex<MState>,
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        Self {
            inner: StdMutex::new(value),
            mstate: StdMutex::new(MState {
                held: false,
                waiters: Vec::new(),
            }),
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match cur_ctx() {
            Some(ctx) => self.lock_model(&ctx),
            None => self.lock_plain(),
        }
    }

    /// Plain acquisition on the real lock; used outside a model run and
    /// as the escape hatch while unwinding out of a failed run.
    fn lock_plain(&self) -> LockResult<MutexGuard<'_, T>> {
        wrap_guard(self, self.inner.lock(), false)
    }

    fn lock_model(&self, ctx: &Ctx) -> LockResult<MutexGuard<'_, T>> {
        if ctx.sched.failed() && std::thread::panicking() {
            // failed-run teardown: model bookkeeping is moot, the real
            // lock below keeps data sound and other unwinders release it
            return self.lock_plain();
        }
        ctx.sched.yield_point(ctx.tid);
        self.raw_acquire(ctx);
        wrap_guard(self, self.inner.lock(), true)
    }

    /// Model-level acquisition loop (diverges if the run fails mid-wait).
    fn raw_acquire(&self, ctx: &Ctx) {
        loop {
            let mut ms = self.mstate.lock().unwrap_or_else(|p| p.into_inner());
            if !ms.held {
                ms.held = true;
                return;
            }
            ms.waiters.push(ctx.tid);
            drop(ms);
            ctx.sched.block(ctx.tid, BlockKind::Mutex);
        }
    }

    /// Model-level release: every waiter re-contends (mirrors the real
    /// world, where any waiter may win the lock next).
    fn model_release(&self, ctx: &Ctx) {
        let mut ms = self.mstate.lock().unwrap_or_else(|p| p.into_inner());
        ms.held = false;
        let waiters: Vec<usize> = ms.waiters.drain(..).collect();
        drop(ms);
        for w in waiters {
            ctx.sched.make_runnable(w);
        }
    }
}

fn wrap_guard<'a, T>(
    lock: &'a Mutex<T>,
    res: LockResult<StdMutexGuard<'a, T>>,
    model: bool,
) -> LockResult<MutexGuard<'a, T>> {
    match res {
        Ok(g) => Ok(MutexGuard {
            lock,
            inner: Some(g),
            model,
        }),
        Err(p) => Err(PoisonError::new(MutexGuard {
            lock,
            inner: Some(p.into_inner()),
            model,
        })),
    }
}

/// Guard over the real `std::sync::MutexGuard`, plus model bookkeeping.
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
    /// whether the model-level `held` flag is ours to clear
    model: bool,
}

impl<'a, T> MutexGuard<'a, T> {
    /// Condvar-wait hand-off: release the real lock *and* the model
    /// state, but without a schedule point — the release-and-block pair
    /// in [`Condvar::wait_model`] must be atomic with respect to the
    /// scheduler, exactly like a real condvar's release-and-sleep.
    fn dismantle(mut self) -> (&'a Mutex<T>, bool) {
        let lock = self.lock;
        let model = self.model;
        let _ = self.inner.take();
        if model {
            if let Some(ctx) = cur_ctx() {
                lock.model_release(&ctx);
            }
        }
        std::mem::forget(self);
        (lock, model)
    }

    /// Fallback-wait hand-off: surrender the raw std guard (no model
    /// bookkeeping; only used when no scheduler is active).
    fn into_raw(mut self) -> (&'a Mutex<T>, StdMutexGuard<'a, T>, bool) {
        let lock = self.lock;
        let model = self.model;
        let inner = self
            .inner
            .take()
            .expect("guard invariant: inner std guard present until drop/dismantle");
        std::mem::forget(self);
        (lock, inner, model)
    }
}

impl<T> Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner
            .as_ref()
            .expect("guard invariant: inner std guard present until drop/dismantle")
    }
}

impl<T> DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner
            .as_mut()
            .expect("guard invariant: inner std guard present until drop/dismantle")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        // release the real lock first: even mid-unwind, data is consistent
        let _ = self.inner.take();
        if self.model {
            if let Some(ctx) = cur_ctx() {
                self.lock.model_release(&ctx);
                // unlock is a schedule point — but not while unwinding,
                // where we must not risk a second panic out of a Drop
                if !std::thread::panicking() {
                    ctx.sched.yield_point(ctx.tid);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Condvar
// ---------------------------------------------------------------------------

/// Result of a `wait_timeout`. Mirrors `std::sync::WaitTimeoutResult`,
/// which has no public constructor the model could use.
pub struct WaitTimeoutResult(bool);

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.0
    }
}

/// Model-aware condition variable.
///
/// Under the scheduler, waiters park in the model (the real `Condvar` is
/// untouched) and `timed_out` is true iff the waiter was woken by the
/// scheduler delivering a timeout rather than by a notify — detected by
/// the waiter still sitting in the waiter list when it resumes.
pub struct Condvar {
    std: StdCondvar,
    waiters: StdMutex<Vec<usize>>,
}

impl Default for Condvar {
    fn default() -> Self {
        Self::new()
    }
}

impl Condvar {
    pub fn new() -> Self {
        Self {
            std: StdCondvar::new(),
            waiters: StdMutex::new(Vec::new()),
        }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let (res, _timed_out) = match cur_ctx() {
            Some(ctx) => self.wait_model(&ctx, guard, false),
            None => self.wait_plain(guard, None),
        };
        res
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        let (res, timed_out) = match cur_ctx() {
            Some(ctx) => self.wait_model(&ctx, guard, true),
            None => self.wait_plain(guard, Some(dur)),
        };
        match res {
            Ok(g) => Ok((g, WaitTimeoutResult(timed_out))),
            Err(p) => Err(PoisonError::new((p.into_inner(), WaitTimeoutResult(timed_out)))),
        }
    }

    fn wait_model<'a, T>(
        &self,
        ctx: &Ctx,
        guard: MutexGuard<'a, T>,
        timed: bool,
    ) -> (LockResult<MutexGuard<'a, T>>, bool) {
        if ctx.sched.failed() && std::thread::panicking() {
            // An unwinding thread in a failed run cannot wait on peers
            // that are themselves being torn down; there is no schedule
            // that satisfies its predicate. Fail loudly (see abort_thread).
            let msg = ctx.sched.lock_state().failure.clone();
            ctx.sched.abort_thread(msg);
        }
        let (lock, was_model) = guard.dismantle();
        if !was_model {
            // guard came from the plain fallback; nothing model-level to
            // wait on — reacquire and let the caller re-check its predicate
            return (lock.lock_plain(), true);
        }
        self.waiters
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(ctx.tid);
        ctx.sched.block(ctx.tid, BlockKind::Cond { timed });
        let timed_out = {
            let mut w = self.waiters.lock().unwrap_or_else(|p| p.into_inner());
            match w.iter().position(|&t| t == ctx.tid) {
                // still registered: nobody notified us — the scheduler
                // delivered a timeout
                Some(i) => {
                    w.remove(i);
                    true
                }
                None => false,
            }
        };
        ctx.sched.yield_point(ctx.tid);
        lock.raw_acquire(ctx);
        (wrap_guard(lock, lock.inner.lock(), true), timed_out)
    }

    fn wait_plain<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        timeout: Option<Duration>,
    ) -> (LockResult<MutexGuard<'a, T>>, bool) {
        let (lock, inner, model) = guard.into_raw();
        match timeout {
            None => match self.std.wait(inner) {
                Ok(g) => (Ok(rebuild_guard(lock, g, model)), false),
                Err(p) => (
                    Err(PoisonError::new(rebuild_guard(lock, p.into_inner(), model))),
                    false,
                ),
            },
            Some(dur) => match self.std.wait_timeout(inner, dur) {
                Ok((g, r)) => (Ok(rebuild_guard(lock, g, model)), r.timed_out()),
                Err(p) => {
                    let (g, r) = p.into_inner();
                    (
                        Err(PoisonError::new(rebuild_guard(lock, g, model))),
                        r.timed_out(),
                    )
                }
            },
        }
    }

    pub fn notify_one(&self) {
        match cur_ctx() {
            Some(ctx) => {
                let woken = {
                    let mut w = self.waiters.lock().unwrap_or_else(|p| p.into_inner());
                    if w.is_empty() {
                        None
                    } else {
                        // which waiter a notify wakes is itself a branch
                        // point real condvars leave unspecified
                        let i = ctx.sched.choose_among(w.len());
                        Some(w.remove(i))
                    }
                };
                if let Some(t) = woken {
                    ctx.sched.make_runnable(t);
                }
                if !std::thread::panicking() {
                    ctx.sched.yield_point(ctx.tid);
                }
            }
            None => self.std.notify_one(),
        }
    }

    pub fn notify_all(&self) {
        match cur_ctx() {
            Some(ctx) => {
                let woken: Vec<usize> = self
                    .waiters
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .drain(..)
                    .collect();
                for t in woken {
                    ctx.sched.make_runnable(t);
                }
                if !std::thread::panicking() {
                    ctx.sched.yield_point(ctx.tid);
                }
            }
            None => self.std.notify_all(),
        }
    }
}

fn rebuild_guard<'a, T>(
    lock: &'a Mutex<T>,
    inner: StdMutexGuard<'a, T>,
    model: bool,
) -> MutexGuard<'a, T> {
    MutexGuard {
        lock,
        inner: Some(inner),
        model,
    }
}

// ---------------------------------------------------------------------------
// Barrier
// ---------------------------------------------------------------------------

/// Result of a `Barrier::wait`. Mirrors `std::sync::BarrierWaitResult`,
/// which has no public constructor the model could use.
pub struct BarrierWaitResult(bool);

impl BarrierWaitResult {
    pub fn is_leader(&self) -> bool {
        self.0
    }
}

/// Model-aware barrier.
pub struct Barrier {
    std: StdBarrier,
    n: usize,
    arrived: StdMutex<Vec<usize>>,
}

impl Barrier {
    pub fn new(n: usize) -> Self {
        Self {
            std: StdBarrier::new(n),
            n: n.max(1),
            arrived: StdMutex::new(Vec::new()),
        }
    }

    pub fn wait(&self) -> BarrierWaitResult {
        match cur_ctx() {
            None => BarrierWaitResult(self.std.wait().is_leader()),
            Some(ctx) => {
                ctx.sched.yield_point(ctx.tid);
                let mut a = self.arrived.lock().unwrap_or_else(|p| p.into_inner());
                a.push(ctx.tid);
                if a.len() >= self.n {
                    let others: Vec<usize> =
                        a.drain(..).filter(|&t| t != ctx.tid).collect();
                    drop(a);
                    for t in others {
                        ctx.sched.make_runnable(t);
                    }
                    ctx.sched.yield_point(ctx.tid);
                    BarrierWaitResult(true)
                } else {
                    drop(a);
                    ctx.sched.block(ctx.tid, BlockKind::Barrier);
                    BarrierWaitResult(false)
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Atomics
// ---------------------------------------------------------------------------

/// Model-aware atomics: every access is a schedule point; the value
/// itself lives in a real std atomic.
pub mod atomic {
    pub use std::sync::atomic::Ordering;
    use std::sync::atomic::{AtomicBool as StdAtomicBool, AtomicUsize as StdAtomicUsize};

    fn interleave() {
        if let Some(ctx) = super::cur_ctx() {
            // yield_point degrades to a no-op when the run has failed and
            // this thread is unwinding, so atomics stay safe in teardown
            ctx.sched.yield_point(ctx.tid);
        }
    }

    pub struct AtomicBool(StdAtomicBool);

    impl AtomicBool {
        pub const fn new(v: bool) -> Self {
            Self(StdAtomicBool::new(v))
        }
        pub fn load(&self, order: Ordering) -> bool {
            interleave();
            self.0.load(order)
        }
        pub fn store(&self, v: bool, order: Ordering) {
            interleave();
            self.0.store(v, order);
        }
        pub fn swap(&self, v: bool, order: Ordering) -> bool {
            interleave();
            self.0.swap(v, order)
        }
    }

    pub struct AtomicUsize(StdAtomicUsize);

    impl AtomicUsize {
        pub const fn new(v: usize) -> Self {
            Self(StdAtomicUsize::new(v))
        }
        pub fn load(&self, order: Ordering) -> usize {
            interleave();
            self.0.load(order)
        }
        pub fn store(&self, v: usize, order: Ordering) {
            interleave();
            self.0.store(v, order);
        }
        pub fn swap(&self, v: usize, order: Ordering) -> usize {
            interleave();
            self.0.swap(v, order)
        }
        pub fn fetch_add(&self, v: usize, order: Ordering) -> usize {
            interleave();
            self.0.fetch_add(v, order)
        }
        pub fn fetch_sub(&self, v: usize, order: Ordering) -> usize {
            interleave();
            self.0.fetch_sub(v, order)
        }
    }
}

// ---------------------------------------------------------------------------
// Threads
// ---------------------------------------------------------------------------

/// Model-aware thread spawning. Inside a run, spawned threads register
/// with the scheduler and execute only when they hold the token; outside
/// a run this delegates to `std::thread`.
pub mod thread {
    pub use std::thread::{current, panicking, Result, ThreadId};

    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::{Arc, Mutex as StdMutex};

    pub struct Builder {
        name: Option<String>,
    }

    impl Default for Builder {
        fn default() -> Self {
            Self::new()
        }
    }

    impl Builder {
        pub fn new() -> Self {
            Self { name: None }
        }

        pub fn name(mut self, name: String) -> Self {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            match super::cur_ctx() {
                None => {
                    let mut b = std::thread::Builder::new();
                    if let Some(n) = self.name {
                        b = b.name(n);
                    }
                    Ok(JoinHandle(Imp::Std(b.spawn(f)?)))
                }
                Some(ctx) => Ok(spawn_model(&ctx, self.name, f)),
            }
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("failed to spawn thread")
    }

    /// Under the model, time does not pass: a sleep is just a schedule
    /// point (protocols must not depend on wall-clock delays).
    pub fn sleep(dur: std::time::Duration) {
        match super::cur_ctx() {
            Some(ctx) => {
                if !(ctx.sched.failed() && std::thread::panicking()) {
                    ctx.sched.yield_point(ctx.tid);
                }
            }
            None => std::thread::sleep(dur),
        }
    }

    enum Imp<T> {
        Std(std::thread::JoinHandle<T>),
        Model {
            sched: Arc<super::Sched>,
            tid: usize,
            slot: Arc<StdMutex<Option<Result<T>>>>,
        },
    }

    pub struct JoinHandle<T>(Imp<T>);

    impl<T> JoinHandle<T> {
        pub fn join(self) -> Result<T> {
            match self.0 {
                Imp::Std(h) => h.join(),
                Imp::Model { sched, tid, slot } => {
                    if let Some(ctx) = super::cur_ctx() {
                        sched.join_wait(ctx.tid, tid);
                    }
                    loop {
                        if let Some(r) =
                            slot.lock().unwrap_or_else(|p| p.into_inner()).take()
                        {
                            return r;
                        }
                        // only reachable when joining from outside the
                        // run (the wrapper always fills the slot before
                        // finishing) — poll briefly rather than hang
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                }
            }
        }
    }

    fn spawn_model<F, T>(ctx: &super::Ctx, name: Option<String>, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        let display = name.clone().unwrap_or_else(|| "model-thread".into());
        let sched = Arc::clone(&ctx.sched);
        let tid = sched.register(display);
        let slot: Arc<StdMutex<Option<Result<T>>>> = Arc::new(StdMutex::new(None));
        let (sched2, slot2) = (Arc::clone(&sched), Arc::clone(&slot));
        let mut b = std::thread::Builder::new();
        if let Some(n) = name {
            b = b.name(n);
        }
        let handle = b
            .spawn(move || {
                super::set_ctx(Some(super::Ctx {
                    sched: Arc::clone(&sched2),
                    tid,
                }));
                let out = catch_unwind(AssertUnwindSafe(|| {
                    sched2.acquire_token(tid);
                    f()
                }));
                if let Err(p) = &out {
                    if !super::is_model_abort(p.as_ref()) {
                        sched2.record_panic(tid, super::payload_text(p.as_ref()));
                    }
                }
                *slot2.lock().unwrap_or_else(|p| p.into_inner()) = Some(out);
                sched2.finish(tid);
                super::set_ctx(None);
            })
            .expect("failed to spawn model checker thread");
        sched.store_handle(tid, handle);
        // registration is a branch point: the child may run first, or not
        sched.yield_point(ctx.tid);
        JoinHandle(Imp::Model { sched, tid, slot })
    }
}

// ---------------------------------------------------------------------------
// Exploration harness
// ---------------------------------------------------------------------------

/// Exploration parameters. Build with [`Config::random`] or
/// [`Config::exhaustive`].
#[derive(Clone, Debug)]
pub struct Config {
    /// random-mode runs
    pub runs: usize,
    /// seed for the per-run schedule RNG stream
    pub seed: u64,
    /// depth-first replay enumeration instead of random sampling
    pub exhaustive: bool,
    /// run budget for exhaustive mode
    pub max_runs: usize,
    /// per-run branch-point budget (livelock backstop)
    pub max_steps: usize,
    /// treat any timeout delivery as a lost wakeup (default: true)
    pub fail_on_timeout_wakeup: bool,
    /// per-run timeout-delivery budget when deliveries are allowed
    pub max_timeout_wakeups: usize,
}

impl Config {
    /// Seeded pseudo-random exploration over `runs` schedules.
    pub fn random(runs: usize, seed: u64) -> Self {
        Self {
            runs,
            seed,
            exhaustive: false,
            max_runs: runs,
            max_steps: 50_000,
            fail_on_timeout_wakeup: true,
            max_timeout_wakeups: 64,
        }
    }

    /// Bounded exhaustive DFS over at most `max_runs` schedules; the
    /// report's `complete` flag says whether the tree was exhausted.
    pub fn exhaustive(max_runs: usize) -> Self {
        Self {
            runs: 0,
            seed: 0,
            exhaustive: true,
            max_runs,
            max_steps: 50_000,
            fail_on_timeout_wakeup: true,
            max_timeout_wakeups: 64,
        }
    }

    /// Permit up to `max` timeout deliveries per run instead of failing
    /// on the first (for protocols that legitimately poll).
    pub fn allow_timeout_wakeups(mut self, max: usize) -> Self {
        self.fail_on_timeout_wakeup = false;
        self.max_timeout_wakeups = max;
        self
    }
}

/// What [`explore`] found.
#[derive(Clone, Debug)]
pub struct Report {
    /// schedules actually executed
    pub runs: usize,
    /// distinct choice traces seen (hash-deduplicated)
    pub distinct_schedules: usize,
    /// total timeout deliveries across all runs
    pub timeout_wakeups: usize,
    /// failure descriptions (exploration stops at the first)
    pub failures: Vec<String>,
    /// exhaustive mode: the whole schedule tree fit in the budget
    pub complete: bool,
}

impl Report {
    pub fn failed(&self) -> bool {
        !self.failures.is_empty()
    }

    /// Panic (failing the surrounding test) if any schedule failed.
    pub fn assert_ok(&self) {
        assert!(
            self.failures.is_empty(),
            "model checker found failures after {} runs ({} distinct schedules):\n{}",
            self.runs,
            self.distinct_schedules,
            self.failures.join("\n---\n")
        );
    }

    /// Panic unless a failure was found (for seeded-bug tests proving
    /// the checker catches real defects). Returns the failure text.
    pub fn assert_failed(&self) -> &str {
        assert!(
            !self.failures.is_empty(),
            "expected the model checker to find a failure, but {} runs \
             ({} distinct schedules) all passed",
            self.runs,
            self.distinct_schedules
        );
        &self.failures[0]
    }
}

/// Run `f` under the model scheduler across many schedules.
///
/// `f` must set up all shared state itself each call (each run is an
/// independent universe). Random mode samples `cfg.runs` schedules from
/// `cfg.seed`; exhaustive mode enumerates the schedule tree depth-first
/// until done or `cfg.max_runs`. Exploration stops at the first failing
/// schedule, whose seed/prefix is embedded in the failure message.
pub fn explore<F: Fn()>(cfg: Config, f: F) -> Report {
    install_panic_hook();
    let mut report = Report {
        runs: 0,
        distinct_schedules: 0,
        timeout_wakeups: 0,
        failures: Vec::new(),
        complete: false,
    };
    let mut seen: HashSet<u64> = HashSet::new();
    if cfg.exhaustive {
        let mut prefix = Some(Vec::new());
        while let Some(p) = prefix.take() {
            if report.runs >= cfg.max_runs {
                break;
            }
            let (trace, failure, tw) = run_once(
                &cfg,
                Mode::Replay {
                    prefix: p.clone(),
                    cursor: 0,
                },
                &f,
            );
            report.runs += 1;
            report.timeout_wakeups += tw;
            seen.insert(trace_hash(&trace));
            if let Some(msg) = failure {
                report
                    .failures
                    .push(format!("run {} (dfs prefix {:?}): {}", report.runs, p, msg));
                break;
            }
            prefix = next_prefix(&trace);
            if prefix.is_none() {
                report.complete = true;
            }
        }
    } else {
        let mut seeds = SplitMix64::new(cfg.seed);
        for run in 0..cfg.runs {
            let run_seed = seeds.next_u64();
            let (trace, failure, tw) =
                run_once(&cfg, Mode::Random(SplitMix64::new(run_seed)), &f);
            report.runs += 1;
            report.timeout_wakeups += tw;
            seen.insert(trace_hash(&trace));
            if let Some(msg) = failure {
                report
                    .failures
                    .push(format!("run {run} (schedule seed {run_seed:#x}): {msg}"));
                break;
            }
        }
    }
    report.distinct_schedules = seen.len();
    report
}

fn run_once<F: Fn()>(cfg: &Config, mode: Mode, f: &F) -> (Vec<Choice>, Option<String>, usize) {
    let sched = Arc::new(Sched::new(cfg.clone(), mode));
    let root = sched.register("root".into());
    debug_assert_eq!(root, 0);
    set_ctx(Some(Ctx {
        sched: Arc::clone(&sched),
        tid: root,
    }));
    let out = catch_unwind(AssertUnwindSafe(|| f()));
    if let Err(p) = &out {
        if !is_model_abort(p.as_ref()) {
            sched.record_panic(root, payload_text(p.as_ref()));
        }
    }
    sched.finish(root);
    set_ctx(None);
    // reap every real thread the run spawned; on failure they unwind via
    // ModelAbort, on success they have all finished already
    for h in sched.take_handles() {
        let _ = h.join();
    }
    sched.outcome()
}

/// Depth-first successor of a completed run's choice trace: bump the
/// deepest branch point that still has an unexplored sibling, drop the
/// suffix. `None` once the whole tree has been visited.
fn next_prefix(trace: &[Choice]) -> Option<Vec<usize>> {
    for i in (0..trace.len()).rev() {
        if trace[i].chosen + 1 < trace[i].options {
            let mut p: Vec<usize> = trace[..i].iter().map(|c| c.chosen).collect();
            p.push(trace[i].chosen + 1);
            return Some(p);
        }
    }
    None
}

fn trace_hash(trace: &[Choice]) -> u64 {
    let mut h = DefaultHasher::new();
    for c in trace {
        (c.options, c.chosen).hash(&mut h);
    }
    h.finish()
}

/// Suppress panic output from model threads (aborts and seeded-bug
/// panics are expected and would flood test logs); panics outside a
/// model context keep the default behaviour.
fn install_panic_hook() {
    static HOOK: Once = Once::new();
    HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let in_model = CTX.with(|c| c.borrow().is_some());
            if !in_model {
                prev(info);
            }
        }));
    });
}
