//! Runtime lock-order checker ("lockdep"): the third personality of the
//! [`crate::sync`] facade, enabled by `--features lockdep`.
//!
//! ## What it checks
//!
//! Every [`Mutex`], [`Condvar`] and [`Barrier`] is constructed with a
//! static **lock class** (`Mutex::new_named("halo.cell", v)`). The
//! runtime maintains
//!
//! * a **per-thread held-lock stack** — which classes this thread holds
//!   right now, and the source location of each acquisition, and
//! * a **global class-order graph** — a directed edge `A → B` is
//!   recorded the first time any thread acquires a `B` lock while
//!   holding an `A` lock, together with both acquisition sites.
//!
//! The first acquisition that would close a **cycle** in that graph
//! panics with a report naming every edge on the cycle and the source
//! locations that created it — *even if the deadlock never manifests*.
//! This is the lockdep property: an AB/BA inversion is flagged the first
//! time the two orders have ever been observed, on any run, under any
//! schedule, rather than on the astronomically unlucky schedule where
//! the two threads actually interleave into a deadlock.
//!
//! Additional disciplines enforced at runtime:
//!
//! * **Same-class nesting** — acquiring a lock of class `C` while
//!   already holding a `C` lock is flagged immediately: two instances of
//!   one class have no defined order, so cross-thread AB/BA between
//!   instances could never be ruled out.
//! * **Condvar waits while double-locked** — `Condvar::wait`/
//!   `wait_timeout` release only the mutex they are handed; waiting
//!   while holding *another* facade lock blocks that lock for the whole
//!   sleep and is a classic deadlock shape. Flagged unless every other
//!   held lock is a **gate** (below).
//! * **Barrier waits while holding a lock** — same shape, same rule.
//! * **Guards held across `WorkerPool` job boundaries** — the pool's
//!   worker loop calls [`checkpoint`] after every task; a task that
//!   leaked a facade guard past its own body (stashed or forgotten) is
//!   flagged with the class and acquisition site of every leaked guard.
//!
//! ## Gates
//!
//! A class constructed with `Mutex::new_gate` is a **job-serialization
//! gate**: a coarse outermost lock (meltframe has exactly one,
//! `serve.exec.run`) that is *designed* to be held across an entire
//! barrier-coordinated run, including the leader's condvar and barrier
//! waits. Gates are exempt from the two wait checks only; they
//! participate in the order graph like any other class, so a gate
//! acquired *under* a leaf lock still closes a cycle and panics.
//!
//! ## Failure mode and teardown
//!
//! Violations panic in the acquiring thread with a formatted report; the
//! offending edge is **not** inserted into the graph, so the recorded
//! graph stays acyclic by construction and
//! [`find_cycle`] doubles as a self-check (the clean-run test in
//! `rust/tests/lockdep_discipline.rs` asserts it returns `None` over the
//! real protocols). Test code catches the panic with `catch_unwind`;
//! guards dropped during the unwind pop their held-stack entries like
//! any other drop.
//!
//! The checker's own bookkeeping uses raw `std::sync` primitives (one
//! leaf mutex around the graph, a thread-local stack) and is therefore
//! invisible to itself; it is only ever locked with the caller's facade
//! locks *already* held and released before control returns, so it can
//! introduce no ordering of its own.

use std::cell::RefCell;
use std::collections::HashMap;
use std::mem::ManuallyDrop;
use std::panic::Location;
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{
    Barrier as StdBarrier, BarrierWaitResult, Condvar as StdCondvar, LockResult,
    Mutex as StdMutex, MutexGuard as StdMutexGuard, OnceLock, PoisonError, WaitTimeoutResult,
};
use std::time::Duration;

/// Index into the global class table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct ClassId(usize);

struct ClassInfo {
    name: &'static str,
    gate: bool,
}

/// First-observation record for one order-graph edge `from → to`.
struct EdgeSites {
    /// Where the already-held `from` lock was acquired.
    held_at: &'static Location<'static>,
    /// Where the `to` lock was acquired while `from` was held.
    acquired_at: &'static Location<'static>,
}

#[derive(Default)]
struct Graph {
    classes: Vec<ClassInfo>,
    by_name: HashMap<&'static str, ClassId>,
    edges: HashMap<(ClassId, ClassId), EdgeSites>,
    adj: HashMap<ClassId, Vec<ClassId>>,
}

impl Graph {
    fn intern(&mut self, name: &'static str, gate: bool) -> ClassId {
        if let Some(&id) = self.by_name.get(name) {
            assert!(
                self.classes[id.0].gate == gate,
                "lockdep: class {name:?} declared both as a gate and as a regular class — \
                 a class has exactly one role"
            );
            return id;
        }
        let id = ClassId(self.classes.len());
        self.classes.push(ClassInfo { name, gate });
        self.by_name.insert(name, id);
        id
    }

    /// Shortest path `from → … → to` over recorded edges, if any.
    fn path(&self, from: ClassId, to: ClassId) -> Option<Vec<ClassId>> {
        let mut parent: HashMap<ClassId, ClassId> = HashMap::new();
        let mut queue = std::collections::VecDeque::from([from]);
        while let Some(c) = queue.pop_front() {
            if c == to {
                let mut path = vec![to];
                while *path.last().expect("path starts non-empty") != from {
                    path.push(parent[path.last().expect("path starts non-empty")]);
                }
                path.reverse();
                return Some(path);
            }
            for &n in self.adj.get(&c).into_iter().flatten() {
                if n != from && !parent.contains_key(&n) {
                    parent.insert(n, c);
                    queue.push_back(n);
                }
            }
        }
        None
    }
}

fn graph() -> &'static StdMutex<Graph> {
    static GRAPH: OnceLock<StdMutex<Graph>> = OnceLock::new();
    GRAPH.get_or_init(|| StdMutex::new(Graph::default()))
}

fn with_graph<R>(f: impl FnOnce(&mut Graph) -> R) -> R {
    // the checker must keep working while unwinding out of a previous
    // violation panic, so a poisoned graph mutex is recovered, not
    // propagated
    f(&mut graph().lock().unwrap_or_else(|p| p.into_inner()))
}

fn register(name: &'static str, gate: bool) -> ClassId {
    with_graph(|g| g.intern(name, gate))
}

/// One entry of the per-thread held-lock stack.
struct Held {
    class: ClassId,
    /// Unique per-guard token: guards may be dropped out of stack order,
    /// so release removes by token, not by popping.
    token: u64,
    site: &'static Location<'static>,
}

thread_local! {
    static HELD: RefCell<Vec<Held>> = const { RefCell::new(Vec::new()) };
}

static NEXT_TOKEN: AtomicU64 = AtomicU64::new(1);

/// Validate acquiring `class` at `site` against every lock the current
/// thread holds, recording new order edges. Panics on same-class nesting
/// or on the first edge that would close a cycle; the offending edge is
/// not recorded.
fn check_order(class: ClassId, site: &'static Location<'static>) {
    let report = HELD.with(|h| {
        let held = h.borrow();
        if held.is_empty() {
            return None;
        }
        with_graph(|g| {
            for e in held.iter() {
                if e.class == class {
                    return Some(format!(
                        "lockdep: same-class nesting on {name:?}\n  \
                         already held since {held_at}\n  \
                         acquired again at {site}\n\
                         two locks of one class have no defined order; give the inner \
                         lock its own class or restructure to drop the outer guard first",
                        name = g.classes[class.0].name,
                        held_at = e.site,
                    ));
                }
                if g.edges.contains_key(&(e.class, class)) {
                    continue;
                }
                if let Some(path) = g.path(class, e.class) {
                    return Some(render_cycle(g, e, class, site, &path));
                }
                g.edges.insert(
                    (e.class, class),
                    EdgeSites {
                        held_at: e.site,
                        acquired_at: site,
                    },
                );
                g.adj.entry(e.class).or_default().push(class);
            }
            None
        })
    });
    if let Some(report) = report {
        panic!("{report}");
    }
}

/// Format the cycle report for a new edge `held.class → class` that
/// closes the existing path `class → … → held.class`.
fn render_cycle(
    g: &Graph,
    held: &Held,
    class: ClassId,
    site: &'static Location<'static>,
    path: &[ClassId],
) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let _ = writeln!(out, "lockdep: lock-order cycle detected");
    let _ = writeln!(
        out,
        "  new dependency {:?} -> {:?}:",
        g.classes[held.class.0].name, g.classes[class.0].name
    );
    let _ = writeln!(
        out,
        "    {:?} held since {held_at}\n    {:?} acquired at {site}",
        g.classes[held.class.0].name,
        g.classes[class.0].name,
        held_at = held.site,
    );
    let _ = writeln!(out, "  conflicts with the previously observed order:");
    for w in path.windows(2) {
        let sites = &g.edges[&(w[0], w[1])];
        let _ = writeln!(
            out,
            "    {:?} -> {:?}  ({:?} held since {}, {:?} acquired at {})",
            g.classes[w[0].0].name,
            g.classes[w[1].0].name,
            g.classes[w[0].0].name,
            sites.held_at,
            g.classes[w[1].0].name,
            sites.acquired_at,
        );
    }
    let _ = write!(
        out,
        "the cycle is flagged on first observation; no deadlock need have occurred yet"
    );
    out
}

fn push_held(class: ClassId, site: &'static Location<'static>) -> u64 {
    let token = NEXT_TOKEN.fetch_add(1, AtomicOrdering::Relaxed);
    HELD.with(|h| h.borrow_mut().push(Held { class, token, site }));
    token
}

fn release_held(token: u64) {
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|e| e.token == token) {
            held.remove(pos);
        }
    });
}

/// Panic if the current thread holds any facade lock whose class fails
/// `keep` (gates are skipped when `allow_gates`); `what` names the
/// violated discipline in the report.
fn check_none_held(what: &str, context: String, allow_gates: bool) {
    let report = HELD.with(|h| {
        let held = h.borrow();
        let offending: Vec<String> = with_graph(|g| {
            held.iter()
                .filter(|e| !(allow_gates && g.classes[e.class.0].gate))
                .map(|e| format!("    {:?} held since {}", g.classes[e.class.0].name, e.site))
                .collect()
        });
        if offending.is_empty() {
            None
        } else {
            Some(format!(
                "lockdep: {what}\n  {context}\n  while holding:\n{}",
                offending.join("\n")
            ))
        }
    });
    if let Some(report) = report {
        panic!("{report}");
    }
}

/// Job-boundary assertion: panics if the calling thread still holds any
/// facade lock. Wired into `WorkerPool`'s worker loop after every task,
/// so a job that leaks a guard (stashes or forgets it) is flagged with
/// the leaked class and its acquisition site instead of silently
/// wedging every later job that contends on it.
pub fn checkpoint(label: &'static str) {
    check_none_held(
        "lock guard held across a job boundary",
        format!("at checkpoint {label:?}"),
        false,
    );
}

/// Classes registered so far, as `(name, is_gate)`.
pub fn classes() -> Vec<(&'static str, bool)> {
    with_graph(|g| g.classes.iter().map(|c| (c.name, c.gate)).collect())
}

/// The observed order edges, as `(held class, acquired class)` pairs.
pub fn order_edges() -> Vec<(&'static str, &'static str)> {
    with_graph(|g| {
        g.edges
            .keys()
            .map(|&(a, b)| (g.classes[a.0].name, g.classes[b.0].name))
            .collect()
    })
}

/// Search the recorded order graph, restricted to classes accepted by
/// `filter`, for a cycle; returns the class names along one if found.
/// Violating edges are never inserted, so this returns `None` unless the
/// checker itself is broken — the clean-run discipline test asserts
/// exactly that over the real protocols' classes.
pub fn find_cycle(filter: impl Fn(&str) -> bool) -> Option<Vec<&'static str>> {
    with_graph(|g| {
        let keep: Vec<bool> = g.classes.iter().map(|c| filter(c.name)).collect();
        // iterative DFS with tri-state marks over the filtered subgraph
        #[derive(Clone, Copy, PartialEq)]
        enum Mark {
            New,
            Open,
            Done,
        }
        let mut marks = vec![Mark::New; g.classes.len()];
        for start in 0..g.classes.len() {
            if !keep[start] || marks[start] != Mark::New {
                continue;
            }
            let mut stack = vec![(ClassId(start), 0usize)];
            marks[start] = Mark::Open;
            while !stack.is_empty() {
                // advance the top frame's successor cursor to the next
                // kept neighbour, then release the frame borrow before
                // mutating the stack
                let (c, next) = {
                    let frame = stack.last_mut().expect("stack checked non-empty");
                    let c = frame.0;
                    let succs = g.adj.get(&c).map(Vec::as_slice).unwrap_or(&[]);
                    let mut found = None;
                    while frame.1 < succs.len() {
                        let n = succs[frame.1];
                        frame.1 += 1;
                        if keep[n.0] {
                            found = Some(n);
                            break;
                        }
                    }
                    (c, found)
                };
                match next {
                    Some(n) if marks[n.0] == Mark::Open => {
                        // cycle: unwind the stack back to n
                        let mut names: Vec<&'static str> = stack
                            .iter()
                            .skip_while(|(s, _)| *s != n)
                            .map(|(s, _)| g.classes[s.0].name)
                            .collect();
                        names.push(g.classes[n.0].name);
                        return Some(names);
                    }
                    Some(n) if marks[n.0] == Mark::New => {
                        marks[n.0] = Mark::Open;
                        stack.push((n, 0));
                    }
                    Some(_) => {} // Done: skip
                    None => {
                        marks[c.0] = Mark::Done;
                        stack.pop();
                    }
                }
            }
        }
        None
    })
}

/// Classes of the locks the current thread holds, outermost first.
pub fn held_classes() -> Vec<&'static str> {
    HELD.with(|h| {
        let held = h.borrow();
        with_graph(|g| held.iter().map(|e| g.classes[e.class.0].name).collect())
    })
}

/// Fallback class for locks built through the plain `new` constructors:
/// one class per construction site, so unmigrated code is still checked
/// (the static lint separately forbids anonymous construction in
/// facade-governed modules).
fn anon_class(kind: &str, site: &'static Location<'static>) -> ClassId {
    let name = format!("anon.{kind}@{}:{}", site.file(), site.line());
    with_graph(|g| {
        if let Some(&id) = g.by_name.get(name.as_str()) {
            return id;
        }
        let leaked: &'static str = Box::leak(name.into_boxed_str());
        g.intern(leaked, false)
    })
}

/// Class-checked mutex: `std::sync::Mutex` plus a lock class consulted
/// on every acquisition. See the module docs for the rules.
pub struct Mutex<T> {
    inner: StdMutex<T>,
    class: ClassId,
}

impl<T> Mutex<T> {
    /// Anonymous construction: a per-call-site fallback class.
    #[track_caller]
    pub fn new(value: T) -> Self {
        Self {
            inner: StdMutex::new(value),
            class: anon_class("mutex", Location::caller()),
        }
    }

    pub fn into_inner(self) -> LockResult<T> {
        self.inner.into_inner()
    }

    #[track_caller]
    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        let site = Location::caller();
        // order is validated BEFORE blocking on the std lock: the
        // inverted acquisition that would deadlock is exactly the one
        // that never returns from lock()
        check_order(self.class, site);
        match self.inner.lock() {
            Ok(inner) => Ok(self.wrap(inner, site)),
            Err(poisoned) => Err(PoisonError::new(self.wrap(poisoned.into_inner(), site))),
        }
    }

    fn wrap<'a>(
        &'a self,
        inner: StdMutexGuard<'a, T>,
        site: &'static Location<'static>,
    ) -> MutexGuard<'a, T> {
        let token = push_held(self.class, site);
        MutexGuard {
            lock: self,
            inner: ManuallyDrop::new(inner),
            token,
        }
    }
}

impl<T> crate::sync::NamedMutex<T> for Mutex<T> {
    /// A mutex of lock class `class`. Instances sharing a class share
    /// order-graph edges (and may never nest within each other).
    fn new_named(class: &'static str, value: T) -> Self {
        Self {
            inner: StdMutex::new(value),
            class: register(class, false),
        }
    }

    /// A job-serialization **gate** of class `class`: exempt from the
    /// condvar/barrier wait-while-holding checks (it is designed to be
    /// held across a whole coordinated run), but a full participant in
    /// the order graph.
    fn new_gate(class: &'static str, value: T) -> Self {
        Self {
            inner: StdMutex::new(value),
            class: register(class, true),
        }
    }
}

/// Guard over the real `std::sync::MutexGuard` plus the held-stack
/// token it pops on drop.
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: ManuallyDrop<StdMutexGuard<'a, T>>,
    token: u64,
}

impl<'a, T> MutexGuard<'a, T> {
    /// Condvar hand-off: surrender the std guard without running this
    /// guard's drop (the held-stack entry is released by the caller
    /// around the actual wait).
    fn dismantle(self) -> (&'a Mutex<T>, StdMutexGuard<'a, T>, u64) {
        let mut this = ManuallyDrop::new(self);
        // SAFETY: `this` is ManuallyDrop, so our Drop (which would both
        // pop the held entry and drop `inner`) never runs; the inner
        // guard is taken exactly once here and `this` is never touched
        // again.
        let inner = unsafe { ManuallyDrop::take(&mut this.inner) };
        (this.lock, inner, this.token)
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        release_held(self.token);
        // SAFETY: drop is the one place the inner guard is released on
        // the normal path; `dismantle` is the only other consumer and it
        // suppresses this Drop entirely via ManuallyDrop.
        unsafe { ManuallyDrop::drop(&mut self.inner) };
    }
}

/// Class-checked condition variable: delegates to `std::sync::Condvar`,
/// flagging waits entered while the thread holds any second (non-gate)
/// facade lock.
pub struct Condvar {
    inner: StdCondvar,
    class: &'static str,
}

impl Condvar {
    /// Anonymous construction (reported as `anon.condvar@file:line`).
    #[track_caller]
    pub fn new() -> Self {
        let site = Location::caller();
        let name = format!("anon.condvar@{}:{}", site.file(), site.line());
        Self {
            inner: StdCondvar::new(),
            class: Box::leak(name.into_boxed_str()),
        }
    }

    #[track_caller]
    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let site = Location::caller();
        self.check_wait(&guard, site);
        let (lock, std_guard, token) = guard.dismantle();
        release_held(token);
        match self.inner.wait(std_guard) {
            Ok(inner) => Ok(lock.wrap(inner, site)),
            Err(poisoned) => Err(PoisonError::new(lock.wrap(poisoned.into_inner(), site))),
        }
    }

    #[track_caller]
    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        let site = Location::caller();
        self.check_wait(&guard, site);
        let (lock, std_guard, token) = guard.dismantle();
        release_held(token);
        match self.inner.wait_timeout(std_guard, dur) {
            Ok((inner, timeout)) => Ok((lock.wrap(inner, site), timeout)),
            Err(poisoned) => {
                let (inner, timeout) = poisoned.into_inner();
                Err(PoisonError::new((lock.wrap(inner, site), timeout)))
            }
        }
    }

    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    pub fn notify_all(&self) {
        self.inner.notify_all();
    }

    /// The wait releases only `guard`'s mutex: holding any second
    /// non-gate lock across the sleep blocks that lock for as long as
    /// the wakeup takes — flag it before sleeping.
    fn check_wait<T>(&self, guard: &MutexGuard<'_, T>, site: &'static Location<'static>) {
        let waited = with_graph(|g| g.classes[guard.lock.class.0].name);
        let report = HELD.with(|h| {
            let held = h.borrow();
            let offending: Vec<String> = with_graph(|g| {
                held.iter()
                    .filter(|e| e.token != guard.token && !g.classes[e.class.0].gate)
                    .map(|e| {
                        format!("    {:?} held since {}", g.classes[e.class.0].name, e.site)
                    })
                    .collect()
            });
            if offending.is_empty() {
                None
            } else {
                Some(format!(
                    "lockdep: condvar wait while holding a second lock\n  \
                     waiting on condvar {:?} (releases only mutex {:?}) at {site}\n  \
                     while holding:\n{}",
                    self.class,
                    waited,
                    offending.join("\n")
                ))
            }
        });
        if let Some(report) = report {
            panic!("{report}");
        }
    }
}

impl crate::sync::NamedCondvar for Condvar {
    /// A condvar of class `class` (used in violation reports; condvars
    /// do not participate in the order graph).
    fn new_named(class: &'static str) -> Self {
        Self {
            inner: StdCondvar::new(),
            class,
        }
    }
}

impl Default for Condvar {
    #[track_caller]
    fn default() -> Self {
        Self::new()
    }
}

/// Class-checked barrier: delegates to `std::sync::Barrier`, flagging
/// waits entered while holding any non-gate facade lock (a barrier wait
/// blocks until the whole fleet arrives — holding a lock across it
/// starves every contender for the full rendezvous).
pub struct Barrier {
    inner: StdBarrier,
    class: &'static str,
}

impl Barrier {
    /// Anonymous construction (reported as `anon.barrier@file:line`).
    #[track_caller]
    pub fn new(n: usize) -> Self {
        let site = Location::caller();
        let name = format!("anon.barrier@{}:{}", site.file(), site.line());
        Self {
            inner: StdBarrier::new(n),
            class: Box::leak(name.into_boxed_str()),
        }
    }

    #[track_caller]
    pub fn wait(&self) -> BarrierWaitResult {
        check_none_held(
            "barrier wait while holding a lock",
            format!(
                "waiting on barrier {:?} at {}",
                self.class,
                Location::caller()
            ),
            true,
        );
        self.inner.wait()
    }
}

impl crate::sync::NamedBarrier for Barrier {
    /// A barrier of class `class` (used in violation reports).
    fn new_named(class: &'static str, n: usize) -> Self {
        Self {
            inner: StdBarrier::new(n),
            class,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sync::{NamedCondvar, NamedMutex};
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // Unit tests here exercise the bookkeeping primitives; the
    // discipline itself (seeded AB/BA, condvar double-lock, clean-run
    // acyclicity over the real protocols) is pinned end-to-end in
    // rust/tests/lockdep_discipline.rs.

    #[test]
    fn guards_push_and_pop_the_held_stack() {
        let m = Mutex::new_named("unit.held.a", 1);
        assert!(!held_classes().contains(&"unit.held.a"));
        let g = m.lock().unwrap();
        assert!(held_classes().contains(&"unit.held.a"));
        drop(g);
        assert!(!held_classes().contains(&"unit.held.a"));
    }

    #[test]
    fn out_of_order_guard_drops_release_correctly() {
        let a = Mutex::new_named("unit.ooo.a", 1);
        let b = Mutex::new_named("unit.ooo.b", 2);
        let ga = a.lock().unwrap();
        let gb = b.lock().unwrap();
        // drop the OUTER guard first: release is by token, not by pop
        drop(ga);
        assert_eq!(held_classes(), vec!["unit.ooo.b"]);
        drop(gb);
        assert!(held_classes().is_empty());
    }

    #[test]
    fn consistent_nesting_records_edges_without_panicking() {
        let a = Mutex::new_named("unit.edge.a", ());
        let b = Mutex::new_named("unit.edge.b", ());
        for _ in 0..2 {
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
        }
        assert!(order_edges().contains(&("unit.edge.a", "unit.edge.b")));
        assert!(find_cycle(|c| c.starts_with("unit.edge.")).is_none());
    }

    #[test]
    fn inversion_panics_and_edge_is_not_recorded() {
        let a = Mutex::new_named("unit.inv.a", ());
        let b = Mutex::new_named("unit.inv.b", ());
        {
            let _ga = a.lock().unwrap();
            let _gb = b.lock().unwrap();
        }
        let flagged = catch_unwind(AssertUnwindSafe(|| {
            let _gb = b.lock().unwrap();
            let _ga = a.lock().unwrap();
        }));
        let msg = format!("{:?}", flagged.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("lock-order cycle"), "{msg}");
        // the violating edge was rejected: the graph stays acyclic
        assert!(!order_edges().contains(&("unit.inv.b", "unit.inv.a")));
        assert!(find_cycle(|c| c.starts_with("unit.inv.")).is_none());
    }

    #[test]
    fn gate_wait_exemption_applies_to_gates_only() {
        let gate = Mutex::new_gate("unit.gate.run", ());
        let m = Mutex::new_named("unit.gate.inner", ());
        let cv = Condvar::new_named("unit.gate.cv");
        let _g = gate.lock().unwrap();
        let guard = m.lock().unwrap();
        // waiting under the gate alone is allowed (times out quickly)
        let (guard, _) = cv.wait_timeout(guard, Duration::from_millis(5)).unwrap();
        drop(guard);
        assert!(classes().contains(&("unit.gate.run", true)));
    }

    #[test]
    fn anonymous_locks_get_per_site_classes() {
        let m = Mutex::new(0);
        let g = m.lock().unwrap();
        let names = held_classes();
        assert!(
            names.iter().any(|n| n.starts_with("anon.mutex@")),
            "{names:?}"
        );
        drop(g);
    }
}
