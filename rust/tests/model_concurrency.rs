//! Model-checked concurrency protocols (`--features model`).
//!
//! Every test drives a real synchronization protocol — not a mock — as
//! compiled against the `crate::sync` facade, through hundreds to
//! thousands of deterministic schedules chosen by the model scheduler in
//! `meltframe::sync::model`. Failures (deadlock, lost wakeup, livelock,
//! violated assertion on *any* schedule) carry the seed or DFS prefix
//! that reproduces them.
//!
//! Run with:
//!
//! ```text
//! cargo test --features model --test model_concurrency
//! ```
//!
//! The `seeded_bug_*` tests keep the checker honest: each injects a
//! classic concurrency defect (lost wakeup, lock-order deadlock, and the
//! unguarded-unwind bug that PR 6's `WaitGuard` fix closed) and asserts
//! the checker *finds* it.

#![cfg(feature = "model")]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use meltframe::coordinator::halo::{HaloBoard, ABORTED_MSG, DEFAULT_WAIT_DEADLINE};
use meltframe::coordinator::scheduler::StageScheduler;
use meltframe::serve::{JobQueue, ResponseSlot, WorkerPool};
use meltframe::sync::atomic::{AtomicUsize, Ordering};
use meltframe::sync::model::{explore, Config, Report};
use meltframe::sync::{thread, Arc, Condvar, Mutex};

/// Schedule-count floor each protocol must clear (acceptance criterion).
const MIN_SCHEDULES: usize = 500;

fn assert_coverage(report: &Report) {
    assert!(
        report.distinct_schedules >= MIN_SCHEDULES,
        "expected >= {MIN_SCHEDULES} distinct schedules, explored {} over {} runs",
        report.distinct_schedules,
        report.runs
    );
}

// ---------------------------------------------------------------------------
// HaloBoard
// ---------------------------------------------------------------------------

#[test]
fn model_halo_publish_then_fetch_is_live_and_exact() {
    let report = explore(Config::random(800, 0x11a1_0b0a), || {
        let board =
            Arc::new(HaloBoard::new(&[0..2, 2..4], 1, DEFAULT_WAIT_DEADLINE).unwrap());
        let b1 = Arc::clone(&board);
        let t1 = thread::spawn(move || b1.publish(0, 0, 1, &[1.0, 2.0]).unwrap());
        let b2 = Arc::clone(&board);
        let t2 = thread::spawn(move || b2.publish(0, 1, 1, &[3.0, 4.0]).unwrap());
        // fetch chunk 1's lower boundary row while the publishers race
        let mut dst = [0.0f32];
        board.fetch_into(0, 2..3, &mut dst).unwrap();
        assert_eq!(dst[0], 3.0);
        t1.join().unwrap();
        t2.join().unwrap();
    });
    report.assert_ok();
    assert_coverage(&report);
    assert_eq!(report.timeout_wakeups, 0, "halo waiters must never need the watchdog");
}

#[test]
fn model_halo_publish_once_is_exclusive() {
    let report = explore(Config::random(800, 0x0ce_5eed), || {
        let board =
            Arc::new(HaloBoard::new(&[0..2, 2..4], 1, DEFAULT_WAIT_DEADLINE).unwrap());
        let b1 = Arc::clone(&board);
        let t1 = thread::spawn(move || b1.publish(0, 0, 1, &[1.0, 2.0]).is_ok());
        let b2 = Arc::clone(&board);
        let t2 = thread::spawn(move || b2.publish(0, 0, 1, &[9.0, 9.0]).is_ok());
        let first = t1.join().unwrap();
        let second = t2.join().unwrap();
        assert!(
            first ^ second,
            "exactly one racing publish must win (got {first} / {second})"
        );
    });
    report.assert_ok();
    assert_coverage(&report);
}

#[test]
fn model_halo_poison_unblocks_waiters_and_rejects_publish() {
    let report = explore(Config::random(800, 0xdead_beef), || {
        let board =
            Arc::new(HaloBoard::new(&[0..2, 2..4], 1, DEFAULT_WAIT_DEADLINE).unwrap());
        let bw = Arc::clone(&board);
        let waiter = thread::spawn(move || {
            // chunk 1 never publishes: this blocks until poison, on every
            // schedule, and must come back as the aborted error
            let mut dst = [0.0f32];
            bw.fetch_into(0, 2..3, &mut dst).unwrap_err()
        });
        let bp = Arc::clone(&board);
        let poisoner = thread::spawn(move || bp.poison());
        let err = waiter.join().unwrap();
        assert!(err.to_string().contains(ABORTED_MSG), "{err}");
        poisoner.join().unwrap();
        // the board stays closed: publish after poison is rejected
        let err = board.publish(0, 0, 1, &[1.0, 2.0]).unwrap_err();
        assert!(err.to_string().contains(ABORTED_MSG), "{err}");
    });
    report.assert_ok();
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// StageScheduler
// ---------------------------------------------------------------------------

fn scheduler_fleet(chunks: usize, workers: usize) -> usize {
    // ranges 0..2, 2..4, ... with 2-stage halos [1, 1]
    let ranges: Vec<std::ops::Range<usize>> = (0..chunks).map(|c| c * 2..c * 2 + 2).collect();
    let sched = Arc::new(StageScheduler::new(&ranges, &[1, 1], DEFAULT_WAIT_DEADLINE));
    let handles: Vec<_> = (0..workers)
        .map(|_| {
            let s = Arc::clone(&sched);
            thread::spawn(move || {
                let mut done = 0usize;
                while let Some(task) = s.next_task().unwrap() {
                    // eager boundary publish, then task completion — the
                    // same order exec.rs uses
                    s.mark_published(task.chunk, task.stage);
                    s.complete(task.chunk, task.stage, vec![0.0; 2]);
                    done += 1;
                }
                done
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).sum()
}

#[test]
fn model_stage_scheduler_is_deadlock_free() {
    let report = explore(Config::random(800, 0x5c4e_d01e), || {
        let total = scheduler_fleet(3, 2);
        assert_eq!(total, 3 * 2, "every (chunk, stage) task runs exactly once");
    });
    report.assert_ok();
    assert_coverage(&report);
    assert_eq!(report.timeout_wakeups, 0, "idle workers must be woken by events, not the watchdog");
}

#[test]
fn model_stage_scheduler_arbitrary_chunk_worker_counts() {
    for (chunks, workers) in [(1, 1), (1, 3), (2, 2), (4, 3)] {
        let report = explore(Config::random(200, 0x1000 + (chunks * 16 + workers) as u64), || {
            let total = scheduler_fleet(chunks, workers);
            assert_eq!(total, chunks * 2);
        });
        report.assert_ok();
        assert!(
            !report.failed() && report.runs == 200,
            "({chunks} chunks, {workers} workers) must survive all schedules"
        );
    }
}

#[test]
fn model_stage_scheduler_poison_propagates() {
    let report = explore(Config::random(800, 0xba11_ad00), || {
        let sched = Arc::new(StageScheduler::new(&[0..2, 2..4], &[1, 1], DEFAULT_WAIT_DEADLINE));
        let sp = Arc::clone(&sched);
        let failer = thread::spawn(move || {
            // claim a task and die without completing it (a panicking
            // kernel's exit path calls poison)
            if sp.next_task().unwrap().is_some() {
                sp.poison();
            }
        });
        let sw = Arc::clone(&sched);
        let worker = thread::spawn(move || loop {
            match sw.next_task() {
                Ok(Some(task)) => {
                    sw.mark_published(task.chunk, task.stage);
                    sw.complete(task.chunk, task.stage, vec![0.0; 2]);
                }
                Ok(None) => return Ok(()),
                Err(e) => return Err(e),
            }
        });
        // liveness is the point: the honest worker must terminate on every
        // schedule — either it finished the work or it sees the abort
        match worker.join().unwrap() {
            Ok(()) => {}
            Err(e) => assert!(e.to_string().contains(ABORTED_MSG), "{e}"),
        }
        failer.join().unwrap();
    });
    report.assert_ok();
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// JobQueue
// ---------------------------------------------------------------------------

#[test]
fn model_jobqueue_close_then_drain_no_lost_no_dup() {
    let report = explore(Config::random(800, 0x9_0b5), || {
        let q = Arc::new(JobQueue::new(4));
        let producers: Vec<_> = (0..2usize)
            .map(|p| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    let mut accepted = Vec::new();
                    for j in 0..2 {
                        let id = p * 10 + j;
                        if q.push(id).is_ok() {
                            accepted.push(id);
                        }
                    }
                    accepted
                })
            })
            .collect();
        let qc = Arc::clone(&q);
        let closer = thread::spawn(move || qc.close());
        // single consumer (the daemon dispatcher role): drain to None
        let mut got = Vec::new();
        while let Some(id) = q.pop() {
            got.push(id);
        }
        let mut accepted: Vec<usize> =
            producers.into_iter().flat_map(|h| h.join().unwrap()).collect();
        closer.join().unwrap();
        // exactly the accepted jobs are delivered — none lost, none twice
        got.sort_unstable();
        accepted.sort_unstable();
        assert_eq!(got, accepted);
        let stats = q.stats();
        assert_eq!(stats.accepted as usize, got.len());
        assert_eq!(stats.queued, 0);
    });
    report.assert_ok();
    assert_coverage(&report);
}

#[test]
fn model_jobqueue_close_while_push_accounts_every_job() {
    let report = explore(Config::random(800, 0xc105_ed), || {
        let q = Arc::new(JobQueue::new(2));
        let qp = Arc::clone(&q);
        let pusher = thread::spawn(move || {
            let mut outcomes = (0usize, 0usize); // (accepted, rejected)
            for id in 0..3 {
                match qp.push(id) {
                    Ok(()) => outcomes.0 += 1,
                    Err(e) => {
                        let msg = e.to_string();
                        assert!(
                            msg.contains("closed") || msg.contains("full"),
                            "rejection must say why: {msg}"
                        );
                        outcomes.1 += 1;
                    }
                }
            }
            outcomes
        });
        let qc = Arc::clone(&q);
        let closer = thread::spawn(move || qc.close());
        let mut delivered = 0usize;
        while q.pop().is_some() {
            delivered += 1;
        }
        let (accepted, rejected) = pusher.join().unwrap();
        closer.join().unwrap();
        assert_eq!(accepted + rejected, 3, "every push resolves exactly once");
        assert_eq!(delivered, accepted, "admitted jobs all drain, none duplicate");
        let stats = q.stats();
        assert_eq!((stats.accepted as usize, stats.rejected as usize), (accepted, rejected));
    });
    report.assert_ok();
    assert_coverage(&report);
}

#[test]
fn model_lanes_no_loss_no_dup_across_clients() {
    let report = explore(Config::random(800, 0xfa13_1a4e), || {
        let q = Arc::new(JobQueue::new(8));
        let producers: Vec<_> = (0..3usize)
            .map(|c| {
                let q = Arc::clone(&q);
                thread::spawn(move || {
                    for j in 0..2 {
                        q.push_from(c as u64, c * 10 + j).unwrap();
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut got = Vec::new();
        while let Some(j) = q.pop() {
            got.push(j);
        }
        // round-robin reorders across lanes but must lose and duplicate
        // nothing, however the three clients' pushes interleave
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 10, 11, 20, 21]);
    });
    report.assert_ok();
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// Batch collector: pop_matching hand-offs
// ---------------------------------------------------------------------------

#[test]
fn model_collector_fills_cap_from_live_pushes() {
    let report = explore(Config::random(800, 0xba7c_4e11), || {
        let q = Arc::new(JobQueue::new(8));
        // one stray non-matching job proves the sweep is selective
        q.push_from(9, 100).unwrap();
        let producers: Vec<_> = (0..2usize)
            .map(|p| {
                let q = Arc::clone(&q);
                thread::spawn(move || q.push_from(p as u64, p + 1).unwrap())
            })
            .collect();
        // the daemon's batch collector: both mates arrive on every
        // schedule, so the cap is reached and the (far-off) window is
        // never needed — pushes must NOTIFY the predicate waiter
        let mut got = q.pop_matching(|&j| j < 100, 2, Duration::from_secs(3600));
        for p in producers {
            p.join().unwrap();
        }
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
        assert_eq!(q.pop(), Some(100), "stray job left for the dispatcher");
    });
    report.assert_ok();
    assert_coverage(&report);
    assert_eq!(
        report.timeout_wakeups, 0,
        "collector must be notify-driven when its mates arrive"
    );
}

#[test]
fn model_collector_close_unblocks_without_timeout() {
    let report = explore(Config::random(800, 0xc011_c105), || {
        let q: Arc<JobQueue<usize>> = Arc::new(JobQueue::new(4));
        let qc = Arc::clone(&q);
        let closer = thread::spawn(move || qc.close());
        // no mate ever arrives; close must wake the collector on every
        // schedule (a lingering collector would strand daemon shutdown)
        let got = q.pop_matching(|_| true, 3, Duration::from_secs(3600));
        assert!(got.is_empty(), "nothing was ever queued: {got:?}");
        closer.join().unwrap();
    });
    report.assert_ok();
    assert_coverage(&report);
    assert_eq!(report.timeout_wakeups, 0, "close must notify, not lean on the window");
}

#[test]
fn model_collector_window_expiry_is_final() {
    // Here the timeout IS the protocol: nothing ever matches, so the only
    // progress is delivering the window expiry — allowed explicitly, and
    // ONE delivery per run must suffice (the post-timeout sweep is final;
    // re-arming the wait would spin the watchdog forever).
    let report = explore(Config::random(200, 0x71e0_0f1e).allow_timeout_wakeups(2), || {
        let q: Arc<JobQueue<usize>> = Arc::new(JobQueue::new(4));
        q.push_from(1, 7).unwrap(); // different key: never matches
        let got = q.pop_matching(|&j| j == 99, 1, Duration::from_millis(5));
        assert!(got.is_empty(), "{got:?}");
        assert_eq!(q.pop(), Some(7), "non-matching job left for the dispatcher");
    });
    report.assert_ok();
    assert!(
        report.timeout_wakeups >= 1,
        "the expiry path must actually exercise the timeout"
    );
}

// ---------------------------------------------------------------------------
// Daemon lifecycle: dispatcher ⇄ connection hand-off under shutdown
// ---------------------------------------------------------------------------

#[test]
fn model_daemon_handoff_answers_admitted_jobs_across_shutdown() {
    let report = explore(Config::random(800, 0xd43_3053), || {
        // The serve() wiring minus the sockets: clients admit jobs into
        // the bounded queue and block on a ResponseSlot; one dispatcher
        // drains; shutdown closes the queue concurrently with admission.
        let queue: Arc<JobQueue<(usize, Arc<ResponseSlot>)>> = Arc::new(JobQueue::new(2));
        let qd = Arc::clone(&queue);
        let dispatcher = thread::spawn(move || {
            let mut served = 0usize;
            while let Some((id, slot)) = qd.pop() {
                slot.fill(format!("r{id}"));
                served += 1;
            }
            served
        });
        let clients: Vec<_> = (0..2)
            .map(|id| {
                let q = Arc::clone(&queue);
                thread::spawn(move || {
                    let slot = Arc::new(ResponseSlot::new());
                    match q.push((id, Arc::clone(&slot))) {
                        // admitted ⇒ the daemon owes exactly this answer,
                        // even if shutdown landed right after admission
                        Ok(()) => {
                            assert_eq!(slot.wait(), format!("r{id}"));
                            true
                        }
                        // rejected ⇒ answered immediately, never waits
                        Err(_) => false,
                    }
                })
            })
            .collect();
        let qs = Arc::clone(&queue);
        let shutdown = thread::spawn(move || qs.close());
        let admitted = clients
            .into_iter()
            .map(|h| h.join().unwrap())
            .filter(|&ok| ok)
            .count();
        shutdown.join().unwrap();
        let served = dispatcher.join().unwrap();
        assert_eq!(served, admitted, "dispatcher answers exactly the admitted jobs");
    });
    report.assert_ok();
    assert_coverage(&report);
}

#[test]
fn model_response_slot_exhaustive_dfs() {
    // Small enough to enumerate the whole schedule tree: one filler, one
    // waiter. `complete` proves the DFS exhausted it; runs ==
    // distinct_schedules proves replay determinism (no leaf visited twice).
    let report = explore(Config::exhaustive(50_000), || {
        let slot = Arc::new(ResponseSlot::new());
        let s2 = Arc::clone(&slot);
        let filler = thread::spawn(move || s2.fill("done".into()));
        assert_eq!(slot.wait(), "done");
        filler.join().unwrap();
    });
    report.assert_ok();
    assert!(
        report.complete,
        "DFS should exhaust the ResponseSlot tree within budget (ran {})",
        report.runs
    );
    assert_eq!(
        report.runs, report.distinct_schedules,
        "deterministic replay must never revisit a schedule"
    );
    assert!(report.runs >= 2, "fill-first and wait-first orders both exist");
}

// ---------------------------------------------------------------------------
// WorkerPool
// ---------------------------------------------------------------------------

#[test]
fn model_worker_pool_run_scoped_completes_in_order() {
    let report = explore(Config::random(600, 0x9001_f00d), || {
        let pool = WorkerPool::new(2);
        let results = pool.run_scoped(3, |w| Ok(w * 2), || {});
        let got: Vec<usize> = results.into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(got, vec![0, 2, 4]);
    });
    report.assert_ok();
    assert_coverage(&report);
}

/// Regression pin for the PR 6 `WaitGuard` soundness fix: a panicking
/// leader must not let `run_scoped` unwind until every enqueued task has
/// completed (the tasks borrow the caller's stack). The model drives the
/// unwind itself through adversarial schedules — with the guard reverted
/// this fails (see `seeded_bug_unguarded_unwind_loses_tasks` for the
/// checker catching exactly that defect when injected).
#[test]
fn model_worker_pool_waitguard_blocks_panicking_leader() {
    let report = explore(Config::random(600, 0x6a4d_ed), || {
        let pool = WorkerPool::new(2);
        let finished = Arc::new(AtomicUsize::new(0));
        let fc = Arc::clone(&finished);
        let unwound = catch_unwind(AssertUnwindSafe(|| {
            pool.run_scoped(
                3,
                |_| {
                    fc.fetch_add(1, Ordering::SeqCst);
                    Ok(())
                },
                || panic!("injected leader panic"),
            )
        }));
        assert!(unwound.is_err());
        // the WaitGuard held the frame open through the unwind: every
        // task observed alive stack state and ran to completion
        assert_eq!(finished.load(Ordering::SeqCst), 3);
        // and the pool survives for the next job on the same threads
        let again = pool.run_scoped(2, |w| Ok(w), || {});
        assert!(again.into_iter().all(|r| r.is_ok()));
    });
    report.assert_ok();
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// Seeded bugs: the checker must FIND these
// ---------------------------------------------------------------------------

/// The WaitGuard-revert equivalent: a leader that unwinds without
/// joining its outstanding tasks. On schedules where a task has not yet
/// run when the leader's caller resumes, the completion invariant is
/// violated — the model must surface it.
#[test]
fn seeded_bug_unguarded_unwind_loses_tasks() {
    let report = explore(Config::random(400, 0xbad_c0de), || {
        let finished = Arc::new(AtomicUsize::new(0));
        let unwound = catch_unwind(AssertUnwindSafe(|| {
            for _ in 0..3 {
                let fc = Arc::clone(&finished);
                // BUG (injected): handles dropped, nothing ties the
                // unwind to task completion — no WaitGuard
                let _ = thread::spawn(move || {
                    fc.fetch_add(1, Ordering::SeqCst);
                });
            }
            panic!("injected leader panic");
        }));
        assert!(unwound.is_err());
        assert_eq!(
            finished.load(Ordering::SeqCst),
            3,
            "leader unwound before its tasks completed"
        );
    });
    let failure = report.assert_failed();
    assert!(
        failure.contains("leader unwound before its tasks completed"),
        "wrong failure: {failure}"
    );
}

/// Classic lost wakeup: check the flag, release the lock, re-lock and
/// wait without re-checking. On schedules where the setter's notify
/// lands in the gap, only the watchdog timeout can make progress — the
/// checker must flag it.
#[test]
fn seeded_bug_lost_wakeup_detected() {
    let report = explore(Config::random(400, 0x105_7a3e), || {
        let flag = Arc::new(Mutex::new(false));
        let cv = Arc::new(Condvar::new());
        let (f2, c2) = (Arc::clone(&flag), Arc::clone(&cv));
        let setter = thread::spawn(move || {
            *f2.lock().unwrap_or_else(|p| p.into_inner()) = true;
            c2.notify_one();
        });
        let ready = *flag.lock().unwrap_or_else(|p| p.into_inner());
        if !ready {
            // BUG (injected): the gap between the check above and this
            // re-lock loses the notify; correct code re-checks the
            // predicate under the same critical section it waits in
            let guard = flag.lock().unwrap_or_else(|p| p.into_inner());
            let _ = cv.wait_timeout(guard, Duration::from_millis(100));
        }
        setter.join().unwrap();
    });
    let failure = report.assert_failed();
    assert!(failure.contains("lost wakeup"), "wrong failure: {failure}");
}

/// Classic AB/BA lock-order inversion. Some schedule interleaves the two
/// first acquisitions — the checker must report the deadlock with both
/// threads' states.
#[test]
fn seeded_bug_lock_order_deadlock_detected() {
    let report = explore(Config::random(400, 0xab_ba), || {
        let a = Arc::new(Mutex::new(0u32));
        let b = Arc::new(Mutex::new(0u32));
        let (a1, b1) = (Arc::clone(&a), Arc::clone(&b));
        let t1 = thread::spawn(move || {
            let _ga = a1.lock().unwrap_or_else(|p| p.into_inner());
            let _gb = b1.lock().unwrap_or_else(|p| p.into_inner());
        });
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        let t2 = thread::spawn(move || {
            let _gb = b2.lock().unwrap_or_else(|p| p.into_inner());
            let _ga = a2.lock().unwrap_or_else(|p| p.into_inner());
        });
        let _ = t1.join();
        let _ = t2.join();
    });
    let failure = report.assert_failed();
    assert!(failure.contains("deadlock"), "wrong failure: {failure}");
}
