//! Integration: the lane-parallel row kernels against their scalar
//! originals, bit for bit.
//!
//! The SIMD contract is stronger than "numerically close": each lane owns
//! one output element and replays the *identical* per-element operation
//! order the scalar loop uses (no reassociation, no FMA contraction, no
//! hardware min/max with different NaN semantics), so `ForceScalar` and
//! `ForceSimd` must produce byte-identical tensors for every kernel ×
//! boundary × grid × shape — including remainder-heavy shapes where most
//! rows fall off the lane groups, and the fused multi-stage executor in
//! both halo modes. The metrics side is pinned too: lane rows plus scalar
//! remainder rows must exactly partition the gathered rows.

use meltframe::coordinator::pipeline::{run_job, ExecOptions};
use meltframe::coordinator::{ChunkPolicy, HaloMode, Job, Plan};
use meltframe::melt::grid::GridMode;
use meltframe::melt::melt::BoundaryMode;
use meltframe::simd::{self, SimdMode, LANES};
use meltframe::tensor::dense::Tensor;
use meltframe::testing::{check_property, SplitMix64};

fn scalar_opts(workers: usize) -> ExecOptions {
    ExecOptions::native(workers).with_simd(SimdMode::ForceScalar)
}

fn simd_opts(workers: usize) -> ExecOptions {
    ExecOptions::native(workers).with_simd(SimdMode::ForceSimd)
}

/// Every built-in kernel spec (same roster the golden suite pins).
fn kernels(window: &[usize]) -> Vec<(&'static str, Job)> {
    vec![
        ("gaussian", Job::gaussian(window, 1.0)),
        ("bilateral_const", Job::bilateral_const(window, 1.5, 25.0)),
        ("bilateral_adaptive", Job::bilateral_adaptive(window, 1.5, 2.0)),
        ("curvature", Job::curvature(window)),
        ("median", Job::median(window)),
        ("quantile_p75", Job::quantile(window, 0.75)),
        ("minimum", Job::rank_min(window)),
        ("maximum", Job::rank_max(window)),
        ("local_mean", Job::local_mean(window)),
        ("local_std", Job::local_std(window)),
    ]
}

fn boundaries() -> Vec<(&'static str, BoundaryMode)> {
    vec![
        ("reflect", BoundaryMode::Reflect),
        ("nearest", BoundaryMode::Nearest),
        ("constant", BoundaryMode::Constant(-2.5)),
        ("wrap", BoundaryMode::Wrap),
    ]
}

fn grids(rank: usize) -> Vec<(&'static str, GridMode)> {
    vec![
        ("same", GridMode::Same),
        ("valid", GridMode::Valid),
        ("strided2", GridMode::Strided(vec![2; rank])),
    ]
}

/// Run one job both ways and assert byte-identical outputs; returns the
/// forced-SIMD metrics for counter checks.
fn assert_bit_identical(
    x: &Tensor<f32>,
    job: &Job,
    workers: usize,
    key: &str,
) -> meltframe::coordinator::RunMetrics {
    let (scalar, sm) = run_job(x, job, &scalar_opts(workers))
        .unwrap_or_else(|e| panic!("{key} (scalar): {e}"));
    let (vector, vm) = run_job(x, job, &simd_opts(workers))
        .unwrap_or_else(|e| panic!("{key} (simd): {e}"));
    assert_eq!(scalar.shape(), vector.shape(), "{key}: shape diverged");
    for (i, (a, b)) in scalar.data().iter().zip(vector.data()).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{key}: element {i} diverged ({a} vs {b})"
        );
    }
    assert_eq!(sm.simd_rows, 0, "{key}: pinned-scalar run counted lane rows");
    assert_eq!(
        vm.simd_rows + vm.scalar_rows,
        vm.gather_rows,
        "{key}: lane + remainder rows must partition the gathered rows"
    );
    vm
}

#[test]
fn every_kernel_boundary_grid_matches_scalar_bitwise() {
    let inputs: [(&str, Vec<usize>); 2] = [("2d", vec![9, 10]), ("3d", vec![5, 6, 7])];
    for (rank_name, dims) in inputs {
        let rank = dims.len();
        let x = Tensor::random(&dims, 0.0, 255.0, 0xA11CE).unwrap();
        let window = vec![3usize; rank];
        for (kernel_name, base_job) in kernels(&window) {
            for (boundary_name, boundary) in boundaries() {
                for (grid_name, grid) in grids(rank) {
                    let mut job = base_job.clone();
                    job.boundary = boundary;
                    job.grid = grid.clone();
                    let key = format!("{rank_name}/{kernel_name}/{boundary_name}/{grid_name}");
                    assert_bit_identical(&x, &job, 2, &key);
                }
            }
        }
    }
}

#[test]
fn remainder_heavy_shapes_match_scalar_bitwise() {
    // shapes chosen so lane groups barely form (or don't form at all):
    // a single melt row, a single column, and row counts straddling LANES
    let cases: Vec<(Vec<usize>, Vec<usize>)> = vec![
        (vec![1, 40], vec![1, 3]),          // one output row: all remainder
        (vec![40, 1], vec![3, 1]),          // one column, 40 rows
        (vec![LANES - 1, 9], vec![3, 3]),   // fewer rows than one group
        (vec![LANES + 1, 9], vec![3, 3]),   // one group + 1 remainder row
        (vec![13, 7], vec![3, 3]),          // non-multiple of LANES
        (vec![3 * LANES, 5], vec![3, 3]),   // exact multiple: no remainder
    ];
    for (dims, window) in &cases {
        let x = Tensor::random(dims, 0.0, 255.0, 77).unwrap();
        for job in [
            Job::gaussian(window, 1.0),
            Job::rank_max(window),
            Job::local_std(window),
        ] {
            let key = format!("{dims:?} {:?}", job.kind);
            assert_bit_identical(&x, &job, 2, &key);
        }
    }
    // single-row tiles: every lane group is broken up by the tile height,
    // so the lane path must degrade to pure remainder without drifting
    let x = Tensor::random(&[20, 9], 0.0, 255.0, 78).unwrap();
    let job = Job::gaussian(&[3, 3], 1.0);
    let mut tiny_scalar = scalar_opts(2);
    tiny_scalar.tile_rows = 1;
    let mut tiny_simd = simd_opts(2);
    tiny_simd.tile_rows = 1;
    let (a, _) = run_job(&x, &job, &tiny_scalar).unwrap();
    let (b, vm) = run_job(&x, &job, &tiny_simd).unwrap();
    assert_eq!(
        a.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        b.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "tile_rows=1 must stay bit-identical"
    );
    assert_eq!(
        vm.simd_rows, 0,
        "1-row tiles cannot fill a lane group — everything is remainder"
    );
    assert_eq!(vm.scalar_rows, vm.gather_rows);
}

#[test]
fn fused_multi_stage_matches_scalar_in_both_halo_modes() {
    check_property("fused simd == fused scalar", 6, |rng: &mut SplitMix64| {
        let dims = vec![10 + rng.below(8), 10 + rng.below(8), 6 + rng.below(4)];
        let x = Tensor::random(&dims, 0.0, 255.0, rng.next_u64()).unwrap();
        let workers = 1 + rng.below(4);
        let window = [3usize, 3, 3];
        let build = |opts: &ExecOptions| {
            Plan::over(&x)
                .gaussian(&window, 1.0)
                .curvature(&window)
                .median(&window)
                .run(opts)
                .unwrap()
        };
        for halo in [HaloMode::Recompute, HaloMode::Exchange] {
            let mut s_opts = scalar_opts(workers).with_halo_mode(halo);
            let mut v_opts = simd_opts(workers).with_halo_mode(halo);
            if rng.below(2) == 1 {
                // oversubscribed: more chunks than workers
                let policy = ChunkPolicy::EvenPerWorker { parts_per_worker: 2 };
                s_opts.chunk_policy = Some(policy);
                v_opts.chunk_policy = Some(policy);
            }
            let (scalar, _) = build(&s_opts);
            let (vector, vpm) = build(&v_opts);
            for (a, b) in scalar.data().iter().zip(vector.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "fused {halo:?} diverged");
            }
            assert_eq!(
                vpm.simd_rows() + vpm.scalar_rows(),
                vpm.gather_rows(),
                "fused {halo:?}: counters must partition gathered rows"
            );
            if vpm.simd_rows() > 0 {
                assert_eq!(vpm.simd_lanes(), LANES);
            }
        }
    });
}

#[test]
fn auto_mode_matches_both_pinned_modes_bitwise() {
    // Auto picks the lane path wherever groups form; whatever it picks,
    // the bits must equal both pinned runs (which already equal each other)
    let x = Tensor::random(&[19, 11], 0.0, 255.0, 99).unwrap();
    let job = Job::bilateral_adaptive(&[3, 3], 1.5, 2.0);
    let (auto_out, _) = run_job(
        &x,
        &job,
        &ExecOptions::native(2).with_simd(SimdMode::Auto),
    )
    .unwrap();
    let (scalar_out, _) = run_job(&x, &job, &scalar_opts(2)).unwrap();
    assert_eq!(
        auto_out.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        scalar_out.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
    );
}

#[test]
fn lane_primitives_mirror_scalar_semantics() {
    // the portable lane primitives the kernels are built from: per-lane
    // results must equal the per-element scalar expression, including the
    // IEEE edge cases (NaN propagation, signed zero) that hardware
    // min/max intrinsics get wrong
    let a: [f32; LANES] = std::array::from_fn(|i| i as f32 - 3.0);
    let b: [f32; LANES] = std::array::from_fn(|i| 0.5 * i as f32 + 1.0);
    let mut acc = simd::splat(2.0);
    simd::mul_add_lanes(&mut acc, &a, &b);
    for l in 0..LANES {
        assert_eq!(acc[l].to_bits(), (2.0f32 + a[l] * b[l]).to_bits());
    }
    let mut mn = [f32::NAN, 0.0, -0.0, 1.0, -1.0, 5.0, f32::INFINITY, 2.0];
    let mut mx = mn;
    let v = [1.0f32, -0.0, 0.0, f32::NAN, -2.0, 5.0, 3.0, f32::NEG_INFINITY];
    simd::min_lanes(&mut mn, &v);
    simd::max_lanes(&mut mx, &v);
    let base = [f32::NAN, 0.0, -0.0, 1.0, -1.0, 5.0, f32::INFINITY, 2.0];
    for l in 0..LANES {
        assert_eq!(mn[l].to_bits(), base[l].min(v[l]).to_bits(), "min lane {l}");
        assert_eq!(mx[l].to_bits(), base[l].max(v[l]).to_bits(), "max lane {l}");
    }
    let mask = [true, false, true, false, true, false, true, false];
    let t = simd::splat(1.0);
    let f = simd::splat(-1.0);
    let sel = simd::select_lanes(&mask, &t, &f);
    for l in 0..LANES {
        assert_eq!(sel[l], if mask[l] { 1.0 } else { -1.0 });
    }
    let src: Vec<f32> = (0..32).map(|i| i as f32 * 1.5).collect();
    let idx: [usize; LANES] = std::array::from_fn(|i| 31 - 2 * i);
    let g = simd::gather_lanes(&src, &idx);
    for l in 0..LANES {
        assert_eq!(g[l], src[idx[l]]);
    }
    // dot2 (AVX2 or portable, whatever this machine dispatches) must equal
    // the documented scalar strip order bit for bit: four parallel strip
    // accumulators, pairwise combine, scalar remainder
    let rng = &mut SplitMix64::new(0xD07);
    let cols = 37usize;
    let row_a: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
    let row_b: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
    let kernel: Vec<f32> = (0..cols).map(|_| rng.normal()).collect();
    let strip_dot = |row: &[f32]| -> f32 {
        let mut acc = [0.0f32; 4];
        let strips = cols / 4;
        for t in 0..strips {
            for i in 0..4 {
                acc[i] += row[4 * t + i] * kernel[4 * t + i];
            }
        }
        let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
        for j in 4 * strips..cols {
            s += row[j] * kernel[j];
        }
        s
    };
    let (da, db) = simd::dot2(&row_a, &row_b, &kernel);
    assert_eq!(da.to_bits(), strip_dot(&row_a).to_bits());
    assert_eq!(db.to_bits(), strip_dot(&row_b).to_bits());
    // dot_rows_into: pairs via dot2, odd tail via the same strip order
    let block: Vec<f32> = (0..3 * cols).map(|_| rng.normal()).collect();
    let mut out = [0.0f32; 3];
    simd::dot_rows_into(&block, cols, &kernel, &mut out);
    for (r, o) in out.iter().enumerate() {
        assert_eq!(
            o.to_bits(),
            strip_dot(&block[r * cols..(r + 1) * cols]).to_bits(),
            "dot_rows_into row {r}"
        );
    }
}
