//! Golden-value regression suite: a checked-in digest for every built-in
//! kernel × boundary mode × grid mode × input rank, over fixed
//! SplitMix64-seeded inputs.
//!
//! The property suites pin *relationships* (fused == legacy, exchange ==
//! recompute); this suite pins the *numbers themselves*, so a future
//! refactor that drifts every executor identically — a changed gather
//! order, a "harmless" reassociation in a kernel hot loop — still trips a
//! failure instead of slipping through.
//!
//! Digests use [`meltframe::testing::value_digest`]: position-sensitive
//! but accumulation-order-independent, so the fingerprint is stable
//! however the chunks were folded. Every case is additionally executed
//! with a multi-worker fleet and must digest identically (the §2.4
//! worker-invariance claim, enforced on every golden case).
//!
//! Bless or re-bless with `UPDATE_GOLDENS=1 cargo test --test
//! golden_values`, then commit `tests/golden/kernel_digests.tsv`. Cases
//! missing from the file are reported (and written to a candidate file in
//! the temp dir) without failing, so the suite bootstraps on machines
//! that cannot regenerate the goldens; cases *present* in the file are
//! hard assertions, and stale keys the suite no longer generates fail it.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use meltframe::coordinator::pipeline::{run_job, ExecOptions};
use meltframe::coordinator::Job;
use meltframe::melt::grid::GridMode;
use meltframe::melt::melt::BoundaryMode;
use meltframe::tensor::dense::Tensor;
use meltframe::testing::value_digest;

const GOLDEN_PATH: &str = concat!(
    env!("CARGO_MANIFEST_DIR"),
    "/tests/golden/kernel_digests.tsv"
);

/// Every built-in kernel spec, by stable case name.
fn kernels(window: &[usize]) -> Vec<(&'static str, Job)> {
    vec![
        ("gaussian", Job::gaussian(window, 1.0)),
        ("bilateral_const", Job::bilateral_const(window, 1.5, 25.0)),
        ("bilateral_adaptive", Job::bilateral_adaptive(window, 1.5, 2.0)),
        ("curvature", Job::curvature(window)),
        ("median", Job::median(window)),
        ("quantile_p75", Job::quantile(window, 0.75)),
        ("minimum", Job::rank_min(window)),
        ("maximum", Job::rank_max(window)),
        ("local_mean", Job::local_mean(window)),
        ("local_std", Job::local_std(window)),
    ]
}

fn boundaries() -> Vec<(&'static str, BoundaryMode)> {
    vec![
        ("reflect", BoundaryMode::Reflect),
        ("nearest", BoundaryMode::Nearest),
        ("constant", BoundaryMode::Constant(-2.5)),
        ("wrap", BoundaryMode::Wrap),
    ]
}

fn grids(rank: usize) -> Vec<(&'static str, GridMode)> {
    vec![
        ("same", GridMode::Same),
        ("valid", GridMode::Valid),
        ("strided2", GridMode::Strided(vec![2; rank])),
    ]
}

/// Compute the digest table: every case key → 16-hex digest, with the
/// worker-invariance cross-check baked in.
fn compute_table() -> BTreeMap<String, String> {
    let inputs: [(&str, Vec<usize>); 2] =
        [("2d", vec![9, 10]), ("3d", vec![5, 6, 7])];
    let mut table = BTreeMap::new();
    for (rank_name, dims) in inputs {
        let rank = dims.len();
        let x = Tensor::random(&dims, 0.0, 255.0, 0xA11CE).unwrap();
        let window = vec![3usize; rank];
        for (kernel_name, base_job) in kernels(&window) {
            for (boundary_name, boundary) in boundaries() {
                for (grid_name, grid) in grids(rank) {
                    let mut job = base_job.clone();
                    job.boundary = boundary;
                    job.grid = grid.clone();
                    let key = format!("{rank_name}/{kernel_name}/{boundary_name}/{grid_name}");
                    let (out, _) = run_job(&x, &job, &ExecOptions::native(1))
                        .unwrap_or_else(|e| panic!("{key}: {e}"));
                    let digest = value_digest(out.data());
                    // worker invariance on the exact same numbers
                    let (multi, _) = run_job(&x, &job, &ExecOptions::native(3))
                        .unwrap_or_else(|e| panic!("{key} (3 workers): {e}"));
                    assert_eq!(
                        value_digest(multi.data()),
                        digest,
                        "{key}: digest changed with worker count"
                    );
                    table.insert(key, format!("{digest:016x}"));
                }
            }
        }
    }
    table
}

fn parse_goldens(text: &str) -> BTreeMap<String, String> {
    text.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .filter_map(|l| {
            let (key, digest) = l.split_once('\t')?;
            Some((key.trim().to_string(), digest.trim().to_string()))
        })
        .collect()
}

fn render(table: &BTreeMap<String, String>) -> String {
    let mut out = String::from(
        "# Golden output digests — see tests/golden_values.rs for the\n\
         # blessing procedure (UPDATE_GOLDENS=1 cargo test --test golden_values).\n",
    );
    for (k, v) in table {
        let _ = writeln!(out, "{k}\t{v}");
    }
    out
}

#[test]
fn kernel_digests_match_goldens() {
    let computed = compute_table();
    assert_eq!(
        computed.len(),
        2 * 10 * 4 * 3,
        "case enumeration drifted — update the expected count deliberately"
    );

    if std::env::var("UPDATE_GOLDENS").is_ok() {
        std::fs::write(GOLDEN_PATH, render(&computed)).unwrap();
        eprintln!("golden_values: blessed {} cases into {GOLDEN_PATH}", computed.len());
        return;
    }

    let stored = parse_goldens(&std::fs::read_to_string(GOLDEN_PATH).unwrap_or_default());
    // stale stored keys mean a kernel/mode was renamed or removed without
    // re-blessing — that is exactly the silent drift this suite exists for
    let stale: Vec<&String> =
        stored.keys().filter(|k| !computed.contains_key(*k)).collect();
    assert!(
        stale.is_empty(),
        "golden file has keys the suite no longer generates: {stale:?} — \
         re-bless with UPDATE_GOLDENS=1"
    );

    let mut missing = Vec::new();
    for (key, digest) in &computed {
        match stored.get(key) {
            Some(want) => assert_eq!(
                digest, want,
                "{key}: output drifted from the blessed golden — if intentional, \
                 re-bless with UPDATE_GOLDENS=1 cargo test --test golden_values"
            ),
            None => missing.push(key.clone()),
        }
    }
    if !missing.is_empty() {
        // bootstrap mode: no failure, but make the candidate easy to bless
        let candidate = std::env::temp_dir().join("meltframe_golden_candidate.tsv");
        std::fs::write(&candidate, render(&computed)).ok();
        eprintln!(
            "golden_values: {} of {} cases not blessed yet ({} verified); candidate \
             table written to {} — bless with UPDATE_GOLDENS=1 cargo test --test \
             golden_values",
            missing.len(),
            computed.len(),
            computed.len() - missing.len(),
            candidate.display()
        );
    }
}

#[test]
fn golden_digests_cover_fused_paths_too() {
    // the stored goldens are recorded off the single-stage barrier path;
    // this pins the fused executors to the same numbers for a fusable
    // subset (Same grid, non-Wrap), in both halo modes
    use meltframe::coordinator::{HaloMode, Plan};
    let x = Tensor::random(&[5, 6, 7], 0.0, 255.0, 0xA11CE).unwrap();
    for (name, job) in kernels(&[3, 3, 3]) {
        let stage = job.to_stage().unwrap();
        let (single, _) = run_job(&x, &job, &ExecOptions::native(1)).unwrap();
        // two copies of the stage → a genuinely fused 2-stage group
        let (rec, _) = Plan::over(&x)
            .stage(stage.clone())
            .stage(stage.clone())
            .run(&ExecOptions::native(3))
            .unwrap();
        let (exc, _) = Plan::over(&x)
            .stage(stage.clone())
            .stage(stage)
            .run(&ExecOptions::native(3).with_halo_mode(HaloMode::Exchange))
            .unwrap();
        assert_eq!(
            value_digest(rec.data()),
            value_digest(exc.data()),
            "{name}: halo modes disagree"
        );
        // and the double-stage plans agree with the two-pass barrier run
        let (two_pass, _) = run_job(&single, &job, &ExecOptions::native(1)).unwrap();
        assert_eq!(value_digest(rec.data()), value_digest(two_pass.data()), "{name}");
    }
}
